//! `mckernel` CLI — leader entrypoint for the three-layer stack.
//!
//! See `mckernel help` (or [`mckernel::cli::commands::USAGE`]).

use mckernel::cli::{commands, Args};

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = commands::run(args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
