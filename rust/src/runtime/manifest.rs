//! Artifact manifest: the contract between `python/compile/aot.py`
//! and the Rust runtime. Parsed with the in-tree JSON parser.

use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// One exported HLO artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: String,
    /// "train" | "predict" | "features".
    pub kind: String,
    /// "mckernel" | "identity".
    pub featurizer: String,
    pub batch: usize,
    /// Padded input width the graph expects.
    pub n: usize,
    /// Kernel expansions E (0 for the LR baseline).
    pub expansions: usize,
    pub classes: usize,
    pub feature_dim: usize,
    /// Output names in tuple order.
    pub outputs: Vec<String>,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Directory the manifest was loaded from (artifact files live here).
    pub dir: PathBuf,
    pub n: usize,
    pub pixels: usize,
    pub classes: usize,
    pub entries: Vec<ArtifactEntry>,
}

fn req_usize(j: &Json, key: &str) -> Result<usize> {
    j.get(key)
        .and_then(Json::as_usize)
        .with_context(|| format!("manifest: missing/invalid '{key}'"))
}

fn req_str(j: &Json, key: &str) -> Result<String> {
    Ok(j.get(key)
        .and_then(Json::as_str)
        .with_context(|| format!("manifest: missing/invalid '{key}'"))?
        .to_string())
}

impl Manifest {
    /// Parse manifest JSON text (`dir` is where artifacts live).
    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let root = Json::parse(text).context("manifest JSON")?;
        let entries_json = root
            .get("entries")
            .and_then(Json::as_arr)
            .context("manifest: 'entries' array")?;
        let mut entries = Vec::with_capacity(entries_json.len());
        for e in entries_json {
            let outputs = e
                .get("outputs")
                .and_then(Json::as_arr)
                .context("entry outputs")?
                .iter()
                .map(|o| o.as_str().map(str::to_string).context("output name"))
                .collect::<Result<Vec<_>>>()?;
            entries.push(ArtifactEntry {
                name: req_str(e, "name")?,
                file: req_str(e, "file")?,
                kind: req_str(e, "kind")?,
                featurizer: req_str(e, "featurizer")?,
                batch: req_usize(e, "batch")?,
                n: req_usize(e, "n")?,
                expansions: req_usize(e, "expansions")?,
                classes: req_usize(e, "classes")?,
                feature_dim: req_usize(e, "feature_dim")?,
                outputs,
            });
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            n: req_usize(&root, "n")?,
            pixels: req_usize(&root, "pixels")?,
            classes: req_usize(&root, "classes")?,
            entries,
        })
    }

    /// Load `manifest.json` from an artifact directory.
    pub fn load<P: AsRef<Path>>(dir: P) -> Result<Manifest> {
        let dir = dir.as_ref();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {} (run `make artifacts` first)", path.display()))?;
        Manifest::parse(&text, dir)
    }

    /// Find an entry by `(kind, featurizer, expansions)`.
    pub fn find(&self, kind: &str, featurizer: &str, expansions: usize) -> Result<&ArtifactEntry> {
        self.entries
            .iter()
            .find(|e| e.kind == kind && e.featurizer == featurizer && e.expansions == expansions)
            .with_context(|| {
                format!(
                    "no artifact kind={kind} featurizer={featurizer} E={expansions}; available: {}",
                    self.entries
                        .iter()
                        .map(|e| e.name.as_str())
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            })
    }

    /// Find an entry by exact name.
    pub fn by_name(&self, name: &str) -> Result<&ArtifactEntry> {
        self.entries
            .iter()
            .find(|e| e.name == name)
            .with_context(|| format!("no artifact named {name}"))
    }

    /// Absolute path of an entry's HLO file.
    pub fn path_of(&self, entry: &ArtifactEntry) -> PathBuf {
        self.dir.join(&entry.file)
    }

    /// Validate basic coherence (shapes consistent with header).
    pub fn validate(&self) -> Result<()> {
        for e in &self.entries {
            if e.featurizer == "mckernel" {
                if e.expansions == 0 {
                    bail!("{}: mckernel artifact with E=0", e.name);
                }
                if e.feature_dim != 2 * e.n * e.expansions {
                    bail!(
                        "{}: feature_dim {} != 2·{}·{}",
                        e.name,
                        e.feature_dim,
                        e.n,
                        e.expansions
                    );
                }
            }
            if e.kind == "train" && e.outputs != ["w", "bias", "loss"] {
                bail!("{}: train artifact with outputs {:?}", e.name, e.outputs);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "classes": 10, "n": 1024, "pixels": 784,
      "entries": [
        {"name": "train_mck_b10_e2", "file": "train_mck_b10_e2.hlo.txt",
         "kind": "train", "featurizer": "mckernel", "batch": 10, "n": 1024,
         "expansions": 2, "classes": 10, "feature_dim": 4096,
         "outputs": ["w", "bias", "loss"], "inputs": []},
        {"name": "predict_lr_b256", "file": "predict_lr_b256.hlo.txt",
         "kind": "predict", "featurizer": "identity", "batch": 256, "n": 784,
         "expansions": 0, "classes": 10, "feature_dim": 784,
         "outputs": ["preds"], "inputs": []}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.classes, 10);
        assert_eq!(m.entries.len(), 2);
        m.validate().unwrap();
        let e = m.find("train", "mckernel", 2).unwrap();
        assert_eq!(e.batch, 10);
        assert_eq!(m.path_of(e), PathBuf::from("/tmp/a/train_mck_b10_e2.hlo.txt"));
    }

    #[test]
    fn find_missing_is_error() {
        let m = Manifest::parse(SAMPLE, Path::new(".")).unwrap();
        assert!(m.find("train", "mckernel", 8).is_err());
        assert!(m.by_name("nope").is_err());
        assert!(m.by_name("predict_lr_b256").is_ok());
    }

    #[test]
    fn validate_rejects_bad_feature_dim() {
        let bad = SAMPLE.replace("\"feature_dim\": 4096", "\"feature_dim\": 17");
        let m = Manifest::parse(&bad, Path::new(".")).unwrap();
        assert!(m.validate().is_err());
    }

    #[test]
    fn rejects_malformed_json() {
        assert!(Manifest::parse("{", Path::new(".")).is_err());
        assert!(Manifest::parse("{\"n\": 1}", Path::new(".")).is_err());
    }
}
