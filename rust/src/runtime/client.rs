//! PJRT client wrapper: compile HLO-text artifacts, build literals.

use super::manifest::{ArtifactEntry, Manifest};
use anyhow::{Context, Result};
use std::path::Path;

/// A PJRT CPU client plus the artifact manifest it serves.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
}

impl Runtime {
    /// Start a CPU PJRT client over an artifact directory.
    pub fn new<P: AsRef<Path>>(artifact_dir: P) -> Result<Runtime> {
        let manifest = Manifest::load(&artifact_dir)?;
        manifest.validate()?;
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        Ok(Runtime { client, manifest })
    }

    /// Platform string (e.g. "cpu") — for logs.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// The manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Underlying PJRT client (advanced use: custom executables).
    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    /// Load + compile one artifact (HLO text → PJRT executable).
    pub fn compile(&self, entry: &ArtifactEntry) -> Result<xla::PjRtLoadedExecutable> {
        let path = self.manifest.path_of(entry);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not UTF-8")?,
        )
        .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("XLA compile {}", entry.name))
    }
}

/// Build an f32 literal of the given dims from a flat slice.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let expect: i64 = dims.iter().product();
    anyhow::ensure!(expect as usize == data.len(), "literal shape mismatch");
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Build an i32 literal of the given dims from a flat slice.
pub fn literal_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let expect: i64 = dims.iter().product();
    anyhow::ensure!(expect as usize == data.len(), "literal shape mismatch");
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Scalar f32 literal.
pub fn literal_scalar(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_builders_check_shapes() {
        assert!(literal_f32(&[1.0, 2.0], &[2]).is_ok());
        assert!(literal_f32(&[1.0, 2.0], &[3]).is_err());
        assert!(literal_i32(&[1, 2, 3, 4], &[2, 2]).is_ok());
        assert!(literal_i32(&[1], &[2]).is_err());
    }

    #[test]
    fn literal_roundtrip() {
        let l = literal_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(l.element_count(), 4);
    }
}
