//! PJRT runtime: loads the AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py` and executes them from Rust — Python is
//! never on this path.
//!
//! * [`manifest`] — parses `artifacts/manifest.json` (shapes, kinds).
//! * [`client`] — thin wrapper over `xla::PjRtClient` (CPU PJRT).
//! * [`executor`] — typed drivers: [`executor::TrainStep`],
//!   [`executor::Predictor`], [`executor::FeatureOp`], holding their
//!   compiled executables and the feature-map coefficient literals.

pub mod client;
pub mod executor;
pub mod manifest;

pub use client::Runtime;
pub use executor::{FeatureOp, Predictor, TrainStep};
pub use manifest::{ArtifactEntry, Manifest};
