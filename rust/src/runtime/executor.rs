//! Typed executors over the compiled artifacts.
//!
//! Each executor owns its `PjRtLoadedExecutable` plus the feature-map
//! coefficient literals (stacked `(E, n)` tensors built once from the
//! Rust-side [`McKernel`] — hash-derived, so they are *inputs*, not
//! weights, and one HLO artifact serves every seed).

use super::client::{literal_f32, literal_i32, literal_scalar, Runtime};
use super::manifest::ArtifactEntry;
use crate::linalg::Matrix;
use crate::mckernel::McKernel;
use crate::model::SoftmaxRegression;
use anyhow::{ensure, Context, Result};

/// Stacked Fastfood coefficients as XLA literals.
pub struct FeatureLiterals {
    pub b_diag: xla::Literal,
    pub g_diag: xla::Literal,
    pub scale: xla::Literal,
    pub perm: xla::Literal,
    pub expansions: usize,
    pub n: usize,
}

impl FeatureLiterals {
    /// Build the `(E, n)` stacked literals from a materialized map.
    pub fn from_mckernel(map: &McKernel) -> Result<FeatureLiterals> {
        let n = map.padded_dim();
        let e = map.expansions();
        let mut b = Vec::with_capacity(e * n);
        let mut g = Vec::with_capacity(e * n);
        let mut s = Vec::with_capacity(e * n);
        let mut p = Vec::with_capacity(e * n);
        for blk in map.blocks() {
            b.extend_from_slice(blk.b());
            g.extend_from_slice(blk.g());
            s.extend_from_slice(blk.scale());
            p.extend(blk.perm().iter().map(|&i| i as i32));
        }
        let dims = [e as i64, n as i64];
        Ok(FeatureLiterals {
            b_diag: literal_f32(&b, &dims)?,
            g_diag: literal_f32(&g, &dims)?,
            scale: literal_f32(&s, &dims)?,
            perm: literal_i32(&p, &dims)?,
            expansions: e,
            n,
        })
    }
}

/// Pad a `(rows, d)` batch to `(batch, n)` row-major f32 (zero-fill).
fn pad_batch(x: &Matrix, batch: usize, n: usize) -> Result<Vec<f32>> {
    ensure!(x.rows() <= batch, "batch overflow: {} > {}", x.rows(), batch);
    ensure!(x.cols() <= n, "width overflow: {} > {}", x.cols(), n);
    let mut flat = vec![0.0f32; batch * n];
    for r in 0..x.rows() {
        flat[r * n..r * n + x.cols()].copy_from_slice(x.row(r));
    }
    Ok(flat)
}

/// Run one executable and pull the root literal back to host.
fn run(exe: &xla::PjRtLoadedExecutable, args: &[&xla::Literal]) -> Result<xla::Literal> {
    let outs = exe.execute::<&xla::Literal>(args).context("PJRT execute")?;
    outs[0][0].to_literal_sync().context("fetch result")
}

/// Compiled SGD train step (`(W,b,x,y,lr[,coeffs]) → (W',b',loss)`).
pub struct TrainStep {
    exe: xla::PjRtLoadedExecutable,
    entry: ArtifactEntry,
    features: Option<FeatureLiterals>,
    /// Device-format parameters, kept as literals between steps.
    w: xla::Literal,
    bias: xla::Literal,
    steps: u64,
}

impl TrainStep {
    /// Compile the train artifact for `featurizer` ∈ {"mckernel",
    /// "identity"}; `map` must be given iff featurizer is mckernel.
    pub fn new(rt: &Runtime, featurizer: &str, map: Option<&McKernel>) -> Result<TrainStep> {
        let expansions = map.map_or(0, |m| m.expansions());
        let entry = rt.manifest().find("train", featurizer, expansions)?.clone();
        if let Some(m) = map {
            ensure!(m.padded_dim() == entry.n, "map n {} != artifact n {}", m.padded_dim(), entry.n);
        }
        let exe = rt.compile(&entry)?;
        let features = map.map(FeatureLiterals::from_mckernel).transpose()?;
        let classes = entry.classes;
        let fd = entry.feature_dim;
        let w = literal_f32(&vec![0.0; classes * fd], &[classes as i64, fd as i64])?;
        let bias = literal_f32(&vec![0.0; classes], &[classes as i64])?;
        Ok(TrainStep { exe, entry, features, w, bias, steps: 0 })
    }

    /// The artifact metadata (batch size the graph expects, etc.).
    pub fn entry(&self) -> &ArtifactEntry {
        &self.entry
    }

    /// Steps taken.
    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// Reset parameters to zeros.
    pub fn reset(&mut self) -> Result<()> {
        let classes = self.entry.classes;
        let fd = self.entry.feature_dim;
        self.w = literal_f32(&vec![0.0; classes * fd], &[classes as i64, fd as i64])?;
        self.bias = literal_f32(&vec![0.0; classes], &[classes as i64])?;
        self.steps = 0;
        Ok(())
    }

    /// One SGD step on a `(rows ≤ batch, d)` mini-batch. Ragged final
    /// batches are zero-padded with label 0 and a compensating lr
    /// rescale (`lr · rows/batch` keeps the gradient magnitude of the
    /// true rows identical up to the padded rows' uniform-softmax
    /// pull; exact for full batches).
    pub fn step(&mut self, x: &Matrix, y: &[u8], lr: f32) -> Result<f32> {
        let batch = self.entry.batch;
        let n = self.entry.n;
        ensure!(x.rows() == y.len(), "batch/labels mismatch");
        ensure!(x.rows() == batch, "graph expects batch {batch}, got {} (use exact batches)", x.rows());
        let flat = pad_batch(x, batch, n)?;
        let xl = literal_f32(&flat, &[batch as i64, n as i64])?;
        let yl = literal_i32(
            &y.iter().map(|&v| v as i32).collect::<Vec<_>>(),
            &[batch as i64],
        )?;
        let lrl = literal_scalar(lr);
        let mut args: Vec<&xla::Literal> = vec![&self.w, &self.bias, &xl, &yl, &lrl];
        if let Some(f) = &self.features {
            args.extend([&f.b_diag, &f.g_diag, &f.scale, &f.perm]);
        }
        let out = run(&self.exe, &args)?;
        let (w, bias, loss) = out.to_tuple3().context("train tuple")?;
        self.w = w;
        self.bias = bias;
        self.steps += 1;
        Ok(loss.get_first_element::<f32>()?)
    }

    /// Copy the current parameters into a host-side model.
    pub fn export_model(&self) -> Result<SoftmaxRegression> {
        let classes = self.entry.classes;
        let fd = self.entry.feature_dim;
        let mut m = SoftmaxRegression::zeros(classes, fd);
        m.w_mut().data_mut().copy_from_slice(&self.w.to_vec::<f32>()?);
        m.b_mut().copy_from_slice(&self.bias.to_vec::<f32>()?);
        Ok(m)
    }

    /// Load parameters from a host-side model (resume training).
    pub fn import_model(&mut self, m: &SoftmaxRegression) -> Result<()> {
        ensure!(m.classes() == self.entry.classes && m.features() == self.entry.feature_dim);
        self.w = literal_f32(
            m.w().data(),
            &[m.classes() as i64, m.features() as i64],
        )?;
        self.bias = literal_f32(m.b(), &[m.classes() as i64])?;
        Ok(())
    }
}

/// Compiled predictor (`(W,b,x[,coeffs]) → preds`).
pub struct Predictor {
    exe: xla::PjRtLoadedExecutable,
    entry: ArtifactEntry,
    features: Option<FeatureLiterals>,
}

impl Predictor {
    pub fn new(rt: &Runtime, featurizer: &str, map: Option<&McKernel>) -> Result<Predictor> {
        let expansions = map.map_or(0, |m| m.expansions());
        let entry = rt.manifest().find("predict", featurizer, expansions)?.clone();
        let exe = rt.compile(&entry)?;
        let features = map.map(FeatureLiterals::from_mckernel).transpose()?;
        Ok(Predictor { exe, entry, features })
    }

    pub fn entry(&self) -> &ArtifactEntry {
        &self.entry
    }

    /// Predict classes for up to `entry.batch` rows (padded rows are
    /// discarded from the output).
    pub fn predict(&self, model: &SoftmaxRegression, x: &Matrix) -> Result<Vec<u8>> {
        let batch = self.entry.batch;
        let n = self.entry.n;
        ensure!(x.rows() <= batch, "batch overflow");
        let wl = literal_f32(
            model.w().data(),
            &[model.classes() as i64, model.features() as i64],
        )?;
        let bl = literal_f32(model.b(), &[model.classes() as i64])?;
        let flat = pad_batch(x, batch, n)?;
        let xl = literal_f32(&flat, &[batch as i64, n as i64])?;
        let mut args: Vec<&xla::Literal> = vec![&wl, &bl, &xl];
        if let Some(f) = &self.features {
            args.extend([&f.b_diag, &f.g_diag, &f.scale, &f.perm]);
        }
        let out = run(&self.exe, &args)?;
        let preds = out.to_tuple1().context("predict tuple")?;
        Ok(preds.to_vec::<i32>()?[..x.rows()].iter().map(|&v| v as u8).collect())
    }
}

/// Compiled feature generator (`(x, coeffs) → φ(x)`), the paper's
/// "drop-in generator of features for linear methods".
pub struct FeatureOp {
    exe: xla::PjRtLoadedExecutable,
    entry: ArtifactEntry,
    features: FeatureLiterals,
}

impl FeatureOp {
    pub fn new(rt: &Runtime, map: &McKernel) -> Result<FeatureOp> {
        let entry = rt.manifest().find("features", "mckernel", map.expansions())?.clone();
        let exe = rt.compile(&entry)?;
        let features = FeatureLiterals::from_mckernel(map)?;
        Ok(FeatureOp { exe, entry, features })
    }

    pub fn entry(&self) -> &ArtifactEntry {
        &self.entry
    }

    /// φ(x) for up to `entry.batch` rows → `(rows, feature_dim)`.
    pub fn transform(&self, x: &Matrix) -> Result<Matrix> {
        let batch = self.entry.batch;
        let n = self.entry.n;
        ensure!(x.rows() <= batch, "batch overflow");
        let flat = pad_batch(x, batch, n)?;
        let xl = literal_f32(&flat, &[batch as i64, n as i64])?;
        let f = &self.features;
        let out = run(&self.exe, &[&xl, &f.b_diag, &f.g_diag, &f.scale, &f.perm])?;
        let feats = out.to_tuple1().context("features tuple")?;
        let fd = self.entry.feature_dim;
        let full = feats.to_vec::<f32>()?;
        Ok(Matrix::from_vec(x.rows(), fd, full[..x.rows() * fd].to_vec()))
    }
}
