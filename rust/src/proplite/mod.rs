//! Property-based testing mini-framework (proptest/quickcheck are
//! unreachable offline).
//!
//! Deterministic by construction: cases are generated from the hash
//! RNG, so failures reproduce exactly. On failure the framework
//! *shrinks* the failing input by re-running the property on smaller
//! derived cases before reporting.
//!
//! ```
//! use mckernel::proplite::{self, Gen};
//! proplite::check("addition commutes", 100, |g| {
//!     let a = g.f32_in(-10.0, 10.0);
//!     let b = g.f32_in(-10.0, 10.0);
//!     proplite::prop(a + b == b + a, format!("{a} {b}"))
//! });
//! ```

use crate::hash::HashRng;

/// Outcome of one property evaluation.
#[derive(Debug, Clone)]
pub enum Outcome {
    Pass,
    /// Failure with a human-readable description of the case.
    Fail(String),
    /// Case rejected (precondition unmet) — does not count.
    Discard,
}

/// Helper: build an [`Outcome`] from a boolean.
pub fn prop(ok: bool, case: impl Into<String>) -> Outcome {
    if ok {
        Outcome::Pass
    } else {
        Outcome::Fail(case.into())
    }
}

/// Case generator handed to properties; wraps the hash RNG with a
/// *size* parameter that grows over the run (small cases first, so
/// minimal counterexamples surface early — generation-time shrinking).
pub struct Gen {
    rng: HashRng,
    /// Current size hint in `1..=max_size`.
    pub size: usize,
}

impl Gen {
    /// Uniform usize in `[lo, hi]` (inclusive).
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.rng.next_below((hi - lo + 1) as u64) as usize
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.rng.next_f32() * (hi - lo)
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.next_f64() * (hi - lo)
    }

    /// Random bool.
    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// 64 random bits.
    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    /// A power of two `2^k` with `k ∈ [lo_log2, hi_log2]`, scaled by
    /// the current size (small sizes early in the run).
    pub fn pow2(&mut self, lo_log2: u32, hi_log2: u32) -> usize {
        let hi_scaled = lo_log2 + ((hi_log2 - lo_log2) as usize * self.size / self.max_size()) as u32;
        1usize << self.usize_in(lo_log2 as usize, hi_scaled.max(lo_log2) as usize)
    }

    /// Vector of uniform f32s in `[lo, hi)`, length ∝ size.
    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32_in(lo, hi)).collect()
    }

    fn max_size(&self) -> usize {
        64
    }
}

/// Run `property` on `cases` generated cases. Panics (with the seed and
/// shrunk case description) on the first failure.
pub fn check<F>(name: &str, cases: usize, mut property: F)
where
    F: FnMut(&mut Gen) -> Outcome,
{
    // Env-overridable seed so failures replay: PROPLITE_SEED=<n>.
    let seed = std::env::var("PROPLITE_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x9e3779b97f4a7c15u64);
    let mut executed = 0usize;
    let mut attempts = 0usize;
    while executed < cases {
        attempts += 1;
        assert!(
            attempts < cases * 10 + 100,
            "property '{name}': too many discards ({executed}/{cases} ran)"
        );
        // size ramps from 1 to 64 across the run
        let size = 1 + (executed * 63) / cases.max(1);
        let mut g = Gen { rng: HashRng::new(seed, attempts as u64), size };
        match property(&mut g) {
            Outcome::Pass => executed += 1,
            Outcome::Discard => continue,
            Outcome::Fail(case) => {
                // Shrink: retry nearby smaller sizes to find a simpler case.
                let mut simplest = case;
                for s in 1..size {
                    let mut g2 = Gen { rng: HashRng::new(seed, attempts as u64), size: s };
                    if let Outcome::Fail(c2) = property(&mut g2) {
                        simplest = c2;
                        break;
                    }
                }
                panic!(
                    "property '{name}' failed (seed={seed}, attempt={attempts}):\n  case: {simplest}"
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("tautology", 50, |g| {
            count += 1;
            let _ = g.u64();
            Outcome::Pass
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'always fails' failed")]
    fn failing_property_panics_with_case() {
        check("always fails", 10, |g| {
            let v = g.usize_in(0, 100);
            prop(false, format!("v={v}"))
        });
    }

    #[test]
    fn discards_do_not_count() {
        let mut passes = 0;
        check("half discard", 20, |g| {
            if g.bool() {
                Outcome::Discard
            } else {
                passes += 1;
                Outcome::Pass
            }
        });
        assert_eq!(passes, 20);
    }

    #[test]
    #[should_panic(expected = "too many discards")]
    fn all_discards_detected() {
        check("discard everything", 10, |_| Outcome::Discard);
    }

    #[test]
    fn generators_in_bounds() {
        check("bounds", 100, |g| {
            let u = g.usize_in(3, 7);
            let f = g.f32_in(-1.0, 1.0);
            let p = g.pow2(2, 10);
            prop(
                (3..=7).contains(&u) && (-1.0..1.0).contains(&f) && p.is_power_of_two() && (4..=1024).contains(&p),
                format!("u={u} f={f} p={p}"),
            )
        });
    }

    #[test]
    fn sizes_ramp() {
        let mut max_seen = 0;
        let mut min_seen = usize::MAX;
        check("ramp", 64, |g| {
            max_seen = max_seen.max(g.size);
            min_seen = min_seen.min(g.size);
            Outcome::Pass
        });
        assert_eq!(min_seen, 1);
        assert!(max_seen >= 32);
    }
}
