//! # McKernel — approximate kernel expansions in log-linear time
//!
//! A Rust + JAX + Pallas reproduction of *McKernel: A Library for
//! Approximate Kernel Expansions in Log-linear Time* (Curtó et al., 2017).
//!
//! The library computes the Fastfood factorization
//!
//! ```text
//! Ẑ := (1/(σ√n)) · C · H · G · Π · H · B          (paper Eq. 8)
//! φ(x) = [cos(Ẑ x̂), sin(Ẑ x̂)]                     (paper Eq. 9)
//! ```
//!
//! in `O(n log n)` time per expansion via a cache-friendly Fast
//! Walsh–Hadamard Transform, with *all* randomness derived from
//! MurmurHash3 so models never store their random coefficients.
//!
//! ## Layer map
//!
//! * [`hash`], [`rand`], [`fwht`], [`linalg`], [`util`] — substrates.
//! * [`mckernel`] — the feature-map library (the paper's
//!   contribution), split plan/execute: `mckernel::plan` compiles the
//!   layout decisions once, `mckernel::engine` is the single executor
//!   every consumer drives.
//! * [`data`], [`model`], [`optim`], [`train`] — the learning stack
//!   (softmax regression + SGD in the mini-batch setting, paper §7–9).
//! * [`runtime`] — PJRT client loading AOT-compiled JAX/Pallas graphs
//!   (`artifacts/*.hlo.txt`), never Python at run time.
//! * [`coordinator`] — mini-batch training orchestration and the
//!   feature-server request loop.
//! * [`obs`] — zero-dependency observability: metrics registry,
//!   scoped spans, JSONL traces, `mckernel stats` export.
//! * [`fault`] — typed error taxonomy ([`fault::McError`]) and the
//!   seeded deterministic chaos injector ([`fault::FaultPlan`]).
//! * [`benchkit`], [`proplite`], [`cli`] — in-tree bench harness,
//!   property-testing framework and CLI parser (offline build: no
//!   criterion / proptest / clap).

// Unsafe hygiene (PR 10): every unsafe operation inside an `unsafe
// fn` must sit in its own explicit `unsafe {}` block with a `// SAFETY:`
// comment — the `mckernel-analyze` linter checks the comments, this
// lint makes the blocks visible for it to check. The historical
// whole-crate clippy allows (needless_range_loop, excessive_precision)
// are gone: no range-loop site in the tree actually trips the lint,
// and the full-precision Cody–Waite tables carry a file-scoped allow
// in `util::fastmath` instead.
#![deny(unsafe_op_in_unsafe_fn)]

pub mod benchkit;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod fault;
pub mod fwht;
pub mod hash;
pub mod linalg;
pub mod mckernel;
pub mod model;
pub mod obs;
pub mod optim;
pub mod proplite;
pub mod rand;
pub mod runtime;
pub mod train;
pub mod util;

/// Library version string (mirrors `Cargo.toml`).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");

/// The seed used throughout the paper's experiments (Figures 3–5).
pub const PAPER_SEED: u64 = 1_398_239_763;
