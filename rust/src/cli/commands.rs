//! CLI subcommand implementations. `main.rs` dispatches here; all
//! logic lives in the library so integration tests can drive it.

use crate::cli::Args;
use crate::coordinator::{FeatureServer, Prefetcher, ServerConfig};
use crate::data::{Dataset, SyntheticSpec};
use crate::fault::{FaultPlan, FaultSite, McError};
use crate::mckernel::{Kernel, McKernelFactory};
use crate::model::checkpoint::Checkpoint;
use crate::obs::MetricsRegistry;
use crate::optim::SgdConfig;
use crate::train::{Featurizer, ParallelTrainer, RetryPolicy, TrainConfig, Trainer};
use crate::util::json::Json;
use anyhow::{anyhow, bail, ensure, Context, Result};
use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

/// Usage text.
pub const USAGE: &str = "mckernel — approximate kernel expansions in log-linear time

USAGE: mckernel <command> [options]

COMMANDS:
  train      train a classifier (LR baseline or McKernel features)
  predict    evaluate a saved checkpoint on a dataset split
  features   featurize one synthetic sample and print stats
  fwht       run one FWHT and report timing
  bench      write BENCH_*.json perf snapshots (per-row vs batched vs SIMD)
  cache-bench  feature-cache drill: bit-identity, hit/miss accounting, timing
  stats      drive the instrumented paths and export a metrics snapshot
  gen-data   write a synthetic dataset as IDX files
  info       list AOT artifacts (requires `make artifacts`)
  serve      run the dynamic-batching feature server demo
  chaos      deterministic fault-injection drill (seeded FaultPlan)

COMMON OPTIONS:
  --dataset mnist|fashion   synthetic dataset family     [mnist]
  --data-dir DIR            load real IDX files from DIR instead
  --seed N                  root seed          [1398239763]
  --train-size N / --test-size N
  --kernel rbf|matern       calibration kernel [matern]
  --expansions E            kernel expansions  [4]
  --sigma S                 bandwidth          [1.0]
  --epochs N --batch-size B --lr G
  --workers N               data-parallel SGD shards (native) [1]
  --backend native|pjrt     execution backend  [native]
  --artifacts DIR           artifact directory [artifacts]
  --checkpoint PATH         model file to write/read
  --resume                  with train: autosave to --checkpoint every
                            epoch and resume from it if present
  --cache / --cache-mb N    content-addressed feature cache on train /
                            serve paths (budget in MiB)        [64]
  --dispatch auto|scalar|simd
                            force the expansion engine's tiled arm
                            (auto = runtime feature detection; also
                            settable via MCKERNEL_DISPATCH)    [auto]
  --csv PATH                write per-epoch history CSV

Run `mckernel <command> --help` for details.";

/// Load the train/test datasets per common flags.
pub fn load_datasets(args: &Args) -> Result<(Dataset, Dataset)> {
    let seed: u64 = args.parse_or("seed", crate::PAPER_SEED)?;
    let train_n: usize = args.parse_or("train-size", 60_000)?;
    let test_n: usize = args.parse_or("test-size", 10_000)?;
    if let Some(dir) = args.get("data-dir") {
        let d = std::path::Path::new(dir);
        let train = Dataset::from_idx_files(
            d.join("train-images-idx3-ubyte"),
            d.join("train-labels-idx1-ubyte"),
        )
        .context("real train split")?;
        let test = Dataset::from_idx_files(
            d.join("t10k-images-idx3-ubyte"),
            d.join("t10k-labels-idx1-ubyte"),
        )
        .context("real test split")?;
        return Ok((train.take(train_n.min(train.len())), test.take(test_n.min(test.len()))));
    }
    let name = args.get_or("dataset", "mnist");
    let spec = SyntheticSpec::by_name(&name)
        .with_context(|| format!("unknown dataset '{name}'"))?;
    Ok((
        Dataset::synthetic(seed, &spec, "train", train_n),
        Dataset::synthetic(seed, &spec, "test", test_n),
    ))
}

/// Build the feature map per common flags (None = identity/LR).
pub fn build_map(args: &Args, input_dim: usize) -> Result<Option<Arc<crate::mckernel::McKernel>>> {
    if args.get_or("featurizer", "mckernel") == "identity" {
        return Ok(None);
    }
    let kernel = Kernel::parse(&args.get_or("kernel", "matern"))
        .context("unknown --kernel (rbf|matern)")?;
    let kernel = match (kernel, args.get("matern-t")) {
        (Kernel::RbfMatern { .. }, Some(t)) => Kernel::RbfMatern { t: t.parse()? },
        (k, _) => k,
    };
    let mut factory = McKernelFactory::new(input_dim)
        .expansions(args.parse_or("expansions", 4usize)?)
        .sigma(args.parse_or("sigma", 1.0f64)?)
        .seed(args.parse_or("seed", crate::PAPER_SEED)?);
    factory = match kernel {
        Kernel::Rbf => factory.rbf(),
        Kernel::RbfMatern { t } => factory.rbf_matern(t),
    };
    Ok(Some(Arc::new(factory.build())))
}

/// Shared `--cache` / `--cache-mb` parsing: either flag opts into the
/// content-addressed feature cache; `--cache-mb N` sets the byte
/// budget (default 64 MiB).
pub fn cache_bytes_from(args: &Args) -> Result<Option<usize>> {
    if args.flag("cache") || args.get("cache-mb").is_some() {
        Ok(Some(args.positive_or("cache-mb", 64)? << 20))
    } else {
        Ok(None)
    }
}

/// Shared TrainConfig from flags.
pub fn train_config(args: &Args, default_lr: f32) -> Result<TrainConfig> {
    Ok(TrainConfig {
        epochs: args.parse_or("epochs", 20usize)?,
        batch_size: args.positive_or("batch-size", 10)?,
        sgd: SgdConfig {
            lr: args.parse_or("lr", default_lr)?,
            momentum: args.parse_or("momentum", 0.0f32)?,
            clip: args.get("clip").map(|c| c.parse()).transpose()?,
        },
        seed: args.parse_or("seed", crate::PAPER_SEED)?,
        eval_every_epoch: !args.flag("final-eval-only"),
        verbose: !args.flag("quiet"),
        workers: args.positive_or("workers", 1)?,
        cache_bytes: cache_bytes_from(args)?,
    })
}

/// `mckernel train`.
pub fn cmd_train(args: &Args) -> Result<()> {
    let (train, test) = load_datasets(args)?;
    let map = build_map(args, train.dim())?;
    let default_lr = if map.is_some() { 0.001 } else { 0.01 };
    let config = train_config(args, default_lr)?;
    let backend = args.get_or("backend", "native");

    let report = match backend.as_str() {
        "native" => {
            let featurizer = match &map {
                // Sharded training parallelizes featurization inside
                // the worker shards — a second default-size pool
                // would just sit parked during the epoch loop.
                Some(m) if config.workers > 1 => Featurizer::McKernel(Arc::clone(m)),
                Some(m) => Featurizer::McKernelParallel(
                    Arc::clone(m),
                    Arc::new(crate::util::ThreadPool::with_default_size()),
                ),
                None => Featurizer::Identity,
            };
            // workers == 1 keeps the serial epoch-loop oracle; > 1
            // runs the sharded data-parallel engine (deterministic
            // fixed-order gradient reduction — see train::trainer).
            let resume = args.flag("resume");
            let (model, report) = if config.workers > 1 || resume {
                let trainer = ParallelTrainer::new(config, featurizer);
                if resume {
                    let path: String = args.require("checkpoint")?;
                    trainer.fit_auto(&path, &train, &test).context("resumable train")?
                } else {
                    trainer.fit(&train, &test).context("sharded train")?
                }
            } else {
                Trainer::new(config, featurizer).fit(&train, &test)
            };
            if !resume {
                // fit_auto already autosaved (cursor included) after
                // every epoch; re-saving here could regress the cursor
                // when a finished checkpoint was merely re-evaluated.
                maybe_save(args, &map, &model, &report)?;
            }
            report
        }
        "pjrt" => {
            let rt = crate::runtime::Runtime::new(args.get_or("artifacts", "artifacts"))?;
            let trainer = crate::coordinator::PjrtTrainer::new(&rt, config, map.clone());
            let train = Arc::new(train);
            let (model, report) = trainer.fit(&train, &test)?;
            maybe_save(args, &map, &model, &report)?;
            report
        }
        other => bail!("unknown --backend '{other}' (native|pjrt)"),
    };

    println!(
        "final test accuracy: {:.4}  (featurizer={}, params={})",
        report.final_test_accuracy, report.featurizer, report.param_count
    );
    if let Some(csv) = args.get("csv") {
        std::fs::write(csv, report.to_csv())?;
        println!("wrote {csv}");
    }
    Ok(())
}

fn maybe_save(
    args: &Args,
    map: &Option<Arc<crate::mckernel::McKernel>>,
    model: &crate::model::SoftmaxRegression,
    report: &crate::train::TrainReport,
) -> Result<()> {
    if let Some(path) = args.get("checkpoint") {
        let mut meta = BTreeMap::new();
        meta.insert("final_test_accuracy".into(), Json::Num(report.final_test_accuracy));
        meta.insert("featurizer".into(), Json::Str(report.featurizer.into()));
        let completed = report.history.last().map(|r| r.epoch + 1).unwrap_or(0);
        Checkpoint {
            feature_config: map.as_ref().map(|m| m.config().clone()),
            model: model.clone(),
            meta,
        }
        .with_epoch(completed)
        .save(path)?;
        println!("wrote checkpoint {path}");
    }
    Ok(())
}

/// `mckernel predict`.
pub fn cmd_predict(args: &Args) -> Result<()> {
    let path: String = args.require("checkpoint")?;
    let ck = Checkpoint::load(&path)?;
    let (_, test) = load_datasets(args)?;
    let featurizer = match &ck.feature_config {
        Some(cfg) => Featurizer::McKernel(Arc::new(crate::mckernel::McKernel::new(cfg.clone()))),
        None => Featurizer::Identity,
    };
    let trainer = Trainer::new(TrainConfig::default(), featurizer);
    let acc = trainer.evaluate(&ck.model, &test);
    println!("checkpoint {path}: test accuracy {acc:.4} over {} samples", test.len());
    Ok(())
}

/// `mckernel features`.
pub fn cmd_features(args: &Args) -> Result<()> {
    let (train, _) = load_datasets(args)?;
    let map = build_map(args, train.dim())?.context("--featurizer identity has no features")?;
    let (x, label) = train.sample(0);
    let f = map.transform(x);
    let norm: f64 = f.iter().map(|v| (*v as f64).powi(2)).sum::<f64>();
    println!(
        "sample label={label}: {} -> {} features  (E={}, n={}, ‖φ‖²={:.1}, params for 10-way head: {})",
        x.len(),
        f.len(),
        map.expansions(),
        map.padded_dim(),
        norm,
        map.head_param_count(10)
    );
    Ok(())
}

/// `mckernel fwht`. Production engines come from [`crate::fwht::Engine`];
/// the reference oracles (`naive`, `recursive`/`spiral`) stay runnable
/// here as explicit baselines for Table 1, without being selectable by
/// the expansion plan.
pub fn cmd_fwht(args: &Args) -> Result<()> {
    use crate::fwht::{reference, Engine};
    let log_n: u32 = args.parse_or("log-n", 20u32)?;
    let n = 1usize << log_n;
    let name = args.get_or("engine", "mckernel");
    let mut rng = crate::hash::HashRng::new(args.parse_or("seed", 1u64)?, 0xF);
    let mut data: Vec<f32> = (0..n).map(|_| rng.next_f32() - 0.5).collect();
    let cfg = crate::benchkit::BenchConfig::default();
    let (label, result) = if let Some(engine) = Engine::parse(&name) {
        (
            engine.name(),
            crate::benchkit::bench(engine.name(), &cfg, |_| engine.run(&mut data)),
        )
    } else {
        match name.as_str() {
            "naive" => {
                anyhow::ensure!(log_n <= 13, "naive reference is O(n²); use --log-n ≤ 13");
                (
                    "naive(reference)",
                    crate::benchkit::bench("naive", &cfg, |_| reference::fwht_naive(&mut data)),
                )
            }
            "recursive" | "spiral" => (
                "recursive(reference)",
                crate::benchkit::bench("recursive", &cfg, |_| {
                    reference::fwht_recursive(&mut data)
                }),
            ),
            other => bail!("bad --engine '{other}' (iterative|mckernel|batch|simd|naive|spiral)"),
        }
    };
    println!(
        "FWHT n=2^{log_n} engine={}: median {:.4} ms  (min {:.4}, p95 {:.4}; {} samples × {} iters)",
        label,
        result.median_ms(),
        result.stats.min * 1e3,
        result.stats.p95 * 1e3,
        result.stats.n,
        result.iters_per_sample
    );
    Ok(())
}

/// `mckernel bench` — machine-readable perf snapshot for cross-PR
/// tracking: per-row oracle vs batched feature pipeline, FWHT, and
/// serial vs data-parallel training, written as
/// `BENCH_features.json` / `BENCH_fwht.json` / `BENCH_train.json` in
/// `--out-dir` (default: the current directory, i.e. the repo root
/// in CI).
pub fn cmd_bench(args: &Args) -> Result<()> {
    use crate::benchkit::{bench, compare_feature_paths, compare_train_paths, BenchConfig};
    use crate::linalg::Matrix;

    let cfg = if args.flag("quick") { BenchConfig::quick() } else { BenchConfig::default() };
    let out_dir = args.get_or("out-dir", ".");
    let batch: usize = args.positive_or("batch", 64)?;
    let e: usize = args.parse_or("expansions", 4usize)?;
    let input_dim: usize = args.parse_or("input-dim", 784usize)?;
    let workers: usize = args.positive_or("workers", 4)?;

    let map = McKernelFactory::new(input_dim)
        .expansions(e)
        .sigma(1.0)
        .rbf_matern(40)
        .seed(1)
        .build();
    let n = map.padded_dim();
    let mut rng = crate::hash::HashRng::new(7, 0xBE);
    let x = Matrix::from_fn(batch, input_dim, |_, _| rng.next_f32() - 0.5);

    // per-row oracle vs scalar vs SIMD tiled pipelines on the same
    // batch (shared harness with bench_features so table and JSON
    // can't diverge)
    let cmp = compare_feature_paths(&map, &x, &cfg);
    println!(
        "features (batch={batch}, n={n}, E={e}): per-row {:.3} ms  batched {:.3} ms  \
         simd {:.3} ms  speedup {:.2}x  simd speedup {:.2}x  max |err| {:.2e}  \
         simd |err| {:.2e}",
        cmp.per_row.median_ms(),
        cmp.batched.median_ms(),
        cmp.simd.median_ms(),
        cmp.speedup(),
        cmp.simd_speedup(),
        cmp.max_abs_err,
        cmp.simd_max_abs_err
    );
    write_bench_json(
        &format!("{out_dir}/BENCH_features.json"),
        &[
            ("bench", Json::Str("features".into())),
            ("batch", Json::Num(batch as f64)),
            ("input_dim", Json::Num(input_dim as f64)),
            ("n", Json::Num(n as f64)),
            ("expansions", Json::Num(e as f64)),
            ("per_row_ms", Json::Num(cmp.per_row.median_ms())),
            ("batched_ms", Json::Num(cmp.batched.median_ms())),
            ("simd_ms", Json::Num(cmp.simd.median_ms())),
            ("speedup", Json::Num(cmp.speedup())),
            ("simd_speedup", Json::Num(cmp.simd_speedup())),
            ("simd_level", Json::Str(crate::util::simd::level().name().into())),
            ("rows_per_s", Json::Num(cmp.rows_per_s())),
            ("max_abs_err", Json::Num(cmp.max_abs_err as f64)),
            ("simd_max_abs_err", Json::Num(cmp.simd_max_abs_err as f64)),
            ("per_row", cmp.per_row.stats.to_dist_json_ns()),
            ("batched", cmp.batched.stats.to_dist_json_ns()),
            ("simd", cmp.simd.stats.to_dist_json_ns()),
        ],
    )?;

    // FWHT per-row loop vs batched tile engine on the same shape. The
    // transform is unnormalized (each pass scales magnitudes by ~n),
    // so fold a 1/n rescale into both timed closures — identical
    // overhead on both sides — to keep the buffers finite across the
    // runner's thousands of iterations.
    let inv_n = 1.0f32 / n as f32;
    let mut rows_buf: Vec<f32> = (0..batch * n).map(|i| (i % 13) as f32 - 6.0).collect();
    let fwht_rows = bench("fwht/per-row", &cfg, |_| {
        for row in rows_buf.chunks_exact_mut(n) {
            crate::fwht::fwht(row);
            for v in row.iter_mut() {
                *v *= inv_n;
            }
        }
    });
    let mut batch_buf: Vec<f32> = (0..batch * n).map(|i| (i % 13) as f32 - 6.0).collect();
    let fwht_batched = bench("fwht/batched", &cfg, |_| {
        crate::fwht::fwht_batch(&mut batch_buf, batch, n);
        for v in batch_buf.iter_mut() {
            *v *= inv_n;
        }
    });
    let mut simd_buf: Vec<f32> = (0..batch * n).map(|i| (i % 13) as f32 - 6.0).collect();
    let fwht_simd = bench("fwht/simd", &cfg, |_| {
        crate::fwht::simd::fwht_batch(&mut simd_buf, batch, n);
        for v in simd_buf.iter_mut() {
            *v *= inv_n;
        }
    });
    let fwht_speedup = fwht_rows.stats.median / fwht_batched.stats.median;
    let fwht_simd_speedup = fwht_batched.stats.median / fwht_simd.stats.median;
    println!(
        "fwht (rows={batch}, n={n}): per-row {:.3} ms  batched {:.3} ms  simd {:.3} ms  \
         speedup {:.2}x  simd speedup {:.2}x",
        fwht_rows.median_ms(),
        fwht_batched.median_ms(),
        fwht_simd.median_ms(),
        fwht_speedup,
        fwht_simd_speedup
    );
    write_bench_json(
        &format!("{out_dir}/BENCH_fwht.json"),
        &[
            ("bench", Json::Str("fwht".into())),
            ("rows", Json::Num(batch as f64)),
            ("n", Json::Num(n as f64)),
            ("per_row_ms", Json::Num(fwht_rows.median_ms())),
            ("batched_ms", Json::Num(fwht_batched.median_ms())),
            ("simd_ms", Json::Num(fwht_simd.median_ms())),
            ("speedup", Json::Num(fwht_speedup)),
            ("simd_speedup", Json::Num(fwht_simd_speedup)),
            ("simd_level", Json::Str(crate::util::simd::level().name().into())),
            (
                "transforms_per_s",
                Json::Num(batch as f64 / fwht_batched.stats.median.min(fwht_simd.stats.median)),
            ),
            ("per_row", fwht_rows.stats.to_dist_json_ns()),
            ("batched", fwht_batched.stats.to_dist_json_ns()),
            ("simd", fwht_simd.stats.to_dist_json_ns()),
        ],
    )?;

    // serial epoch-loop oracle vs the sharded data-parallel trainer
    // on one epoch of mini-batch SGD (identity features: the SGD step
    // is the part the shard engine parallelizes)
    let train_rows = if args.flag("quick") { 128 } else { 1024 };
    let tcmp = compare_train_paths(train_rows, batch, workers, &cfg);
    println!(
        "train (rows={train_rows}, batch={batch}, workers={workers}): serial {:.3} ms  \
         sharded {:.3} ms  speedup {:.2}x  |Δacc| {:.2e}",
        tcmp.serial.median_ms(),
        tcmp.parallel.median_ms(),
        tcmp.speedup(),
        tcmp.acc_delta
    );
    write_bench_json(
        &format!("{out_dir}/BENCH_train.json"),
        &[
            ("bench", Json::Str("train".into())),
            ("rows", Json::Num(train_rows as f64)),
            ("batch", Json::Num(batch as f64)),
            ("workers", Json::Num(workers as f64)),
            ("serial_ms", Json::Num(tcmp.serial.median_ms())),
            ("parallel_ms", Json::Num(tcmp.parallel.median_ms())),
            ("speedup", Json::Num(tcmp.speedup())),
            ("rows_per_s", Json::Num(tcmp.rows_per_s())),
            ("acc_delta", Json::Num(tcmp.acc_delta)),
            ("serial", tcmp.serial.stats.to_dist_json_ns()),
            ("parallel", tcmp.parallel.stats.to_dist_json_ns()),
        ],
    )?;

    // Compact scalar-vs-SIMD median summary — the table EXPERIMENTS.md
    // records from the first toolchain-bearing CI run.
    println!();
    println!(
        "scalar vs simd medians (level={}, rows={batch}, n={n}):",
        crate::util::simd::level().name()
    );
    println!("  {:<10} {:>12} {:>12} {:>9}", "kernel", "scalar ms", "simd ms", "speedup");
    for (kernel, scalar_ms, simd_ms) in [
        ("fwht", fwht_batched.median_ms(), fwht_simd.median_ms()),
        ("features", cmp.batched.median_ms(), cmp.simd.median_ms()),
    ] {
        println!(
            "  {kernel:<10} {scalar_ms:>12.4} {simd_ms:>12.4} {:>8.2}x",
            scalar_ms / simd_ms
        );
    }
    Ok(())
}

/// `mckernel cache-bench` — deterministic feature-cache drill plus
/// timing. Phase 1 replays batches drawn from a fixed pool of unique
/// rows through a cached and an uncached engine side by side,
/// enforcing the cache invariants (bit-identical output, exact
/// hit+miss accounting, byte budget respected). Phase 2 times the
/// steady-state hit regime against the uncached engine and writes
/// `BENCH_cache.json` (`--out`) in the shared bench schema.
pub fn cmd_cache_bench(args: &Args) -> Result<()> {
    use crate::benchkit::{bench, BenchConfig};
    use crate::linalg::Matrix;
    use crate::mckernel::{CacheKey, ExpansionEngine, FeatureCache};

    let quick = args.flag("quick");
    let cfg = if quick { BenchConfig::quick() } else { BenchConfig::default() };
    let out = args.get_or("out", "BENCH_cache.json");
    let input_dim: usize = args.parse_or("input-dim", 64usize)?;
    let e: usize = args.parse_or("expansions", 2usize)?;
    let batch: usize = args.positive_or("batch", 32)?;
    let unique: usize = args.positive_or("unique", if quick { 64 } else { 256 })?;
    let batches: usize = args.positive_or("batches", if quick { 32 } else { 256 })?;
    let cache_mb: usize = args.positive_or("cache-mb", 64)?;
    let cache_bytes = cache_mb << 20;

    let map = McKernelFactory::new(input_dim)
        .expansions(e)
        .sigma(1.0)
        .rbf_matern(40)
        .seed(1)
        .build();
    let fd = map.feature_dim();
    let mut rng = crate::hash::HashRng::new(9, 0xCB);
    let pool = Matrix::from_fn(unique, input_dim, |_, _| rng.next_f32() - 0.5);
    // deterministic replay: batch b draws rows (b·batch + 7r) mod
    // unique from the pool, so repeats start inside the first pass
    let batch_rows = |b: usize| {
        Matrix::from_fn(batch, input_dim, |r, c| pool.row((b * batch + r * 7) % unique)[c])
    };

    // Phase 1: invariants, on a private registry for exact counts.
    let reg = MetricsRegistry::new();
    let cache = FeatureCache::with_registry(cache_bytes, 8, &reg);
    let mut cached_eng = ExpansionEngine::new(&map, batch);
    let mut plain_eng = ExpansionEngine::new(&map, batch);
    let key = CacheKey::new(map.config(), cached_eng.plan());
    let mut want = Matrix::zeros(batch, fd);
    let mut got = Matrix::zeros(batch, fd);
    let verify_batches = batches.min(16);
    for b in 0..verify_batches {
        let xb = batch_rows(b);
        plain_eng.execute_matrix(&map, &xb, &mut want);
        cache.execute_matrix(key, &mut cached_eng, &map, &xb, &mut got);
        ensure!(want.data() == got.data(), "cached path diverged from engine on batch {b}");
    }
    let lookups = (verify_batches * batch) as u64;
    ensure!(
        cache.hits() + cache.misses() == lookups,
        "accounting broken: {} hits + {} misses != {lookups} lookups",
        cache.hits(),
        cache.misses()
    );
    ensure!(cache.hits() > 0, "replayed pool produced no cache hits");
    ensure!(
        cache.bytes() <= cache_bytes,
        "cache overran its budget: {} > {cache_bytes}",
        cache.bytes()
    );
    ensure!(
        reg.counter_value("cache.hits") == Some(cache.hits()),
        "registry view disagrees with cache accessors"
    );

    // Phase 2: timing. Warm a fresh cache to steady state first so the
    // cached numbers measure the hit regime, not pool fill.
    let inputs: Vec<Matrix> = (0..batches).map(batch_rows).collect();
    let timing_reg = MetricsRegistry::new();
    let tcache = FeatureCache::with_registry(cache_bytes, 8, &timing_reg);
    let mut eng_c = ExpansionEngine::new(&map, batch);
    let mut feats = Matrix::zeros(batch, fd);
    for xb in &inputs {
        tcache.execute_matrix(key, &mut eng_c, &map, xb, &mut feats);
    }
    let cached = bench("cache/cached", &cfg, |i| {
        tcache.execute_matrix(key, &mut eng_c, &map, &inputs[i % batches], &mut feats);
    });
    let mut eng_u = ExpansionEngine::new(&map, batch);
    let uncached = bench("cache/uncached", &cfg, |i| {
        eng_u.execute_matrix(&map, &inputs[i % batches], &mut feats);
    });
    let total = tcache.hits() + tcache.misses();
    let hit_rate = if total > 0 { tcache.hits() as f64 / total as f64 } else { 0.0 };
    ensure!(tcache.hits() > tcache.misses(), "steady state should be hit-dominated");
    let speedup = uncached.stats.median / cached.stats.median;
    println!(
        "cache (batch={batch}, unique={unique}, n={}, E={e}): uncached {:.3} ms  \
         cached {:.3} ms  speedup {:.2}x  hit rate {:.3}  evictions {}",
        map.padded_dim(),
        uncached.median_ms(),
        cached.median_ms(),
        speedup,
        hit_rate,
        tcache.evictions()
    );
    write_bench_json(
        &out,
        &[
            ("bench", Json::Str("cache".into())),
            ("input_dim", Json::Num(input_dim as f64)),
            ("n", Json::Num(map.padded_dim() as f64)),
            ("expansions", Json::Num(e as f64)),
            ("batch", Json::Num(batch as f64)),
            ("unique_rows", Json::Num(unique as f64)),
            ("batches", Json::Num(batches as f64)),
            ("cache_mb", Json::Num(cache_mb as f64)),
            ("hit_rate", Json::Num(hit_rate)),
            ("hits", Json::Num(tcache.hits() as f64)),
            ("misses", Json::Num(tcache.misses() as f64)),
            ("evictions", Json::Num(tcache.evictions() as f64)),
            ("resident_bytes", Json::Num(tcache.bytes() as f64)),
            ("uncached_ms", Json::Num(uncached.median_ms())),
            ("cached_ms", Json::Num(cached.median_ms())),
            ("speedup", Json::Num(speedup)),
            ("uncached", uncached.stats.to_dist_json_ns()),
            ("cached", cached.stats.to_dist_json_ns()),
        ],
    )?;
    Ok(())
}

fn write_bench_json(path: &str, fields: &[(&str, Json)]) -> Result<()> {
    let obj: BTreeMap<String, Json> =
        fields.iter().map(|(k, v)| (k.to_string(), v.clone())).collect();
    std::fs::write(path, Json::Obj(obj).to_string())?;
    println!("wrote {path}");
    Ok(())
}

/// `mckernel stats` — enable the observability registry, drive each
/// instrumented layer once (engine stages, sharded trainer, prefetch
/// pipeline, feature server), and write the registry snapshot as JSON
/// (`--out`, default `STATS_snapshot.json`). `--trace FILE`
/// additionally streams span events as JSONL. The snapshot uses the
/// same distribution schema as the BENCH_*.json dists
/// ([`crate::benchkit::Stats::to_dist_json_ns`]).
pub fn cmd_stats(args: &Args) -> Result<()> {
    use crate::linalg::Matrix;
    use crate::mckernel::ExpansionEngine;
    use crate::obs;

    obs::enable();
    if let Some(path) = args.get("trace") {
        obs::trace_to(path).with_context(|| format!("open --trace file {path}"))?;
    }
    let quick = args.flag("quick");
    let input_dim: usize = args.parse_or("input-dim", 64usize)?;
    let e: usize = args.parse_or("expansions", 2usize)?;
    let rows: usize = args.positive_or("rows", 32)?;
    let iters = if quick { 2 } else { 8 };
    let requests: usize = args.positive_or("requests", 16)?;
    let workers: usize = args.positive_or("workers", 2)?.max(2);
    let out = args.get_or("out", "STATS_snapshot.json");

    // 1. Engine stage timings (fwht/trig/write per plan fingerprint).
    {
        let _g = obs::span("stats.engine");
        let map = McKernelFactory::new(input_dim).expansions(e).rbf().seed(7).build();
        let mut rng = crate::hash::HashRng::new(7, 0x57A7);
        let x = Matrix::from_fn(rows, input_dim, |_, _| rng.next_f32() - 0.5);
        let mut engine = ExpansionEngine::new(&map, rows);
        let mut feats = Matrix::zeros(rows, map.feature_dim());
        for _ in 0..iters {
            engine.execute_matrix(&map, &x, &mut feats);
        }
    }

    // 2. Sharded trainer (epoch/shard/reduce timings + row counter);
    //    workers ≥ 2 so the shard and reduction paths both run.
    {
        let _g = obs::span("stats.train");
        let spec = SyntheticSpec::mnist();
        let train = Dataset::synthetic(7, &spec, "train", (rows * 4).max(workers));
        let test = Dataset::synthetic(7, &spec, "test", 16);
        let cfg = TrainConfig {
            epochs: 1,
            batch_size: 16,
            sgd: SgdConfig { lr: 0.01, momentum: 0.0, clip: None },
            seed: 7,
            eval_every_epoch: false,
            verbose: false,
            workers,
            cache_bytes: None,
        };
        let _ = ParallelTrainer::new(cfg, Featurizer::Identity).fit(&train, &test);
    }

    // 3. Prefetch pipeline (queue-stall histogram).
    {
        let _g = obs::span("stats.prefetch");
        let d = Arc::new(Dataset::synthetic(7, &SyntheticSpec::mnist(), "train", rows.max(8)));
        let p = Prefetcher::spawn(d, 4, 7, 0, 1, false, None);
        for _ in p.iter() {}
    }

    // 4. Feature server (latency/batch-occupancy/deadline-miss) with
    //    the feature cache on: the 7 distinct request rows repeat, so
    //    the snapshot carries non-trivial `cache.*` counters too.
    {
        let _g = obs::span("stats.serve");
        let map = Arc::new(McKernelFactory::new(16).expansions(1).rbf().seed(7).build());
        let server = FeatureServer::start(
            map,
            ServerConfig::new(8, Duration::from_micros(100)).cache_bytes(1 << 20),
        );
        for i in 0..requests {
            let row = vec![(i % 7) as f32 * 0.1; 16];
            server.transform(row).context("server request")?;
        }
        server.shutdown();
    }

    obs::trace_off();
    let snapshot = obs::global().snapshot_json();
    std::fs::write(&out, snapshot.to_string())?;
    println!("wrote {out}");
    if let Some(hists) = snapshot.get("histograms").and_then(Json::as_obj) {
        for (name, h) in hists {
            let f = |k: &str| h.get(k).and_then(Json::as_f64).unwrap_or(0.0);
            println!(
                "  {name:<32} count {:>6}  p50 {:>12.0} ns  p95 {:>12.0} ns  p99 {:>12.0} ns",
                f("count") as u64,
                f("p50"),
                f("p95"),
                f("p99")
            );
        }
    }
    Ok(())
}

/// `mckernel gen-data`.
pub fn cmd_gen_data(args: &Args) -> Result<()> {
    let out: String = args.require("out")?;
    let (train, test) = load_datasets(args)?;
    let d = std::path::Path::new(&out);
    train.write_idx_files(
        d.join("train-images-idx3-ubyte"),
        d.join("train-labels-idx1-ubyte"),
    )?;
    test.write_idx_files(
        d.join("t10k-images-idx3-ubyte"),
        d.join("t10k-labels-idx1-ubyte"),
    )?;
    println!("wrote {} train / {} test samples to {out}", train.len(), test.len());
    Ok(())
}

/// `mckernel info`.
pub fn cmd_info(args: &Args) -> Result<()> {
    let dir = args.get_or("artifacts", "artifacts");
    let manifest = crate::runtime::Manifest::load(&dir)?;
    manifest.validate()?;
    println!(
        "artifacts in {dir}: n={} pixels={} classes={}",
        manifest.n, manifest.pixels, manifest.classes
    );
    for e in &manifest.entries {
        println!(
            "  {:<24} kind={:<8} featurizer={:<9} batch={:<4} E={} feature_dim={}",
            e.name, e.kind, e.featurizer, e.batch, e.expansions, e.feature_dim
        );
    }
    Ok(())
}

/// `mckernel serve` — demo loop: N requests through the server.
pub fn cmd_serve(args: &Args) -> Result<()> {
    let (train, _) = load_datasets(args)?;
    let map = build_map(args, train.dim())?.context("serve needs a feature map")?;
    let max_batch: usize = args.parse_or("max-batch", 32usize)?;
    let wait_us: u64 = args.parse_or("max-wait-us", 200u64)?;
    let requests: usize = args.parse_or("requests", 1000usize)?;
    let clients: usize = args.parse_or("clients", 8usize)?;
    let mut config = ServerConfig::new(max_batch, Duration::from_micros(wait_us));
    let cached = cache_bytes_from(args)?;
    if let Some(b) = cached {
        config = config.cache_bytes(b);
    }
    let server = FeatureServer::start(Arc::clone(&map), config);
    let t0 = std::time::Instant::now();
    let per_client = requests / clients;
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            let client = server.client();
            let data = train.images().clone();
            // analyze: allow(thread-spawn) -- load-drill clients must be independent OS threads, not pool jobs competing with the server
            std::thread::spawn(move || {
                for i in 0..per_client {
                    let row = data.row((c * per_client + i) % data.rows()).to_vec();
                    client.transform(row).expect("server alive");
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let secs = t0.elapsed().as_secs_f64();
    let stats = server.stats();
    println!(
        "served {} requests from {clients} clients in {:.2}s  ({:.0} req/s, mean batch {:.1})",
        per_client * clients,
        secs,
        (per_client * clients) as f64 / secs,
        stats.mean_batch_size()
    );
    if cached.is_some() {
        // cache metrics record unconditionally into the global
        // registry (the cache itself is the opt-in)
        let g = crate::obs::global();
        println!(
            "cache: {} hits / {} misses ({} evictions)",
            g.counter_value("cache.hits").unwrap_or(0),
            g.counter_value("cache.misses").unwrap_or(0),
            g.counter_value("cache.evictions").unwrap_or(0),
        );
    }
    server.shutdown();
    Ok(())
}

/// `mckernel chaos` — deterministic fault-injection drill: drives the
/// hardened server, trainer, pool and prefetcher under seeded
/// [`FaultPlan`]s and checks the fault-tolerance invariants end to
/// end — every admitted request answered exactly once, panicked
/// batches quarantined and recovered, load shed at the admission
/// bound, retried training bit-identical to the fault-free run.
/// Evidence is written as JSON (`--out`, default
/// `CHAOS_snapshot.json`); any violated invariant is a non-zero exit.
pub fn cmd_chaos(args: &Args) -> Result<()> {
    let seed: u64 = args.parse_or("seed", crate::PAPER_SEED)?;
    let quick = args.flag("quick");
    let requests: usize = args.positive_or("requests", if quick { 48 } else { 256 })?;
    let out = args.get_or("out", "CHAOS_snapshot.json");

    // Injected panics are the point of this drill; silence the default
    // hook's backtrace spew for the run so real output stays readable.
    let hook = std::panic::take_hook();
    std::panic::set_hook(Box::new(|_| {}));
    let run = || -> Result<Json> {
        let mut m = BTreeMap::new();
        m.insert("seed".into(), Json::Num(seed as f64));
        m.insert("accounting".into(), chaos_accounting(seed, requests)?);
        m.insert("restart".into(), chaos_restart(seed)?);
        m.insert("shedding".into(), chaos_shedding(seed)?);
        m.insert("trainer".into(), chaos_trainer(seed, quick)?);
        m.insert("lifecycle".into(), chaos_lifecycle(seed)?);
        Ok(Json::Obj(m))
    };
    let outcome = run();
    std::panic::set_hook(hook);
    let snapshot = outcome?;
    std::fs::write(&out, snapshot.to_string())?;
    println!("wrote {out}");
    println!("all fault-tolerance invariants held (seed {seed})");
    Ok(())
}

fn chaos_map(seed: u64) -> Arc<crate::mckernel::McKernel> {
    Arc::new(McKernelFactory::new(16).expansions(1).rbf().seed(seed).build())
}

/// Mixed engine faults, worker panics and latency injection: every
/// submitted request must come back with a feature row or a typed
/// error — zero hangs, zero lost replies, zero leaked admission slots.
fn chaos_accounting(seed: u64, requests: usize) -> Result<Json> {
    let reg = MetricsRegistry::new();
    let plan = Arc::new(
        FaultPlan::with_registry(seed, &reg)
            .with_rate(FaultSite::EngineFault, 0.10)
            .with_rate(FaultSite::WorkerPanic, 0.05)
            .with_rate(FaultSite::Latency, 0.10)
            .with_latency(Duration::from_millis(1)),
    );
    let config = ServerConfig::new(8, Duration::from_micros(200))
        .max_queue(requests.max(1))
        .deadline(Duration::from_secs(10))
        .faults(Arc::clone(&plan));
    let server = FeatureServer::start_with_registry(chaos_map(seed), config, &reg);
    let clients = 4usize;
    let per = requests.div_ceil(clients);
    let (otx, orx) = std::sync::mpsc::channel();
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            let client = server.client();
            let otx = otx.clone();
            // analyze: allow(thread-spawn) -- chaos drill needs real concurrent clients to exercise shedding and restarts
            std::thread::spawn(move || {
                for i in 0..per {
                    let x = vec![((c * per + i) % 9) as f32 * 0.1; 16];
                    let _ = otx.send(client.transform(x).map(|_| ()));
                }
            })
        })
        .collect();
    drop(otx);
    for h in handles {
        h.join().expect("chaos client thread");
    }
    let (mut ok, mut errors) = (0u64, 0u64);
    let mut kinds: BTreeMap<String, u64> = BTreeMap::new();
    for outcome in orx.iter() {
        match outcome {
            Ok(()) => ok += 1,
            Err(e) => {
                errors += 1;
                *kinds.entry(e.kind().to_string()).or_insert(0) += 1;
            }
        }
    }
    let submitted = (clients * per) as u64;
    ensure!(
        ok + errors == submitted,
        "lost replies: {ok} ok + {errors} errors != {submitted} submitted"
    );
    let stats = server.stats().clone();
    server.shutdown();
    ensure!(stats.queue_depth() == 0, "admission slots leaked: {}", stats.queue_depth());
    println!(
        "chaos/accounting: {submitted} submitted = {ok} ok + {errors} typed errors  \
         (restarts {}, injected {})",
        stats.restarts(),
        plan.injected()
    );
    let mut j = BTreeMap::new();
    j.insert("submitted".into(), Json::Num(submitted as f64));
    j.insert("ok".into(), Json::Num(ok as f64));
    j.insert(
        "errors".into(),
        Json::Obj(kinds.into_iter().map(|(k, v)| (k, Json::Num(v as f64))).collect()),
    );
    j.insert("restarts".into(), Json::Num(stats.restarts() as f64));
    j.insert("injected".into(), Json::Num(plan.injected() as f64));
    Ok(Json::Obj(j))
}

/// One guaranteed serve-loop panic: the poisoned batch's request gets
/// `WorkerPanic`, the restart is counted, and the next request is
/// answered bit-exactly.
fn chaos_restart(seed: u64) -> Result<Json> {
    let reg = MetricsRegistry::new();
    let plan = Arc::new(
        FaultPlan::with_registry(seed, &reg)
            .with_rate(FaultSite::WorkerPanic, 1.0)
            .with_limit(FaultSite::WorkerPanic, 1),
    );
    let map = chaos_map(seed);
    let config = ServerConfig::new(4, Duration::from_micros(50)).faults(plan);
    let server = FeatureServer::start_with_registry(Arc::clone(&map), config, &reg);
    let x: Vec<f32> = (0..16).map(|i| i as f32 * 0.05).collect();
    let first = server.transform(x.clone());
    ensure!(
        first == Err(McError::WorkerPanic),
        "first request should hit the injected panic: {first:?}"
    );
    let second = server
        .transform(x.clone())
        .map_err(|e| anyhow!("post-restart request failed: {e}"))?;
    ensure!(second == map.transform(&x), "post-restart reply must be bit-exact");
    let restarts = server.stats().restarts();
    ensure!(restarts >= 1, "panic recovery must be counted");
    server.shutdown();
    println!("chaos/restart: injected serve-loop panic -> WorkerPanic reply, then recovered");
    let mut j = BTreeMap::new();
    j.insert("restarts".into(), Json::Num(restarts as f64));
    Ok(Json::Obj(j))
}

/// Admission control under guaranteed latency: with `max_queue` 2 and
/// a 50 ms injected stall, a burst of 6 submits sheds the overflow
/// with `Overloaded` while every admitted request is still served.
fn chaos_shedding(seed: u64) -> Result<Json> {
    let reg = MetricsRegistry::new();
    let plan = Arc::new(
        FaultPlan::with_registry(seed, &reg)
            .with_rate(FaultSite::Latency, 1.0)
            .with_latency(Duration::from_millis(50)),
    );
    let config = ServerConfig::new(1, Duration::from_micros(10))
        .max_queue(2)
        .faults(plan);
    let server = FeatureServer::start_with_registry(chaos_map(seed), config, &reg);
    let client = server.client();
    let mut admitted = Vec::new();
    let mut shed = 0u64;
    for i in 0..6 {
        match client.submit(vec![0.1 * (i + 1) as f32; 16]) {
            Ok(p) => admitted.push(p),
            Err(McError::Overloaded { limit }) => {
                ensure!(limit == 2, "shed error must carry the bound, got {limit}");
                shed += 1;
            }
            Err(e) => bail!("unexpected submit error: {e}"),
        }
    }
    let served = admitted.len() as u64;
    ensure!(shed > 0, "burst never hit the admission bound");
    for p in admitted {
        p.wait().map_err(|e| anyhow!("admitted request failed: {e}"))?;
    }
    let rejected = server.stats().rejected();
    ensure!(rejected == shed, "rejected counter {rejected} != shed {shed}");
    server.shutdown();
    println!("chaos/shedding: {shed} of 6 shed at max_queue=2, all {served} admitted served");
    let mut j = BTreeMap::new();
    j.insert("shed".into(), Json::Num(shed as f64));
    j.insert("served".into(), Json::Num(served as f64));
    Ok(Json::Obj(j))
}

/// Injected shard panics + bounded retries must leave the final
/// weights bit-identical to the fault-free run (recomputed shards are
/// pure functions of their inputs; the reduction order is fixed).
fn chaos_trainer(seed: u64, quick: bool) -> Result<Json> {
    let spec = SyntheticSpec::mnist();
    let train = Dataset::synthetic(seed, &spec, "train", if quick { 60 } else { 200 });
    let test = Dataset::synthetic(seed, &spec, "test", 20);
    let cfg = TrainConfig {
        epochs: if quick { 2 } else { 3 },
        batch_size: 10,
        sgd: SgdConfig { lr: 0.05, momentum: 0.0, clip: None },
        seed,
        eval_every_epoch: false,
        verbose: false,
        workers: 4,
        cache_bytes: None,
    };
    let (clean, _) = ParallelTrainer::new(cfg.clone(), Featurizer::Identity)
        .fit(&train, &test)
        .map_err(|e| anyhow!("fault-free fit failed: {e}"))?;
    let reg = MetricsRegistry::new();
    let plan =
        Arc::new(FaultPlan::with_registry(seed, &reg).with_rate(FaultSite::WorkerPanic, 0.2));
    let retries_before = crate::obs::global().counter("train.retries").get();
    let (chaotic, _) = ParallelTrainer::new(cfg, Featurizer::Identity)
        .with_retry(RetryPolicy {
            max_retries: 8,
            backoff: Duration::from_millis(1),
            backoff_cap: Duration::from_millis(10),
        })
        .with_faults(Arc::clone(&plan))
        .fit(&train, &test)
        .map_err(|e| anyhow!("chaotic fit failed: {e}"))?;
    let retried = crate::obs::global().counter("train.retries").get() - retries_before;
    ensure!(plan.injected() > 0, "chaos run never injected a fault");
    ensure!(retried > 0, "injected panics must surface as counted retries");
    ensure!(
        chaotic.w().data() == clean.w().data() && chaotic.b() == clean.b(),
        "retried training diverged from the fault-free run"
    );
    println!(
        "chaos/trainer: {} injected shard panics, {retried} retries, weights bit-identical",
        plan.injected()
    );
    let mut j = BTreeMap::new();
    j.insert("injected".into(), Json::Num(plan.injected() as f64));
    j.insert("retries".into(), Json::Num(retried as f64));
    j.insert("bit_identical".into(), Json::Bool(true));
    Ok(Json::Obj(j))
}

/// Lifecycle edges: pool submission after shutdown is a typed error
/// (not a panic), and a consumer abandoning a prefetch epoch aborts
/// the producer cleanly (joined, counted).
fn chaos_lifecycle(seed: u64) -> Result<Json> {
    let mut pool = crate::util::ThreadPool::new(2);
    pool.execute(|| {}).map_err(|e| anyhow!("healthy pool rejected a job: {e}"))?;
    pool.shutdown();
    ensure!(
        pool.execute(|| {}) == Err(McError::ShuttingDown),
        "submit-after-shutdown must be ShuttingDown"
    );
    let reg = MetricsRegistry::new();
    let d = Arc::new(Dataset::synthetic(seed, &SyntheticSpec::mnist(), "train", 100));
    let p = Prefetcher::spawn_with_registry(d, 5, seed, 0, 1, false, None, &reg);
    let _first = p.next();
    drop(p);
    let aborted = reg.counter("prefetch.aborted").get();
    ensure!(aborted == 1, "prefetch abort not counted: {aborted}");
    println!("chaos/lifecycle: pool shutdown + prefetch abort are typed and leak-free");
    let mut j = BTreeMap::new();
    j.insert("prefetch_aborted".into(), Json::Num(aborted as f64));
    Ok(Json::Obj(j))
}

/// Top-level dispatch.
pub fn run(args: Args) -> Result<()> {
    match args.subcommand() {
        None => {
            println!("{USAGE}");
            Ok(())
        }
        Some(cmd) => {
            let rest = args.rest();
            // Dispatch force is process-global (the plan's one knob):
            // resolve it up front so every engine any subcommand builds
            // compiles onto the requested arm.
            if let Some(d) = rest.get("dispatch") {
                let force = crate::mckernel::DispatchForce::parse(d)
                    .with_context(|| format!("bad --dispatch '{d}' (auto|scalar|simd)"))?;
                crate::mckernel::set_dispatch_force(force);
            }
            match cmd {
                "train" => cmd_train(&rest),
                "predict" => cmd_predict(&rest),
                "features" => cmd_features(&rest),
                "fwht" => cmd_fwht(&rest),
                "bench" => cmd_bench(&rest),
                "cache-bench" => cmd_cache_bench(&rest),
                "stats" => cmd_stats(&rest),
                "gen-data" => cmd_gen_data(&rest),
                "info" => cmd_info(&rest),
                "serve" => cmd_serve(&rest),
                "chaos" => cmd_chaos(&rest),
                "help" | "--help" => {
                    println!("{USAGE}");
                    Ok(())
                }
                other => bail!("unknown command '{other}'\n\n{USAGE}"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &[&str]) -> Args {
        Args::parse(s.iter().copied()).unwrap()
    }

    #[test]
    fn datasets_from_flags() {
        let a = args(&["--dataset", "fashion", "--train-size", "30", "--test-size", "10"]);
        let (tr, te) = load_datasets(&a).unwrap();
        assert_eq!(tr.len(), 30);
        assert_eq!(te.len(), 10);
    }

    #[test]
    fn map_from_flags() {
        let a = args(&["--expansions", "2", "--kernel", "rbf", "--sigma", "3.0", "--seed", "5"]);
        let m = build_map(&a, 100).unwrap().unwrap();
        assert_eq!(m.expansions(), 2);
        assert_eq!(m.config().sigma, 3.0);
        assert_eq!(m.config().kernel, Kernel::Rbf);
    }

    #[test]
    fn identity_featurizer_flag() {
        let a = args(&["--featurizer", "identity"]);
        assert!(build_map(&a, 100).unwrap().is_none());
    }

    #[test]
    fn train_config_defaults_match_paper() {
        let a = args(&[]);
        let c = train_config(&a, 0.001).unwrap();
        assert_eq!(c.epochs, 20);
        assert_eq!(c.batch_size, 10);
        assert_eq!(c.sgd.lr, 0.001);
        assert_eq!(c.seed, 1398239763);
        assert_eq!(c.workers, 1, "serial oracle by default");
    }

    #[test]
    fn workers_flag_parses_and_rejects_zero() {
        let a = args(&["--workers", "4"]);
        assert_eq!(train_config(&a, 0.01).unwrap().workers, 4);
        let bad = args(&["--workers", "0"]);
        assert!(train_config(&bad, 0.01).is_err());
    }

    #[test]
    fn unknown_command_is_error() {
        assert!(run(args(&["frobnicate"])).is_err());
    }

    #[test]
    fn cache_flags_parse() {
        assert_eq!(cache_bytes_from(&args(&[])).unwrap(), None);
        assert_eq!(cache_bytes_from(&args(&["--cache"])).unwrap(), Some(64 << 20));
        assert_eq!(
            cache_bytes_from(&args(&["--cache-mb", "8"])).unwrap(),
            Some(8 << 20)
        );
        assert!(cache_bytes_from(&args(&["--cache-mb", "0"])).is_err());
        assert_eq!(
            train_config(&args(&["--cache"]), 0.01).unwrap().cache_bytes,
            Some(64 << 20)
        );
        assert_eq!(train_config(&args(&[]), 0.01).unwrap().cache_bytes, None);
    }

    #[test]
    fn cache_bench_drill_holds_invariants_and_writes_json() {
        let dir = std::env::temp_dir()
            .join(format!("mckernel_cache_bench_cmd_test_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let out = dir.join("BENCH_cache.json");
        let a = args(&[
            "--quick", "--input-dim", "16", "--expansions", "1", "--batch", "8",
            "--unique", "24", "--batches", "8", "--out", out.to_str().unwrap(),
        ]);
        cmd_cache_bench(&a).unwrap();
        let json = Json::parse(&std::fs::read_to_string(&out).unwrap()).unwrap();
        let hit_rate = json.get("hit_rate").and_then(Json::as_f64).unwrap();
        assert!(hit_rate > 0.5, "steady-state replay should be hit-dominated: {hit_rate}");
        assert!(json.get("speedup").and_then(Json::as_f64).is_some());
        for key in ["cached", "uncached"] {
            let dist = json.get(key).unwrap();
            for field in ["count", "mean", "p50", "p95", "p99"] {
                assert!(dist.get(field).and_then(Json::as_f64).is_some(), "{key}.{field}");
            }
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn bench_writes_machine_readable_json() {
        // per-process dir, wiped first: stale files from a previous
        // run must not be able to mask a broken write
        let dir = std::env::temp_dir()
            .join(format!("mckernel_bench_cmd_test_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let a = args(&[
            "--quick", "--batch", "4", "--expansions", "1", "--input-dim", "16",
            "--workers", "2", "--out-dir", dir.to_str().unwrap(),
        ]);
        cmd_bench(&a).unwrap();
        for name in ["BENCH_features.json", "BENCH_fwht.json", "BENCH_train.json"] {
            let text = std::fs::read_to_string(dir.join(name)).unwrap();
            let json = Json::parse(&text).unwrap();
            assert!(json.get("speedup").and_then(Json::as_f64).is_some(), "{name}");
        }
        for name in ["BENCH_features.json", "BENCH_fwht.json"] {
            let text = std::fs::read_to_string(dir.join(name)).unwrap();
            let json = Json::parse(&text).unwrap();
            assert!(json.get("n").and_then(Json::as_f64).is_some(), "{name}");
        }
        let train = Json::parse(&std::fs::read_to_string(dir.join("BENCH_train.json")).unwrap())
            .unwrap();
        assert_eq!(train.get("workers").and_then(Json::as_f64), Some(2.0));
        assert!(train.get("acc_delta").and_then(Json::as_f64).is_some());
        // each file embeds nested dists in the shared obs schema,
        // including the PR 9 `simd` leg
        for (name, keys) in [
            ("BENCH_features.json", &["per_row", "batched", "simd"][..]),
            ("BENCH_fwht.json", &["per_row", "batched", "simd"][..]),
            ("BENCH_train.json", &["serial", "parallel"][..]),
        ] {
            let json = Json::parse(&std::fs::read_to_string(dir.join(name)).unwrap()).unwrap();
            for key in keys {
                let dist = json.get(key).unwrap_or_else(|| panic!("{name} missing {key}"));
                for field in ["count", "mean", "p50", "p95", "p99"] {
                    assert!(
                        dist.get(field).and_then(Json::as_f64).is_some(),
                        "{name}.{key}.{field}"
                    );
                }
            }
        }
        // the simd legs carry their scalar-relative numbers + level tag
        for name in ["BENCH_features.json", "BENCH_fwht.json"] {
            let json = Json::parse(&std::fs::read_to_string(dir.join(name)).unwrap()).unwrap();
            assert!(json.get("simd_ms").and_then(Json::as_f64).is_some(), "{name}");
            assert!(json.get("simd_speedup").and_then(Json::as_f64).is_some(), "{name}");
            assert!(json.get("simd_level").is_some(), "{name}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn dispatch_flag_rejects_unknown_values() {
        let a = args(&["fwht", "--log-n", "4", "--dispatch", "bogus"]);
        assert!(run(a).is_err());
    }

    #[test]
    fn fwht_accepts_the_simd_engine() {
        let a = args(&["fwht", "--log-n", "6", "--engine", "simd"]);
        run(a).unwrap();
    }

    #[test]
    fn chaos_quick_holds_invariants_and_writes_snapshot() {
        let dir = std::env::temp_dir()
            .join(format!("mckernel_chaos_cmd_test_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let out = dir.join("CHAOS_snapshot.json");
        let a = args(&["--quick", "--out", out.to_str().unwrap()]);
        cmd_chaos(&a).unwrap();
        let json = Json::parse(&std::fs::read_to_string(&out).unwrap()).unwrap();
        for key in ["accounting", "restart", "shedding", "trainer", "lifecycle"] {
            assert!(json.get(key).is_some(), "snapshot missing {key}");
        }
        let trainer = json.get("trainer").unwrap();
        assert_eq!(trainer.get("bit_identical").and_then(Json::as_bool), Some(true));
        assert!(trainer.get("injected").and_then(Json::as_f64).unwrap() > 0.0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn tiny_native_train_runs() {
        let a = args(&[
            "train", "--train-size", "40", "--test-size", "20", "--epochs", "1",
            "--expansions", "1", "--quiet", "--batch-size", "10",
        ]);
        run(a).unwrap();
    }

    #[test]
    fn tiny_sharded_train_runs() {
        let a = args(&[
            "train", "--train-size", "40", "--test-size", "20", "--epochs", "1",
            "--expansions", "1", "--quiet", "--batch-size", "10", "--workers", "3",
        ]);
        run(a).unwrap();
    }

    #[test]
    fn resumable_train_autosaves_and_reruns() {
        let dir = std::env::temp_dir()
            .join(format!("mckernel_resume_cmd_test_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let ck = dir.join("resume.mck");
        let argv = [
            "train", "--train-size", "40", "--test-size", "20", "--epochs", "2",
            "--featurizer", "identity", "--quiet", "--batch-size", "10", "--workers", "2",
            "--resume", "--checkpoint", ck.to_str().unwrap(),
        ];
        run(args(&argv)).unwrap(); // fresh run, autosaving every epoch
        let saved = Checkpoint::load(&ck).unwrap();
        assert_eq!(saved.epoch(), Some(2), "cursor records completed epochs");
        run(args(&argv)).unwrap(); // complete checkpoint: evaluate only
        assert_eq!(Checkpoint::load(&ck).unwrap().epoch(), Some(2), "cursor untouched");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
