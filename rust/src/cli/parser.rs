//! A small, strict argument parser.

use std::collections::BTreeMap;
use std::fmt;

/// Parse error.
#[derive(Debug, Clone, PartialEq)]
pub struct CliError(pub String);

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for CliError {}

/// Parsed command line: positionals + `--key[=value]` options.
#[derive(Debug, Clone, Default)]
pub struct Args {
    positionals: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
    consumed: std::cell::RefCell<std::collections::BTreeSet<String>>,
}

impl Args {
    /// Parse an argv slice (without the program name).
    ///
    /// Grammar: `--key=value` | `--key value` | `--flag` (when the next
    /// token starts with `--` or is absent) | positional. A literal
    /// `--` ends option parsing.
    pub fn parse<I, S>(argv: I) -> Result<Args, CliError>
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        let tokens: Vec<String> = argv.into_iter().map(Into::into).collect();
        let mut a = Args::default();
        let mut i = 0;
        let mut raw = false;
        while i < tokens.len() {
            let t = &tokens[i];
            if raw || !t.starts_with("--") {
                a.positionals.push(t.clone());
                i += 1;
                continue;
            }
            if t == "--" {
                raw = true;
                i += 1;
                continue;
            }
            let body = &t[2..];
            if body.is_empty() {
                return Err(CliError("empty option name".into()));
            }
            if let Some(eq) = body.find('=') {
                let (k, v) = body.split_at(eq);
                a.options.insert(k.to_string(), v[1..].to_string());
                i += 1;
            } else if i + 1 < tokens.len() && !tokens[i + 1].starts_with("--") {
                a.options.insert(body.to_string(), tokens[i + 1].clone());
                i += 2;
            } else {
                a.flags.push(body.to_string());
                i += 1;
            }
        }
        Ok(a)
    }

    /// Parse the process argv (skipping program name).
    pub fn from_env() -> Result<Args, CliError> {
        Args::parse(std::env::args().skip(1))
    }

    fn mark(&self, key: &str) {
        self.consumed.borrow_mut().insert(key.to_string());
    }

    /// Positional argument at `idx`.
    pub fn positional(&self, idx: usize) -> Option<&str> {
        self.positionals.get(idx).map(|s| s.as_str())
    }

    /// All positionals.
    pub fn positionals(&self) -> &[String] {
        &self.positionals
    }

    /// Take the first positional as a subcommand name.
    pub fn subcommand(&self) -> Option<&str> {
        self.positional(0)
    }

    /// Args with the subcommand stripped (for dispatch).
    pub fn rest(&self) -> Args {
        let mut a = self.clone();
        if !a.positionals.is_empty() {
            a.positionals.remove(0);
        }
        a
    }

    /// Whether a boolean flag was given (either `--x` or `--x=true`).
    pub fn flag(&self, key: &str) -> bool {
        self.mark(key);
        self.flags.iter().any(|f| f == key)
            || matches!(self.options.get(key).map(|s| s.as_str()), Some("true" | "1" | "yes"))
    }

    /// Raw string option.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.mark(key);
        self.options.get(key).map(|s| s.as_str())
    }

    /// String option with default.
    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Typed option with default.
    pub fn parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T, CliError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError(format!("--{key}: cannot parse '{v}'"))),
        }
    }

    /// `usize` option with default that must be ≥ 1 (worker/shard
    /// counts, batch sizes — zero is never a valid cardinality).
    pub fn positive_or(&self, key: &str, default: usize) -> Result<usize, CliError> {
        let v: usize = self.parse_or(key, default)?;
        if v == 0 {
            return Err(CliError(format!("--{key}: must be ≥ 1")));
        }
        Ok(v)
    }

    /// Required typed option.
    pub fn require<T: std::str::FromStr>(&self, key: &str) -> Result<T, CliError> {
        let v = self
            .get(key)
            .ok_or_else(|| CliError(format!("missing required --{key}")))?;
        v.parse()
            .map_err(|_| CliError(format!("--{key}: cannot parse '{v}'")))
    }

    /// Comma-separated list option, e.g. `--expansions 1,2,4`.
    pub fn list_or<T: std::str::FromStr>(&self, key: &str, default: &[T]) -> Result<Vec<T>, CliError>
    where
        T: Clone,
    {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|p| {
                    p.trim()
                        .parse()
                        .map_err(|_| CliError(format!("--{key}: cannot parse '{p}'")))
                })
                .collect(),
        }
    }

    /// Error on unknown (never-queried) options — catches typos. Call
    /// after all gets.
    pub fn reject_unknown(&self) -> Result<(), CliError> {
        let seen = self.consumed.borrow();
        for k in self.options.keys().chain(self.flags.iter()) {
            if !seen.contains(k) {
                return Err(CliError(format!("unknown option --{k}")));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().copied()).unwrap()
    }

    #[test]
    fn key_value_forms() {
        let a = parse(&["--alpha", "3", "--beta=4", "--gamma"]);
        assert_eq!(a.get("alpha"), Some("3"));
        assert_eq!(a.get("beta"), Some("4"));
        assert!(a.flag("gamma"));
        assert!(!a.flag("delta"));
    }

    #[test]
    fn positionals_and_subcommand() {
        let a = parse(&["train", "file.idx", "--lr", "0.01"]);
        assert_eq!(a.subcommand(), Some("train"));
        let rest = a.rest();
        assert_eq!(rest.positional(0), Some("file.idx"));
        assert_eq!(rest.get("lr"), Some("0.01"));
    }

    #[test]
    fn typed_accessors() {
        let a = parse(&["--n", "42", "--x", "1.5"]);
        assert_eq!(a.parse_or("n", 0usize).unwrap(), 42);
        assert_eq!(a.parse_or("x", 0.0f64).unwrap(), 1.5);
        assert_eq!(a.parse_or("missing", 7u32).unwrap(), 7);
        assert!(a.require::<usize>("n").is_ok());
        assert!(a.require::<usize>("absent").is_err());
        assert!(a.parse_or("x", 0usize).is_err()); // 1.5 not usize
    }

    #[test]
    fn positive_rejects_zero() {
        let a = parse(&["--workers", "0", "--shards", "3"]);
        assert!(a.positive_or("workers", 1).is_err());
        assert_eq!(a.positive_or("shards", 1).unwrap(), 3);
        assert_eq!(a.positive_or("absent", 4).unwrap(), 4);
    }

    #[test]
    fn lists() {
        let a = parse(&["--e", "1,2, 4"]);
        assert_eq!(a.list_or::<usize>("e", &[]).unwrap(), vec![1, 2, 4]);
        assert_eq!(a.list_or::<usize>("f", &[9]).unwrap(), vec![9]);
    }

    #[test]
    fn flag_like_value_followed_by_option() {
        // `--a --b 3`: a is a flag, b has value 3.
        let a = parse(&["--a", "--b", "3"]);
        assert!(a.flag("a"));
        assert_eq!(a.get("b"), Some("3"));
    }

    #[test]
    fn double_dash_ends_options() {
        let a = parse(&["--x", "1", "--", "--not-an-option"]);
        assert_eq!(a.get("x"), Some("1"));
        assert_eq!(a.positional(0), Some("--not-an-option"));
    }

    #[test]
    fn reject_unknown_catches_typos() {
        let a = parse(&["--learning-rate", "3"]);
        let _ = a.get("lr");
        assert!(a.reject_unknown().is_err());
        let _ = a.get("learning-rate");
        assert!(a.reject_unknown().is_ok());
    }

    #[test]
    fn bool_option_as_value() {
        let a = parse(&["--verbose=true", "--quiet=false"]);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }
}
