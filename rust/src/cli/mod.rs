//! Command-line argument parsing (clap is unreachable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional
//! arguments, typed accessors with defaults, and generated usage text.

pub mod commands;
pub mod parser;

pub use parser::{Args, CliError};
