//! Models: the linear softmax head `softmax(Wx̃ + b)` the paper trains
//! on top of the feature map (Eq. 23), which doubles as plain
//! multinomial logistic regression when fed raw pixels (the paper's
//! LR baseline in Figures 3–5). Plus binary checkpointing.

pub mod checkpoint;
pub mod krr;
pub mod softmax_reg;

pub use krr::{FeatureRidge, KernelRidge};
pub use softmax_reg::{Gradients, SoftmaxRegression};
