//! Multinomial logistic (softmax) regression with explicit
//! forward/backward — the paper's linear head (Eq. 23) and its LR
//! baseline. Loss is the multiclass logistic loss (Eq. 20's softmax
//! generalization), minimized by SGD (Eq. 21).

use crate::hash::hash_rng::streams;
use crate::hash::HashRng;
use crate::linalg::ops::{gemm_nt, gemm_tn, softmax_rows};
use crate::linalg::Matrix;

/// `softmax(W x + b)` classifier. `W: (classes, features)`, `b: (classes)`.
#[derive(Debug, Clone)]
pub struct SoftmaxRegression {
    w: Matrix,
    b: Vec<f32>,
}

/// Gradients of the loss w.r.t. `(W, b)`.
///
/// Doubles as the data-parallel trainer's per-shard accumulator:
/// workers fill disjoint `Gradients` with per-shard *sums* via
/// [`SoftmaxRegression::shard_loss_grad_sums`], the combiner folds
/// them together with [`Gradients::merge`] in a fixed order, and a
/// single [`Gradients::scale`] converts the merged sum to the batch
/// mean before the optimizer step.
#[derive(Debug, Clone)]
pub struct Gradients {
    pub dw: Matrix,
    pub db: Vec<f32>,
}

impl Gradients {
    /// Zero gradients shaped for a `(classes, features)` model.
    pub fn zeros(classes: usize, features: usize) -> Gradients {
        Gradients { dw: Matrix::zeros(classes, features), db: vec![0.0; classes] }
    }

    /// Reset to zero in place (shard buffers are reused every step —
    /// no allocation in the step loop).
    pub fn reset(&mut self) {
        self.dw.data_mut().fill(0.0);
        self.db.fill(0.0);
    }

    /// `self += other`, elementwise — the shard-combine primitive.
    pub fn merge(&mut self, other: &Gradients) {
        self.dw.axpy(1.0, &other.dw);
        for (a, b) in self.db.iter_mut().zip(&other.db) {
            *a += b;
        }
    }

    /// Multiply both components by `s` (sum→mean conversion).
    pub fn scale(&mut self, s: f32) {
        self.dw.scale(s);
        for v in self.db.iter_mut() {
            *v *= s;
        }
    }
}

impl SoftmaxRegression {
    /// Zero-initialized model (convex problem: zeros are a fine start,
    /// and they make runs bit-reproducible trivially).
    pub fn zeros(classes: usize, features: usize) -> SoftmaxRegression {
        SoftmaxRegression { w: Matrix::zeros(classes, features), b: vec![0.0; classes] }
    }

    /// Small hash-seeded Gaussian init (scale `0.01`), for parity with
    /// the Python/JAX L2 model.
    pub fn init(classes: usize, features: usize, seed: u64) -> SoftmaxRegression {
        let rng = HashRng::new(seed, streams::INIT);
        let mut w = Matrix::zeros(classes, features);
        for (k, v) in w.data_mut().iter_mut().enumerate() {
            *v = 0.01 * crate::rand::BoxMuller::at(&rng, k as u64) as f32;
        }
        SoftmaxRegression { w, b: vec![0.0; classes] }
    }

    pub fn classes(&self) -> usize {
        self.w.rows()
    }

    pub fn features(&self) -> usize {
        self.w.cols()
    }

    /// Learned parameter count `C·(features + 1)` (paper Eq. 22 when
    /// `features = 2·[S]₂·E`).
    pub fn param_count(&self) -> usize {
        self.classes() * (self.features() + 1)
    }

    pub fn w(&self) -> &Matrix {
        &self.w
    }

    pub fn b(&self) -> &[f32] {
        &self.b
    }

    pub fn w_mut(&mut self) -> &mut Matrix {
        &mut self.w
    }

    pub fn b_mut(&mut self) -> &mut [f32] {
        &mut self.b
    }

    /// Logits `X·Wᵀ + b` for a `(batch, features)` input.
    pub fn logits(&self, x: &Matrix) -> Matrix {
        assert_eq!(x.cols(), self.features(), "feature width");
        let mut out = Matrix::zeros(x.rows(), self.classes());
        gemm_nt(x, &self.w, &mut out);
        for r in 0..out.rows() {
            for (v, bias) in out.row_mut(r).iter_mut().zip(self.b.iter()) {
                *v += bias;
            }
        }
        out
    }

    /// Class probabilities.
    pub fn predict_proba(&self, x: &Matrix) -> Matrix {
        let mut p = self.logits(x);
        softmax_rows(&mut p);
        p
    }

    /// Hard predictions.
    pub fn predict(&self, x: &Matrix) -> Vec<u8> {
        let p = self.logits(x);
        (0..p.rows())
            .map(|r| crate::linalg::argmax(p.row(r)) as u8)
            .collect()
    }

    /// Mean cross-entropy loss and gradients for a batch.
    ///
    /// Backward pass in closed form: with `P = softmax(XWᵀ+b)` and
    /// one-hot `Y`, `δ = (P − Y)/batch`, `∂L/∂W = δᵀX`, `∂L/∂b = Σᵣ δᵣ`.
    pub fn loss_and_grad(&self, x: &Matrix, labels: &[u8]) -> (f32, Gradients) {
        let batch = x.rows();
        assert_eq!(labels.len(), batch);
        let classes = self.classes();
        let mut delta = self.logits(x);
        // loss from log-softmax before overwriting with probabilities
        let mut loss = 0.0f64;
        for r in 0..batch {
            let row = delta.row(r);
            let lse = crate::linalg::logsumexp(row);
            loss += (lse - row[labels[r] as usize]) as f64;
        }
        loss /= batch as f64;
        softmax_rows(&mut delta);
        let inv = 1.0 / batch as f32;
        for r in 0..batch {
            let row = delta.row_mut(r);
            row[labels[r] as usize] -= 1.0;
            for v in row.iter_mut() {
                *v *= inv;
            }
        }
        // dW = deltaᵀ · X   ((batch,classes)ᵀ·(batch,features))
        let mut dw = Matrix::zeros(classes, self.features());
        gemm_tn(&delta, x, &mut dw);
        let mut db = vec![0.0f32; classes];
        for r in 0..batch {
            for (a, v) in db.iter_mut().zip(delta.row(r)) {
                *a += v;
            }
        }
        (loss as f32, Gradients { dw, db })
    }

    /// Per-shard backward pass for the data-parallel trainer:
    /// accumulate gradient *sums* (not divided by the batch size —
    /// the combiner scales the merged total once per step) over
    /// `rows` pre-featurized rows into `g`, returning the summed
    /// loss and the argmax hit count.
    ///
    /// `feats` is `(rows, features)` row-major; `delta` is
    /// caller-owned scratch of at least `rows × classes`. The row
    /// math matches [`SoftmaxRegression::loss_and_grad`] — logits via
    /// the same [`dot`](crate::linalg::ops::dot) kernel, loss via the
    /// same log-sum-exp — so any shard split agrees with the
    /// full-batch oracle up to summation order.
    pub fn shard_loss_grad_sums(
        &self,
        feats: &[f32],
        rows: usize,
        labels: &[u8],
        delta: &mut [f32],
        g: &mut Gradients,
    ) -> (f64, usize) {
        let classes = self.classes();
        let fdim = self.features();
        assert_eq!(feats.len(), rows * fdim, "shard feature length");
        assert_eq!(labels.len(), rows, "shard label count");
        assert!(delta.len() >= rows * classes, "delta scratch too small");
        assert_eq!(g.dw.shape(), (classes, fdim), "gradient shape");
        let mut loss_sum = 0.0f64;
        let mut hits = 0usize;
        for r in 0..rows {
            let xrow = &feats[r * fdim..(r + 1) * fdim];
            let drow = &mut delta[r * classes..(r + 1) * classes];
            for (c, dv) in drow.iter_mut().enumerate() {
                *dv = crate::linalg::ops::dot(self.w.row(c), xrow) + self.b[c];
            }
            let label = labels[r] as usize;
            hits += usize::from(crate::linalg::argmax(drow) == label);
            let lse = crate::linalg::logsumexp(drow);
            loss_sum += (lse - drow[label]) as f64;
            // softmax through the same log-sum-exp (lse ≥ max ⇒ the
            // exponent is ≤ 0: no overflow)
            for v in drow.iter_mut() {
                *v = (*v - lse).exp();
            }
            drow[label] -= 1.0;
            for (c, &dv) in drow.iter().enumerate() {
                g.db[c] += dv;
                if dv != 0.0 {
                    let wrow = g.dw.row_mut(c);
                    for (o, &xv) in wrow.iter_mut().zip(xrow) {
                        *o += dv * xv;
                    }
                }
            }
        }
        (loss_sum, hits)
    }

    /// Numerical-gradient check helper (tests): loss only.
    pub fn loss(&self, x: &Matrix, labels: &[u8]) -> f32 {
        let mut l = self.logits(x);
        let mut loss = 0.0f64;
        for r in 0..x.rows() {
            let row = l.row_mut(r);
            let lse = crate::linalg::logsumexp(row);
            loss += (lse - row[labels[r] as usize]) as f64;
        }
        (loss / x.rows() as f64) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_batch() -> (Matrix, Vec<u8>) {
        // 4 samples, 3 features, 3 classes — separable
        let x = Matrix::from_vec(
            4,
            3,
            vec![
                1.0, 0.0, 0.0, //
                0.9, 0.1, 0.0, //
                0.0, 1.0, 0.1, //
                0.0, 0.0, 1.0,
            ],
        );
        (x, vec![0, 0, 1, 2])
    }

    #[test]
    fn zero_model_uniform_probs_ln_c_loss() {
        let (x, y) = toy_batch();
        let m = SoftmaxRegression::zeros(3, 3);
        let p = m.predict_proba(&x);
        for r in 0..4 {
            for c in 0..3 {
                assert!((p[(r, c)] - 1.0 / 3.0).abs() < 1e-6);
            }
        }
        let (loss, _) = m.loss_and_grad(&x, &y);
        assert!((loss - (3.0f32).ln()).abs() < 1e-5);
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let (x, y) = toy_batch();
        let mut m = SoftmaxRegression::init(3, 3, 42);
        let (_, g) = m.loss_and_grad(&x, &y);
        let eps = 1e-3f32;
        for idx in [(0usize, 0usize), (1, 2), (2, 1)] {
            let orig = m.w()[idx];
            m.w_mut()[idx] = orig + eps;
            let lp = m.loss(&x, &y);
            m.w_mut()[idx] = orig - eps;
            let lm = m.loss(&x, &y);
            m.w_mut()[idx] = orig;
            let num = (lp - lm) / (2.0 * eps);
            assert!(
                (num - g.dw[idx]).abs() < 1e-3,
                "dW{idx:?}: numeric {num} analytic {}",
                g.dw[idx]
            );
        }
        // bias gradient
        let eps = 1e-3f32;
        let orig = m.b()[1];
        m.b_mut()[1] = orig + eps;
        let lp = m.loss(&x, &y);
        m.b_mut()[1] = orig - eps;
        let lm = m.loss(&x, &y);
        m.b_mut()[1] = orig;
        let num = (lp - lm) / (2.0 * eps);
        assert!((num - g.db[1]).abs() < 1e-3);
    }

    #[test]
    fn grad_rows_sum_to_zero() {
        // Columns of delta sum to 0 across classes ⇒ Σ_c db_c = 0.
        let (x, y) = toy_batch();
        let m = SoftmaxRegression::init(3, 3, 7);
        let (_, g) = m.loss_and_grad(&x, &y);
        let s: f32 = g.db.iter().sum();
        assert!(s.abs() < 1e-6);
    }

    #[test]
    fn sgd_descends_and_learns_toy_problem() {
        let (x, y) = toy_batch();
        let mut m = SoftmaxRegression::zeros(3, 3);
        let mut prev = f32::INFINITY;
        for _ in 0..200 {
            let (loss, g) = m.loss_and_grad(&x, &y);
            assert!(loss <= prev + 1e-4, "loss must not increase: {prev} -> {loss}");
            prev = loss;
            m.w_mut().axpy(-0.5, &g.dw);
            for (b, d) in m.b_mut().iter_mut().zip(&g.db) {
                *b -= 0.5 * d;
            }
        }
        assert_eq!(m.predict(&x), y);
        assert!(prev < 0.2);
    }

    #[test]
    fn gradients_merge_scale_reset() {
        let mut a = Gradients::zeros(2, 3);
        let mut b = Gradients::zeros(2, 3);
        a.dw[(0, 1)] = 2.0;
        a.db[1] = 4.0;
        b.dw[(0, 1)] = 1.0;
        b.db[1] = -1.0;
        a.merge(&b);
        assert_eq!(a.dw[(0, 1)], 3.0);
        assert_eq!(a.db[1], 3.0);
        a.scale(0.5);
        assert_eq!(a.dw[(0, 1)], 1.5);
        assert_eq!(a.db[1], 1.5);
        a.reset();
        assert!(a.dw.data().iter().all(|&v| v == 0.0));
        assert!(a.db.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn shard_sums_match_full_batch_up_to_scaling() {
        let (x, y) = toy_batch();
        let m = SoftmaxRegression::init(3, 3, 42);
        let (loss, g_full) = m.loss_and_grad(&x, &y);
        // two shards: rows 0..2 and 2..4
        let mut g = Gradients::zeros(3, 3);
        let mut delta = vec![0.0f32; 4 * 3];
        let (l0, h0) = m.shard_loss_grad_sums(&x.data()[..2 * 3], 2, &y[..2], &mut delta, &mut g);
        let (l1, h1) = m.shard_loss_grad_sums(&x.data()[2 * 3..], 2, &y[2..], &mut delta, &mut g);
        g.scale(1.0 / 4.0);
        let shard_loss = ((l0 + l1) / 4.0) as f32;
        // 1e-5: the shard path rounds differently (f32 exp(v−lse),
        // sum-then-scale) from the f64-softmax pre-scaled oracle
        assert!((shard_loss - loss).abs() < 1e-5, "{shard_loss} vs {loss}");
        for (a, b) in g.dw.data().iter().zip(g_full.dw.data()) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
        for (a, b) in g.db.iter().zip(&g_full.db) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
        // hit counts come from the same argmax as predict()
        let preds = m.predict(&x);
        let want: usize = preds.iter().zip(&y).filter(|(p, t)| p == t).count();
        assert_eq!(h0 + h1, want);
    }

    #[test]
    fn param_count_eq22() {
        let m = SoftmaxRegression::zeros(10, 2 * 1024 * 4);
        assert_eq!(m.param_count(), 10 * (2 * 1024 * 4 + 1));
    }

    #[test]
    fn init_deterministic() {
        let a = SoftmaxRegression::init(3, 5, 9);
        let b = SoftmaxRegression::init(3, 5, 9);
        assert_eq!(a.w().data(), b.w().data());
        let c = SoftmaxRegression::init(3, 5, 10);
        assert_ne!(a.w().data(), c.w().data());
    }
}
