//! Multinomial logistic (softmax) regression with explicit
//! forward/backward — the paper's linear head (Eq. 23) and its LR
//! baseline. Loss is the multiclass logistic loss (Eq. 20's softmax
//! generalization), minimized by SGD (Eq. 21).

use crate::hash::hash_rng::streams;
use crate::hash::HashRng;
use crate::linalg::ops::{gemm_nt, gemm_tn, softmax_rows};
use crate::linalg::Matrix;

/// `softmax(W x + b)` classifier. `W: (classes, features)`, `b: (classes)`.
#[derive(Debug, Clone)]
pub struct SoftmaxRegression {
    w: Matrix,
    b: Vec<f32>,
}

/// Gradients of the loss w.r.t. `(W, b)`.
#[derive(Debug, Clone)]
pub struct Gradients {
    pub dw: Matrix,
    pub db: Vec<f32>,
}

impl SoftmaxRegression {
    /// Zero-initialized model (convex problem: zeros are a fine start,
    /// and they make runs bit-reproducible trivially).
    pub fn zeros(classes: usize, features: usize) -> SoftmaxRegression {
        SoftmaxRegression { w: Matrix::zeros(classes, features), b: vec![0.0; classes] }
    }

    /// Small hash-seeded Gaussian init (scale `0.01`), for parity with
    /// the Python/JAX L2 model.
    pub fn init(classes: usize, features: usize, seed: u64) -> SoftmaxRegression {
        let rng = HashRng::new(seed, streams::INIT);
        let mut w = Matrix::zeros(classes, features);
        for (k, v) in w.data_mut().iter_mut().enumerate() {
            *v = 0.01 * crate::rand::BoxMuller::at(&rng, k as u64) as f32;
        }
        SoftmaxRegression { w, b: vec![0.0; classes] }
    }

    pub fn classes(&self) -> usize {
        self.w.rows()
    }

    pub fn features(&self) -> usize {
        self.w.cols()
    }

    /// Learned parameter count `C·(features + 1)` (paper Eq. 22 when
    /// `features = 2·[S]₂·E`).
    pub fn param_count(&self) -> usize {
        self.classes() * (self.features() + 1)
    }

    pub fn w(&self) -> &Matrix {
        &self.w
    }

    pub fn b(&self) -> &[f32] {
        &self.b
    }

    pub fn w_mut(&mut self) -> &mut Matrix {
        &mut self.w
    }

    pub fn b_mut(&mut self) -> &mut [f32] {
        &mut self.b
    }

    /// Logits `X·Wᵀ + b` for a `(batch, features)` input.
    pub fn logits(&self, x: &Matrix) -> Matrix {
        assert_eq!(x.cols(), self.features(), "feature width");
        let mut out = Matrix::zeros(x.rows(), self.classes());
        gemm_nt(x, &self.w, &mut out);
        for r in 0..out.rows() {
            for (v, bias) in out.row_mut(r).iter_mut().zip(self.b.iter()) {
                *v += bias;
            }
        }
        out
    }

    /// Class probabilities.
    pub fn predict_proba(&self, x: &Matrix) -> Matrix {
        let mut p = self.logits(x);
        softmax_rows(&mut p);
        p
    }

    /// Hard predictions.
    pub fn predict(&self, x: &Matrix) -> Vec<u8> {
        let p = self.logits(x);
        (0..p.rows())
            .map(|r| crate::linalg::argmax(p.row(r)) as u8)
            .collect()
    }

    /// Mean cross-entropy loss and gradients for a batch.
    ///
    /// Backward pass in closed form: with `P = softmax(XWᵀ+b)` and
    /// one-hot `Y`, `δ = (P − Y)/batch`, `∂L/∂W = δᵀX`, `∂L/∂b = Σᵣ δᵣ`.
    pub fn loss_and_grad(&self, x: &Matrix, labels: &[u8]) -> (f32, Gradients) {
        let batch = x.rows();
        assert_eq!(labels.len(), batch);
        let classes = self.classes();
        let mut delta = self.logits(x);
        // loss from log-softmax before overwriting with probabilities
        let mut loss = 0.0f64;
        for r in 0..batch {
            let row = delta.row(r);
            let lse = crate::linalg::logsumexp(row);
            loss += (lse - row[labels[r] as usize]) as f64;
        }
        loss /= batch as f64;
        softmax_rows(&mut delta);
        let inv = 1.0 / batch as f32;
        for r in 0..batch {
            let row = delta.row_mut(r);
            row[labels[r] as usize] -= 1.0;
            for v in row.iter_mut() {
                *v *= inv;
            }
        }
        // dW = deltaᵀ · X   ((batch,classes)ᵀ·(batch,features))
        let mut dw = Matrix::zeros(classes, self.features());
        gemm_tn(&delta, x, &mut dw);
        let mut db = vec![0.0f32; classes];
        for r in 0..batch {
            for (a, v) in db.iter_mut().zip(delta.row(r)) {
                *a += v;
            }
        }
        (loss as f32, Gradients { dw, db })
    }

    /// Numerical-gradient check helper (tests): loss only.
    pub fn loss(&self, x: &Matrix, labels: &[u8]) -> f32 {
        let mut l = self.logits(x);
        let mut loss = 0.0f64;
        for r in 0..x.rows() {
            let row = l.row_mut(r);
            let lse = crate::linalg::logsumexp(row);
            loss += (lse - row[labels[r] as usize]) as f64;
        }
        (loss / x.rows() as f64) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_batch() -> (Matrix, Vec<u8>) {
        // 4 samples, 3 features, 3 classes — separable
        let x = Matrix::from_vec(
            4,
            3,
            vec![
                1.0, 0.0, 0.0, //
                0.9, 0.1, 0.0, //
                0.0, 1.0, 0.1, //
                0.0, 0.0, 1.0,
            ],
        );
        (x, vec![0, 0, 1, 2])
    }

    #[test]
    fn zero_model_uniform_probs_ln_c_loss() {
        let (x, y) = toy_batch();
        let m = SoftmaxRegression::zeros(3, 3);
        let p = m.predict_proba(&x);
        for r in 0..4 {
            for c in 0..3 {
                assert!((p[(r, c)] - 1.0 / 3.0).abs() < 1e-6);
            }
        }
        let (loss, _) = m.loss_and_grad(&x, &y);
        assert!((loss - (3.0f32).ln()).abs() < 1e-5);
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let (x, y) = toy_batch();
        let mut m = SoftmaxRegression::init(3, 3, 42);
        let (_, g) = m.loss_and_grad(&x, &y);
        let eps = 1e-3f32;
        for idx in [(0usize, 0usize), (1, 2), (2, 1)] {
            let orig = m.w()[idx];
            m.w_mut()[idx] = orig + eps;
            let lp = m.loss(&x, &y);
            m.w_mut()[idx] = orig - eps;
            let lm = m.loss(&x, &y);
            m.w_mut()[idx] = orig;
            let num = (lp - lm) / (2.0 * eps);
            assert!(
                (num - g.dw[idx]).abs() < 1e-3,
                "dW{idx:?}: numeric {num} analytic {}",
                g.dw[idx]
            );
        }
        // bias gradient
        let eps = 1e-3f32;
        let orig = m.b()[1];
        m.b_mut()[1] = orig + eps;
        let lp = m.loss(&x, &y);
        m.b_mut()[1] = orig - eps;
        let lm = m.loss(&x, &y);
        m.b_mut()[1] = orig;
        let num = (lp - lm) / (2.0 * eps);
        assert!((num - g.db[1]).abs() < 1e-3);
    }

    #[test]
    fn grad_rows_sum_to_zero() {
        // Columns of delta sum to 0 across classes ⇒ Σ_c db_c = 0.
        let (x, y) = toy_batch();
        let m = SoftmaxRegression::init(3, 3, 7);
        let (_, g) = m.loss_and_grad(&x, &y);
        let s: f32 = g.db.iter().sum();
        assert!(s.abs() < 1e-6);
    }

    #[test]
    fn sgd_descends_and_learns_toy_problem() {
        let (x, y) = toy_batch();
        let mut m = SoftmaxRegression::zeros(3, 3);
        let mut prev = f32::INFINITY;
        for _ in 0..200 {
            let (loss, g) = m.loss_and_grad(&x, &y);
            assert!(loss <= prev + 1e-4, "loss must not increase: {prev} -> {loss}");
            prev = loss;
            m.w_mut().axpy(-0.5, &g.dw);
            for (b, d) in m.b_mut().iter_mut().zip(&g.db) {
                *b -= 0.5 * d;
            }
        }
        assert_eq!(m.predict(&x), y);
        assert!(prev < 0.2);
    }

    #[test]
    fn param_count_eq22() {
        let m = SoftmaxRegression::zeros(10, 2 * 1024 * 4);
        assert_eq!(m.param_count(), 10 * (2 * 1024 * 4 + 1));
    }

    #[test]
    fn init_deterministic() {
        let a = SoftmaxRegression::init(3, 5, 9);
        let b = SoftmaxRegression::init(3, 5, 9);
        assert_eq!(a.w().data(), b.w().data());
        let c = SoftmaxRegression::init(3, 5, 10);
        assert_ne!(a.w().data(), c.w().data());
    }
}
