//! Binary checkpoints: JSON header + little-endian f32 payload.
//!
//! Only the *learned* parameters (W, b) are stored — the feature map
//! is reconstructed from its config (the paper's compact-model story:
//! "no need to save the coefficients generated for McKernel when
//! deploying", §6).

use crate::linalg::Matrix;
use crate::mckernel::{Kernel, McKernelConfig};
use crate::model::SoftmaxRegression;
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"MCKCKPT1";

/// Everything needed to reconstruct an inference pipeline.
#[derive(Debug, Clone)]
pub struct Checkpoint {
    /// Feature-map config (`None` = raw-pixel LR baseline).
    pub feature_config: Option<McKernelConfig>,
    /// The linear head.
    pub model: SoftmaxRegression,
    /// Training metadata (epochs run, final loss, …) — free-form.
    pub meta: BTreeMap<String, Json>,
}

fn config_to_json(c: &McKernelConfig) -> Json {
    let mut m = BTreeMap::new();
    m.insert("input_dim".into(), Json::Num(c.input_dim as f64));
    m.insert("expansions".into(), Json::Num(c.expansions as f64));
    m.insert("sigma".into(), Json::Num(c.sigma));
    m.insert("kernel".into(), Json::Str(c.kernel.name().into()));
    if let Kernel::RbfMatern { t } = c.kernel {
        m.insert("matern_t".into(), Json::Num(t as f64));
    }
    m.insert("seed".into(), Json::Num(c.seed as f64));
    Json::Obj(m)
}

fn config_from_json(j: &Json) -> Result<McKernelConfig> {
    let get = |k: &str| j.get(k).with_context(|| format!("missing config key {k}"));
    let kernel = match get("kernel")?.as_str().context("kernel type")? {
        "rbf" => Kernel::Rbf,
        "rbf_matern" => Kernel::RbfMatern {
            t: get("matern_t")?.as_usize().context("matern_t")? as u32,
        },
        other => bail!("unknown kernel '{other}'"),
    };
    Ok(McKernelConfig {
        input_dim: get("input_dim")?.as_usize().context("input_dim")?,
        expansions: get("expansions")?.as_usize().context("expansions")?,
        sigma: get("sigma")?.as_f64().context("sigma")?,
        kernel,
        seed: get("seed")?.as_f64().context("seed")? as u64,
    })
}

impl Checkpoint {
    /// Serialize: magic, u32 header length, JSON header, then W then b
    /// as little-endian f32.
    pub fn write_to<W: Write>(&self, mut w: W) -> Result<()> {
        let mut head = BTreeMap::new();
        head.insert("classes".into(), Json::Num(self.model.classes() as f64));
        head.insert("features".into(), Json::Num(self.model.features() as f64));
        if let Some(fc) = &self.feature_config {
            head.insert("feature_config".into(), config_to_json(fc));
        }
        head.insert("meta".into(), Json::Obj(self.meta.clone()));
        let header = Json::Obj(head).to_string();
        w.write_all(MAGIC)?;
        w.write_all(&(header.len() as u32).to_le_bytes())?;
        w.write_all(header.as_bytes())?;
        for v in self.model.w().data() {
            w.write_all(&v.to_le_bytes())?;
        }
        for v in self.model.b() {
            w.write_all(&v.to_le_bytes())?;
        }
        Ok(())
    }

    /// Deserialize (format produced by [`Checkpoint::write_to`]).
    pub fn read_from<R: Read>(mut r: R) -> Result<Checkpoint> {
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic).context("checkpoint magic")?;
        if &magic != MAGIC {
            bail!("not a McKernel checkpoint");
        }
        let mut len = [0u8; 4];
        r.read_exact(&mut len)?;
        let mut header = vec![0u8; u32::from_le_bytes(len) as usize];
        r.read_exact(&mut header).context("checkpoint header")?;
        let head = Json::parse(std::str::from_utf8(&header)?).context("header JSON")?;
        let classes = head.get("classes").and_then(Json::as_usize).context("classes")?;
        let features = head.get("features").and_then(Json::as_usize).context("features")?;
        let feature_config = match head.get("feature_config") {
            Some(fc) => Some(config_from_json(fc)?),
            None => None,
        };
        let meta = head
            .get("meta")
            .and_then(Json::as_obj)
            .cloned()
            .unwrap_or_default();
        let mut buf = vec![0u8; (classes * features + classes) * 4];
        r.read_exact(&mut buf).context("checkpoint payload")?;
        let floats: Vec<f32> = buf
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        let (wdata, bdata) = floats.split_at(classes * features);
        let mut model = SoftmaxRegression::zeros(classes, features);
        model.w_mut().data_mut().copy_from_slice(wdata);
        model.b_mut().copy_from_slice(bdata);
        let _ = Matrix::zeros(0, 0); // keep Matrix import honest
        Ok(Checkpoint { feature_config, model, meta })
    }

    /// Build a mid-training checkpoint: model + feature config with
    /// the resume cursor set to `completed_epochs` — what the trainer
    /// autosaves after each epoch so `fit_auto` can pick up a killed
    /// run.
    pub fn for_training(
        feature_config: Option<McKernelConfig>,
        model: SoftmaxRegression,
        completed_epochs: usize,
    ) -> Checkpoint {
        Checkpoint { feature_config, model, meta: BTreeMap::new() }.with_epoch(completed_epochs)
    }

    /// Record the number of completed epochs in the metadata — the
    /// resume cursor read back by [`Checkpoint::epoch`] and passed to
    /// `ParallelTrainer::fit_resume`.
    pub fn with_epoch(mut self, epoch: usize) -> Checkpoint {
        self.meta.insert("epoch".into(), Json::Num(epoch as f64));
        self
    }

    /// Completed-epoch resume cursor, if recorded.
    pub fn epoch(&self) -> Option<usize> {
        self.meta.get("epoch").and_then(Json::as_usize)
    }

    /// Save to a file.
    pub fn save<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let f = std::fs::File::create(&path)
            .with_context(|| format!("create {}", path.as_ref().display()))?;
        self.write_to(std::io::BufWriter::new(f))
    }

    /// Load from a file.
    pub fn load<P: AsRef<Path>>(path: P) -> Result<Checkpoint> {
        let f = std::fs::File::open(&path)
            .with_context(|| format!("open {}", path.as_ref().display()))?;
        Checkpoint::read_from(std::io::BufReader::new(f))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        let mut model = SoftmaxRegression::zeros(3, 5);
        for (i, v) in model.w_mut().data_mut().iter_mut().enumerate() {
            *v = i as f32 * 0.5 - 3.0;
        }
        model.b_mut()[1] = 9.25;
        let mut meta = BTreeMap::new();
        meta.insert("epochs".into(), Json::Num(20.0));
        Checkpoint {
            feature_config: Some(McKernelConfig {
                input_dim: 784,
                expansions: 4,
                sigma: 1.0,
                kernel: Kernel::RbfMatern { t: 40 },
                seed: 1398239763,
            }),
            model,
            meta,
        }
    }

    #[test]
    fn roundtrip_bytes() {
        let ck = sample();
        let mut buf = Vec::new();
        ck.write_to(&mut buf).unwrap();
        let back = Checkpoint::read_from(&buf[..]).unwrap();
        assert_eq!(back.model.w().data(), ck.model.w().data());
        assert_eq!(back.model.b(), ck.model.b());
        assert_eq!(back.feature_config, ck.feature_config);
        assert_eq!(back.meta.get("epochs"), Some(&Json::Num(20.0)));
    }

    #[test]
    fn roundtrip_file() {
        let dir = std::env::temp_dir().join("mckernel_ckpt_test");
        let p = dir.join("model.mck");
        let ck = sample();
        ck.save(&p).unwrap();
        let back = Checkpoint::load(&p).unwrap();
        assert_eq!(back.model.w().data(), ck.model.w().data());
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn lr_baseline_without_feature_config() {
        let ck = Checkpoint {
            feature_config: None,
            model: SoftmaxRegression::zeros(10, 784),
            meta: BTreeMap::new(),
        };
        let mut buf = Vec::new();
        ck.write_to(&mut buf).unwrap();
        let back = Checkpoint::read_from(&buf[..]).unwrap();
        assert!(back.feature_config.is_none());
        assert_eq!(back.model.features(), 784);
    }

    #[test]
    fn epoch_cursor_roundtrips() {
        let ck = sample().with_epoch(7);
        assert_eq!(ck.epoch(), Some(7));
        let mut buf = Vec::new();
        ck.write_to(&mut buf).unwrap();
        let back = Checkpoint::read_from(&buf[..]).unwrap();
        assert_eq!(back.epoch(), Some(7));
        assert_eq!(sample().epoch(), None);
    }

    #[test]
    fn for_training_sets_cursor() {
        let ck = Checkpoint::for_training(None, SoftmaxRegression::zeros(3, 4), 5);
        assert_eq!(ck.epoch(), Some(5));
        assert!(ck.feature_config.is_none());
        assert_eq!(ck.model.features(), 4);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Checkpoint::read_from(&b"NOTACKPT"[..]).is_err());
        assert!(Checkpoint::read_from(&b"MCKCKPT1\xff\xff\xff\xff"[..]).is_err());
    }

    #[test]
    fn feature_map_reconstruction_matches() {
        // The checkpoint's promise: rebuilding the map from config
        // yields the identical featurizer.
        let ck = sample();
        let cfg = ck.feature_config.clone().unwrap();
        let a = crate::mckernel::McKernel::new(cfg.clone());
        let b = crate::mckernel::McKernel::new(cfg);
        let x: Vec<f32> = (0..784).map(|i| (i % 255) as f32 / 255.0).collect();
        assert_eq!(a.transform(&x), b.transform(&x));
    }
}
