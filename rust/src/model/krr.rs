//! Learning with kernels, exactly as in paper §2: kernel ridge
//! regression `f(x) = Σ t_z k(x_z, x)` with `(nγI + K)t = y` (Eq. 1–2),
//! the V-matrix invariant generalization `(nγI + VK)t = Vy` (Eq. 4–5),
//! and the random-features approximation that replaces `K` with
//! `Φ Φᵀ` — demonstrating the paper's core promise that McKernel
//! features "obviate the need for explicit kernel computations".

use crate::linalg::cholesky::solve_spd;
use crate::linalg::ops::gemm_nt;
use crate::linalg::Matrix;
use crate::mckernel::{ExpansionEngine, Kernel, McKernel};
use anyhow::{ensure, Result};

/// Exact kernel ridge regression (paper Eq. 1–2).
pub struct KernelRidge {
    kernel: Kernel,
    sigma: f64,
    gamma: f64,
    x_train: Matrix,
    t: Vec<f32>,
}

impl KernelRidge {
    /// Fit `(nγI + K)t = y` (Eq. 2) by Cholesky.
    pub fn fit(kernel: Kernel, sigma: f64, gamma: f64, x: &Matrix, y: &[f32]) -> Result<KernelRidge> {
        let n = x.rows();
        ensure!(n == y.len(), "sample/label mismatch");
        ensure!(gamma > 0.0, "gamma must be positive (well-posedness, §2)");
        let mut k = Matrix::from_fn(n, n, |i, j| kernel.exact(x.row(i), x.row(j), sigma) as f32);
        for i in 0..n {
            k[(i, i)] += (n as f64 * gamma) as f32;
        }
        let t = solve_spd(&k, y)?;
        Ok(KernelRidge { kernel, sigma, gamma, x_train: x.clone(), t })
    }

    /// Fit the V-matrix variant `(nγI + VK)t = Vy` (paper Eq. 4):
    /// mutual-position weighting via `V(c,z) = Σ_k (t_k − max(x_c^k, x_z^k))`
    /// (Eq. 5) with `t_k = 1` for data in `[0,1]^d`. `VK` is not
    /// symmetric in general; we solve the symmetrized normal form.
    pub fn fit_with_invariants(
        kernel: Kernel,
        sigma: f64,
        gamma: f64,
        x: &Matrix,
        y: &[f32],
    ) -> Result<KernelRidge> {
        let n = x.rows();
        ensure!(n == y.len(), "sample/label mismatch");
        let d = x.cols();
        // V(c,z) per Eq. 5 (t_k = 1; inputs expected in [0,1])
        let v = Matrix::from_fn(n, n, |c, z| {
            let mut s = 0.0f32;
            for k in 0..d {
                s += 1.0 - x.row(c)[k].max(x.row(z)[k]);
            }
            s / d as f32 // normalize so V ~ O(1)
        });
        let km = Matrix::from_fn(n, n, |i, j| kernel.exact(x.row(i), x.row(j), sigma) as f32);
        // A = nγI + VK ; solve AᵀA t = Aᵀ V y  (SPD normal equations)
        let mut vk = Matrix::zeros(n, n);
        crate::linalg::gemm(&v, &km, &mut vk);
        for i in 0..n {
            vk[(i, i)] += (n as f64 * gamma) as f32;
        }
        let mut vy = vec![0.0f32; n];
        crate::linalg::gemv(&v, y, &mut vy);
        let mut ata = Matrix::zeros(n, n);
        crate::linalg::ops::gemm_tn(&vk, &vk, &mut ata);
        let vkt = vk.transpose();
        let mut rhs = vec![0.0f32; n];
        crate::linalg::gemv(&vkt, &vy, &mut rhs);
        // Jitter the normal equations relative to their scale (f32
        // Cholesky on AᵀA squares the condition number), growing until
        // the factorization succeeds.
        let mean_diag: f32 = (0..n).map(|i| ata[(i, i)]).sum::<f32>() / n as f32;
        let mut jitter = 1e-6 * mean_diag.max(1e-12);
        let t = loop {
            let mut reg = ata.clone();
            for i in 0..n {
                reg[(i, i)] += jitter;
            }
            match solve_spd(&reg, &rhs) {
                Ok(t) => break t,
                Err(_) if jitter < mean_diag => jitter *= 10.0,
                Err(e) => return Err(e),
            }
        };
        Ok(KernelRidge { kernel, sigma, gamma, x_train: x.clone(), t })
    }

    /// `f(x) = Σ_z t_z k(x_z, x)` (Eq. 1).
    pub fn predict_one(&self, x: &[f32]) -> f32 {
        self.t
            .iter()
            .enumerate()
            .map(|(z, &tz)| tz * self.kernel.exact(self.x_train.row(z), x, self.sigma) as f32)
            .sum()
    }

    /// Batch prediction.
    pub fn predict(&self, x: &Matrix) -> Vec<f32> {
        (0..x.rows()).map(|r| self.predict_one(x.row(r))).collect()
    }

    /// Regularization strength γ.
    pub fn gamma(&self) -> f64 {
        self.gamma
    }
}

/// Ridge regression on McKernel random features: `K ≈ Φ Φᵀ` with
/// `Φ = φ̄(X)` — linear-time in n for fitting the primal weights.
pub struct FeatureRidge {
    w: Vec<f32>,
}

impl FeatureRidge {
    /// Fit primal ridge `(ΦᵀΦ + λI) w = Φᵀ y` over normalized McKernel
    /// features.
    pub fn fit(map: &McKernel, lambda: f64, x: &Matrix, y: &[f32]) -> Result<FeatureRidge> {
        ensure!(x.rows() == y.len());
        let phi = normalized_features(map, x);
        let d = phi.cols();
        // Gram in feature space
        let phit = phi.transpose();
        let mut gram = Matrix::zeros(d, d);
        crate::linalg::ops::gemm_tn(&phi, &phi, &mut gram);
        for i in 0..d {
            gram[(i, i)] += lambda as f32;
        }
        let mut rhs = vec![0.0f32; d];
        crate::linalg::gemv(&phit, y, &mut rhs);
        let w = solve_spd(&gram, &rhs)?;
        Ok(FeatureRidge { w })
    }

    /// `f(x) = ⟨w, φ̄(x)⟩`.
    pub fn predict(&self, map: &McKernel, x: &Matrix) -> Vec<f32> {
        let phi = normalized_features(map, x);
        let mut out = Matrix::zeros(x.rows(), 1);
        let wm = Matrix::from_vec(1, self.w.len(), self.w.clone());
        gemm_nt(&phi, &wm, &mut out);
        out.into_vec()
    }
}

fn normalized_features(map: &McKernel, x: &Matrix) -> Matrix {
    // compiled engine path with the 1/√(n·E) estimator scaling folded
    // into the feature write by the plan — no second pass over Φ
    let mut phi = Matrix::zeros(x.rows(), map.feature_dim());
    ExpansionEngine::normalized(map, x.rows()).execute_matrix(map, x, &mut phi);
    phi
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mckernel::McKernelFactory;

    /// Smooth 1-target regression problem on [0,1]^d.
    fn problem(n: usize, d: usize, seed: u64) -> (Matrix, Vec<f32>) {
        let mut rng = crate::hash::HashRng::new(seed, 0x12);
        let x = Matrix::from_fn(n, d, |_, _| rng.next_f32());
        let y: Vec<f32> = (0..n)
            .map(|i| {
                let r = x.row(i);
                (2.0 * std::f32::consts::PI * r[0]).sin() + r[1 % d]
            })
            .collect();
        (x, y)
    }

    #[test]
    fn krr_interpolates_training_data_with_small_gamma() {
        let (x, y) = problem(40, 2, 1);
        let m = KernelRidge::fit(Kernel::Rbf, 0.5, 1e-6, &x, &y).unwrap();
        let pred = m.predict(&x);
        let mse: f32 = pred.iter().zip(&y).map(|(p, t)| (p - t) * (p - t)).sum::<f32>() / 40.0;
        assert!(mse < 1e-3, "train mse {mse}");
    }

    #[test]
    fn krr_generalizes_smooth_function() {
        let (x, y) = problem(120, 2, 2);
        let (xt, yt) = problem(40, 2, 3);
        let m = KernelRidge::fit(Kernel::Rbf, 0.5, 1e-4, &x, &y).unwrap();
        let pred = m.predict(&xt);
        let mse: f32 = pred.iter().zip(&yt).map(|(p, t)| (p - t) * (p - t)).sum::<f32>() / 40.0;
        assert!(mse < 0.05, "test mse {mse}");
    }

    #[test]
    fn gamma_controls_smoothing() {
        // Large gamma shrinks the fit toward zero (Eq. 2's nγI term).
        let (x, y) = problem(30, 2, 4);
        let tight = KernelRidge::fit(Kernel::Rbf, 0.5, 1e-6, &x, &y).unwrap();
        let smooth = KernelRidge::fit(Kernel::Rbf, 0.5, 10.0, &x, &y).unwrap();
        let norm = |p: &[f32]| p.iter().map(|v| v * v).sum::<f32>();
        assert!(norm(&smooth.predict(&x)) < norm(&tight.predict(&x)) * 0.5);
    }

    #[test]
    fn invariant_variant_runs_and_fits() {
        let (x, y) = problem(40, 2, 5);
        let m = KernelRidge::fit_with_invariants(Kernel::Rbf, 0.5, 1e-3, &x, &y).unwrap();
        let pred = m.predict(&x);
        let mse: f32 = pred.iter().zip(&y).map(|(p, t)| (p - t) * (p - t)).sum::<f32>() / 40.0;
        assert!(mse < 0.2, "train mse {mse}");
    }

    #[test]
    fn feature_ridge_approximates_exact_krr() {
        // The paper's pitch: Φ Φᵀ ≈ K, so primal ridge on McKernel
        // features tracks exact KRR.
        let (x, y) = problem(100, 2, 6);
        let (xt, _) = problem(30, 2, 7);
        let exact = KernelRidge::fit(Kernel::Rbf, 0.5, 1e-3, &x, &y).unwrap();
        let map = McKernelFactory::new(2).expansions(64).sigma(0.5).rbf().seed(8).build();
        let approx = FeatureRidge::fit(&map, 100.0 * 1e-3, &x, &y).unwrap();
        let pe = exact.predict(&xt);
        let pa = approx.predict(&map, &xt);
        let corr = {
            let me = pe.iter().sum::<f32>() / pe.len() as f32;
            let ma = pa.iter().sum::<f32>() / pa.len() as f32;
            let cov: f32 = pe.iter().zip(&pa).map(|(a, b)| (a - me) * (b - ma)).sum();
            let va: f32 = pe.iter().map(|a| (a - me) * (a - me)).sum();
            let vb: f32 = pa.iter().map(|b| (b - ma) * (b - ma)).sum();
            cov / (va.sqrt() * vb.sqrt())
        };
        assert!(corr > 0.9, "exact-vs-features prediction correlation {corr}");
    }
}
