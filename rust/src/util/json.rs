//! Minimal JSON parser/writer — just enough for the artifact manifest
//! (`artifacts/manifest.json`) written by `python/compile/aot.py`.
//! No external crates are reachable offline, so this is an in-tree
//! substrate: full JSON value model, recursive-descent parser with
//! escapes and numbers, and a stable writer used by checkpoint
//! metadata.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { s: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.s.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// String value.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Numeric value.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Numeric value as usize (must be a non-negative integer).
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    /// Array elements.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Object map.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Boolean value.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    s: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.s.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.s[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(a)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                            code = code * 16 + d;
                        }
                        // no surrogate-pair handling: manifest is ASCII
                        out.push(char::from_u32(code).ok_or_else(|| self.err("bad codepoint"))?);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // reassemble UTF-8 multibyte sequence
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        0xF0..=0xF7 => 4,
                        _ => return Err(self.err("bad UTF-8")),
                    };
                    let start = self.pos - 1;
                    for _ in 1..len {
                        self.bump().ok_or_else(|| self.err("bad UTF-8"))?;
                    }
                    let s = std::str::from_utf8(&self.s[start..self.pos])
                        .map_err(|_| self.err("bad UTF-8"))?;
                    out.push_str(s);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.s[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

/// Serialize (stable key order — `Obj` is a BTreeMap).
impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\r' => write!(f, "\\r")?,
                        '\t' => write!(f, "\\t")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
        assert_eq!(v.get("d"), Some(&Json::Null));
    }

    #[test]
    fn parse_escapes() {
        let v = Json::parse(r#""a\nb\t\"c\" A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"c\" A"));
    }

    #[test]
    fn parse_unicode_passthrough() {
        let v = Json::parse("\"Matérn σ\"").unwrap();
        assert_eq!(v.as_str(), Some("Matérn σ"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn display_roundtrip() {
        let src = r#"{"batch":10,"dims":[1024,2048],"kernel":"rbf_matern","ok":true,"sigma":1.0}"#;
        let v = Json::parse(src).unwrap();
        let printed = v.to_string();
        let v2 = Json::parse(&printed).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn accessor_types() {
        let v = Json::parse(r#"{"n": 10, "f": 1.5, "s": "x", "b": true}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_usize(), Some(10));
        assert_eq!(v.get("f").unwrap().as_usize(), None);
        assert_eq!(v.get("f").unwrap().as_f64(), Some(1.5));
        assert_eq!(v.get("b").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("s").unwrap().as_f64(), None);
        assert!(v.as_obj().is_some());
        assert!(v.get("missing").is_none());
    }

    #[test]
    fn whitespace_tolerant() {
        let v = Json::parse(" {\n\t\"a\" : [ 1 , 2 ] \r\n} ").unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn integer_display_has_no_fraction() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.25).to_string(), "3.25");
    }
}
