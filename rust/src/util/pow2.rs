//! Power-of-two helpers. The paper's operator `[·]₂` (Eq. 22) pads the
//! input dimension to the next power of two, which is [`next_pow2`].

/// The next power of two ≥ `n` (the paper's `[n]₂`). `next_pow2(0) == 1`.
pub fn next_pow2(n: usize) -> usize {
    n.max(1).next_power_of_two()
}

/// Whether `n` is a power of two (0 is not).
pub fn is_pow2(n: usize) -> bool {
    n != 0 && n & (n - 1) == 0
}

/// `log₂ n` for exact powers of two.
///
/// # Panics
/// If `n` is not a power of two.
pub fn log2_exact(n: usize) -> u32 {
    assert!(is_pow2(n), "{n} is not a power of two");
    n.trailing_zeros()
}

/// Zero-pad `x` to the next power of two (paper Figure 1: "the original
/// image is padded in form of long vector to the nearest power of 2").
pub fn pad_pow2(x: &[f32]) -> Vec<f32> {
    let n = next_pow2(x.len());
    let mut out = vec![0.0f32; n];
    out[..x.len()].copy_from_slice(x);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn next_pow2_values() {
        assert_eq!(next_pow2(0), 1);
        assert_eq!(next_pow2(1), 1);
        assert_eq!(next_pow2(2), 2);
        assert_eq!(next_pow2(3), 4);
        assert_eq!(next_pow2(784), 1024); // MNIST image
        assert_eq!(next_pow2(1024), 1024);
        assert_eq!(next_pow2(1025), 2048);
    }

    #[test]
    fn is_pow2_values() {
        assert!(!is_pow2(0));
        assert!(is_pow2(1));
        assert!(is_pow2(4096));
        assert!(!is_pow2(4097));
        assert!(!is_pow2(usize::MAX));
    }

    #[test]
    fn log2_exact_values() {
        assert_eq!(log2_exact(1), 0);
        assert_eq!(log2_exact(1024), 10);
        assert_eq!(log2_exact(1 << 20), 20);
    }

    #[test]
    #[should_panic]
    fn log2_rejects_non_pow2() {
        log2_exact(12);
    }

    #[test]
    fn pad_preserves_prefix_and_zeroes_tail() {
        let x = [1.0f32, 2.0, 3.0];
        let p = pad_pow2(&x);
        assert_eq!(p.len(), 4);
        assert_eq!(&p[..3], &x);
        assert_eq!(p[3], 0.0);
        // already a power of two → unchanged
        let y = [1.0f32, 2.0];
        assert_eq!(pad_pow2(&y), vec![1.0, 2.0]);
    }
}
