//! Fast, vectorizable transcendental kernels.
//!
//! `f32::sin_cos` goes through libm one call per element, and the trig
//! map dominates the per-sample feature profile (see
//! [`crate::mckernel::feature_map`]). The kernel here is the classic
//! Cody–Waite + minimax-polynomial design (cf. cephes `sinf`/`cosf`):
//! reduce by multiples of π/2 with a three-term split constant,
//! evaluate degree-7/8 polynomials on `|r| ≤ π/4`, and select/sign the
//! (sin, cos) pair from the quadrant index. The loop body is
//! straight-line with branchless selects, so rustc auto-vectorizes it
//! across a batch.
//!
//! Accuracy: max abs error ≈ 1e-7 against libm for `|x| ≤ 5·10³`
//! (validated in tests — well inside the ≤1e-5 budget of the batched
//! feature pipeline); the reduction degrades gracefully beyond that as
//! `q·ulp(π/2)` grows.

/// 2/π.
const FRAC_2_PI: f32 = 0.636_619_772_367_581_34;

// π/2 split into three summands (Cody–Waite): A+B+C ≈ π/2 with each
// term short enough that `q·A`, `q·B` are exact for small `q`, so
// `((x − q·A) − q·B) − q·C` keeps ~7 extra bits over a single-constant
// reduction.
const PI2_A: f32 = 1.570_312_5;
const PI2_B: f32 = 4.837_512_969_970_703_125e-4;
const PI2_C: f32 = 7.549_789_954_891_88e-8;

// Minimax polynomial coefficients on |r| ≤ π/4 (cephes sinf/cosf).
const S1: f32 = -1.666_665_461_1e-1;
const S2: f32 = 8.332_160_873_6e-3;
const S3: f32 = -1.951_529_589_1e-4;
const C1: f32 = 4.166_664_568_298_827e-2;
const C2: f32 = -1.388_731_625_493_765e-3;
const C3: f32 = 2.443_315_711_809_948e-5;

/// `(sin x, cos x)` by range reduction + polynomial evaluation — see
/// the module docs for the accuracy contract.
#[inline(always)]
pub fn sin_cos(x: f32) -> (f32, f32) {
    let q = (x * FRAC_2_PI).round();
    let r = ((x - q * PI2_A) - q * PI2_B) - q * PI2_C;
    let m = (q as i32) & 3;
    let r2 = r * r;
    let sp = r + r * r2 * (S1 + r2 * (S2 + r2 * S3));
    let cp = 1.0 - 0.5 * r2 + r2 * r2 * (C1 + r2 * (C2 + r2 * C3));
    // quadrant m: sin = [s, c, -s, -c][m], cos = [c, -s, -c, s][m]
    let (sm, cm) = if m & 1 == 0 { (sp, cp) } else { (cp, sp) };
    let s = if m & 2 == 0 { sm } else { -sm };
    let c = if (m + 1) & 2 == 0 { cm } else { -cm };
    (s, c)
}

/// Elementwise `sin`/`cos` of `x` into two equal-length output slices.
pub fn sin_cos_batch(x: &[f32], sin_out: &mut [f32], cos_out: &mut [f32]) {
    assert_eq!(x.len(), sin_out.len(), "sin output length");
    assert_eq!(x.len(), cos_out.len(), "cos output length");
    for ((v, s), c) in x.iter().zip(sin_out.iter_mut()).zip(cos_out.iter_mut()) {
        let (sv, cv) = sin_cos(*v);
        *s = sv;
        *c = cv;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hash::HashRng;

    fn check_range(seed: u64, count: usize, half_width: f32, tol: f64) {
        let mut r = HashRng::new(seed, 0xFA);
        for _ in 0..count {
            let x = (r.next_f32() - 0.5) * 2.0 * half_width;
            let (s, c) = sin_cos(x);
            let xd = x as f64;
            assert!(
                (s as f64 - xd.sin()).abs() < tol,
                "sin({x}) = {s}, want {}",
                xd.sin()
            );
            assert!(
                (c as f64 - xd.cos()).abs() < tol,
                "cos({x}) = {c}, want {}",
                xd.cos()
            );
        }
    }

    #[test]
    fn reduced_range_is_tight() {
        // |x| ≤ π/4: pure polynomial error, no reduction involved.
        check_range(1, 20_000, std::f32::consts::FRAC_PI_4, 1e-6);
    }

    #[test]
    fn typical_feature_range() {
        // |Ẑx| values the feature map actually produces.
        check_range(2, 20_000, 20.0, 1e-5);
    }

    #[test]
    fn wide_range_within_budget() {
        check_range(3, 50_000, 500.0, 1e-5);
    }

    #[test]
    fn pythagorean_identity() {
        let mut r = HashRng::new(4, 0xFB);
        for _ in 0..10_000 {
            let x = (r.next_f32() - 0.5) * 100.0;
            let (s, c) = sin_cos(x);
            assert!((s * s + c * c - 1.0).abs() < 1e-5, "x={x}");
        }
    }

    #[test]
    fn quadrant_landmarks() {
        use std::f32::consts::PI;
        for (x, ws, wc) in [
            (0.0f32, 0.0f32, 1.0f32),
            (PI / 2.0, 1.0, 0.0),
            (PI, 0.0, -1.0),
            (3.0 * PI / 2.0, -1.0, 0.0),
            (-PI / 2.0, -1.0, 0.0),
            (2.0 * PI, 0.0, 1.0),
        ] {
            let (s, c) = sin_cos(x);
            assert!((s - ws).abs() < 1e-6, "sin({x}) = {s}, want {ws}");
            assert!((c - wc).abs() < 1e-6, "cos({x}) = {c}, want {wc}");
        }
    }

    #[test]
    fn batch_matches_scalar_exactly() {
        let mut r = HashRng::new(5, 0xFC);
        let xs: Vec<f32> = (0..257).map(|_| (r.next_f32() - 0.5) * 50.0).collect();
        let mut s = vec![0.0f32; xs.len()];
        let mut c = vec![0.0f32; xs.len()];
        sin_cos_batch(&xs, &mut s, &mut c);
        for (i, &x) in xs.iter().enumerate() {
            let (ws, wc) = sin_cos(x);
            assert_eq!(s[i], ws);
            assert_eq!(c[i], wc);
        }
    }

    #[test]
    #[should_panic]
    fn mismatched_lengths_rejected() {
        let mut s = vec![0.0f32; 3];
        let mut c = vec![0.0f32; 4];
        sin_cos_batch(&[0.0; 4], &mut s, &mut c);
    }
}
