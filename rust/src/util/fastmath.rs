//! Fast, vectorizable transcendental kernels.
//!
//! `f32::sin_cos` goes through libm one call per element, and the trig
//! map dominates the per-sample feature profile (see
//! [`crate::mckernel::feature_map`]). The kernel here is the classic
//! Cody–Waite + minimax-polynomial design (cf. cephes `sinf`/`cosf`):
//! reduce by multiples of π/2 with a three-term split constant,
//! evaluate degree-7/8 polynomials on `|r| ≤ π/4`, and select/sign the
//! (sin, cos) pair from the quadrant index. The loop body is
//! straight-line with branchless selects, so rustc auto-vectorizes it
//! across a batch.
//!
//! Accuracy: max abs error ≈ 1e-7 against libm for `|x| ≤ 5·10³`
//! (validated in tests — well inside the ≤1e-5 budget of the batched
//! feature pipeline); the reduction degrades gracefully beyond that as
//! `q·ulp(π/2)` grows.

// The Cody–Waite split constants and minimax coefficients below carry
// their published full-precision decimal expansions on purpose (the
// compiler truncates to f32); this is the only file allowed to.
#![allow(clippy::excessive_precision)]

/// 2/π.
const FRAC_2_PI: f32 = 0.636_619_772_367_581_34;

// π/2 split into three summands (Cody–Waite): A+B+C ≈ π/2 with each
// term short enough that `q·A`, `q·B` are exact for small `q`, so
// `((x − q·A) − q·B) − q·C` keeps ~7 extra bits over a single-constant
// reduction.
const PI2_A: f32 = 1.570_312_5;
const PI2_B: f32 = 4.837_512_969_970_703_125e-4;
const PI2_C: f32 = 7.549_789_954_891_88e-8;

// Minimax polynomial coefficients on |r| ≤ π/4 (cephes sinf/cosf).
const S1: f32 = -1.666_665_461_1e-1;
const S2: f32 = 8.332_160_873_6e-3;
const S3: f32 = -1.951_529_589_1e-4;
const C1: f32 = 4.166_664_568_298_827e-2;
const C2: f32 = -1.388_731_625_493_765e-3;
const C3: f32 = 2.443_315_711_809_948e-5;

/// `(sin x, cos x)` by range reduction + polynomial evaluation — see
/// the module docs for the accuracy contract.
#[inline(always)]
pub fn sin_cos(x: f32) -> (f32, f32) {
    let q = (x * FRAC_2_PI).round();
    let r = ((x - q * PI2_A) - q * PI2_B) - q * PI2_C;
    let m = (q as i32) & 3;
    let r2 = r * r;
    let sp = r + r * r2 * (S1 + r2 * (S2 + r2 * S3));
    let cp = 1.0 - 0.5 * r2 + r2 * r2 * (C1 + r2 * (C2 + r2 * C3));
    // quadrant m: sin = [s, c, -s, -c][m], cos = [c, -s, -c, s][m]
    let (sm, cm) = if m & 1 == 0 { (sp, cp) } else { (cp, sp) };
    let s = if m & 2 == 0 { sm } else { -sm };
    let c = if (m + 1) & 2 == 0 { cm } else { -cm };
    (s, c)
}

/// Elementwise `sin`/`cos` of `x` into two equal-length output slices.
pub fn sin_cos_batch(x: &[f32], sin_out: &mut [f32], cos_out: &mut [f32]) {
    assert_eq!(x.len(), sin_out.len(), "sin output length");
    assert_eq!(x.len(), cos_out.len(), "cos output length");
    for ((v, s), c) in x.iter().zip(sin_out.iter_mut()).zip(cos_out.iter_mut()) {
        let (sv, cv) = sin_cos(*v);
        *s = sv;
        *c = cv;
    }
}

/// [`sin_cos_batch`] through explicit vector intrinsics (8-wide AVX2 /
/// 4-wide NEON), runtime-dispatched with a scalar fallback — the trig
/// leg of `mckernel::plan::FwhtDispatch::Simd`.
///
/// Same Cody–Waite constants, same polynomial coefficients, and the
/// same multiply/add op order as [`sin_cos`] (no FMA contraction), so
/// the scalar accuracy contract carries over. The single permitted
/// divergence: the vector `q = round(x·2/π)` rounds half-**even**
/// (`_mm256_round_ps` / `vrndnq_f32`) while the scalar `.round()`
/// rounds half-away-from-zero. They disagree only when `x·2/π` lands
/// exactly on `k + ½` — the boundary between two reduction intervals,
/// where either quadrant choice is valid and the results differ by at
/// most ~2× the polynomial error at `|r| = π/4` (≈2e-7). The
/// differential tests pin SIMD-vs-scalar agreement at ≤1e-6.
pub fn sin_cos_batch_simd(x: &[f32], sin_out: &mut [f32], cos_out: &mut [f32]) {
    assert_eq!(x.len(), sin_out.len(), "sin output length");
    assert_eq!(x.len(), cos_out.len(), "cos output length");
    match crate::util::simd::level() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: level() == Avx2 only after is_x86_feature_detected!.
        crate::util::simd::SimdLevel::Avx2 => unsafe {
            avx2::sin_cos_batch(x, sin_out, cos_out)
        },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: level() == Neon only after runtime NEON detection.
        crate::util::simd::SimdLevel::Neon => unsafe {
            neon::sin_cos_batch(x, sin_out, cos_out)
        },
        _ => sin_cos_batch(x, sin_out, cos_out),
    }
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::{C1, C2, C3, FRAC_2_PI, PI2_A, PI2_B, PI2_C, S1, S2, S3};
    use std::arch::x86_64::*;

    /// # Safety
    /// Caller must guarantee the CPU supports AVX2; slices must be
    /// equal-length (asserted by the public wrapper).
    #[target_feature(enable = "avx2")]
    pub unsafe fn sin_cos_batch(x: &[f32], sin_out: &mut [f32], cos_out: &mut [f32]) {
        let n = x.len();
        let mut i = 0;
        while i + 8 <= n {
            // SAFETY: i + 8 <= n bounds the 8-float loads/stores into
            // the equal-length slices, and sin_cos8 inherits the AVX2
            // precondition this fn's caller already proved.
            unsafe {
                let v = _mm256_loadu_ps(x.as_ptr().add(i));
                let (s, c) = sin_cos8(v);
                _mm256_storeu_ps(sin_out.as_mut_ptr().add(i), s);
                _mm256_storeu_ps(cos_out.as_mut_ptr().add(i), c);
            }
            i += 8;
        }
        while i < n {
            let (s, c) = super::sin_cos(x[i]);
            sin_out[i] = s;
            cos_out[i] = c;
            i += 1;
        }
    }

    /// Eight lanes of [`super::sin_cos`]: identical constants and op
    /// order, explicit mul/add (no FMA) so lanes match the scalar
    /// kernel bit-for-bit away from round-to-nearest ties in `q`.
    ///
    /// # Safety
    /// Caller must guarantee the CPU supports AVX2.
    #[target_feature(enable = "avx2")]
    unsafe fn sin_cos8(x: __m256) -> (__m256, __m256) {
        let q = _mm256_round_ps::<{ _MM_FROUND_TO_NEAREST_INT | _MM_FROUND_NO_EXC }>(
            _mm256_mul_ps(x, _mm256_set1_ps(FRAC_2_PI)),
        );
        // r = ((x − q·A) − q·B) − q·C
        let mut r = _mm256_sub_ps(x, _mm256_mul_ps(q, _mm256_set1_ps(PI2_A)));
        r = _mm256_sub_ps(r, _mm256_mul_ps(q, _mm256_set1_ps(PI2_B)));
        r = _mm256_sub_ps(r, _mm256_mul_ps(q, _mm256_set1_ps(PI2_C)));
        // q is integral, so the int conversion is exact.
        let qi = _mm256_cvtps_epi32(q);
        let r2 = _mm256_mul_ps(r, r);
        // sp = r + r·r2·(S1 + r2·(S2 + r2·S3))
        let mut sp = _mm256_add_ps(_mm256_set1_ps(S2), _mm256_mul_ps(r2, _mm256_set1_ps(S3)));
        sp = _mm256_add_ps(_mm256_set1_ps(S1), _mm256_mul_ps(r2, sp));
        sp = _mm256_add_ps(r, _mm256_mul_ps(_mm256_mul_ps(r, r2), sp));
        // cp = (1 − 0.5·r2) + r2·r2·(C1 + r2·(C2 + r2·C3))
        let mut cp = _mm256_add_ps(_mm256_set1_ps(C2), _mm256_mul_ps(r2, _mm256_set1_ps(C3)));
        cp = _mm256_add_ps(_mm256_set1_ps(C1), _mm256_mul_ps(r2, cp));
        cp = _mm256_add_ps(
            _mm256_sub_ps(_mm256_set1_ps(1.0), _mm256_mul_ps(_mm256_set1_ps(0.5), r2)),
            _mm256_mul_ps(_mm256_mul_ps(r2, r2), cp),
        );
        // Quadrant m = qi & 3 (identical to the scalar `(q as i32) & 3`
        // for negative q too — two's complement). Swap sin/cos on odd
        // m; sign = bit1 of m (sin) / of m+1 (cos) moved to bit 31.
        let one = _mm256_set1_epi32(1);
        let two = _mm256_set1_epi32(2);
        let swap = _mm256_castsi256_ps(_mm256_cmpeq_epi32(_mm256_and_si256(qi, one), one));
        let sm = _mm256_blendv_ps(sp, cp, swap);
        let cm = _mm256_blendv_ps(cp, sp, swap);
        let ssign = _mm256_slli_epi32::<30>(_mm256_and_si256(qi, two));
        let csign = _mm256_slli_epi32::<30>(_mm256_and_si256(_mm256_add_epi32(qi, one), two));
        let s = _mm256_xor_ps(sm, _mm256_castsi256_ps(ssign));
        let c = _mm256_xor_ps(cm, _mm256_castsi256_ps(csign));
        (s, c)
    }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    use super::{C1, C2, C3, FRAC_2_PI, PI2_A, PI2_B, PI2_C, S1, S2, S3};
    use std::arch::aarch64::*;

    /// # Safety
    /// Caller must guarantee the CPU supports NEON; slices must be
    /// equal-length (asserted by the public wrapper).
    #[target_feature(enable = "neon")]
    pub unsafe fn sin_cos_batch(x: &[f32], sin_out: &mut [f32], cos_out: &mut [f32]) {
        let n = x.len();
        let mut i = 0;
        while i + 4 <= n {
            // SAFETY: i + 4 <= n bounds the 4-float loads/stores into
            // the equal-length slices, and sin_cos4 inherits the NEON
            // precondition this fn's caller already proved.
            unsafe {
                let v = vld1q_f32(x.as_ptr().add(i));
                let (s, c) = sin_cos4(v);
                vst1q_f32(sin_out.as_mut_ptr().add(i), s);
                vst1q_f32(cos_out.as_mut_ptr().add(i), c);
            }
            i += 4;
        }
        while i < n {
            let (s, c) = super::sin_cos(x[i]);
            sin_out[i] = s;
            cos_out[i] = c;
            i += 1;
        }
    }

    /// Four lanes of [`super::sin_cos`]: identical constants and op
    /// order, explicit mul/add (no FMA).
    ///
    /// # Safety
    /// Caller must guarantee the CPU supports NEON.
    #[target_feature(enable = "neon")]
    unsafe fn sin_cos4(x: float32x4_t) -> (float32x4_t, float32x4_t) {
        let q = vrndnq_f32(vmulq_f32(x, vdupq_n_f32(FRAC_2_PI)));
        // r = ((x − q·A) − q·B) − q·C
        let mut r = vsubq_f32(x, vmulq_f32(q, vdupq_n_f32(PI2_A)));
        r = vsubq_f32(r, vmulq_f32(q, vdupq_n_f32(PI2_B)));
        r = vsubq_f32(r, vmulq_f32(q, vdupq_n_f32(PI2_C)));
        // q is integral, so truncation toward zero is exact.
        let qi = vcvtq_s32_f32(q);
        let r2 = vmulq_f32(r, r);
        // sp = r + r·r2·(S1 + r2·(S2 + r2·S3))
        let mut sp = vaddq_f32(vdupq_n_f32(S2), vmulq_f32(r2, vdupq_n_f32(S3)));
        sp = vaddq_f32(vdupq_n_f32(S1), vmulq_f32(r2, sp));
        sp = vaddq_f32(r, vmulq_f32(vmulq_f32(r, r2), sp));
        // cp = (1 − 0.5·r2) + r2·r2·(C1 + r2·(C2 + r2·C3))
        let mut cp = vaddq_f32(vdupq_n_f32(C2), vmulq_f32(r2, vdupq_n_f32(C3)));
        cp = vaddq_f32(vdupq_n_f32(C1), vmulq_f32(r2, cp));
        cp = vaddq_f32(
            vsubq_f32(vdupq_n_f32(1.0), vmulq_f32(vdupq_n_f32(0.5), r2)),
            vmulq_f32(vmulq_f32(r2, r2), cp),
        );
        // Quadrant select/sign, same logic as the scalar kernel.
        let one = vdupq_n_s32(1);
        let two = vdupq_n_s32(2);
        let swap = vceqq_s32(vandq_s32(qi, one), one);
        let sm = vbslq_f32(swap, cp, sp);
        let cm = vbslq_f32(swap, sp, cp);
        let ssign = vreinterpretq_u32_s32(vshlq_n_s32::<30>(vandq_s32(qi, two)));
        let csign =
            vreinterpretq_u32_s32(vshlq_n_s32::<30>(vandq_s32(vaddq_s32(qi, one), two)));
        let s = vreinterpretq_f32_u32(veorq_u32(vreinterpretq_u32_f32(sm), ssign));
        let c = vreinterpretq_f32_u32(veorq_u32(vreinterpretq_u32_f32(cm), csign));
        (s, c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hash::HashRng;

    fn check_range(seed: u64, count: usize, half_width: f32, tol: f64) {
        let mut r = HashRng::new(seed, 0xFA);
        for _ in 0..count {
            let x = (r.next_f32() - 0.5) * 2.0 * half_width;
            let (s, c) = sin_cos(x);
            let xd = x as f64;
            assert!(
                (s as f64 - xd.sin()).abs() < tol,
                "sin({x}) = {s}, want {}",
                xd.sin()
            );
            assert!(
                (c as f64 - xd.cos()).abs() < tol,
                "cos({x}) = {c}, want {}",
                xd.cos()
            );
        }
    }

    #[test]
    fn reduced_range_is_tight() {
        // |x| ≤ π/4: pure polynomial error, no reduction involved.
        check_range(1, 20_000, std::f32::consts::FRAC_PI_4, 1e-6);
    }

    #[test]
    fn typical_feature_range() {
        // |Ẑx| values the feature map actually produces.
        check_range(2, 20_000, 20.0, 1e-5);
    }

    #[test]
    fn wide_range_within_budget() {
        check_range(3, 50_000, 500.0, 1e-5);
    }

    #[test]
    fn pythagorean_identity() {
        let mut r = HashRng::new(4, 0xFB);
        for _ in 0..10_000 {
            let x = (r.next_f32() - 0.5) * 100.0;
            let (s, c) = sin_cos(x);
            assert!((s * s + c * c - 1.0).abs() < 1e-5, "x={x}");
        }
    }

    #[test]
    fn quadrant_landmarks() {
        use std::f32::consts::PI;
        for (x, ws, wc) in [
            (0.0f32, 0.0f32, 1.0f32),
            (PI / 2.0, 1.0, 0.0),
            (PI, 0.0, -1.0),
            (3.0 * PI / 2.0, -1.0, 0.0),
            (-PI / 2.0, -1.0, 0.0),
            (2.0 * PI, 0.0, 1.0),
        ] {
            let (s, c) = sin_cos(x);
            assert!((s - ws).abs() < 1e-6, "sin({x}) = {s}, want {ws}");
            assert!((c - wc).abs() < 1e-6, "cos({x}) = {c}, want {wc}");
        }
    }

    #[test]
    fn batch_matches_scalar_exactly() {
        let mut r = HashRng::new(5, 0xFC);
        let xs: Vec<f32> = (0..257).map(|_| (r.next_f32() - 0.5) * 50.0).collect();
        let mut s = vec![0.0f32; xs.len()];
        let mut c = vec![0.0f32; xs.len()];
        sin_cos_batch(&xs, &mut s, &mut c);
        for (i, &x) in xs.iter().enumerate() {
            let (ws, wc) = sin_cos(x);
            assert_eq!(s[i], ws);
            assert_eq!(c[i], wc);
        }
    }

    #[test]
    #[should_panic]
    fn mismatched_lengths_rejected() {
        let mut s = vec![0.0f32; 3];
        let mut c = vec![0.0f32; 4];
        sin_cos_batch(&[0.0; 4], &mut s, &mut c);
    }

    /// The PR 9 accuracy contract: SIMD trig agrees with the scalar
    /// kernel to ≤1e-6 everywhere (bit-identical away from the
    /// measure-zero round-to-nearest ties in `q` — see the
    /// `sin_cos_batch_simd` docs). Odd lengths exercise the scalar
    /// remainder loop; length 0/1/lane-width are the edge shapes.
    #[test]
    fn simd_batch_matches_scalar_within_1e6() {
        let mut r = HashRng::new(6, 0xFD);
        for len in [0usize, 1, 3, 4, 7, 8, 9, 31, 257, 1000] {
            let xs: Vec<f32> = (0..len).map(|_| (r.next_f32() - 0.5) * 1000.0).collect();
            let mut ss = vec![0.0f32; len];
            let mut cs = vec![0.0f32; len];
            sin_cos_batch(&xs, &mut ss, &mut cs);
            let mut sv = vec![0.0f32; len];
            let mut cv = vec![0.0f32; len];
            sin_cos_batch_simd(&xs, &mut sv, &mut cv);
            for i in 0..len {
                assert!(
                    (ss[i] - sv[i]).abs() <= 1e-6,
                    "sin({}) scalar={} simd={}",
                    xs[i],
                    ss[i],
                    sv[i]
                );
                assert!(
                    (cs[i] - cv[i]).abs() <= 1e-6,
                    "cos({}) scalar={} simd={}",
                    xs[i],
                    cs[i],
                    cv[i]
                );
            }
        }
    }

    /// And against libm directly, same budget as the scalar kernel.
    #[test]
    fn simd_batch_matches_libm() {
        let mut r = HashRng::new(7, 0xFE);
        let xs: Vec<f32> = (0..20_000).map(|_| (r.next_f32() - 0.5) * 40.0).collect();
        let mut s = vec![0.0f32; xs.len()];
        let mut c = vec![0.0f32; xs.len()];
        sin_cos_batch_simd(&xs, &mut s, &mut c);
        for (i, &x) in xs.iter().enumerate() {
            let xd = x as f64;
            assert!((s[i] as f64 - xd.sin()).abs() < 1e-5, "sin({x})");
            assert!((c[i] as f64 - xd.cos()).abs() < 1e-5, "cos({x})");
        }
    }

    #[test]
    #[should_panic]
    fn simd_mismatched_lengths_rejected() {
        let mut s = vec![0.0f32; 4];
        let mut c = vec![0.0f32; 3];
        sin_cos_batch_simd(&[0.0; 4], &mut s, &mut c);
    }
}
