//! Fixed-size thread pool over `std::sync::mpsc` — the execution
//! substrate for the coordinator's prefetch pipeline and the parallel
//! feature generator (offline build: no tokio/rayon).
//!
//! Fault posture: workers run every job under `catch_unwind`, so a
//! panicking job never kills its worker — the pool keeps its full
//! width for the trainer's retry path. Submission returns a typed
//! [`McError`] instead of panicking when the pool is shut down, and
//! [`ThreadPool::scope_shards`] reports *which* shards panicked so
//! the caller can recompute exactly those.

use crate::fault::McError;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Completion guard for the scoped barriers: signals its job's index
/// even when the job panics (Drop runs during unwinding), so a
/// barrier always sees exactly one message per submitted job.
struct Done(mpsc::Sender<(usize, bool)>, usize);

impl Drop for Done {
    fn drop(&mut self) {
        let _ = self.0.send((self.1, thread::panicking()));
    }
}

/// Shard-base pointer made `Send` so scoped jobs can carry it to the
/// workers directly — no int→ptr roundtrip, so provenance survives
/// and Miri can check the aliasing argument below.
struct ShardBase<S>(*mut S);

impl<S> Clone for ShardBase<S> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<S> Copy for ShardBase<S> {}

// SAFETY: the pointer is dereferenced only inside `scope_shards` jobs,
// each at its own distinct offset, while the completion barrier keeps
// the underlying `&mut [S]` borrow pinned to the submitting frame —
// handing it to a worker is exactly the disjoint-&mut transfer that
// `S: Send` permits.
unsafe impl<S: Send> Send for ShardBase<S> {}

/// A fixed pool of worker threads executing boxed jobs FIFO.
pub struct ThreadPool {
    sender: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn a pool with `size` workers (`size ≥ 1`).
    pub fn new(size: usize) -> ThreadPool {
        assert!(size > 0, "pool must have at least one worker");
        let (sender, receiver) = mpsc::channel::<Job>();
        let receiver = Arc::new(Mutex::new(receiver));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&receiver);
                thread::Builder::new()
                    .name(format!("mckernel-worker-{i}"))
                    .spawn(move || loop {
                        // The lock guard drops before the job runs, so
                        // a panicking job can never poison the mutex.
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            // Contain panics here: the worker survives
                            // and keeps serving the queue at full pool
                            // width. Scoped callers observe the panic
                            // through their completion guards.
                            Ok(job) => {
                                let _ = catch_unwind(AssertUnwindSafe(job));
                            }
                            Err(_) => break, // channel closed: shut down
                        }
                    })
                    .expect("failed to spawn worker thread")
            })
            .collect();
        ThreadPool { sender: Some(sender), workers }
    }

    /// A pool sized to the machine (`available_parallelism`, min 1).
    pub fn with_default_size() -> ThreadPool {
        let n = thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        ThreadPool::new(n)
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Submit a job. `Err(ShuttingDown)` after [`ThreadPool::shutdown`];
    /// `Err(WorkerPanic)` if every worker is gone (the queue can no
    /// longer drain).
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) -> Result<(), McError> {
        self.submit(Box::new(f))
    }

    fn submit(&self, job: Job) -> Result<(), McError> {
        let tx = self.sender.as_ref().ok_or(McError::ShuttingDown)?;
        tx.send(job).map_err(|_| McError::WorkerPanic)
    }

    /// Run `f(s, &mut shards[s])` for every shard across the pool and
    /// block until all jobs have finished — the data-parallel
    /// trainer's step primitive. Unlike [`ThreadPool::scope_for_each`],
    /// both the closure and the shard slice may borrow from the
    /// caller's stack: the completion barrier guarantees every job has
    /// run to completion (normally or by panic) before this returns,
    /// so no erased borrow can outlive the call.
    ///
    /// Returns the (sorted) indices of shards whose job panicked —
    /// empty on a clean pass. The shards themselves are untouched by
    /// this call after the panic point, so the caller can repair state
    /// and resubmit exactly those indices. `Err` means submission
    /// failed (pool shut down mid-loop); even then, every job that
    /// *was* submitted has completed before the error returns, so the
    /// borrow-safety argument holds on the error path too.
    pub fn scope_shards<S, F>(&self, shards: &mut [S], f: F) -> Result<Vec<usize>, McError>
    where
        S: Send,
        F: Fn(usize, &mut S) + Send + Sync,
    {
        let n = shards.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        let (done_tx, done_rx) = mpsc::channel::<(usize, bool)>();
        let base = ShardBase(shards.as_mut_ptr());
        let mut submitted = 0usize;
        let mut submit_err = None;
        for i in 0..n {
            let done = Done(done_tx.clone(), i);
            let fr: &F = &f;
            let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                let _done = done;
                // SAFETY: job `i` touches only shard `i` (disjoint
                // &mut), and the barrier keeps `shards` borrowed by
                // this frame until every job has dropped its guard.
                let shard = unsafe { &mut *base.0.add(i) };
                fr(i, shard);
            });
            // SAFETY: lifetime erasure to fit the queue's 'static Job
            // type; soundness is the barrier argument above — this
            // frame (owning `f` and borrowing `shards`) outlives every
            // job, and the barrier waits for every *submitted* job on
            // both the Ok and Err paths before returning.
            let job: Job = unsafe { std::mem::transmute(job) };
            match self.submit(job) {
                Ok(()) => submitted += 1,
                Err(e) => {
                    submit_err = Some(e);
                    break;
                }
            }
        }
        drop(done_tx);
        let mut panicked = Vec::new();
        for _ in 0..submitted {
            match done_rx.recv() {
                Ok((i, p)) => {
                    if p {
                        panicked.push(i);
                    }
                }
                // Unreachable (each submitted job holds a guard), but
                // never block past the guards we will actually get.
                Err(_) => break,
            }
        }
        if let Some(e) = submit_err {
            return Err(e);
        }
        panicked.sort_unstable();
        Ok(panicked)
    }

    /// Run `f(i)` for `i ∈ 0..n` across the pool and wait for all.
    /// `Err(WorkerPanic)` if any job panicked (all jobs still ran to
    /// completion or unwound before this returns).
    pub fn scope_for_each<F>(&self, n: usize, f: F) -> Result<(), McError>
    where
        F: Fn(usize) + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let (done_tx, done_rx) = mpsc::channel::<(usize, bool)>();
        let mut submitted = 0usize;
        let mut submit_err = None;
        for i in 0..n {
            let f = Arc::clone(&f);
            let done = Done(done_tx.clone(), i);
            let result = self.execute(move || {
                let _done = done;
                f(i);
            });
            match result {
                Ok(()) => submitted += 1,
                Err(e) => {
                    submit_err = Some(e);
                    break;
                }
            }
        }
        drop(done_tx);
        let mut panicked = false;
        for _ in 0..submitted {
            match done_rx.recv() {
                Ok((_, p)) => panicked |= p,
                Err(_) => break,
            }
        }
        if let Some(e) = submit_err {
            return Err(e);
        }
        if panicked {
            return Err(McError::WorkerPanic);
        }
        Ok(())
    }

    /// Stop accepting jobs, drain the queue, and join every worker.
    /// Subsequent submissions return `Err(ShuttingDown)`. Idempotent;
    /// `Drop` calls this too.
    pub fn shutdown(&mut self) {
        self.sender.take();
        for w in self.workers.drain(..) {
            // Panic-safe even if a worker died: a failed join only
            // means that worker is already gone.
            let _ = w.join();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = mpsc::channel();
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                tx.send(()).unwrap();
            })
            .unwrap();
        }
        for _ in 0..100 {
            rx.recv().unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn scope_for_each_covers_range() {
        let pool = ThreadPool::new(3);
        let hits: Arc<Vec<AtomicUsize>> =
            Arc::new((0..50).map(|_| AtomicUsize::new(0)).collect());
        let h = Arc::clone(&hits);
        pool.scope_for_each(50, move |i| {
            h[i].fetch_add(1, Ordering::SeqCst);
        })
        .unwrap();
        for (i, a) in hits.iter().enumerate() {
            assert_eq!(a.load(Ordering::SeqCst), 1, "index {i}");
        }
    }

    #[test]
    fn scope_for_each_reports_panics_as_typed_error() {
        let pool = ThreadPool::new(2);
        let err = pool
            .scope_for_each(8, |i| {
                if i == 5 {
                    panic!("boom");
                }
            })
            .unwrap_err();
        assert_eq!(err, McError::WorkerPanic);
        // and the pool is still fully usable afterwards
        pool.scope_for_each(8, |_| {}).unwrap();
    }

    #[test]
    fn scope_shards_gives_each_job_its_own_slot() {
        let pool = ThreadPool::new(4);
        let mut shards: Vec<(usize, u64)> = (0..23).map(|i| (i, 0u64)).collect();
        // borrow a stack-local from the closure: the scoped API's
        // whole point is that this needs no Arc and no 'static
        let offset = 100u64;
        let off = &offset;
        let panicked = pool
            .scope_shards(&mut shards, |i, slot| {
                assert_eq!(slot.0, i, "job index must match slot index");
                slot.1 = i as u64 + off;
            })
            .unwrap();
        assert!(panicked.is_empty());
        for (i, s) in shards.iter().enumerate() {
            assert_eq!(s.1, i as u64 + 100, "slot {i}");
        }
    }

    #[test]
    fn scope_shards_empty_is_noop() {
        let pool = ThreadPool::new(2);
        let mut shards: Vec<u32> = vec![];
        assert!(pool.scope_shards(&mut shards, |_, _| unreachable!()).unwrap().is_empty());
    }

    #[test]
    fn scope_shards_more_jobs_than_workers() {
        let pool = ThreadPool::new(2);
        let mut shards = vec![0usize; 64];
        pool.scope_shards(&mut shards, |i, s| *s = i * i).unwrap();
        assert!(shards.iter().enumerate().all(|(i, &s)| s == i * i));
    }

    #[test]
    fn scope_shards_reports_exactly_the_panicked_indices() {
        let pool = ThreadPool::new(3);
        let mut shards = vec![0u8; 7];
        let panicked = pool
            .scope_shards(&mut shards, |i, s| {
                if i == 2 || i == 5 {
                    panic!("boom {i}");
                }
                *s = 1;
            })
            .unwrap();
        assert_eq!(panicked, vec![2, 5]);
        // healthy shards completed; panicked shards untouched
        for (i, &s) in shards.iter().enumerate() {
            assert_eq!(s != 0, !panicked.contains(&i), "shard {i}");
        }
        // workers survived the panics: a follow-up pass is clean
        let clean = pool.scope_shards(&mut shards, |_, s| *s = 2).unwrap();
        assert!(clean.is_empty());
        assert!(shards.iter().all(|&s| s == 2));
    }

    #[test]
    fn submit_after_shutdown_is_typed_error_not_panic() {
        let mut pool = ThreadPool::new(2);
        pool.execute(|| {}).unwrap();
        pool.shutdown();
        assert_eq!(pool.execute(|| {}).unwrap_err(), McError::ShuttingDown);
        let mut shards = vec![0u8; 3];
        assert_eq!(
            pool.scope_shards(&mut shards, |_, _| {}).unwrap_err(),
            McError::ShuttingDown
        );
        assert_eq!(pool.scope_for_each(3, |_| {}).unwrap_err(), McError::ShuttingDown);
        pool.shutdown(); // idempotent
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(2);
        pool.execute(|| {
            std::thread::sleep(std::time::Duration::from_millis(10));
        })
        .unwrap();
        drop(pool); // must not hang or panic
    }

    #[test]
    fn drop_is_panic_safe_after_job_panics() {
        let pool = ThreadPool::new(2);
        for _ in 0..4 {
            pool.execute(|| panic!("boom")).unwrap();
        }
        drop(pool); // must not hang or propagate the job panics
    }

    #[test]
    fn size_reported() {
        assert_eq!(ThreadPool::new(5).size(), 5);
        assert!(ThreadPool::with_default_size().size() >= 1);
    }

    #[test]
    #[should_panic]
    fn zero_workers_rejected() {
        ThreadPool::new(0);
    }
}
