//! Fixed-size thread pool over `std::sync::mpsc` — the execution
//! substrate for the coordinator's prefetch pipeline and the parallel
//! feature generator (offline build: no tokio/rayon).

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed pool of worker threads executing boxed jobs FIFO.
pub struct ThreadPool {
    sender: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn a pool with `size` workers (`size ≥ 1`).
    pub fn new(size: usize) -> ThreadPool {
        assert!(size > 0, "pool must have at least one worker");
        let (sender, receiver) = mpsc::channel::<Job>();
        let receiver = Arc::new(Mutex::new(receiver));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&receiver);
                thread::Builder::new()
                    .name(format!("mckernel-worker-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // channel closed: shut down
                        }
                    })
                    .expect("failed to spawn worker thread")
            })
            .collect();
        ThreadPool { sender: Some(sender), workers }
    }

    /// A pool sized to the machine (`available_parallelism`, min 1).
    pub fn with_default_size() -> ThreadPool {
        let n = thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        ThreadPool::new(n)
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Submit a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.sender
            .as_ref()
            .expect("pool is shut down")
            .send(Box::new(f))
            .expect("worker channel closed");
    }

    /// Run `f(s, &mut shards[s])` for every shard across the pool and
    /// block until all jobs have finished — the data-parallel
    /// trainer's step primitive. Unlike [`ThreadPool::scope_for_each`],
    /// both the closure and the shard slice may borrow from the
    /// caller's stack: the completion barrier guarantees every job has
    /// run to completion (normally or by panic) before this returns,
    /// so no erased borrow can outlive the call.
    ///
    /// A panic inside `f` is re-raised here after the barrier (the
    /// worker thread that hosted it dies; remaining workers keep
    /// serving the queue). If *every* worker has already died from
    /// prior panics, queued jobs can no longer run and this call
    /// blocks — a deliberate trade: deadlock is diagnosable, freed
    /// stack borrows racing live jobs would be undefined behaviour.
    pub fn scope_shards<S, F>(&self, shards: &mut [S], f: F)
    where
        S: Send,
        F: Fn(usize, &mut S) + Send + Sync,
    {
        let n = shards.len();
        if n == 0 {
            return;
        }
        // Completion guard: signals even when the job panics (Drop
        // runs during unwinding), so the barrier below always sees
        // exactly `n` messages.
        struct Done(mpsc::Sender<bool>);
        impl Drop for Done {
            fn drop(&mut self) {
                let _ = self.0.send(thread::panicking());
            }
        }
        let (done_tx, done_rx) = mpsc::channel::<bool>();
        let base = shards.as_mut_ptr() as usize;
        for i in 0..n {
            let done = Done(done_tx.clone());
            let fr: &F = &f;
            let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                let _done = done;
                // SAFETY: job `i` touches only shard `i` (disjoint
                // &mut), and the barrier keeps `shards` borrowed by
                // this frame until every job has dropped its guard.
                let shard = unsafe { &mut *(base as *mut S).add(i) };
                fr(i, shard);
            });
            // SAFETY: lifetime erasure to fit the queue's 'static Job
            // type; soundness is the barrier argument above — this
            // frame (owning `f` and borrowing `shards`) outlives every
            // job, and after `n` guard signals no job code can run.
            let job: Job = unsafe { std::mem::transmute(job) };
            self.sender
                .as_ref()
                .expect("pool is shut down")
                .send(job)
                .expect("worker channel closed");
        }
        drop(done_tx);
        let mut panicked = false;
        for _ in 0..n {
            panicked |= done_rx.recv().expect("scope barrier broken");
        }
        assert!(!panicked, "a shard job panicked");
    }

    /// Run `f(i)` for `i ∈ 0..n` across the pool and wait for all.
    pub fn scope_for_each<F>(&self, n: usize, f: F)
    where
        F: Fn(usize) + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let (done_tx, done_rx) = mpsc::channel();
        for i in 0..n {
            let f = Arc::clone(&f);
            let done = done_tx.clone();
            self.execute(move || {
                f(i);
                let _ = done.send(());
            });
        }
        drop(done_tx);
        for _ in 0..n {
            done_rx.recv().expect("worker panicked");
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        // Close the channel, then join every worker.
        self.sender.take();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = mpsc::channel();
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                tx.send(()).unwrap();
            });
        }
        for _ in 0..100 {
            rx.recv().unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn scope_for_each_covers_range() {
        let pool = ThreadPool::new(3);
        let hits: Arc<Vec<AtomicUsize>> =
            Arc::new((0..50).map(|_| AtomicUsize::new(0)).collect());
        let h = Arc::clone(&hits);
        pool.scope_for_each(50, move |i| {
            h[i].fetch_add(1, Ordering::SeqCst);
        });
        for (i, a) in hits.iter().enumerate() {
            assert_eq!(a.load(Ordering::SeqCst), 1, "index {i}");
        }
    }

    #[test]
    fn scope_shards_gives_each_job_its_own_slot() {
        let pool = ThreadPool::new(4);
        let mut shards: Vec<(usize, u64)> = (0..23).map(|i| (i, 0u64)).collect();
        // borrow a stack-local from the closure: the scoped API's
        // whole point is that this needs no Arc and no 'static
        let offset = 100u64;
        let off = &offset;
        pool.scope_shards(&mut shards, |i, slot| {
            assert_eq!(slot.0, i, "job index must match slot index");
            slot.1 = i as u64 + off;
        });
        for (i, s) in shards.iter().enumerate() {
            assert_eq!(s.1, i as u64 + 100, "slot {i}");
        }
    }

    #[test]
    fn scope_shards_empty_is_noop() {
        let pool = ThreadPool::new(2);
        let mut shards: Vec<u32> = vec![];
        pool.scope_shards(&mut shards, |_, _| unreachable!());
    }

    #[test]
    fn scope_shards_more_jobs_than_workers() {
        let pool = ThreadPool::new(2);
        let mut shards = vec![0usize; 64];
        pool.scope_shards(&mut shards, |i, s| *s = i * i);
        assert!(shards.iter().enumerate().all(|(i, &s)| s == i * i));
    }

    #[test]
    #[should_panic(expected = "a shard job panicked")]
    fn scope_shards_propagates_panics() {
        let pool = ThreadPool::new(3);
        let mut shards = vec![0u8; 5];
        pool.scope_shards(&mut shards, |i, _| {
            if i == 3 {
                panic!("boom");
            }
        });
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(2);
        pool.execute(|| {
            std::thread::sleep(std::time::Duration::from_millis(10));
        });
        drop(pool); // must not hang or panic
    }

    #[test]
    fn size_reported() {
        assert_eq!(ThreadPool::new(5).size(), 5);
        assert!(ThreadPool::with_default_size().size() >= 1);
    }

    #[test]
    #[should_panic]
    fn zero_workers_rejected() {
        ThreadPool::new(0);
    }
}
