//! Fixed-size thread pool over `std::sync::mpsc` — the execution
//! substrate for the coordinator's prefetch pipeline and the parallel
//! feature generator (offline build: no tokio/rayon).

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed pool of worker threads executing boxed jobs FIFO.
pub struct ThreadPool {
    sender: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn a pool with `size` workers (`size ≥ 1`).
    pub fn new(size: usize) -> ThreadPool {
        assert!(size > 0, "pool must have at least one worker");
        let (sender, receiver) = mpsc::channel::<Job>();
        let receiver = Arc::new(Mutex::new(receiver));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&receiver);
                thread::Builder::new()
                    .name(format!("mckernel-worker-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // channel closed: shut down
                        }
                    })
                    .expect("failed to spawn worker thread")
            })
            .collect();
        ThreadPool { sender: Some(sender), workers }
    }

    /// A pool sized to the machine (`available_parallelism`, min 1).
    pub fn with_default_size() -> ThreadPool {
        let n = thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        ThreadPool::new(n)
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Submit a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.sender
            .as_ref()
            .expect("pool is shut down")
            .send(Box::new(f))
            .expect("worker channel closed");
    }

    /// Run `f(i)` for `i ∈ 0..n` across the pool and wait for all.
    pub fn scope_for_each<F>(&self, n: usize, f: F)
    where
        F: Fn(usize) + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let (done_tx, done_rx) = mpsc::channel();
        for i in 0..n {
            let f = Arc::clone(&f);
            let done = done_tx.clone();
            self.execute(move || {
                f(i);
                let _ = done.send(());
            });
        }
        drop(done_tx);
        for _ in 0..n {
            done_rx.recv().expect("worker panicked");
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        // Close the channel, then join every worker.
        self.sender.take();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = mpsc::channel();
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                tx.send(()).unwrap();
            });
        }
        for _ in 0..100 {
            rx.recv().unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn scope_for_each_covers_range() {
        let pool = ThreadPool::new(3);
        let hits: Arc<Vec<AtomicUsize>> =
            Arc::new((0..50).map(|_| AtomicUsize::new(0)).collect());
        let h = Arc::clone(&hits);
        pool.scope_for_each(50, move |i| {
            h[i].fetch_add(1, Ordering::SeqCst);
        });
        for (i, a) in hits.iter().enumerate() {
            assert_eq!(a.load(Ordering::SeqCst), 1, "index {i}");
        }
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(2);
        pool.execute(|| {
            std::thread::sleep(std::time::Duration::from_millis(10));
        });
        drop(pool); // must not hang or panic
    }

    #[test]
    fn size_reported() {
        assert_eq!(ThreadPool::new(5).size(), 5);
        assert!(ThreadPool::with_default_size().size() >= 1);
    }

    #[test]
    #[should_panic]
    fn zero_workers_rejected() {
        ThreadPool::new(0);
    }
}
