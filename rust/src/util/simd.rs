//! Runtime SIMD capability detection, shared by the explicit-intrinsics
//! kernels (`fwht::simd` butterflies, `util::fastmath` vectorized trig).
//!
//! Detection runs once per process (`is_x86_feature_detected!` /
//! aarch64 mandatory-NEON) and is cached in an atomic, so kernel entry
//! points pay one relaxed load. The *policy* decision — whether the
//! expansion pipeline uses the SIMD arm at all — does not live here; it
//! belongs to `mckernel::plan::ExpansionPlan`, which consults
//! [`available`] under its `DispatchForce::Auto` mode. Kernels in the
//! SIMD modules fall back to their scalar twins when the level is
//! [`SimdLevel::Scalar`], so a plan *forced* onto the SIMD arm still
//! executes correctly (and bit-identically for the add/sub butterflies)
//! on machines without vector units.

use std::sync::atomic::{AtomicU8, Ordering};

/// The instruction-set tier the running CPU supports.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimdLevel {
    /// No usable vector extension — SIMD entry points run their
    /// portable scalar fallbacks.
    Scalar,
    /// x86_64 AVX2: 8 f32 lanes per vector.
    Avx2,
    /// aarch64 NEON: 4 f32 lanes per vector.
    Neon,
}

impl SimdLevel {
    /// f32 elements per vector register at this level.
    pub fn lanes(self) -> usize {
        match self {
            SimdLevel::Scalar => 1,
            SimdLevel::Avx2 => 8,
            SimdLevel::Neon => 4,
        }
    }

    /// Stable short name (bench/CLI labels, EXPERIMENTS records).
    pub fn name(self) -> &'static str {
        match self {
            SimdLevel::Scalar => "scalar",
            SimdLevel::Avx2 => "avx2",
            SimdLevel::Neon => "neon",
        }
    }
}

const UNKNOWN: u8 = u8::MAX;
static LEVEL: AtomicU8 = AtomicU8::new(UNKNOWN);

fn detect() -> SimdLevel {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            return SimdLevel::Avx2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            return SimdLevel::Neon;
        }
    }
    SimdLevel::Scalar
}

fn decode(v: u8) -> SimdLevel {
    match v {
        1 => SimdLevel::Avx2,
        2 => SimdLevel::Neon,
        _ => SimdLevel::Scalar,
    }
}

fn encode(l: SimdLevel) -> u8 {
    match l {
        SimdLevel::Scalar => 0,
        SimdLevel::Avx2 => 1,
        SimdLevel::Neon => 2,
    }
}

/// The detected level for this process (cached after the first call).
pub fn level() -> SimdLevel {
    let v = LEVEL.load(Ordering::Relaxed);
    if v != UNKNOWN {
        return decode(v);
    }
    let l = detect();
    // Benign race: detect() is a pure function of the CPU, so every
    // contender stores the same value.
    LEVEL.store(encode(l), Ordering::Relaxed);
    l
}

/// Whether any vector extension is available (what the plan's Auto
/// dispatch consults).
pub fn available() -> bool {
    level() != SimdLevel::Scalar
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_is_cached_and_consistent() {
        let a = level();
        let b = level();
        assert_eq!(a, b);
        assert_eq!(available(), a != SimdLevel::Scalar);
        assert_eq!(a.lanes() > 1, available());
    }

    #[test]
    fn names_and_lanes() {
        assert_eq!(SimdLevel::Scalar.lanes(), 1);
        assert_eq!(SimdLevel::Avx2.lanes(), 8);
        assert_eq!(SimdLevel::Neon.lanes(), 4);
        assert_eq!(SimdLevel::Scalar.name(), "scalar");
        assert_eq!(SimdLevel::Avx2.name(), "avx2");
        assert_eq!(SimdLevel::Neon.name(), "neon");
    }

    #[test]
    fn arch_matches_level() {
        // The detected tier must be one the build target can express.
        match level() {
            SimdLevel::Avx2 => assert!(cfg!(target_arch = "x86_64")),
            SimdLevel::Neon => assert!(cfg!(target_arch = "aarch64")),
            SimdLevel::Scalar => {}
        }
    }
}
