//! Small shared utilities: power-of-two helpers, fast vectorizable
//! transcendentals, a minimal JSON parser/writer (for the artifact
//! manifest — no serde offline), and a thread pool (no tokio offline).

pub mod fastmath;
pub mod json;
pub mod pow2;
pub mod threadpool;

pub use pow2::{is_pow2, log2_exact, next_pow2};
pub use threadpool::ThreadPool;
