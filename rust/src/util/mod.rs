//! Small shared utilities: power-of-two helpers, fast vectorizable
//! transcendentals, runtime SIMD capability detection, a minimal JSON
//! parser/writer (for the artifact manifest — no serde offline), and a
//! thread pool (no tokio offline).

pub mod fastmath;
pub mod json;
pub mod pow2;
pub mod simd;
pub mod threadpool;

pub use pow2::{is_pow2, log2_exact, next_pow2};
pub use threadpool::ThreadPool;

/// Fixed-order pairwise tree reduction: merges `items[i+gap]` into
/// `items[i]` for gaps 1, 2, 4, … so `items[0]` ends up holding the
/// combined total. The merge order is a function of `items.len()`
/// alone — never of timing — which is what makes the data-parallel
/// trainer's gradient combine bit-reproducible across runs and
/// schedules (floating-point addition is not associative, so the
/// *order* is part of the contract).
pub fn tree_reduce_with<T>(items: &mut [T], merge: impl Fn(&mut T, &T)) {
    let n = items.len();
    let mut gap = 1;
    while gap < n {
        let mut i = 0;
        while i + gap < n {
            let (head, tail) = items.split_at_mut(i + gap);
            merge(&mut head[i], &tail[0]);
            i += 2 * gap;
        }
        gap *= 2;
    }
}

#[cfg(test)]
mod tree_reduce_tests {
    use super::tree_reduce_with;

    #[test]
    fn sums_into_first_slot() {
        for n in 1..=9usize {
            let mut v: Vec<u64> = (1..=n as u64).collect();
            tree_reduce_with(&mut v, |a, b| *a += *b);
            assert_eq!(v[0], (n as u64) * (n as u64 + 1) / 2, "n={n}");
        }
    }

    #[test]
    fn order_is_pairwise_not_sequential() {
        // f32 addition is not associative: ((a+b)+(c+d)) with a=-c=1e8
        // cancels exactly, while the left fold (((a+b)+c)+d) absorbs
        // both 1s into the 1e8 terms first. The tree must produce the
        // pairwise answer.
        let mut v = vec![1e8f32, 1.0, -1e8, 1.0];
        tree_reduce_with(&mut v, |a, b| *a += *b);
        assert_eq!(v[0], (1e8f32 + 1.0) + (-1e8f32 + 1.0));
    }

    #[test]
    fn empty_and_single_are_noops() {
        let mut empty: Vec<f32> = vec![];
        tree_reduce_with(&mut empty, |a, b| *a += *b);
        let mut one = vec![7.5f32];
        tree_reduce_with(&mut one, |a, b| *a += *b);
        assert_eq!(one[0], 7.5);
    }
}
