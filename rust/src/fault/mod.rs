//! Fault tolerance: the typed error taxonomy ([`McError`]) threaded
//! through the public serving/training APIs, and the seeded
//! deterministic fault injector ([`FaultPlan`]) that makes chaos
//! scenarios replayable bit-for-bit.
//!
//! The paper's recomputation premise — features are cheap to
//! regenerate from a hashed seed — makes *retry-instead-of-die* the
//! natural recovery strategy everywhere in this codebase: a panicked
//! trainer shard is recomputed bit-identically on the surviving
//! workers, a poisoned server batch is quarantined and its engine
//! rebuilt, and a killed run resumes from the last epoch checkpoint.

pub mod error;
pub mod inject;

pub use error::McError;
pub use inject::{shard_key, FaultPlan, FaultSite};
