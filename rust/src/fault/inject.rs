//! Seeded, deterministic fault injection.
//!
//! A [`FaultPlan`] decides — from the same counter-based MurmurHash3
//! randomness the feature map uses for its coefficients — at which
//! call indices a fault fires. Two plans built from the same seed and
//! rates make identical decisions on every machine, so chaos
//! scenarios replay bit-for-bit in CI. Consumers hold an
//! `Option<Arc<FaultPlan>>` and branch on `None`: with no plan
//! installed the production path pays a single pointer test, the same
//! gating pattern the observability layer uses.

use crate::hash::hash_rng::{streams, HashRng};
use crate::obs::{self, Counter, MetricsRegistry};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Where a fault is injected. Each site draws from its own derived
/// hash stream, so changing one site's rate never reshuffles another
/// site's firing pattern.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultSite {
    /// Poison the expansion engine's output (NaN rows) after a batch
    /// executes — exercises the server's output-finiteness quarantine.
    EngineFault = 0,
    /// Panic inside a worker (serve-loop batch or trainer shard).
    WorkerPanic = 1,
    /// Sleep before executing a batch — drives client deadlines.
    Latency = 2,
}

impl FaultSite {
    /// All sites, in stream order.
    pub const ALL: [FaultSite; 3] =
        [FaultSite::EngineFault, FaultSite::WorkerPanic, FaultSite::Latency];

    /// Metric/log tag.
    pub fn name(self) -> &'static str {
        match self {
            FaultSite::EngineFault => "engine_fault",
            FaultSite::WorkerPanic => "worker_panic",
            FaultSite::Latency => "latency",
        }
    }
}

const SITES: usize = 3;

/// A deterministic chaos schedule: per-site firing rates over hashed
/// call indices, an optional per-site fire limit, and an artificial
/// latency amount. Cheap to share (`Arc`) and lock-free to consult.
pub struct FaultPlan {
    seed: u64,
    rngs: [HashRng; SITES],
    rates: [f64; SITES],
    limits: [u64; SITES],
    latency: Duration,
    /// Per-site sequential call cursors for [`FaultPlan::fires`].
    cursors: [AtomicU64; SITES],
    /// Per-site count of faults actually fired (enforces `limits`).
    fired: [AtomicU64; SITES],
    /// `fault.injected` — total faults fired across all sites.
    injected: Arc<Counter>,
}

impl FaultPlan {
    /// A plan with every rate 0 (never fires) reporting into the
    /// global registry; configure with the `with_*` builders.
    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan::with_registry(seed, obs::global())
    }

    /// Like [`FaultPlan::new`] but counting `fault.injected` in
    /// `registry` — the test-isolation seam.
    pub fn with_registry(seed: u64, registry: &MetricsRegistry) -> FaultPlan {
        let base = HashRng::new(seed, streams::FAULT);
        let rngs = [base.derive(0), base.derive(1), base.derive(2)];
        FaultPlan {
            seed,
            rngs,
            rates: [0.0; SITES],
            limits: [u64::MAX; SITES],
            latency: Duration::from_millis(1),
            cursors: Default::default(),
            fired: Default::default(),
            injected: registry.counter("fault.injected"),
        }
    }

    /// Set `site` to fire on a `rate` fraction of call indices
    /// (`0.0..=1.0`).
    pub fn with_rate(mut self, site: FaultSite, rate: f64) -> FaultPlan {
        assert!((0.0..=1.0).contains(&rate), "rate must be in [0, 1]");
        self.rates[site as usize] = rate;
        self
    }

    /// Cap `site` at `max_fires` total faults (after which it goes
    /// quiet) — for "fail once, then recover" scenarios.
    pub fn with_limit(mut self, site: FaultSite, max_fires: u64) -> FaultPlan {
        self.limits[site as usize] = max_fires;
        self
    }

    /// Sleep amount injected when [`FaultSite::Latency`] fires.
    pub fn with_latency(mut self, latency: Duration) -> FaultPlan {
        self.latency = latency;
        self
    }

    /// The seed this plan was built from.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// The configured injected-latency amount.
    pub fn latency(&self) -> Duration {
        self.latency
    }

    /// Total faults fired so far (all sites).
    pub fn injected(&self) -> u64 {
        self.injected.get()
    }

    /// Would `site` fire at call index `key`, ignoring limits and
    /// firing no fault? Pure: the decision depends only on
    /// (seed, site, key) — this is the replayable schedule itself.
    pub fn scheduled(&self, site: FaultSite, key: u64) -> bool {
        let i = site as usize;
        self.rates[i] > 0.0 && self.rngs[i].at_f64(key) < self.rates[i]
    }

    /// Fire `site` at explicit call index `key` (deterministic even
    /// across threads when callers derive `key` from their work item —
    /// the trainer keys on (epoch, batch, shard, attempt)). Returns
    /// true and counts the fault iff the schedule says fire and the
    /// site's limit is not exhausted.
    pub fn fires_at(&self, site: FaultSite, key: u64) -> bool {
        if !self.scheduled(site, key) {
            return false;
        }
        let i = site as usize;
        let claimed = self.fired[i]
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |n| {
                (n < self.limits[i]).then_some(n + 1)
            })
            .is_ok();
        if claimed {
            self.injected.inc();
        }
        claimed
    }

    /// Sequential form of [`FaultPlan::fires_at`]: each call consumes
    /// the site's next cursor index. Deterministic for single-threaded
    /// call sites (the serve loop).
    pub fn fires(&self, site: FaultSite) -> bool {
        let k = self.cursors[site as usize].fetch_add(1, Ordering::Relaxed);
        self.fires_at(site, k)
    }
}

impl std::fmt::Debug for FaultPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FaultPlan")
            .field("seed", &self.seed)
            .field("rates", &self.rates)
            .field("latency", &self.latency)
            .finish()
    }
}

/// Mix a trainer work item into one injection key: epoch, batch index
/// within the epoch, shard index, and retry attempt. `attempt` is part
/// of the key so a retried shard draws *fresh* randomness — otherwise
/// a scheduled fault would re-fire forever and retries could never
/// succeed.
pub fn shard_key(epoch: usize, batch: usize, shard: usize, attempt: u32) -> u64 {
    ((epoch as u64) << 44)
        ^ ((batch as u64 & 0xFF_FFFF) << 20)
        ^ ((shard as u64 & 0xFFF) << 8)
        ^ (attempt as u64 & 0xFF)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_seed_same_schedule() {
        let a = FaultPlan::with_registry(42, &MetricsRegistry::new())
            .with_rate(FaultSite::WorkerPanic, 0.3);
        let b = FaultPlan::with_registry(42, &MetricsRegistry::new())
            .with_rate(FaultSite::WorkerPanic, 0.3);
        for k in 0..512 {
            assert_eq!(
                a.scheduled(FaultSite::WorkerPanic, k),
                b.scheduled(FaultSite::WorkerPanic, k),
                "schedules diverge at {k}"
            );
        }
    }

    #[test]
    fn different_seeds_differ_and_rate_is_roughly_honored() {
        let a = FaultPlan::with_registry(1, &MetricsRegistry::new())
            .with_rate(FaultSite::EngineFault, 0.25);
        let b = FaultPlan::with_registry(2, &MetricsRegistry::new())
            .with_rate(FaultSite::EngineFault, 0.25);
        let hits = |p: &FaultPlan| {
            (0..2048).filter(|&k| p.scheduled(FaultSite::EngineFault, k)).count()
        };
        let (ha, hb) = (hits(&a), hits(&b));
        // ~512 expected; a loose band catches rate bugs without flaking
        assert!((300..750).contains(&ha), "rate off: {ha}/2048");
        assert!((300..750).contains(&hb), "rate off: {hb}/2048");
        let agree = (0..2048)
            .filter(|&k| {
                a.scheduled(FaultSite::EngineFault, k) == b.scheduled(FaultSite::EngineFault, k)
            })
            .count();
        assert!(agree < 2048, "independent seeds produced identical schedules");
    }

    #[test]
    fn sites_draw_independent_streams() {
        let p = FaultPlan::with_registry(7, &MetricsRegistry::new())
            .with_rate(FaultSite::EngineFault, 0.5)
            .with_rate(FaultSite::Latency, 0.5);
        let same = (0..1024)
            .filter(|&k| {
                p.scheduled(FaultSite::EngineFault, k) == p.scheduled(FaultSite::Latency, k)
            })
            .count();
        assert!(same < 1024, "sites share a stream");
    }

    #[test]
    fn limit_caps_fired_faults_and_counts_them() {
        let reg = MetricsRegistry::new();
        let p = FaultPlan::with_registry(9, &reg)
            .with_rate(FaultSite::WorkerPanic, 1.0)
            .with_limit(FaultSite::WorkerPanic, 2);
        let fired = (0..100).filter(|_| p.fires(FaultSite::WorkerPanic)).count();
        assert_eq!(fired, 2, "limit not enforced");
        assert_eq!(p.injected(), 2);
        assert_eq!(reg.counter("fault.injected").get(), 2);
    }

    #[test]
    fn zero_rate_never_fires() {
        let p = FaultPlan::with_registry(11, &MetricsRegistry::new());
        assert!((0..256).all(|_| !p.fires(FaultSite::EngineFault)));
        assert_eq!(p.injected(), 0);
    }

    #[test]
    fn shard_key_varies_with_every_component() {
        let base = shard_key(1, 2, 3, 0);
        assert_ne!(base, shard_key(2, 2, 3, 0));
        assert_ne!(base, shard_key(1, 3, 3, 0));
        assert_ne!(base, shard_key(1, 2, 4, 0));
        assert_ne!(base, shard_key(1, 2, 3, 1));
    }
}
