//! The crate's typed error taxonomy for serving and training.
//!
//! Every recoverable failure on the public serving/training APIs is
//! one of these variants — callers match on them instead of fishing
//! through panic payloads or `Option` ambiguity. The taxonomy is
//! deliberately small and closed: each variant maps to exactly one
//! operational response (fix the request, retry, back off, or stop).

use std::fmt;
use std::time::Duration;

/// A typed, recoverable fault from the feature server, the parallel
/// trainer, or the thread pool.
#[derive(Debug, Clone, PartialEq)]
pub enum McError {
    /// Request width does not match the feature map's input width.
    DimMismatch { expected: usize, got: usize },
    /// A NaN/∞ value at `index` (request validation at submit, or a
    /// poisoned feature row detected before the reply scatter).
    NonFinite { index: usize },
    /// The per-request deadline elapsed before a reply arrived.
    Timeout { waited: Duration },
    /// Admission control shed the request: `limit` requests were
    /// already in flight.
    Overloaded { limit: usize },
    /// A worker panicked while holding this work item (the batch was
    /// quarantined, or shard retries were exhausted).
    WorkerPanic,
    /// The target is shutting down (or already gone).
    ShuttingDown,
    /// An I/O failure (checkpoint autosave/load) with its cause.
    Io(String),
}

impl McError {
    /// Stable short tag — metric/log key for the variant.
    pub fn kind(&self) -> &'static str {
        match self {
            McError::DimMismatch { .. } => "dim_mismatch",
            McError::NonFinite { .. } => "non_finite",
            McError::Timeout { .. } => "timeout",
            McError::Overloaded { .. } => "overloaded",
            McError::WorkerPanic => "worker_panic",
            McError::ShuttingDown => "shutting_down",
            McError::Io(_) => "io",
        }
    }
}

impl fmt::Display for McError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            McError::DimMismatch { expected, got } => {
                write!(f, "dimension mismatch: expected {expected}, got {got}")
            }
            McError::NonFinite { index } => {
                write!(f, "non-finite value at index {index}")
            }
            McError::Timeout { waited } => {
                write!(f, "deadline elapsed after {waited:?}")
            }
            McError::Overloaded { limit } => {
                write!(f, "overloaded: {limit} requests already in flight")
            }
            McError::WorkerPanic => write!(f, "worker panicked"),
            McError::ShuttingDown => write!(f, "shutting down"),
            McError::Io(cause) => write!(f, "i/o failure: {cause}"),
        }
    }
}

impl std::error::Error for McError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_and_kind_cover_every_variant() {
        let cases: Vec<(McError, &str)> = vec![
            (McError::DimMismatch { expected: 16, got: 3 }, "dim_mismatch"),
            (McError::NonFinite { index: 7 }, "non_finite"),
            (McError::Timeout { waited: Duration::from_millis(5) }, "timeout"),
            (McError::Overloaded { limit: 4 }, "overloaded"),
            (McError::WorkerPanic, "worker_panic"),
            (McError::ShuttingDown, "shutting_down"),
            (McError::Io("disk full".into()), "io"),
        ];
        for (e, kind) in cases {
            assert_eq!(e.kind(), kind);
            assert!(!e.to_string().is_empty());
        }
    }

    #[test]
    fn is_a_std_error_usable_with_anyhow() {
        fn takes_anyhow(r: std::result::Result<(), McError>) -> anyhow::Result<()> {
            r?;
            Ok(())
        }
        let err = takes_anyhow(Err(McError::WorkerPanic)).unwrap_err();
        assert!(err.to_string().contains("panicked"));
    }
}
