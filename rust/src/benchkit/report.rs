//! Table / CSV reporting for benchmark results — prints the same row
//! layout as the paper's Table 1 and emits CSV series for the figures.

use super::runner::BenchResult;

/// A named collection of benchmark rows: one row = one x-axis point
/// (e.g. transform size), columns = competing implementations.
pub struct Report {
    title: String,
    columns: Vec<String>,
    rows: Vec<(String, Vec<f64>)>,
}

impl Report {
    /// New report with the given column headers.
    pub fn new(title: &str, columns: &[&str]) -> Report {
        Report {
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row of values (same order as the headers).
    pub fn add_row(&mut self, label: &str, values: &[f64]) {
        assert_eq!(values.len(), self.columns.len(), "column count mismatch");
        self.rows.push((label.to_string(), values.to_vec()));
    }

    /// Append a row from bench results (median ms).
    pub fn add_results(&mut self, label: &str, results: &[&BenchResult]) {
        let vals: Vec<f64> = results.iter().map(|r| r.median_ms()).collect();
        self.add_row(label, &vals);
    }

    /// Markdown-ish aligned table.
    pub fn to_table(&self) -> String {
        let mut widths: Vec<usize> = self.columns.iter().map(|c| c.len().max(10)).collect();
        let label_w = self
            .rows
            .iter()
            .map(|(l, _)| l.len())
            .chain([8])
            .max()
            .unwrap();
        let fmt_val = |v: f64| {
            if v == 0.0 {
                "0".to_string()
            } else if v.abs() < 0.01 {
                format!("{v:.5}")
            } else if v.abs() < 10.0 {
                format!("{v:.4}")
            } else {
                format!("{v:.2}")
            }
        };
        for (_, vals) in &self.rows {
            for (w, v) in widths.iter_mut().zip(vals) {
                *w = (*w).max(fmt_val(*v).len());
            }
        }
        let mut out = format!("# {}\n", self.title);
        out += &format!("{:>label_w$}", "");
        for (c, w) in self.columns.iter().zip(&widths) {
            out += &format!("  {c:>w$}");
        }
        out += "\n";
        for (label, vals) in &self.rows {
            out += &format!("{label:>label_w$}");
            for (v, w) in vals.iter().zip(&widths) {
                out += &format!("  {:>w$}", fmt_val(*v));
            }
            out += "\n";
        }
        out
    }

    /// CSV (for plotting the figures).
    pub fn to_csv(&self) -> String {
        let mut out = String::from("label");
        for c in &self.columns {
            out += &format!(",{c}");
        }
        out += "\n";
        for (label, vals) in &self.rows {
            out += label;
            for v in vals {
                out += &format!(",{v}");
            }
            out += "\n";
        }
        out
    }

    /// Write the CSV next to stdout reporting (best effort).
    pub fn write_csv(&self, path: &str) -> std::io::Result<()> {
        if let Some(parent) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_csv())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_contains_everything() {
        let mut r = Report::new("Table 1", &["mckernel", "spiral"]);
        r.add_row("1024", &[0.0333, 0.0667]);
        r.add_row("1048576", &[15.97, 35.7]);
        let t = r.to_table();
        assert!(t.contains("Table 1"));
        assert!(t.contains("mckernel"));
        assert!(t.contains("1048576"));
        assert!(t.contains("35.70"));
    }

    #[test]
    fn csv_shape() {
        let mut r = Report::new("x", &["a", "b"]);
        r.add_row("r1", &[1.0, 2.0]);
        let csv = r.to_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], "label,a,b");
        assert_eq!(lines[1], "r1,1,2");
    }

    #[test]
    #[should_panic]
    fn wrong_arity_rejected() {
        let mut r = Report::new("x", &["a"]);
        r.add_row("r", &[1.0, 2.0]);
    }
}
