//! Shared per-row vs batched feature-pipeline comparison, used by the
//! `bench_features` binary and the `mckernel bench` CLI subcommand so
//! the printed table and the machine-readable JSON snapshot can never
//! diverge. Both paths execute through `mckernel::engine` — the
//! per-row baseline via the plan's explicit per-row override, the
//! batched path via the plan the engine would compile anyway — so the
//! numbers track exactly what the library ships.

use super::runner::{bench, BenchConfig, BenchResult};
use crate::linalg::Matrix;
use crate::mckernel::{ExpansionEngine, McKernel};

/// Timings + output deviation of the two feature paths on one batch.
pub struct FeatureComparison {
    /// Per-row libm oracle (plan forced onto `FwhtDispatch::PerRow`).
    pub per_row: BenchResult,
    /// Batched engine pipeline (the compiled default).
    pub batched: BenchResult,
    /// Max |per-row − batched| over all features (trig-kernel budget).
    pub max_abs_err: f32,
    /// Rows in the timed batch.
    pub rows: usize,
}

impl FeatureComparison {
    /// Median-over-median speedup of the batched path.
    pub fn speedup(&self) -> f64 {
        self.per_row.stats.median / self.batched.stats.median
    }

    /// Batched throughput in rows per second.
    pub fn rows_per_s(&self) -> f64 {
        self.rows as f64 / self.batched.stats.median
    }
}

/// Time the per-row oracle vs the batched engine on the same batch
/// and report the max output deviation between them.
pub fn compare_feature_paths(map: &McKernel, x: &Matrix, cfg: &BenchConfig) -> FeatureComparison {
    let rows = x.rows();
    let mut out_rows = Matrix::zeros(rows, map.feature_dim());
    let mut oracle = ExpansionEngine::per_row_oracle(map);
    let per_row = bench("features/per-row", cfg, |_| {
        oracle.execute_matrix(map, x, &mut out_rows)
    });
    let mut out_batch = Matrix::zeros(rows, map.feature_dim());
    let mut engine = ExpansionEngine::new(map, rows);
    let batched = bench("features/batched", cfg, |_| {
        engine.execute_matrix(map, x, &mut out_batch)
    });
    let max_abs_err = out_rows
        .data()
        .iter()
        .zip(out_batch.data())
        .fold(0.0f32, |m, (a, b)| m.max((a - b).abs()));
    FeatureComparison { per_row, batched, max_abs_err, rows }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mckernel::McKernelFactory;

    #[test]
    fn comparison_outputs_stay_within_budget() {
        let map = McKernelFactory::new(16).expansions(1).seed(2).build();
        let x = Matrix::from_fn(4, 16, |r, c| (r + c) as f32 * 0.1);
        let cmp = compare_feature_paths(&map, &x, &BenchConfig::quick());
        assert!(cmp.max_abs_err < 1e-5, "err {}", cmp.max_abs_err);
        assert!(cmp.speedup() > 0.0);
        assert!(cmp.rows_per_s() > 0.0);
        assert_eq!(cmp.rows, 4);
    }
}
