//! Shared per-row vs batched vs SIMD feature-pipeline comparison, used
//! by the `bench_features` binary and the `mckernel bench` CLI
//! subcommand so the printed table and the machine-readable JSON
//! snapshot can never diverge. All paths execute through
//! `mckernel::engine` — the per-row baseline via the plan's explicit
//! per-row override, the scalar and SIMD tiled paths via explicitly
//! forced plans — so the numbers track exactly what the library ships.

use super::runner::{bench, BenchConfig, BenchResult};
use crate::linalg::Matrix;
use crate::mckernel::{DispatchForce, ExpansionEngine, ExpansionPlan, McKernel};

/// Timings + output deviations of the three feature paths on one batch.
pub struct FeatureComparison {
    /// Per-row libm oracle (plan forced onto `FwhtDispatch::PerRow`).
    pub per_row: BenchResult,
    /// Scalar tiled pipeline (plan forced onto `FwhtDispatch::Batched`).
    pub batched: BenchResult,
    /// SIMD tiled pipeline (plan forced onto `FwhtDispatch::Simd`; on
    /// CPUs without a vector unit its kernels run their scalar
    /// fallbacks, so the timing degenerates to ≈`batched`).
    pub simd: BenchResult,
    /// Max |per-row − batched| over all features (trig-kernel budget).
    pub max_abs_err: f32,
    /// Max |batched − simd| over all features (≤1e-6 contract: FWHT is
    /// bit-identical, only the trig rounding may differ).
    pub simd_max_abs_err: f32,
    /// Rows in the timed batch.
    pub rows: usize,
}

impl FeatureComparison {
    /// Median-over-median speedup of the scalar tiled path over the
    /// per-row oracle.
    pub fn speedup(&self) -> f64 {
        self.per_row.stats.median / self.batched.stats.median
    }

    /// Median-over-median speedup of the SIMD path over the scalar
    /// tiled path (≈1.0 on CPUs without a vector unit).
    pub fn simd_speedup(&self) -> f64 {
        self.batched.stats.median / self.simd.stats.median
    }

    /// Best tiled throughput in rows per second (SIMD if it wins).
    pub fn rows_per_s(&self) -> f64 {
        self.rows as f64 / self.batched.stats.median.min(self.simd.stats.median)
    }
}

/// Time the per-row oracle vs the scalar and SIMD tiled engines on the
/// same batch and report the max output deviations between them.
pub fn compare_feature_paths(map: &McKernel, x: &Matrix, cfg: &BenchConfig) -> FeatureComparison {
    let rows = x.rows();
    let mut out_rows = Matrix::zeros(rows, map.feature_dim());
    let mut oracle = ExpansionEngine::per_row_oracle(map);
    let per_row = bench("features/per-row", cfg, |_| {
        oracle.execute_matrix(map, x, &mut out_rows)
    });
    let mut out_batch = Matrix::zeros(rows, map.feature_dim());
    let mut engine = ExpansionEngine::with_plan(ExpansionPlan::new_forced(
        map.config(),
        rows,
        DispatchForce::Scalar,
    ));
    let batched = bench("features/batched", cfg, |_| {
        engine.execute_matrix(map, x, &mut out_batch)
    });
    let mut out_simd = Matrix::zeros(rows, map.feature_dim());
    let mut simd_engine = ExpansionEngine::with_plan(ExpansionPlan::new_forced(
        map.config(),
        rows,
        DispatchForce::Simd,
    ));
    let simd = bench("features/simd", cfg, |_| {
        simd_engine.execute_matrix(map, x, &mut out_simd)
    });
    let max_abs = |a: &Matrix, b: &Matrix| {
        a.data()
            .iter()
            .zip(b.data())
            .fold(0.0f32, |m, (p, q)| m.max((p - q).abs()))
    };
    let max_abs_err = max_abs(&out_rows, &out_batch);
    let simd_max_abs_err = max_abs(&out_batch, &out_simd);
    FeatureComparison { per_row, batched, simd, max_abs_err, simd_max_abs_err, rows }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mckernel::McKernelFactory;

    #[test]
    fn comparison_outputs_stay_within_budget() {
        let map = McKernelFactory::new(16).expansions(1).seed(2).build();
        let x = Matrix::from_fn(4, 16, |r, c| (r + c) as f32 * 0.1);
        let cmp = compare_feature_paths(&map, &x, &BenchConfig::quick());
        assert!(cmp.max_abs_err < 1e-5, "err {}", cmp.max_abs_err);
        // the PR 9 contract, enforced on every bench run too
        assert!(cmp.simd_max_abs_err <= 1e-6, "simd err {}", cmp.simd_max_abs_err);
        assert!(cmp.speedup() > 0.0);
        assert!(cmp.simd_speedup() > 0.0);
        assert!(cmp.rows_per_s() > 0.0);
        assert_eq!(cmp.rows, 4);
    }
}
