//! Shared per-row vs batched feature-pipeline comparison, used by the
//! `bench_features` binary and the `mckernel bench` CLI subcommand so
//! the printed table and the machine-readable JSON snapshot can never
//! diverge.

use super::runner::{bench, BenchConfig, BenchResult};
use crate::linalg::Matrix;
use crate::mckernel::McKernel;

/// Timings + output deviation of the two feature paths on one batch.
pub struct FeatureComparison {
    /// Per-row `transform_into` loop (the libm oracle).
    pub per_row: BenchResult,
    /// Batched `transform_batch_into` pipeline.
    pub batched: BenchResult,
    /// Max |per-row − batched| over all features (trig-kernel budget).
    pub max_abs_err: f32,
    /// Rows in the timed batch.
    pub rows: usize,
}

impl FeatureComparison {
    /// Median-over-median speedup of the batched path.
    pub fn speedup(&self) -> f64 {
        self.per_row.stats.median / self.batched.stats.median
    }

    /// Batched throughput in rows per second.
    pub fn rows_per_s(&self) -> f64 {
        self.rows as f64 / self.batched.stats.median
    }
}

/// Time the per-row oracle vs the batched pipeline on the same batch
/// and report the max output deviation between them.
pub fn compare_feature_paths(map: &McKernel, x: &Matrix, cfg: &BenchConfig) -> FeatureComparison {
    let rows = x.rows();
    let mut out_rows = Matrix::zeros(rows, map.feature_dim());
    let mut scratch_row = map.make_scratch();
    let per_row = bench("features/per-row", cfg, |_| {
        for r in 0..rows {
            map.transform_into(x.row(r), out_rows.row_mut(r), &mut scratch_row);
        }
    });
    let mut out_batch = Matrix::zeros(rows, map.feature_dim());
    let mut scratch = map.make_batch_scratch();
    let batched = bench("features/batched", cfg, |_| {
        map.transform_batch_into(x, &mut out_batch, &mut scratch)
    });
    let max_abs_err = out_rows
        .data()
        .iter()
        .zip(out_batch.data())
        .fold(0.0f32, |m, (a, b)| m.max((a - b).abs()));
    FeatureComparison { per_row, batched, max_abs_err, rows }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mckernel::McKernelFactory;

    #[test]
    fn comparison_outputs_stay_within_budget() {
        let map = McKernelFactory::new(16).expansions(1).seed(2).build();
        let x = Matrix::from_fn(4, 16, |r, c| (r + c) as f32 * 0.1);
        let cmp = compare_feature_paths(&map, &x, &BenchConfig::quick());
        assert!(cmp.max_abs_err < 1e-5, "err {}", cmp.max_abs_err);
        assert!(cmp.speedup() > 0.0);
        assert!(cmp.rows_per_s() > 0.0);
        assert_eq!(cmp.rows, 4);
    }
}
