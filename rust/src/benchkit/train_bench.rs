//! Serial-oracle vs data-parallel trainer comparison, shared by the
//! `mckernel bench` CLI subcommand (which writes `BENCH_train.json`)
//! so the printed table and the machine-readable snapshot can never
//! diverge — the same contract `compare_feature_paths` gives the
//! feature pipeline.

use super::runner::{bench, BenchConfig, BenchResult};
use crate::data::{Dataset, SyntheticSpec};
use crate::optim::SgdConfig;
use crate::train::{Featurizer, ParallelTrainer, TrainConfig, Trainer};

/// Timings + accuracy deviation of serial vs sharded training on the
/// same synthetic problem.
pub struct TrainComparison {
    /// The single-threaded epoch-loop [`Trainer`] (the oracle).
    pub serial: BenchResult,
    /// The N-worker sharded [`ParallelTrainer`].
    pub parallel: BenchResult,
    /// Worker threads in the parallel run.
    pub workers: usize,
    /// Training rows per timed epoch.
    pub rows: usize,
    /// |serial − parallel| final test accuracy (summation-order drift;
    /// the parallel_train.rs suite bounds this at 1e-5).
    pub acc_delta: f64,
}

impl TrainComparison {
    /// Median-over-median speedup of the sharded trainer.
    pub fn speedup(&self) -> f64 {
        self.serial.stats.median / self.parallel.stats.median
    }

    /// Sharded training throughput in rows per second.
    pub fn rows_per_s(&self) -> f64 {
        self.rows as f64 / self.parallel.stats.median
    }
}

/// Time one epoch of mini-batch SGD (identity features, so the SGD
/// step — the part this engine parallelizes — dominates; both timed
/// regions include the same serial final-epoch evaluation) through
/// the serial trainer vs the `workers`-sharded trainer, and record
/// the final-accuracy deviation between the two paths. Both trainers
/// are deterministic, so the reports captured from the timed runs are
/// the reports of every run.
pub fn compare_train_paths(
    rows: usize,
    batch: usize,
    workers: usize,
    cfg: &BenchConfig,
) -> TrainComparison {
    let spec = SyntheticSpec::mnist();
    let train = Dataset::synthetic(7, &spec, "train", rows);
    let test = Dataset::synthetic(7, &spec, "test", (rows / 4).max(16));
    let tc = TrainConfig {
        epochs: 1,
        batch_size: batch,
        sgd: SgdConfig { lr: 0.01, momentum: 0.0, clip: None },
        seed: 7,
        eval_every_epoch: false,
        verbose: false,
        workers,
        cache_bytes: None,
    };
    let serial_trainer =
        Trainer::new(TrainConfig { workers: 1, ..tc.clone() }, Featurizer::Identity);
    let mut serial_acc = f64::NAN;
    let serial = bench("train/serial", cfg, |_| {
        serial_acc = serial_trainer.fit(&train, &test).1.final_test_accuracy;
    });
    let parallel_trainer = ParallelTrainer::new(tc, Featurizer::Identity);
    let mut parallel_acc = f64::NAN;
    let parallel = bench("train/parallel", cfg, |_| {
        parallel_acc = parallel_trainer
            .fit(&train, &test)
            .expect("parallel fit")
            .1
            .final_test_accuracy;
    });
    let acc_delta = (serial_acc - parallel_acc).abs();
    TrainComparison { serial, parallel, workers, rows, acc_delta }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comparison_is_tight_and_positive() {
        let cmp = compare_train_paths(64, 16, 2, &BenchConfig::quick());
        assert!(cmp.acc_delta <= 1e-5, "accuracy drift {}", cmp.acc_delta);
        assert!(cmp.speedup() > 0.0);
        assert!(cmp.rows_per_s() > 0.0);
        assert_eq!(cmp.rows, 64);
        assert_eq!(cmp.workers, 2);
    }
}
