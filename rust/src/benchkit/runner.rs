//! Benchmark execution: warmup, auto-calibrated batching, repeated
//! measurement.

use super::stats::Stats;
use std::time::{Duration, Instant};

/// Configuration for one benchmark.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    /// Warmup wall-clock budget.
    pub warmup: Duration,
    /// Measurement wall-clock budget.
    pub measure: Duration,
    /// Number of timed samples to split the budget into.
    pub samples: usize,
    /// Lower bound on iterations per sample (after calibration).
    pub min_iters: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup: Duration::from_millis(150),
            measure: Duration::from_millis(600),
            samples: 20,
            min_iters: 1,
        }
    }
}

impl BenchConfig {
    /// A faster profile for CI / tests.
    pub fn quick() -> Self {
        BenchConfig {
            warmup: Duration::from_millis(20),
            measure: Duration::from_millis(80),
            samples: 8,
            min_iters: 1,
        }
    }
}

/// Result of one benchmark: per-iteration statistics in seconds.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub stats: Stats,
    pub iters_per_sample: usize,
}

impl BenchResult {
    /// Median time in milliseconds (the Table 1 unit).
    pub fn median_ms(&self) -> f64 {
        self.stats.median * 1e3
    }

    /// Median time in nanoseconds.
    pub fn median_ns(&self) -> f64 {
        self.stats.median * 1e9
    }

    /// Throughput in items/second given items processed per iteration.
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.stats.median
    }
}

/// Run `f` under `cfg`, timing per-iteration cost. `f` receives the
/// iteration index (so it can rotate inputs and defeat value caching).
pub fn bench<F: FnMut(usize)>(name: &str, cfg: &BenchConfig, mut f: F) -> BenchResult {
    // Warmup + calibration: count how many iterations fit the budget.
    let start = Instant::now();
    let mut warm_iters = 0usize;
    while start.elapsed() < cfg.warmup || warm_iters == 0 {
        f(warm_iters);
        warm_iters += 1;
    }
    let per_iter = start.elapsed().as_secs_f64() / warm_iters as f64;

    // Choose iterations per sample so samples fill the measure budget.
    let budget = cfg.measure.as_secs_f64() / cfg.samples as f64;
    let iters = ((budget / per_iter).ceil() as usize).max(cfg.min_iters);

    let mut samples = Vec::with_capacity(cfg.samples);
    let mut k = 0usize;
    for _ in 0..cfg.samples {
        let t0 = Instant::now();
        for _ in 0..iters {
            f(k);
            k += 1;
        }
        samples.push(t0.elapsed().as_secs_f64() / iters as f64);
    }
    BenchResult { name: name.to_string(), stats: Stats::of(&samples), iters_per_sample: iters }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let cfg = BenchConfig::quick();
        let mut acc = 0u64;
        let r = bench("spin", &cfg, |i| {
            // ~constant work
            for j in 0..100 {
                acc = acc.wrapping_add((i * j) as u64);
            }
        });
        assert!(r.stats.median > 0.0);
        assert!(r.stats.min <= r.stats.median);
        assert!(r.stats.median <= r.stats.max);
        assert_eq!(r.stats.n, cfg.samples);
        assert!(acc != 42); // keep acc live
    }

    #[test]
    fn ranks_workloads_by_cost() {
        let cfg = BenchConfig::quick();
        let mut sink = 0.0f64;
        let small = bench("small", &cfg, |_| {
            for i in 0..50 {
                sink += (i as f64).sqrt();
            }
        });
        let large = bench("large", &cfg, |_| {
            for i in 0..5000 {
                sink += (i as f64).sqrt();
            }
        });
        assert!(
            large.stats.median > small.stats.median * 5.0,
            "large {} vs small {} (sink {sink})",
            large.stats.median,
            small.stats.median
        );
    }

    #[test]
    fn unit_conversions() {
        let r = BenchResult {
            name: "x".into(),
            stats: Stats::of(&[0.002]),
            iters_per_sample: 1,
        };
        assert!((r.median_ms() - 2.0).abs() < 1e-9);
        assert!((r.median_ns() - 2e6).abs() < 1e-3);
        assert!((r.throughput(10.0) - 5000.0).abs() < 1e-6);
    }
}
