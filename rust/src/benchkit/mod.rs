//! In-tree micro-benchmark harness (criterion is unreachable offline).
//!
//! Provides warmed-up, repetition-based timing with robust statistics
//! (min / median / mean / p95), table and CSV reporting — enough to
//! regenerate the paper's Table 1 / Figure 2 and the ablation benches.

pub mod feature_bench;
pub mod report;
pub mod runner;
pub mod stats;
pub mod train_bench;

pub use feature_bench::{compare_feature_paths, FeatureComparison};
pub use report::Report;
pub use runner::{bench, BenchConfig, BenchResult};
pub use stats::Stats;
pub use train_bench::{compare_train_paths, TrainComparison};
