//! Robust summary statistics over timing samples.

/// Summary statistics of a sample of per-iteration times (seconds).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Stats {
    pub n: usize,
    pub min: f64,
    pub max: f64,
    pub mean: f64,
    pub median: f64,
    pub p95: f64,
    pub stddev: f64,
}

impl Stats {
    /// Compute statistics of `samples` (need not be sorted; empty
    /// samples are rejected).
    pub fn of(samples: &[f64]) -> Stats {
        assert!(!samples.is_empty(), "no samples");
        let mut s = samples.to_vec();
        s.sort_by(f64::total_cmp);
        let n = s.len();
        let mean = s.iter().sum::<f64>() / n as f64;
        let var = s.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Stats {
            n,
            min: s[0],
            max: s[n - 1],
            mean,
            median: percentile_sorted(&s, 50.0),
            p95: percentile_sorted(&s, 95.0),
            stddev: var.sqrt(),
        }
    }
}

/// Linear-interpolated percentile of an already sorted slice.
pub fn percentile_sorted(sorted: &[f64], pct: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&pct));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = pct / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let s = Stats::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.median, 3.0);
        assert!((s.stddev - 2.0f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn unsorted_input_ok() {
        let s = Stats::of(&[5.0, 1.0, 3.0]);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.median, 3.0);
    }

    #[test]
    fn percentile_interpolates() {
        let s = [0.0, 10.0];
        assert_eq!(percentile_sorted(&s, 0.0), 0.0);
        assert_eq!(percentile_sorted(&s, 50.0), 5.0);
        assert_eq!(percentile_sorted(&s, 100.0), 10.0);
    }

    #[test]
    fn single_sample() {
        let s = Stats::of(&[7.5]);
        assert_eq!(s.median, 7.5);
        assert_eq!(s.p95, 7.5);
        assert_eq!(s.stddev, 0.0);
    }

    #[test]
    #[should_panic]
    fn empty_rejected() {
        Stats::of(&[]);
    }
}
