//! Robust summary statistics over timing samples.

use crate::obs::Dist;
use crate::util::json::Json;

/// Summary statistics of a sample of per-iteration times (seconds).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Stats {
    pub n: usize,
    pub min: f64,
    pub max: f64,
    pub mean: f64,
    pub median: f64,
    pub p95: f64,
    pub p99: f64,
    pub stddev: f64,
}

impl Stats {
    /// Compute statistics of `samples` (need not be sorted). An empty
    /// slice yields the NaN-free all-zero [`Stats::empty`] rather than
    /// panicking or propagating NaN into reports.
    pub fn of(samples: &[f64]) -> Stats {
        if samples.is_empty() {
            return Stats::empty();
        }
        let mut s = samples.to_vec();
        s.sort_by(f64::total_cmp);
        let n = s.len();
        let mean = s.iter().sum::<f64>() / n as f64;
        let var = s.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Stats {
            n,
            min: s[0],
            max: s[n - 1],
            mean,
            median: percentile_sorted(&s, 50.0),
            p95: percentile_sorted(&s, 95.0),
            p99: percentile_sorted(&s, 99.0),
            stddev: var.sqrt(),
        }
    }

    /// The zero-sample summary: every field 0, nothing NaN.
    pub fn empty() -> Stats {
        Stats { n: 0, min: 0.0, max: 0.0, mean: 0.0, median: 0.0, p95: 0.0, p99: 0.0, stddev: 0.0 }
    }

    /// This summary in the shared observability distribution schema
    /// ([`crate::obs::Dist`]), converted from seconds to nanoseconds —
    /// so a BENCH_*.json distribution and a live `server.latency_ns`
    /// snapshot parse identically.
    pub fn to_dist_json_ns(&self) -> Json {
        let ns = 1e9;
        Dist {
            count: self.n as u64,
            sum: self.mean * self.n as f64 * ns,
            min: self.min * ns,
            max: self.max * ns,
            mean: self.mean * ns,
            p50: self.median * ns,
            p95: self.p95 * ns,
            p99: self.p99 * ns,
        }
        .to_json()
    }
}

/// Linear-interpolated percentile of an already sorted slice
/// (0.0 for an empty slice — NaN never escapes into reports).
pub fn percentile_sorted(sorted: &[f64], pct: f64) -> f64 {
    assert!((0.0..=100.0).contains(&pct));
    if sorted.is_empty() {
        return 0.0;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = pct / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let s = Stats::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.median, 3.0);
        assert!(s.p95 <= s.p99 && s.p99 <= s.max);
        assert!((s.stddev - 2.0f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn unsorted_input_ok() {
        let s = Stats::of(&[5.0, 1.0, 3.0]);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.median, 3.0);
    }

    #[test]
    fn percentile_interpolates() {
        let s = [0.0, 10.0];
        assert_eq!(percentile_sorted(&s, 0.0), 0.0);
        assert_eq!(percentile_sorted(&s, 50.0), 5.0);
        assert_eq!(percentile_sorted(&s, 100.0), 10.0);
    }

    #[test]
    fn single_sample() {
        let s = Stats::of(&[7.5]);
        assert_eq!(s.median, 7.5);
        assert_eq!(s.p95, 7.5);
        assert_eq!(s.p99, 7.5);
        assert_eq!(s.stddev, 0.0);
    }

    #[test]
    fn empty_samples_are_nan_free_zeros() {
        let s = Stats::of(&[]);
        assert_eq!(s, Stats::empty());
        assert_eq!(s.n, 0);
        for v in [s.min, s.max, s.mean, s.median, s.p95, s.p99, s.stddev] {
            assert_eq!(v, 0.0);
        }
        assert_eq!(percentile_sorted(&[], 50.0), 0.0);
        assert_eq!(percentile_sorted(&[], 0.0), 0.0);
        assert_eq!(percentile_sorted(&[], 100.0), 0.0);
    }

    #[test]
    fn dist_json_uses_shared_schema_in_ns() {
        let s = Stats::of(&[0.001, 0.002, 0.003]); // 1–3 ms
        let j = s.to_dist_json_ns();
        assert_eq!(j.get("count").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("mean").unwrap().as_f64(), Some(2.0e6));
        assert_eq!(j.get("min").unwrap().as_f64(), Some(1.0e6));
        assert_eq!(j.get("p50").unwrap().as_f64(), Some(2.0e6));
        // same keys as a live histogram snapshot
        let live = crate::obs::Hist::new();
        live.record(100);
        let live_j = live.snapshot().to_json();
        let keys = |v: &crate::util::json::Json| -> Vec<String> {
            v.as_obj().unwrap().keys().cloned().collect()
        };
        assert_eq!(keys(&j), keys(&live_j));
    }
}
