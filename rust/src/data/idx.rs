//! IDX file format (the MNIST container: Y. LeCun's format).
//!
//! Layout: big-endian magic `0x00 0x00 <dtype> <ndim>`, then `ndim`
//! u32 dimension sizes, then the payload. We support the two dtypes
//! the MNIST family uses: `0x08` (unsigned byte) for both images
//! (ndim 3) and labels (ndim 1). The loader accepts real MNIST /
//! FASHION-MNIST files when the user has them; the synthetic
//! generator writes the same format so the whole pipeline is
//! format-identical to the paper's inputs.

use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

/// A parsed IDX tensor of unsigned bytes.
#[derive(Debug, Clone, PartialEq)]
pub struct IdxU8 {
    /// Dimension sizes (e.g. `[60000, 28, 28]` for MNIST images).
    pub dims: Vec<usize>,
    /// Row-major payload.
    pub data: Vec<u8>,
}

const DTYPE_U8: u8 = 0x08;

impl IdxU8 {
    /// Total element count.
    pub fn len(&self) -> usize {
        self.dims.iter().product()
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Number of items along the first axis.
    pub fn items(&self) -> usize {
        *self.dims.first().unwrap_or(&0)
    }

    /// Elements per item (product of trailing dims).
    pub fn item_size(&self) -> usize {
        self.dims.iter().skip(1).product()
    }

    /// Parse from a reader.
    pub fn read_from<R: Read>(mut r: R) -> Result<IdxU8> {
        let mut head = [0u8; 4];
        r.read_exact(&mut head).context("IDX header")?;
        if head[0] != 0 || head[1] != 0 {
            bail!("bad IDX magic: {:02x}{:02x}", head[0], head[1]);
        }
        if head[2] != DTYPE_U8 {
            bail!("unsupported IDX dtype 0x{:02x} (only u8 supported)", head[2]);
        }
        let ndim = head[3] as usize;
        if ndim == 0 || ndim > 4 {
            bail!("unreasonable IDX ndim {ndim}");
        }
        let mut dims = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            let mut b = [0u8; 4];
            r.read_exact(&mut b).context("IDX dims")?;
            dims.push(u32::from_be_bytes(b) as usize);
        }
        let total: usize = dims.iter().product();
        if total > 1 << 31 {
            bail!("IDX payload too large: {total} elements");
        }
        let mut data = vec![0u8; total];
        r.read_exact(&mut data).context("IDX payload")?;
        Ok(IdxU8 { dims, data })
    }

    /// Load from a file path.
    pub fn read_file<P: AsRef<Path>>(path: P) -> Result<IdxU8> {
        let f = std::fs::File::open(&path)
            .with_context(|| format!("open {}", path.as_ref().display()))?;
        IdxU8::read_from(std::io::BufReader::new(f))
    }

    /// Serialize to a writer.
    pub fn write_to<W: Write>(&self, mut w: W) -> Result<()> {
        assert_eq!(self.data.len(), self.len(), "dims/payload mismatch");
        assert!(self.dims.len() <= 4 && !self.dims.is_empty());
        w.write_all(&[0, 0, DTYPE_U8, self.dims.len() as u8])?;
        for &d in &self.dims {
            w.write_all(&(d as u32).to_be_bytes())?;
        }
        w.write_all(&self.data)?;
        Ok(())
    }

    /// Write to a file path.
    pub fn write_file<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let f = std::fs::File::create(&path)
            .with_context(|| format!("create {}", path.as_ref().display()))?;
        self.write_to(std::io::BufWriter::new(f))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_images() {
        let idx = IdxU8 {
            dims: vec![3, 4, 5],
            data: (0..60).map(|i| (i * 3) as u8).collect(),
        };
        let mut buf = Vec::new();
        idx.write_to(&mut buf).unwrap();
        let back = IdxU8::read_from(&buf[..]).unwrap();
        assert_eq!(idx, back);
        assert_eq!(back.items(), 3);
        assert_eq!(back.item_size(), 20);
    }

    #[test]
    fn roundtrip_labels() {
        let idx = IdxU8 { dims: vec![7], data: vec![0, 1, 2, 3, 4, 5, 6] };
        let mut buf = Vec::new();
        idx.write_to(&mut buf).unwrap();
        let back = IdxU8::read_from(&buf[..]).unwrap();
        assert_eq!(idx, back);
        assert_eq!(back.item_size(), 1);
    }

    #[test]
    fn header_layout_matches_mnist_spec() {
        let idx = IdxU8 { dims: vec![2, 28, 28], data: vec![0; 2 * 28 * 28] };
        let mut buf = Vec::new();
        idx.write_to(&mut buf).unwrap();
        // magic for u8 3-dim: 00 00 08 03
        assert_eq!(&buf[..4], &[0, 0, 8, 3]);
        // first dim big-endian = 2
        assert_eq!(&buf[4..8], &[0, 0, 0, 2]);
    }

    #[test]
    fn rejects_bad_magic() {
        assert!(IdxU8::read_from(&[1, 2, 3, 4][..]).is_err());
    }

    #[test]
    fn rejects_wrong_dtype() {
        // dtype 0x0D (float) unsupported
        let buf = [0u8, 0, 0x0D, 1, 0, 0, 0, 0];
        assert!(IdxU8::read_from(&buf[..]).is_err());
    }

    #[test]
    fn rejects_truncated_payload() {
        let buf = [0u8, 0, 8, 1, 0, 0, 0, 10, 1, 2, 3]; // says 10, has 3
        assert!(IdxU8::read_from(&buf[..]).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("mckernel_idx_test");
        let path = dir.join("t.idx");
        let idx = IdxU8 { dims: vec![2, 3], data: vec![9; 6] };
        idx.write_file(&path).unwrap();
        assert_eq!(IdxU8::read_file(&path).unwrap(), idx);
        let _ = std::fs::remove_dir_all(dir);
    }
}
