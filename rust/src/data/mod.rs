//! Dataset substrate: the IDX (MNIST) container format, deterministic
//! synthetic MNIST/FASHION-MNIST generators (the data substitution —
//! see DESIGN.md §5), and mini-batch iteration.

pub mod batcher;
pub mod dataset;
pub mod idx;
pub mod synthetic;

pub use batcher::Batcher;
pub use dataset::Dataset;
pub use synthetic::{generate, SyntheticSpec};
