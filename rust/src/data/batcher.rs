//! Mini-batch iteration with deterministic per-epoch shuffling — the
//! paper's mini-batch SGD setting (§7, §9; batch size 10 in the
//! figures).

use super::dataset::Dataset;
use crate::hash::hash_rng::streams;
use crate::hash::HashRng;
use crate::linalg::Matrix;
use crate::rand::fisher_yates::random_permutation;

/// One mini-batch: a dense `(b, d)` slice of the dataset + labels.
#[derive(Debug, Clone)]
pub struct Batch {
    pub images: Matrix,
    pub labels: Vec<u8>,
    /// Position of this batch within the epoch.
    pub index: usize,
}

/// Deterministic shuffling batcher: epoch `e` visits the dataset in
/// the order of a hash-derived Fisher–Yates permutation of `(seed, e)`.
#[derive(Debug)]
pub struct Batcher {
    batch_size: usize,
    seed: u64,
    /// When false, iterate in dataset order (full-batch / eval).
    shuffle: bool,
    /// When true, drop the final ragged batch.
    drop_last: bool,
}

impl Batcher {
    /// New shuffling batcher.
    pub fn new(batch_size: usize, seed: u64) -> Batcher {
        assert!(batch_size > 0);
        Batcher { batch_size, seed, shuffle: true, drop_last: false }
    }

    /// Disable shuffling (evaluation order).
    pub fn sequential(mut self) -> Batcher {
        self.shuffle = false;
        self
    }

    /// Drop the final ragged batch.
    pub fn drop_last(mut self) -> Batcher {
        self.drop_last = true;
        self
    }

    /// Number of batches per epoch over `n` samples.
    pub fn batches_per_epoch(&self, n: usize) -> usize {
        if self.drop_last {
            n / self.batch_size
        } else {
            n.div_ceil(self.batch_size)
        }
    }

    /// Materialize the batches of `epoch` over `data`.
    pub fn epoch<'d>(&self, data: &'d Dataset, epoch: usize) -> BatchIter<'d> {
        let n = data.len();
        let order: Vec<u32> = if self.shuffle {
            let mut rng = HashRng::new(self.seed, streams::SHUFFLE).derive(epoch as u64);
            random_permutation(n, &mut rng)
        } else {
            (0..n as u32).collect()
        };
        BatchIter {
            data,
            order,
            batch_size: self.batch_size,
            drop_last: self.drop_last,
            cursor: 0,
            index: 0,
        }
    }
}

/// Iterator over one epoch's batches.
pub struct BatchIter<'d> {
    data: &'d Dataset,
    order: Vec<u32>,
    batch_size: usize,
    drop_last: bool,
    cursor: usize,
    index: usize,
}

impl Iterator for BatchIter<'_> {
    type Item = Batch;

    fn next(&mut self) -> Option<Batch> {
        let n = self.order.len();
        if self.cursor >= n {
            return None;
        }
        let end = (self.cursor + self.batch_size).min(n);
        if self.drop_last && end - self.cursor < self.batch_size {
            return None;
        }
        let idxs = &self.order[self.cursor..end];
        let d = self.data.dim();
        let mut images = Matrix::zeros(idxs.len(), d);
        let mut labels = Vec::with_capacity(idxs.len());
        for (r, &i) in idxs.iter().enumerate() {
            images.row_mut(r).copy_from_slice(self.data.images().row(i as usize));
            labels.push(self.data.labels()[i as usize]);
        }
        let batch = Batch { images, labels, index: self.index };
        self.cursor = end;
        self.index += 1;
        Some(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::SyntheticSpec;

    fn data(n: usize) -> Dataset {
        Dataset::synthetic(7, &SyntheticSpec::mnist(), "train", n)
    }

    #[test]
    fn covers_every_sample_exactly_once() {
        let d = data(53);
        let b = Batcher::new(10, 1);
        let mut seen = vec![0u32; 53];
        for batch in b.epoch(&d, 0) {
            for r in 0..batch.images.rows() {
                // match rows back to dataset by exhaustive comparison
                let row = batch.images.row(r);
                let i = (0..53).find(|&i| d.images().row(i) == row).unwrap();
                seen[i] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1), "{seen:?}");
    }

    #[test]
    fn batch_count_and_ragged_tail() {
        let d = data(53);
        let b = Batcher::new(10, 1);
        assert_eq!(b.batches_per_epoch(53), 6);
        let batches: Vec<_> = b.epoch(&d, 0).collect();
        assert_eq!(batches.len(), 6);
        assert_eq!(batches[5].images.rows(), 3);
        let dropping = Batcher::new(10, 1).drop_last();
        assert_eq!(dropping.batches_per_epoch(53), 5);
        assert_eq!(dropping.epoch(&d, 0).count(), 5);
    }

    #[test]
    fn epochs_shuffle_differently_but_deterministically() {
        let d = data(40);
        let b = Batcher::new(40, 9);
        let e0: Vec<u8> = b.epoch(&d, 0).next().unwrap().labels;
        let e0_again: Vec<u8> = b.epoch(&d, 0).next().unwrap().labels;
        let e1: Vec<u8> = b.epoch(&d, 1).next().unwrap().labels;
        assert_eq!(e0, e0_again);
        assert_ne!(e0, e1);
    }

    #[test]
    fn sequential_preserves_order() {
        let d = data(25);
        let b = Batcher::new(25, 0).sequential();
        let batch = b.epoch(&d, 3).next().unwrap();
        assert_eq!(batch.labels, d.labels());
    }

    #[test]
    fn labels_travel_with_rows() {
        let d = data(30);
        let b = Batcher::new(7, 2);
        for batch in b.epoch(&d, 5) {
            for r in 0..batch.images.rows() {
                let row = batch.images.row(r);
                let i = (0..30).find(|&i| d.images().row(i) == row).unwrap();
                assert_eq!(batch.labels[r], d.labels()[i]);
            }
        }
    }
}
