//! In-memory labelled dataset: images as normalized f32 rows.

use super::idx::IdxU8;
use super::synthetic::{self, SyntheticSpec, CLASSES, PIXELS};
use crate::linalg::Matrix;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// A labelled dataset: `(n, d)` feature matrix (pixels normalized to
/// `[0,1]`) + class labels.
#[derive(Debug, Clone)]
pub struct Dataset {
    images: Matrix,
    labels: Vec<u8>,
    classes: usize,
}

impl Dataset {
    /// Build from raw parts.
    pub fn new(images: Matrix, labels: Vec<u8>, classes: usize) -> Dataset {
        assert_eq!(images.rows(), labels.len(), "image/label count");
        assert!(labels.iter().all(|&l| (l as usize) < classes), "label out of range");
        Dataset { images, labels, classes }
    }

    /// Generate a synthetic split (see [`super::synthetic`]).
    pub fn synthetic(seed: u64, spec: &SyntheticSpec, split: &str, n: usize) -> Dataset {
        let (raw, labels) = synthetic::generate(seed, spec, split, n);
        let images = Matrix::from_vec(
            n,
            PIXELS,
            raw.iter().map(|&b| b as f32 / 255.0).collect(),
        );
        Dataset { images, labels, classes: CLASSES }
    }

    /// Load an MNIST-format pair of IDX files
    /// (`images`: `[n, 28, 28]` u8, `labels`: `[n]` u8).
    pub fn from_idx_files<P: AsRef<Path>>(images_path: P, labels_path: P) -> Result<Dataset> {
        let img = IdxU8::read_file(&images_path).context("images file")?;
        let lab = IdxU8::read_file(&labels_path).context("labels file")?;
        if img.dims.len() != 3 {
            bail!("expected 3-dim image tensor, got {:?}", img.dims);
        }
        if lab.dims.len() != 1 {
            bail!("expected 1-dim label tensor, got {:?}", lab.dims);
        }
        if img.items() != lab.items() {
            bail!("image/label count mismatch: {} vs {}", img.items(), lab.items());
        }
        let d = img.item_size();
        let images = Matrix::from_vec(
            img.items(),
            d,
            img.data.iter().map(|&b| b as f32 / 255.0).collect(),
        );
        let classes = lab.data.iter().copied().max().unwrap_or(0) as usize + 1;
        Ok(Dataset { images, labels: lab.data, classes })
    }

    /// Write this dataset out as the IDX pair (for interchange with
    /// the Python compile path and external tools).
    pub fn write_idx_files<P: AsRef<Path>>(&self, images_path: P, labels_path: P) -> Result<()> {
        let side = (self.dim() as f64).sqrt() as usize;
        assert_eq!(side * side, self.dim(), "non-square images");
        let img = IdxU8 {
            dims: vec![self.len(), side, side],
            data: self
                .images
                .data()
                .iter()
                .map(|&v| (v * 255.0).round().clamp(0.0, 255.0) as u8)
                .collect(),
        };
        let lab = IdxU8 { dims: vec![self.len()], data: self.labels.clone() };
        img.write_file(images_path)?;
        lab.write_file(labels_path)?;
        Ok(())
    }

    /// Number of samples.
    pub fn len(&self) -> usize {
        self.images.rows()
    }

    /// True if no samples.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Feature dimension (pixels).
    pub fn dim(&self) -> usize {
        self.images.cols()
    }

    /// Number of classes.
    pub fn classes(&self) -> usize {
        self.classes
    }

    /// Feature matrix.
    pub fn images(&self) -> &Matrix {
        &self.images
    }

    /// Labels.
    pub fn labels(&self) -> &[u8] {
        &self.labels
    }

    /// Sample `i` as `(row, label)`.
    pub fn sample(&self, i: usize) -> (&[f32], u8) {
        (self.images.row(i), self.labels[i])
    }

    /// First `n` samples as a new dataset (paper Figure 3 rounds the
    /// train/test sizes to powers of two).
    pub fn take(&self, n: usize) -> Dataset {
        assert!(n <= self.len());
        let images = Matrix::from_vec(
            n,
            self.dim(),
            self.images.data()[..n * self.dim()].to_vec(),
        );
        Dataset { images, labels: self.labels[..n].to_vec(), classes: self.classes }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        Dataset::synthetic(1, &SyntheticSpec::mnist(), "train", 30)
    }

    #[test]
    fn synthetic_shape() {
        let d = tiny();
        assert_eq!(d.len(), 30);
        assert_eq!(d.dim(), 784);
        assert_eq!(d.classes(), 10);
        assert!(!d.is_empty());
    }

    #[test]
    fn pixels_normalized() {
        let d = tiny();
        assert!(d.images().data().iter().all(|&v| (0.0..=1.0).contains(&v)));
        // and not all zero
        assert!(d.images().data().iter().any(|&v| v > 0.3));
    }

    #[test]
    fn sample_accessor() {
        let d = tiny();
        let (row, label) = d.sample(3);
        assert_eq!(row.len(), 784);
        assert!((label as usize) < 10);
        assert_eq!(row, d.images().row(3));
    }

    #[test]
    fn take_prefix() {
        let d = tiny();
        let t = d.take(10);
        assert_eq!(t.len(), 10);
        assert_eq!(t.images().row(5), d.images().row(5));
        assert_eq!(t.labels()[..], d.labels()[..10]);
    }

    #[test]
    fn idx_roundtrip_through_files() {
        let d = tiny();
        let dir = std::env::temp_dir().join("mckernel_ds_test");
        let ip = dir.join("img.idx");
        let lp = dir.join("lab.idx");
        d.write_idx_files(&ip, &lp).unwrap();
        let back = Dataset::from_idx_files(&ip, &lp).unwrap();
        assert_eq!(back.len(), d.len());
        assert_eq!(back.dim(), d.dim());
        assert_eq!(back.labels(), d.labels());
        // round-trip through u8 quantization: max error 0.5/255
        for (a, b) in back.images().data().iter().zip(d.images().data()) {
            assert!((a - b).abs() <= 0.5 / 255.0 + 1e-6);
        }
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn rejects_mismatched_idx() {
        let dir = std::env::temp_dir().join("mckernel_ds_bad");
        let ip = dir.join("img.idx");
        let lp = dir.join("lab.idx");
        IdxU8 { dims: vec![2, 28, 28], data: vec![0; 2 * 784] }.write_file(&ip).unwrap();
        IdxU8 { dims: vec![3], data: vec![0; 3] }.write_file(&lp).unwrap();
        assert!(Dataset::from_idx_files(&ip, &lp).is_err());
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    #[should_panic]
    fn label_range_checked() {
        Dataset::new(Matrix::zeros(1, 4), vec![7], 3);
    }
}
