//! Deterministic synthetic MNIST / FASHION-MNIST generators.
//!
//! **Substitution note (DESIGN.md §5):** real MNIST downloads are not
//! reachable in this environment, so experiments run on synthetic
//! 28×28 ten-class data that exercises the identical code path (IDX
//! tensors → pad to 1024 → feature map → SGD) and preserves the
//! evaluation's qualitative structure: classes are *multi-modal* blob
//! compositions, so they are not linearly separable and a kernel
//! expansion visibly outperforms plain logistic regression — the
//! paper's Figures 3–5 comparison shape. Real IDX files are accepted
//! wherever synthetic data is used (`--data-dir`).
//!
//! Generation model, all randomness hash-derived from `(seed, split,
//! index)` so train/test are disjoint deterministic streams:
//!
//! * each `(class, mode)` has a prototype: `blobs` Gaussian bumps with
//!   hash-random centers/widths/amplitudes;
//! * each sample picks a mode, jitters every blob center (class-
//!   conditional deformation ≈ MNIST stroke variation), applies a
//!   global translation, adds pixel noise, clips to `[0, 255]`.
//!
//! The FASHION variant uses more modes, wider blobs, shared
//! cross-class background texture and stronger noise — measurably
//! harder, as FASHION-MNIST is relative to MNIST.

use crate::hash::hash_rng::streams;
use crate::hash::HashRng;
use crate::rand::BoxMuller;

/// Image side (MNIST geometry).
pub const SIDE: usize = 28;
/// Pixels per image.
pub const PIXELS: usize = SIDE * SIDE;
/// Number of classes.
pub const CLASSES: usize = 10;

/// Generator parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct SyntheticSpec {
    /// Prototype modes per class (multi-modality → non-linearity).
    pub modes: usize,
    /// Gaussian bumps per prototype.
    pub blobs: usize,
    /// Per-blob center jitter (pixels, std-dev).
    pub jitter: f64,
    /// Global translation range (pixels, uniform ±).
    pub shift: i64,
    /// Additive pixel noise std-dev (0–255 scale).
    pub noise: f64,
    /// Blob width range (pixels).
    pub width: (f64, f64),
    /// Cross-class shared background amplitude (0 disables).
    pub background: f64,
}

impl SyntheticSpec {
    /// MNIST-like: compact strokes, moderate variation.
    pub fn mnist() -> SyntheticSpec {
        SyntheticSpec {
            modes: 3,
            blobs: 6,
            jitter: 1.0,
            shift: 2,
            noise: 12.0,
            width: (1.3, 2.6),
            background: 0.0,
        }
    }

    /// FASHION-MNIST-like: larger shapes, more modes, shared texture,
    /// heavier noise → harder problem (larger LR-vs-kernel gap).
    pub fn fashion() -> SyntheticSpec {
        SyntheticSpec {
            modes: 5,
            blobs: 9,
            jitter: 1.6,
            shift: 2,
            noise: 22.0,
            width: (2.0, 4.5),
            background: 40.0,
        }
    }

    /// Look up by dataset name (`mnist` | `fashion`).
    pub fn by_name(name: &str) -> Option<SyntheticSpec> {
        match name {
            "mnist" => Some(SyntheticSpec::mnist()),
            "fashion" | "fashion-mnist" | "fashion_mnist" => Some(SyntheticSpec::fashion()),
            _ => None,
        }
    }
}

/// One prototype blob.
#[derive(Debug, Clone, Copy)]
struct Blob {
    cx: f64,
    cy: f64,
    w: f64,
    amp: f64,
}

/// Deterministic prototype for `(class, mode)`.
fn prototype(seed: u64, spec: &SyntheticSpec, class: usize, mode: usize) -> Vec<Blob> {
    let rng = HashRng::new(seed, streams::DATA)
        .derive(0x5060)
        .derive(class as u64)
        .derive(mode as u64);
    let mut r = rng;
    (0..spec.blobs)
        .map(|_| {
            // keep centers away from the border so shifts stay inside
            let cx = 5.0 + r.next_f64() * (SIDE as f64 - 10.0);
            let cy = 5.0 + r.next_f64() * (SIDE as f64 - 10.0);
            let w = spec.width.0 + r.next_f64() * (spec.width.1 - spec.width.0);
            let amp = 120.0 + r.next_f64() * 135.0;
            Blob { cx, cy, w, amp }
        })
        .collect()
}

/// Render sample `index` of `split` ("train"/"test") for `class`.
fn render(
    seed: u64,
    spec: &SyntheticSpec,
    split_tag: u64,
    index: u64,
    class: usize,
    out: &mut [u8],
) {
    debug_assert_eq!(out.len(), PIXELS);
    let sample_rng = HashRng::new(seed, streams::DATA)
        .derive(split_tag)
        .derive(index);
    let mut r = sample_rng.clone();
    let mode = r.next_below(spec.modes as u64) as usize;
    let proto = prototype(seed, spec, class, mode);
    let mut bm = BoxMuller::new(sample_rng.derive(1));
    let dx = r.next_range(-spec.shift, spec.shift + 1) as f64;
    let dy = r.next_range(-spec.shift, spec.shift + 1) as f64;
    let mut img = [0.0f64; PIXELS];

    // class-shared background texture (fashion only): 2 wide bumps
    if spec.background > 0.0 {
        let bg_proto = prototype(seed, spec, CLASSES, mode % 2); // pseudo-class
        for b in bg_proto.iter().take(2) {
            splat(&mut img, b.cx, b.cy, b.w * 2.0, spec.background);
        }
    }
    for b in &proto {
        let cx = b.cx + dx + bm.next() * spec.jitter;
        let cy = b.cy + dy + bm.next() * spec.jitter;
        let amp = b.amp * (0.85 + 0.3 * r.next_f64());
        splat(&mut img, cx, cy, b.w, amp);
    }
    // pixel noise + clip
    let mut noise = BoxMuller::new(sample_rng.derive(2));
    for (o, v) in out.iter_mut().zip(img.iter()) {
        let n = noise.next() * spec.noise;
        *o = (v + n).clamp(0.0, 255.0) as u8;
    }
}

/// Add a Gaussian bump to the accumulator (3σ support window).
fn splat(img: &mut [f64; PIXELS], cx: f64, cy: f64, w: f64, amp: f64) {
    let r = (3.0 * w).ceil() as i64;
    let x0 = ((cx as i64) - r).max(0);
    let x1 = ((cx as i64) + r).min(SIDE as i64 - 1);
    let y0 = ((cy as i64) - r).max(0);
    let y1 = ((cy as i64) + r).min(SIDE as i64 - 1);
    let inv = 1.0 / (2.0 * w * w);
    for y in y0..=y1 {
        for x in x0..=x1 {
            let d2 = (x as f64 - cx).powi(2) + (y as f64 - cy).powi(2);
            img[y as usize * SIDE + x as usize] += amp * (-d2 * inv).exp();
        }
    }
}

/// Generate `n` samples for `split` ("train" or "test"): returns
/// `(images, labels)` with images as `n × 784` u8 rows. Labels cycle
/// through classes in hash-shuffled order (balanced to ±1).
pub fn generate(seed: u64, spec: &SyntheticSpec, split: &str, n: usize) -> (Vec<u8>, Vec<u8>) {
    let split_tag = match split {
        "train" => 0x7121u64,
        "test" => 0x7e57u64,
        other => crate::hash::murmur3::murmur3_x64_128(other.as_bytes(), seed).0,
    };
    let mut images = vec![0u8; n * PIXELS];
    let mut labels = vec![0u8; n];
    let label_rng = HashRng::new(seed, streams::DATA).derive(split_tag).derive(0xAB);
    for i in 0..n {
        // balanced-ish labels, order hash-shuffled
        let class = ((i as u64 + label_rng.at(i as u64 / CLASSES as u64) % CLASSES as u64)
            % CLASSES as u64) as usize;
        labels[i] = class as u8;
        render(
            seed,
            spec,
            split_tag,
            i as u64,
            class,
            &mut images[i * PIXELS..(i + 1) * PIXELS],
        );
    }
    (images, labels)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let spec = SyntheticSpec::mnist();
        let (a, la) = generate(1, &spec, "train", 20);
        let (b, lb) = generate(1, &spec, "train", 20);
        assert_eq!(a, b);
        assert_eq!(la, lb);
    }

    #[test]
    fn splits_differ() {
        let spec = SyntheticSpec::mnist();
        let (a, _) = generate(1, &spec, "train", 10);
        let (b, _) = generate(1, &spec, "test", 10);
        assert_ne!(a, b);
    }

    #[test]
    fn labels_roughly_balanced() {
        let spec = SyntheticSpec::mnist();
        let (_, labels) = generate(2, &spec, "train", 1000);
        let mut counts = [0usize; CLASSES];
        for &l in &labels {
            counts[l as usize] += 1;
        }
        for &c in &counts {
            assert!((50..=200).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn images_have_signal() {
        let spec = SyntheticSpec::mnist();
        let (imgs, _) = generate(3, &spec, "train", 10);
        for i in 0..10 {
            let img = &imgs[i * PIXELS..(i + 1) * PIXELS];
            let mean: f64 = img.iter().map(|&v| v as f64).sum::<f64>() / PIXELS as f64;
            let max = *img.iter().max().unwrap();
            assert!(mean > 2.0, "image {i} empty: mean {mean}");
            assert!(max > 100, "image {i} washed out: max {max}");
        }
    }

    #[test]
    fn same_class_more_similar_than_cross_class() {
        // Sanity: class structure exists. Average L2 distance between
        // same-class/same-mode pairs must be below cross-class pairs.
        let spec = SyntheticSpec::mnist();
        let n = 400;
        let (imgs, labels) = generate(4, &spec, "train", n);
        let img = |i: usize| &imgs[i * PIXELS..(i + 1) * PIXELS];
        let dist = |a: &[u8], b: &[u8]| -> f64 {
            a.iter()
                .zip(b)
                .map(|(&x, &y)| ((x as f64) - (y as f64)).powi(2))
                .sum::<f64>()
                .sqrt()
        };
        let mut same = (0.0, 0usize);
        let mut cross = (0.0, 0usize);
        for i in 0..n {
            for j in (i + 1)..n.min(i + 40) {
                let d = dist(img(i), img(j));
                if labels[i] == labels[j] {
                    same = (same.0 + d, same.1 + 1);
                } else {
                    cross = (cross.0 + d, cross.1 + 1);
                }
            }
        }
        let same_mean = same.0 / same.1 as f64;
        let cross_mean = cross.0 / cross.1 as f64;
        assert!(
            same_mean < cross_mean * 0.95,
            "same {same_mean} cross {cross_mean}"
        );
    }

    #[test]
    fn fashion_is_noisier_than_mnist() {
        let (m, _) = generate(5, &SyntheticSpec::mnist(), "train", 50);
        let (f, _) = generate(5, &SyntheticSpec::fashion(), "train", 50);
        let mean = |v: &[u8]| v.iter().map(|&x| x as f64).sum::<f64>() / v.len() as f64;
        // fashion has background + wider blobs → higher mean intensity
        assert!(mean(&f) > mean(&m), "fashion {} mnist {}", mean(&f), mean(&m));
    }

    #[test]
    fn spec_by_name() {
        assert_eq!(SyntheticSpec::by_name("mnist"), Some(SyntheticSpec::mnist()));
        assert_eq!(SyntheticSpec::by_name("fashion"), Some(SyntheticSpec::fashion()));
        assert_eq!(SyntheticSpec::by_name("imagenet"), None);
    }
}
