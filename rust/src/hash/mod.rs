//! Hash-derived randomness.
//!
//! The paper (§3, §7) replaces stored random matrices with values
//! *recomputed on demand* from a hash function: "to allow for very
//! compact distribution of models, we use hashing … for each feature
//! dimension, we only need one floating point number." This module
//! provides MurmurHash3 (the hash named in the paper) and a
//! counter-based deterministic RNG built on it.

pub mod hash_rng;
pub mod murmur3;

pub use hash_rng::HashRng;
pub use murmur3::{murmur3_x64_128, murmur3_x86_32};
