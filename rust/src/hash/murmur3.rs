//! MurmurHash3 — public-domain hash by Austin Appleby, reimplemented
//! from the reference description.
//!
//! The paper uses Murmurhash to derive every random coefficient of the
//! feature map (the binary diagonal `B`, the permutation `Π`, the
//! Gaussian diagonal `G` and the calibration `C`), so the hash must be
//! byte-for-byte deterministic across platforms. Both the 32-bit x86
//! variant and the 128-bit x64 variant are provided; the RNG
//! ([`crate::hash::HashRng`]) uses the 128-bit variant for throughput
//! (one hash call yields 128 bits).

#[inline(always)]
fn fmix32(mut h: u32) -> u32 {
    h ^= h >> 16;
    h = h.wrapping_mul(0x85eb_ca6b);
    h ^= h >> 13;
    h = h.wrapping_mul(0xc2b2_ae35);
    h ^= h >> 16;
    h
}

#[inline(always)]
fn fmix64(mut k: u64) -> u64 {
    k ^= k >> 33;
    k = k.wrapping_mul(0xff51_afd7_ed55_8ccd);
    k ^= k >> 33;
    k = k.wrapping_mul(0xc4ce_b9fe_1a85_ec53);
    k ^= k >> 33;
    k
}

/// MurmurHash3_x86_32. Returns a 32-bit hash of `data` under `seed`.
pub fn murmur3_x86_32(data: &[u8], seed: u32) -> u32 {
    const C1: u32 = 0xcc9e_2d51;
    const C2: u32 = 0x1b87_3593;
    let nblocks = data.len() / 4;
    let mut h1 = seed;

    // body
    for b in 0..nblocks {
        let k = u32::from_le_bytes([
            data[4 * b],
            data[4 * b + 1],
            data[4 * b + 2],
            data[4 * b + 3],
        ]);
        let mut k1 = k.wrapping_mul(C1);
        k1 = k1.rotate_left(15);
        k1 = k1.wrapping_mul(C2);
        h1 ^= k1;
        h1 = h1.rotate_left(13);
        h1 = h1.wrapping_mul(5).wrapping_add(0xe654_6b64);
    }

    // tail
    let tail = &data[nblocks * 4..];
    let mut k1: u32 = 0;
    if tail.len() >= 3 {
        k1 ^= (tail[2] as u32) << 16;
    }
    if tail.len() >= 2 {
        k1 ^= (tail[1] as u32) << 8;
    }
    if !tail.is_empty() {
        k1 ^= tail[0] as u32;
        k1 = k1.wrapping_mul(C1);
        k1 = k1.rotate_left(15);
        k1 = k1.wrapping_mul(C2);
        h1 ^= k1;
    }

    // finalization
    h1 ^= data.len() as u32;
    fmix32(h1)
}

/// MurmurHash3_x64_128. Returns the 128-bit hash as `(low, high)` u64s.
pub fn murmur3_x64_128(data: &[u8], seed: u64) -> (u64, u64) {
    const C1: u64 = 0x87c3_7b91_1142_53d5;
    const C2: u64 = 0x4cf5_ad43_2745_937f;
    let nblocks = data.len() / 16;
    let mut h1 = seed;
    let mut h2 = seed;

    // body
    for b in 0..nblocks {
        let base = 16 * b;
        let k1 = u64::from_le_bytes(data[base..base + 8].try_into().unwrap());
        let k2 = u64::from_le_bytes(data[base + 8..base + 16].try_into().unwrap());

        let mut k1 = k1.wrapping_mul(C1);
        k1 = k1.rotate_left(31);
        k1 = k1.wrapping_mul(C2);
        h1 ^= k1;
        h1 = h1.rotate_left(27);
        h1 = h1.wrapping_add(h2);
        h1 = h1.wrapping_mul(5).wrapping_add(0x52dc_e729);

        let mut k2 = k2.wrapping_mul(C2);
        k2 = k2.rotate_left(33);
        k2 = k2.wrapping_mul(C1);
        h2 ^= k2;
        h2 = h2.rotate_left(31);
        h2 = h2.wrapping_add(h1);
        h2 = h2.wrapping_mul(5).wrapping_add(0x3849_5ab5);
    }

    // tail
    let tail = &data[nblocks * 16..];
    let mut k1: u64 = 0;
    let mut k2: u64 = 0;
    let t = tail.len();
    // bytes 15..8 feed k2, bytes 7..0 feed k1 (reference order)
    if t >= 15 {
        k2 ^= (tail[14] as u64) << 48;
    }
    if t >= 14 {
        k2 ^= (tail[13] as u64) << 40;
    }
    if t >= 13 {
        k2 ^= (tail[12] as u64) << 32;
    }
    if t >= 12 {
        k2 ^= (tail[11] as u64) << 24;
    }
    if t >= 11 {
        k2 ^= (tail[10] as u64) << 16;
    }
    if t >= 10 {
        k2 ^= (tail[9] as u64) << 8;
    }
    if t >= 9 {
        k2 ^= tail[8] as u64;
        k2 = k2.wrapping_mul(C2);
        k2 = k2.rotate_left(33);
        k2 = k2.wrapping_mul(C1);
        h2 ^= k2;
    }
    if t >= 8 {
        k1 ^= (tail[7] as u64) << 56;
    }
    if t >= 7 {
        k1 ^= (tail[6] as u64) << 48;
    }
    if t >= 6 {
        k1 ^= (tail[5] as u64) << 40;
    }
    if t >= 5 {
        k1 ^= (tail[4] as u64) << 32;
    }
    if t >= 4 {
        k1 ^= (tail[3] as u64) << 24;
    }
    if t >= 3 {
        k1 ^= (tail[2] as u64) << 16;
    }
    if t >= 2 {
        k1 ^= (tail[1] as u64) << 8;
    }
    if t >= 1 {
        k1 ^= tail[0] as u64;
        k1 = k1.wrapping_mul(C1);
        k1 = k1.rotate_left(31);
        k1 = k1.wrapping_mul(C2);
        h1 ^= k1;
    }

    // finalization
    h1 ^= data.len() as u64;
    h2 ^= data.len() as u64;
    h1 = h1.wrapping_add(h2);
    h2 = h2.wrapping_add(h1);
    h1 = fmix64(h1);
    h2 = fmix64(h2);
    h1 = h1.wrapping_add(h2);
    h2 = h2.wrapping_add(h1);
    (h1, h2)
}

/// Fast-path: hash three u64 words (seed-stream-counter) without
/// allocating. Equivalent to `murmur3_x64_128` over their LE bytes.
#[inline]
pub fn murmur3_words(a: u64, b: u64, c: u64, seed: u64) -> (u64, u64) {
    let mut buf = [0u8; 24];
    buf[0..8].copy_from_slice(&a.to_le_bytes());
    buf[8..16].copy_from_slice(&b.to_le_bytes());
    buf[16..24].copy_from_slice(&c.to_le_bytes());
    murmur3_x64_128(&buf, seed)
}

#[cfg(test)]
mod tests {
    use super::*;

    // Reference vectors computed with the canonical C++ smhasher
    // implementation (MurmurHash3_x86_32 / MurmurHash3_x64_128).
    #[test]
    fn x86_32_known_vectors() {
        assert_eq!(murmur3_x86_32(b"", 0), 0);
        assert_eq!(murmur3_x86_32(b"", 1), 0x514e_28b7);
        assert_eq!(murmur3_x86_32(b"", 0xffff_ffff), 0x81f1_6f39);
        assert_eq!(murmur3_x86_32(b"test", 0), 0xba6b_d213);
        assert_eq!(murmur3_x86_32(b"test", 0x9747_b28c), 0x704b_81dc);
        assert_eq!(murmur3_x86_32(b"Hello, world!", 0x9747_b28c), 0x2488_4cba);
        assert_eq!(murmur3_x86_32(b"The quick brown fox jumps over the lazy dog", 0x9747_b28c), 0x2fa8_26cd);
    }

    #[test]
    fn x64_128_known_vectors() {
        // canonical: MurmurHash3_x64_128("", 0) = 0x00000000000000000000000000000000
        assert_eq!(murmur3_x64_128(b"", 0), (0, 0));
        // MurmurHash3_x64_128("", 1) = b55cff6ee5ab1046 8335f878aa2d6251
        // (canonical smhasher byte string, little-endian words)
        let (l, h) = murmur3_x64_128(b"", 1);
        assert_eq!(l, 0x4610_abe5_6eff_5cb5);
        assert_eq!(h, 0x5162_2daa_78f8_3583);
    }

    #[test]
    fn x64_128_tail_lengths_all_distinct() {
        // Exercise every tail length 0..=15: hashes must all differ.
        let data: Vec<u8> = (0u8..64).collect();
        let mut seen = std::collections::HashSet::new();
        for len in 0..=31 {
            let hv = murmur3_x64_128(&data[..len], 42);
            assert!(seen.insert(hv), "collision at len {len}");
        }
    }

    #[test]
    fn x86_32_tail_lengths_all_distinct() {
        let data: Vec<u8> = (0u8..32).collect();
        let mut seen = std::collections::HashSet::new();
        for len in 0..=15 {
            let hv = murmur3_x86_32(&data[..len], 7);
            assert!(seen.insert(hv), "collision at len {len}");
        }
    }

    #[test]
    fn words_matches_byte_path() {
        let (a, b, c, s) = (0x0123_4567_89ab_cdefu64, 42u64, u64::MAX, 1_398_239_763u64);
        let mut buf = [0u8; 24];
        buf[0..8].copy_from_slice(&a.to_le_bytes());
        buf[8..16].copy_from_slice(&(b as u64).to_le_bytes());
        buf[16..24].copy_from_slice(&c.to_le_bytes());
        assert_eq!(murmur3_words(a, b, c, s), murmur3_x64_128(&buf, s));
    }

    #[test]
    fn seed_sensitivity() {
        let d = b"mckernel";
        assert_ne!(murmur3_x86_32(d, 0), murmur3_x86_32(d, 1));
        assert_ne!(murmur3_x64_128(d, 0), murmur3_x64_128(d, 1));
    }
}
