//! Counter-based deterministic RNG built on MurmurHash3.
//!
//! The paper's key operational trick (§3, §7): *never store* random
//! coefficients — recompute them from `hash(seed, stream, counter)` at
//! any time, on any machine, in any order. This makes models a few
//! bytes (a seed), makes training/testing use identical randomness, and
//! makes distributed workers coefficient-consistent for free.
//!
//! `HashRng` is a *random-access* generator: `at(k)` returns the k-th
//! variate directly, without sequencing, which is exactly what the
//! diagonal operators `B`, `G`, `C` need ("for each feature dimension,
//! we only need one floating point number").

use super::murmur3::murmur3_words;

/// Deterministic counter-based RNG: the k-th block of 128 random bits
/// is `murmur3_x64_128(seed ‖ stream ‖ k)`.
///
/// Distinct `stream` values give statistically independent sequences
/// under the same seed (used to separate B / Π / G / C and the
/// per-expansion draws).
#[derive(Debug, Clone)]
pub struct HashRng {
    seed: u64,
    stream: u64,
    counter: u64,
    /// one buffered u64 from the last 128-bit hash output
    spare: Option<u64>,
}

/// Well-known stream ids for the feature-map operators. Keeping them
/// in one place guarantees Rust and the AOT-compile path (Python
/// `python/compile/model.py`) derive identical coefficients.
pub mod streams {
    /// Binary ±1 diagonal `B`.
    pub const BINARY: u64 = 0xB1;
    /// Permutation `Π` (Fisher–Yates draws).
    pub const PERMUTATION: u64 = 0x91;
    /// Gaussian diagonal `G` (Box–Muller pairs).
    pub const GAUSS: u64 = 0x6A;
    /// Calibration diagonal `C`.
    pub const CALIBRATION: u64 = 0xCA;
    /// Dataset synthesis.
    pub const DATA: u64 = 0xDA;
    /// Weight initialization.
    pub const INIT: u64 = 0x14;
    /// Mini-batch shuffling.
    pub const SHUFFLE: u64 = 0x5F;
    /// Deterministic fault injection (`fault::FaultPlan`).
    pub const FAULT: u64 = 0xFA;
    /// Content-addressed feature cache keys (`mckernel::cache`).
    pub const CACHE: u64 = 0xCE;
}

impl HashRng {
    /// New generator for `(seed, stream)`; counter starts at 0.
    pub fn new(seed: u64, stream: u64) -> Self {
        HashRng { seed, stream, counter: 0, spare: None }
    }

    /// Sub-stream derivation: a new independent generator obtained by
    /// hashing the parent identity with `tag` (used for per-expansion
    /// operators: expansion `e`'s `G` is `derive(GAUSS).derive(e)`…).
    pub fn derive(&self, tag: u64) -> HashRng {
        let (lo, hi) = murmur3_words(self.stream, tag, 0x6d63_6b65_726e_656c, self.seed);
        HashRng { seed: lo, stream: hi, counter: 0, spare: None }
    }

    /// The seed this generator was constructed with.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Random-access: the `k`-th 64-bit word of this stream,
    /// independent of any sequential state.
    #[inline]
    pub fn at(&self, k: u64) -> u64 {
        murmur3_words(self.stream, k, 0, self.seed).0
    }

    /// Random-access uniform in `[0, 1)` (f64, 53 mantissa bits).
    #[inline]
    pub fn at_f64(&self, k: u64) -> f64 {
        (self.at(k) >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Random-access uniform in `[0, 1)` (f32, 24 mantissa bits).
    #[inline]
    pub fn at_f32(&self, k: u64) -> f32 {
        (self.at(k) >> 40) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Random-access ±1 sign (the `B` diagonal's entries).
    #[inline]
    pub fn at_sign(&self, k: u64) -> f32 {
        if self.at(k) & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Next 64 random bits (sequential API).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        if let Some(s) = self.spare.take() {
            return s;
        }
        let (lo, hi) = murmur3_words(self.stream, self.counter, 0, self.seed);
        self.counter += 1;
        self.spare = Some(hi);
        lo
    }

    /// Next 32 random bits.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in `[0, bound)` via Lemire's multiply-shift
    /// rejection method (unbiased).
    pub fn next_below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "bound must be positive");
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= bound.wrapping_neg() % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform integer in `[lo, hi)` (half-open).
    pub fn next_range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo < hi, "empty range");
        lo + self.next_below((hi - lo) as u64) as i64
    }

    /// Fill a slice with uniform `[0,1)` f32s.
    pub fn fill_f32(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.next_f32();
        }
    }

    /// Reset the sequential counter to zero (random-access `at*` calls
    /// are unaffected; they never touch the counter).
    pub fn reset(&mut self) {
        self.counter = 0;
        self.spare = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_replay() {
        let mut a = HashRng::new(1398239763, streams::GAUSS);
        let mut b = HashRng::new(1398239763, streams::GAUSS);
        let xs: Vec<u64> = (0..100).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..100).map(|_| b.next_u64()).collect();
        assert_eq!(xs, ys);
    }

    #[test]
    fn reset_replays() {
        let mut a = HashRng::new(7, 1);
        let first: Vec<u64> = (0..10).map(|_| a.next_u64()).collect();
        a.reset();
        let again: Vec<u64> = (0..10).map(|_| a.next_u64()).collect();
        assert_eq!(first, again);
    }

    #[test]
    fn streams_independent() {
        let mut a = HashRng::new(42, streams::BINARY);
        let mut b = HashRng::new(42, streams::GAUSS);
        let xs: Vec<u64> = (0..32).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..32).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }

    #[test]
    fn seeds_independent() {
        let mut a = HashRng::new(1, 0);
        let mut b = HashRng::new(2, 0);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn derive_differs_from_parent_and_siblings() {
        let root = HashRng::new(9, 9);
        let mut c0 = root.derive(0);
        let mut c1 = root.derive(1);
        let mut p = root.clone();
        let x0 = c0.next_u64();
        let x1 = c1.next_u64();
        let xp = p.next_u64();
        assert_ne!(x0, x1);
        assert_ne!(x0, xp);
    }

    #[test]
    fn unit_interval_bounds() {
        let mut r = HashRng::new(3, 3);
        for _ in 0..10_000 {
            let f = r.next_f64();
            assert!((0.0..1.0).contains(&f));
            let g = r.next_f32();
            assert!((0.0..1.0).contains(&g));
        }
    }

    #[test]
    fn uniform_mean_close_to_half() {
        let mut r = HashRng::new(5, 5);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn next_below_unbiased_small_bound() {
        let mut r = HashRng::new(11, 0);
        let mut counts = [0u32; 3];
        for _ in 0..30_000 {
            counts[r.next_below(3) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 500.0, "{counts:?}");
        }
    }

    #[test]
    fn next_range_bounds() {
        let mut r = HashRng::new(13, 0);
        for _ in 0..1000 {
            let v = r.next_range(-5, 5);
            assert!((-5..5).contains(&v));
        }
    }

    #[test]
    fn random_access_is_stateless() {
        let r = HashRng::new(17, 4);
        let a = r.at(100);
        let _ = r.at(5);
        assert_eq!(a, r.at(100));
    }

    #[test]
    fn at_sign_balanced() {
        let r = HashRng::new(19, streams::BINARY);
        let n = 50_000;
        let sum: f32 = (0..n).map(|k| r.at_sign(k)).sum();
        assert!(sum.abs() < 1_000.0, "sign sum {sum}");
    }

    #[test]
    fn sequential_matches_hash_blocks() {
        // next_u64 must yield (lo, hi) pairs of successive counter hashes.
        let mut r = HashRng::new(23, 8);
        let a = r.next_u64();
        let b = r.next_u64();
        let (lo, hi) = crate::hash::murmur3::murmur3_words(8, 0, 0, 23);
        assert_eq!((a, b), (lo, hi));
    }

    #[test]
    #[should_panic]
    fn next_below_zero_panics() {
        HashRng::new(0, 0).next_below(0);
    }
}
