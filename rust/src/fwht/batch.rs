//! Batch-axis vectorized FWHT — the engine behind the batched feature
//! pipeline.
//!
//! The per-row engines ([`super::optimized`]) are latency-bound at the
//! small strides: stage `h` touches pairs `(j, j+h)`, and the serial
//! dependency chain between stages leaves the SIMD units idle when `h`
//! is below the vector width. Here a tile of `T` rows is transposed
//! into a column-major `(n, T)` layout — lane `l` of coefficient `j`
//! sits at `tile[j*T + l]`, so the batch dimension is innermost — and
//! a butterfly between coefficients `j` and `j+h` becomes an
//! elementwise op over two contiguous `T`-float runs *no matter how
//! small `h` is*. The stage loop is then literally the scalar engine
//! with every stride scaled by `T`, so the fused radix-4 passes apply
//! unchanged and the arithmetic DAG per lane is exactly the per-row
//! DAG: results are bit-identical to [`super::fwht`] applied row by
//! row (lanes never interact).
//!
//! `T` is capped so a tile stays L1/L2-resident (see [`tile_lanes`]);
//! row-major callers stream whole tiles through transpose-in /
//! stages / transpose-out, and the feature pipeline fuses its
//! diagonals and gathers into those transposes.

use super::optimized::{radix2_pass, radix4_pass};

/// Tile footprint budget in f32 elements (128 KiB — L2-resident with
/// headroom for the gather/trig scratch of the feature pipeline).
const TILE_FLOATS: usize = 1 << 15;

/// Batch lanes per tile for transform size `n`: as many rows as fit
/// the footprint budget, clamped to `1..=64`.
pub fn tile_lanes(n: usize) -> usize {
    (TILE_FLOATS / n.max(1)).clamp(1, 64)
}

/// Run all `log₂ n` butterfly stages over a column-major `(n, lanes)`
/// tile in place, batch dimension innermost. Equivalent to an
/// independent FWHT of each lane; bit-identical to the per-row
/// optimized engine (same stage order, same arithmetic per lane).
pub fn fwht_colmajor(tile: &mut [f32], n: usize, lanes: usize) {
    assert!(n.is_power_of_two(), "FWHT length must be a power of two");
    assert_eq!(tile.len(), n * lanes, "tile shape mismatch");
    if n <= 1 || lanes == 0 {
        return;
    }
    // Stage stride in elements = coefficient stride × lane count; the
    // pass kernels are shared with the scalar engine.
    let stages = n.trailing_zeros();
    let mut h = lanes;
    if stages % 2 == 1 {
        radix2_pass(tile, h);
        h *= 2;
    }
    while h < n * lanes {
        radix4_pass(tile, h);
        h *= 4;
    }
}

/// Gather `lanes` rows of a row-major `(lanes, n)` slice into a
/// column-major tile.
pub(crate) fn load_tile(rows: &[f32], n: usize, lanes: usize, tile: &mut [f32]) {
    for (l, row) in rows.chunks_exact(n).enumerate() {
        for (j, &v) in row.iter().enumerate() {
            tile[j * lanes + l] = v;
        }
    }
}

/// Scatter a column-major tile back into row-major rows.
pub(crate) fn store_tile(tile: &[f32], n: usize, lanes: usize, rows: &mut [f32]) {
    for (l, row) in rows.chunks_exact_mut(n).enumerate() {
        for (j, v) in row.iter_mut().enumerate() {
            *v = tile[j * lanes + l];
        }
    }
}

/// FWHT of every row of a row-major `(rows, n)` matrix, vectorized
/// across the batch dimension. Bit-identical to [`super::fwht`]
/// applied per row — including at `tile_lanes(n) == 1`, where a
/// one-lane tile runs the same passes in the same stride order (the
/// batch-vs-per-row *dispatch* decision is not made here; it belongs
/// to `mckernel::plan::ExpansionPlan`, the codebase's one fallback
/// point).
pub fn fwht_batch(data: &mut [f32], rows: usize, n: usize) {
    assert!(n.is_power_of_two(), "row length must be a power of two");
    assert_eq!(data.len(), rows * n, "buffer shape mismatch");
    if n <= 1 {
        return;
    }
    let lanes_max = tile_lanes(n);
    let mut tile = vec![0.0f32; n * lanes_max];
    let mut base = 0;
    while base < rows {
        let lanes = lanes_max.min(rows - base);
        let rows_slice = &mut data[base * n..(base + lanes) * n];
        let tile = &mut tile[..n * lanes];
        load_tile(rows_slice, n, lanes, tile);
        fwht_colmajor(tile, n, lanes);
        store_tile(tile, n, lanes, rows_slice);
        base += lanes;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fwht;
    use crate::hash::HashRng;

    fn random_rows(rows: usize, n: usize, seed: u64) -> Vec<f32> {
        let mut r = HashRng::new(seed, 0xB7);
        (0..rows * n).map(|_| r.next_f32() * 2.0 - 1.0).collect()
    }

    fn check_exact(rows: usize, n: usize, seed: u64) {
        let flat = random_rows(rows, n, seed);
        let mut batch = flat.clone();
        fwht_batch(&mut batch, rows, n);
        for r in 0..rows {
            let mut row = flat[r * n..(r + 1) * n].to_vec();
            fwht::fwht(&mut row);
            assert_eq!(
                &batch[r * n..(r + 1) * n],
                &row[..],
                "rows={rows} n={n} r={r}"
            );
        }
    }

    #[test]
    fn matches_per_row_exactly() {
        for n in [1usize, 2, 4, 8, 64, 256, 1024] {
            for rows in [1usize, 3, 7, 33] {
                check_exact(rows, n, (rows * 1000 + n) as u64);
            }
        }
    }

    #[test]
    fn tail_tile_smaller_than_lane_count() {
        // tile_lanes(1024) = 32: one full tile plus a 1-row tail.
        check_exact(33, 1024, 42);
        // and a tail that is most of a tile
        check_exact(63, 1024, 43);
    }

    #[test]
    fn single_lane_colmajor_is_plain_fwht() {
        let n = 512;
        let x = random_rows(1, n, 7);
        let mut a = x.clone();
        let mut b = x;
        fwht_colmajor(&mut a, n, 1);
        fwht::fwht(&mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn tile_lanes_bounds() {
        assert_eq!(tile_lanes(1024), 32);
        assert_eq!(tile_lanes(1 << 20), 1);
        assert_eq!(tile_lanes(1), 64);
        for n in [2usize, 16, 256, 4096, 1 << 16] {
            let t = tile_lanes(n);
            assert!((1..=64).contains(&t), "n={n} lanes={t}");
        }
    }

    #[test]
    fn batched_involution() {
        let (rows, n) = (5, 256);
        let x = random_rows(rows, n, 9);
        let mut y = x.clone();
        fwht_batch(&mut y, rows, n);
        fwht_batch(&mut y, rows, n);
        for (a, b) in y.iter().zip(x.iter()) {
            assert!((a / n as f32 - b).abs() < 1e-3);
        }
    }

    #[test]
    #[should_panic]
    fn non_pow2_rows_rejected() {
        let mut x = vec![0.0f32; 3 * 12];
        fwht_batch(&mut x, 3, 12);
    }
}
