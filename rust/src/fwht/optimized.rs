//! The McKernel FWHT engine (paper §5) — cache-blocked, SIMD-friendly,
//! in place, any power-of-two size.
//!
//! Structure, following the paper's description:
//!
//! 1. **Bottom phase** ("… till a small routine Hadamard that fits in
//!    cache"): the array is cut into contiguous blocks of
//!    [`BLOCK`] floats (half an L1 cache); each block is fully
//!    transformed while resident, with the first three butterfly
//!    stages fused into a straight-line radix-8 codelet (the analogue
//!    of the paper's unrolled SSE2 codelets — here expressed as
//!    slice loops the compiler auto-vectorizes under
//!    `-C target-cpu=native`).
//! 2. **Top phase** ("then the algorithm continues … doubling on each
//!    iteration the input dimension"): the remaining `log₂(n/BLOCK)`
//!    stages run as *fused radix-4 passes* — two butterfly stages per
//!    memory sweep, halving DRAM traffic relative to the textbook
//!    radix-2 loop. All inner loops walk contiguous streams, so they
//!    vectorize and prefetch cleanly.
//!
//! Unlike Spiral the partitioning is computed on the fly from `n`
//! (no plan precomputation, no size cap).

/// In-cache block size in f32 elements (32 KiB = one L1D).
///
/// §Perf ablation (EXPERIMENTS.md): 2^13 beat 2^11/2^12 at n ≥ 2^19
/// (1.43 ms vs 1.73/1.87 ms at n = 2^20) and was neutral below — the
/// bottom phase walks one block at a time, so using the full L1 halves
/// the number of top-phase stages without evicting anything hot.
pub const BLOCK: usize = 1 << 13;

/// In-place FWHT, optimized engine.
///
/// # Panics
/// If `data.len()` is not a power of two.
pub fn fwht(data: &mut [f32]) {
    let n = data.len();
    assert!(n.is_power_of_two(), "FWHT length must be a power of two");
    if n <= BLOCK {
        fwht_incache(data);
        return;
    }
    // Bottom phase: transform every L1-resident block.
    for block in data.chunks_exact_mut(BLOCK) {
        fwht_incache(block);
    }
    // Top phase: strides BLOCK … n/2, two stages per sweep.
    let mut h = BLOCK;
    let stages = (n / BLOCK).trailing_zeros();
    if stages % 2 == 1 {
        radix2_pass(data, h);
        h *= 2;
    }
    while h < n {
        radix4_pass(data, h);
        h *= 4;
    }
}

/// Transform a block that fits in L1 (`n ≤ BLOCK`).
fn fwht_incache(d: &mut [f32]) {
    let n = d.len();
    match n {
        0 | 1 => return,
        2 => {
            butterfly2(d);
            return;
        }
        4 => {
            butterfly4(d);
            return;
        }
        _ => {}
    }
    // Stages 0–2 fused: straight-line radix-8 on contiguous chunks.
    for c in d.chunks_exact_mut(8) {
        butterfly8(c);
    }
    // Remaining in-cache stages, radix-4 fused where possible.
    let mut h = 8;
    let stages = (n / 8).trailing_zeros();
    if stages % 2 == 1 {
        radix2_pass(d, h);
        h *= 2;
    }
    while h < n {
        radix4_pass(d, h);
        h *= 4;
    }
}

/// One radix-2 butterfly stage at stride `h` (contiguous dual-stream
/// inner loop; auto-vectorizes). Shared with [`crate::fwht::batch`],
/// whose column-major tiles are this same pass with `h` scaled by the
/// lane count.
#[inline]
pub(crate) fn radix2_pass(data: &mut [f32], h: usize) {
    for pair in data.chunks_exact_mut(2 * h) {
        let (a, b) = pair.split_at_mut(h);
        for i in 0..h {
            let x = a[i];
            let y = b[i];
            a[i] = x + y;
            b[i] = x - y;
        }
    }
}

/// Two butterfly stages (strides `h` and `2h`) fused into one sweep:
/// each element is read and written once instead of twice. Shared with
/// [`crate::fwht::batch`].
#[inline]
pub(crate) fn radix4_pass(data: &mut [f32], h: usize) {
    for quad in data.chunks_exact_mut(4 * h) {
        let (ab, cd) = quad.split_at_mut(2 * h);
        let (a, b) = ab.split_at_mut(h);
        let (c, d) = cd.split_at_mut(h);
        for i in 0..h {
            let t0 = a[i] + b[i];
            let t1 = a[i] - b[i];
            let t2 = c[i] + d[i];
            let t3 = c[i] - d[i];
            a[i] = t0 + t2;
            b[i] = t1 + t3;
            c[i] = t0 - t2;
            d[i] = t1 - t3;
        }
    }
}

/// Size-2 straight-line butterfly.
#[inline(always)]
fn butterfly2(d: &mut [f32]) {
    let (a, b) = (d[0], d[1]);
    d[0] = a + b;
    d[1] = a - b;
}

/// Size-4 straight-line butterfly (stages 0–1 fused in registers).
#[inline(always)]
fn butterfly4(d: &mut [f32]) {
    let (x0, x1, x2, x3) = (d[0], d[1], d[2], d[3]);
    let (s0, d0, s1, d1) = (x0 + x1, x0 - x1, x2 + x3, x2 - x3);
    d[0] = s0 + s1;
    d[1] = d0 + d1;
    d[2] = s0 - s1;
    d[3] = d0 - d1;
}

/// Size-8 straight-line butterfly (stages 0–2 fused in registers —
/// the "small routine Hadamard" codelet).
#[inline(always)]
fn butterfly8(d: &mut [f32]) {
    let (x0, x1, x2, x3) = (d[0], d[1], d[2], d[3]);
    let (x4, x5, x6, x7) = (d[4], d[5], d[6], d[7]);
    // stage 0 (stride 1)
    let (a0, a1) = (x0 + x1, x0 - x1);
    let (a2, a3) = (x2 + x3, x2 - x3);
    let (a4, a5) = (x4 + x5, x4 - x5);
    let (a6, a7) = (x6 + x7, x6 - x7);
    // stage 1 (stride 2)
    let (b0, b2) = (a0 + a2, a0 - a2);
    let (b1, b3) = (a1 + a3, a1 - a3);
    let (b4, b6) = (a4 + a6, a4 - a6);
    let (b5, b7) = (a5 + a7, a5 - a7);
    // stage 2 (stride 4)
    d[0] = b0 + b4;
    d[1] = b1 + b5;
    d[2] = b2 + b6;
    d[3] = b3 + b7;
    d[4] = b0 - b4;
    d[5] = b1 - b5;
    d[6] = b2 - b6;
    d[7] = b3 - b7;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fwht::reference;

    fn check_against_naive(n: usize, seed: u64) {
        let mut r = crate::hash::HashRng::new(seed, 0xF1);
        let x: Vec<f32> = (0..n).map(|_| r.next_f32() * 4.0 - 2.0).collect();
        let mut a = x.clone();
        let mut b = x;
        fwht(&mut a);
        reference::fwht_naive(&mut b);
        for (i, (u, v)) in a.iter().zip(b.iter()).enumerate() {
            assert!(
                (u - v).abs() < 1e-3 * v.abs().max(1.0),
                "n={n} i={i} got={u} want={v}"
            );
        }
    }

    #[test]
    fn codelet_sizes() {
        for n in [1usize, 2, 4, 8] {
            check_against_naive(n, n as u64);
        }
    }

    #[test]
    fn incache_sizes() {
        for log_n in 4..=12 {
            check_against_naive(1 << log_n, log_n as u64);
        }
    }

    #[test]
    fn cross_block_sizes() {
        // Exercise the top phase: BLOCK·2, BLOCK·4, BLOCK·8
        for mult in [2usize, 4, 8] {
            check_against_naive(BLOCK * mult, mult as u64);
        }
    }

    #[test]
    fn odd_and_even_top_stage_counts() {
        // stages above BLOCK: 1 (odd → radix-2 then none) and 2 (even).
        check_against_naive(BLOCK * 2, 101);
        check_against_naive(BLOCK * 4, 102);
    }

    #[test]
    fn radix4_equals_two_radix2() {
        let n = 64;
        let mut r = crate::hash::HashRng::new(64, 0xF2);
        let x: Vec<f32> = (0..n).map(|_| r.next_f32()).collect();
        let mut a = x.clone();
        let mut b = x;
        radix4_pass(&mut a, 8);
        radix2_pass(&mut b, 8);
        radix2_pass(&mut b, 16);
        assert_eq!(a, b);
    }

    #[test]
    fn large_involution() {
        let n = BLOCK * 4;
        let mut r = crate::hash::HashRng::new(9, 0xF3);
        let x: Vec<f32> = (0..n).map(|_| r.next_f32() - 0.5).collect();
        let mut y = x.clone();
        fwht(&mut y);
        fwht(&mut y);
        for (a, b) in y.iter().zip(x.iter()) {
            assert!((a / n as f32 - b).abs() < 1e-3);
        }
    }
}
