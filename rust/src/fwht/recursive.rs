//! Plan-based recursive FWHT — the *Spiral-like baseline* of Table 1 /
//! Figure 2.
//!
//! Spiral [Johnson & Püschel 2000] searches over recursive
//! factorizations ("breakdown trees") of the transform and executes the
//! chosen plan by straight-line recursion. We reproduce that execution
//! model: a precomputed [`Plan`] tree describing the split at every
//! level, walked by a recursive interpreter with a scalar size-≤8 base
//! codelet. This carries Spiral's structural costs — call/plan-node
//! overhead per region and no cross-stage cache blocking — which is
//! precisely what the paper's engine removes. (Spiral's published FWHT
//! also caps at `n = 2²⁰`; we note but do not impose the cap.)

/// One node of a Spiral-style breakdown tree.
#[derive(Debug)]
pub struct Plan {
    /// Transform size at this node (power of two).
    pub n: usize,
    /// `None` for a leaf codelet; `Some((left, right))` for the
    /// divide-and-conquer split into two half-size transforms.
    pub children: Option<Box<(Plan, Plan)>>,
}

/// Leaf codelet size: transforms of ≤ this size run straight-line.
const LEAF: usize = 8;

impl Plan {
    /// Build the balanced radix-2 breakdown tree for size `n`
    /// (Spiral's default FWHT rule `WHT_{2^k} → WHT_2 ⊗ WHT_{2^{k-1}}`
    /// evaluated as split-in-half recursion).
    pub fn build(n: usize) -> Plan {
        assert!(n.is_power_of_two(), "FWHT length must be a power of two");
        if n <= LEAF {
            Plan { n, children: None }
        } else {
            let half = Plan::build(n / 2);
            let half2 = Plan::build(n / 2);
            Plan { n, children: Some(Box::new((half, half2))) }
        }
    }

    /// Number of nodes in the plan (bench metadata: Spiral's
    /// "precompute trees" cost is proportional to this).
    pub fn node_count(&self) -> usize {
        1 + self
            .children
            .as_ref()
            .map_or(0, |c| c.0.node_count() + c.1.node_count())
    }

    /// Execute the plan in place.
    pub fn execute(&self, data: &mut [f32]) {
        debug_assert_eq!(data.len(), self.n);
        match &self.children {
            None => leaf_codelet(data),
            Some(c) => {
                let (lo, hi) = data.split_at_mut(self.n / 2);
                c.0.execute(lo);
                c.1.execute(hi);
                // combine: [lo+hi, lo-hi]  (paper Eq. 12)
                for (a, b) in lo.iter_mut().zip(hi.iter_mut()) {
                    let s = *a + *b;
                    let d = *a - *b;
                    *a = s;
                    *b = d;
                }
            }
        }
    }
}

/// Straight-line transform for n ∈ {1, 2, 4, 8}.
fn leaf_codelet(d: &mut [f32]) {
    match d.len() {
        1 => {}
        2 => {
            let (a, b) = (d[0], d[1]);
            d[0] = a + b;
            d[1] = a - b;
        }
        4 => {
            let (a, b, c, e) = (d[0], d[1], d[2], d[3]);
            let (s0, d0, s1, d1) = (a + b, a - b, c + e, c - e);
            d[0] = s0 + s1;
            d[1] = d0 + d1;
            d[2] = s0 - s1;
            d[3] = d0 - d1;
        }
        8 => {
            // two size-4 transforms + combine
            let (lo, hi) = d.split_at_mut(4);
            leaf_codelet(lo);
            leaf_codelet(hi);
            for i in 0..4 {
                let s = lo[i] + hi[i];
                let t = lo[i] - hi[i];
                lo[i] = s;
                hi[i] = t;
            }
        }
        _ => unreachable!("leaf codelet sizes are 1,2,4,8"),
    }
}

/// One-shot plan-build + execute (what the Table 1 baseline times; a
/// cached-plan variant is exposed for fairness in the bench harness).
pub fn fwht(data: &mut [f32]) {
    let plan = Plan::build(data.len());
    plan.execute(data);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fwht::naive;

    #[test]
    fn matches_naive() {
        for log_n in 0..=12 {
            let n = 1usize << log_n;
            let x: Vec<f32> = (0..n).map(|i| ((i * 37 + 11) % 17) as f32 - 8.0).collect();
            let mut a = x.clone();
            let mut b = x;
            fwht(&mut a);
            naive::fwht(&mut b);
            for (u, v) in a.iter().zip(b.iter()) {
                assert!((u - v).abs() < 1e-3 * v.abs().max(1.0), "n={n}");
            }
        }
    }

    #[test]
    fn plan_node_count_grows_linearly() {
        // Balanced binary tree over n/LEAF leaves → ~2·n/LEAF − 1 nodes.
        let p = Plan::build(1 << 12);
        assert_eq!(p.node_count(), 2 * (1 << 12) / LEAF - 1);
    }

    #[test]
    fn plan_reuse_is_consistent() {
        let plan = Plan::build(256);
        let x: Vec<f32> = (0..256).map(|i| i as f32).collect();
        let mut a = x.clone();
        let mut b = x;
        plan.execute(&mut a);
        plan.execute(&mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn leaf_sizes_direct() {
        for n in [1usize, 2, 4, 8] {
            let x: Vec<f32> = (0..n).map(|i| (i as f32) - 1.5).collect();
            let mut a = x.clone();
            let mut b = x;
            fwht(&mut a);
            naive::fwht(&mut b);
            assert_eq!(a, b, "n={n}");
        }
    }
}
