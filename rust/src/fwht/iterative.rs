//! Textbook in-place radix-2 iterative FWHT (Cooley–Tukey ordering).
//!
//! `log₂ n` passes; pass `s` combines elements at stride `s`. Simple
//! and branch-free, but every pass streams the whole array through the
//! cache — the deficiency the optimized engine (paper §5) fixes.

/// In-place radix-2 iterative FWHT.
pub fn fwht(data: &mut [f32]) {
    let n = data.len();
    assert!(n.is_power_of_two(), "FWHT length must be a power of two");
    let mut h = 1;
    while h < n {
        let mut base = 0;
        while base < n {
            for i in base..base + h {
                let a = data[i];
                let b = data[i + h];
                data[i] = a + b;
                data[i + h] = a - b;
            }
            base += 2 * h;
        }
        h *= 2;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fwht::reference;

    #[test]
    fn matches_naive_many_sizes() {
        for log_n in 0..=12 {
            let n = 1usize << log_n;
            let x: Vec<f32> = (0..n).map(|i| ((i * 97 + 3) % 23) as f32 - 11.0).collect();
            let mut a = x.clone();
            let mut b = x;
            fwht(&mut a);
            reference::fwht_naive(&mut b);
            for (u, v) in a.iter().zip(b.iter()) {
                assert!((u - v).abs() < 1e-3 * v.abs().max(1.0), "n={n}");
            }
        }
    }

    #[test]
    fn idempotent_scaling() {
        let n = 1024;
        let x: Vec<f32> = (0..n).map(|i| (i % 7) as f32).collect();
        let mut y = x.clone();
        fwht(&mut y);
        fwht(&mut y);
        for (a, b) in y.iter().zip(x.iter()) {
            assert!((a / n as f32 - b).abs() < 1e-4);
        }
    }
}
