//! Fast Walsh–Hadamard Transform engines (paper §4–5).
//!
//! The Walsh–Hadamard matrix is defined recursively (`paper Eq. 10-11`):
//!
//! ```text
//! H_0 = [1],   H_n = [[H_{n-1},  H_{n-1}],
//!                     [H_{n-1}, -H_{n-1}]]
//! ```
//!
//! `H·c` factors into `log₂ n` butterfly stages (`paper Eq. 12-13`),
//! giving `O(n log n)` time. Four **production engines** are
//! provided — the set `mckernel::plan::ExpansionPlan` selects
//! between — plus a reference module of test oracles:
//!
//! * [`iterative`] — textbook in-place radix-2 Cooley–Tukey loop.
//! * [`optimized`] — the paper's contribution, re-created: cache-blocked
//!   two-phase traversal with unrolled SIMD-friendly codelets
//!   ("vectorized sums and subtractions … till a small routine Hadamard
//!   that fits in cache … then doubling on each iteration").
//! * [`batch`] — `rows` transforms in lockstep on column-major tiles
//!   (batch dimension innermost), the mini-batch hot path; bit-identical
//!   to [`optimized`] per row.
//! * [`simd`] — the batch tile engine with explicit `std::arch`
//!   butterflies (AVX2 8-wide / NEON 4-wide), runtime-detected with a
//!   scalar fallback; bit-identical to [`batch`] and [`optimized`]
//!   (butterflies are pure adds/subs — vectorizing them cannot change
//!   rounding).
//! * [`reference`] — the `O(n²)` naïve oracle and the Spiral-like
//!   recursive baseline. Test/bench oracles only; never dispatched to
//!   by the expansion plan.
//!
//! All engines operate **in place** and compute the *unnormalized*
//! transform (`H x`, not `H x/√n`); [`crate::mckernel`] folds the
//! `1/(σ√n)` normalization of Eq. 8 into the calibration diagonal.
//! The batch-vs-per-row dispatch decision for the expansion pipeline
//! is made in exactly one place: `mckernel::plan::ExpansionPlan`.

pub mod batch;
pub mod iterative;
pub mod optimized;
pub mod reference;
pub mod simd;

pub use batch::{fwht_batch, fwht_colmajor, tile_lanes};

/// The default engine used by the library hot path.
pub use optimized::fwht as fwht_fast;

/// Which production FWHT engine to run (CLI / bench selectable; the
/// expansion plan picks between [`Engine::Optimized`] per row and
/// [`Engine::Batch`]/[`Engine::Simd`] tiles). The reference oracles
/// ([`reference::fwht_naive`], [`reference::fwht_recursive`]) are
/// deliberately *not* variants: nothing in the library may dispatch
/// to them.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Engine {
    /// Plain in-place radix-2 loop.
    Iterative,
    /// Cache-blocked, unrolled (the McKernel per-row engine).
    Optimized,
    /// Column-major batch-lockstep tiles (bit-identical to Optimized
    /// per row; on a single row it degenerates to one lane). At
    /// `tile_lanes(n) == 1` (n ≥ 2^15) a timing of this engine mostly
    /// measures transpose copies the expansion plan avoids by
    /// dispatching `PerRow` — keep that in mind when reading large-n
    /// CLI/bench numbers for it.
    Batch,
    /// The batch tile engine driven through explicit AVX2/NEON
    /// butterflies (runtime-detected; scalar fallback elsewhere).
    /// Bit-identical to Batch and Optimized.
    Simd,
}

impl Engine {
    /// All production engines, for sweeps.
    pub const ALL: [Engine; 4] =
        [Engine::Iterative, Engine::Optimized, Engine::Batch, Engine::Simd];

    /// Human name (used by benches and the CLI).
    pub fn name(self) -> &'static str {
        match self {
            Engine::Iterative => "iterative",
            Engine::Optimized => "mckernel",
            Engine::Batch => "batch",
            Engine::Simd => "simd",
        }
    }

    /// Parse a CLI name.
    pub fn parse(s: &str) -> Option<Engine> {
        match s {
            "iterative" => Some(Engine::Iterative),
            "optimized" | "mckernel" => Some(Engine::Optimized),
            "batch" => Some(Engine::Batch),
            "simd" => Some(Engine::Simd),
            _ => None,
        }
    }

    /// Run this engine in place on `data` (`data.len()` must be a
    /// power of two). The batch engines treat `data` as a single row.
    pub fn run(self, data: &mut [f32]) {
        match self {
            Engine::Iterative => iterative::fwht(data),
            Engine::Optimized => optimized::fwht(data),
            Engine::Batch => {
                let n = data.len();
                batch::fwht_batch(data, 1, n);
            }
            Engine::Simd => simd::fwht(data),
        }
    }
}

/// In-place FWHT with the default (optimized) engine.
///
/// # Panics
/// If `data.len()` is not a power of two.
pub fn fwht(data: &mut [f32]) {
    optimized::fwht(data);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hash::HashRng;

    fn random_vec(n: usize, seed: u64) -> Vec<f32> {
        let mut r = HashRng::new(seed, 0xF0);
        (0..n).map(|_| r.next_f32() * 2.0 - 1.0).collect()
    }

    /// THE engine-equivalence pin (PR 4 satellite): every production
    /// engine against the reference oracles, across the shapes the
    /// expansion plan actually produces — padded non-power-of-two
    /// input dims (e.g. 784 → 1024, 12 → 16) and the `lanes == 1`
    /// regime where the tile engine degenerates to per-row order
    /// (`tile_lanes(n) == 1` for n ≥ 2^15). The naïve oracle covers
    /// the sizes where O(n²) is affordable; above that the recursive
    /// oracle (itself pinned against naïve in `reference::tests`)
    /// takes over, and Batch-vs-Optimized stays *exact* because the
    /// per-lane arithmetic DAG is identical.
    #[test]
    fn production_engines_match_reference() {
        for n in [
            1usize,
            2,
            8,
            16,          // next_pow2(12)
            64,          // next_pow2(48)
            1024,        // next_pow2(784): the MNIST geometry
            4096,        // largest naïve-checked size
            1 << 14,     // tile_lanes = 2: two-lane tiles
            1 << 15,     // tile_lanes = 1: the per-row-order regime
        ] {
            let x = random_vec(n, n as u64);
            let mut want = x.clone();
            if n <= 4096 {
                reference::fwht_naive(&mut want);
            } else {
                reference::fwht_recursive(&mut want);
            }
            let mut opt = x.clone();
            Engine::Optimized.run(&mut opt);
            for eng in Engine::ALL {
                let mut got = x.clone();
                eng.run(&mut got);
                for (i, (g, w)) in got.iter().zip(want.iter()).enumerate() {
                    assert!(
                        (g - w).abs() <= 1e-3 * w.abs().max(1.0),
                        "{} n={} i={} got={} want={}",
                        eng.name(),
                        n,
                        i,
                        g,
                        w
                    );
                }
                // Optimized and Batch share the per-lane DAG exactly.
                if eng == Engine::Batch {
                    assert_eq!(got, opt, "batch vs optimized exact, n={n}");
                }
            }
        }
    }

    #[test]
    fn involution_up_to_n() {
        // H(Hx) = n·x
        for log_n in [0usize, 1, 4, 7, 10] {
            let n = 1usize << log_n;
            let x = random_vec(n, 77 + log_n as u64);
            let mut y = x.clone();
            fwht(&mut y);
            fwht(&mut y);
            for (a, b) in y.iter().zip(x.iter()) {
                assert!((a / n as f32 - b).abs() < 1e-3, "n={n}");
            }
        }
    }

    #[test]
    fn parseval_energy() {
        // ‖Hx‖² = n·‖x‖²
        let n = 2048;
        let x = random_vec(n, 9);
        let e0: f64 = x.iter().map(|v| (*v as f64) * (*v as f64)).sum();
        let mut y = x;
        fwht(&mut y);
        let e1: f64 = y.iter().map(|v| (*v as f64) * (*v as f64)).sum();
        assert!((e1 / (n as f64 * e0) - 1.0).abs() < 1e-5);
    }

    #[test]
    fn impulse_gives_constant_row() {
        // H e_0 = all-ones
        let n = 512;
        let mut x = vec![0.0f32; n];
        x[0] = 1.0;
        fwht(&mut x);
        assert!(x.iter().all(|&v| (v - 1.0).abs() < 1e-6));
    }

    #[test]
    fn linearity() {
        let n = 256;
        let a = random_vec(n, 1);
        let b = random_vec(n, 2);
        let mut ab: Vec<f32> = a.iter().zip(&b).map(|(x, y)| 2.0 * x + 3.0 * y).collect();
        let (mut ha, mut hb) = (a, b);
        fwht(&mut ha);
        fwht(&mut hb);
        fwht(&mut ab);
        for i in 0..n {
            assert!((ab[i] - (2.0 * ha[i] + 3.0 * hb[i])).abs() < 1e-2);
        }
    }

    #[test]
    fn batch_equals_per_row() {
        let cols = 128;
        let rows = 5;
        let flat = random_vec(rows * cols, 3);
        let mut batch = flat.clone();
        fwht_batch(&mut batch, rows, cols);
        for r in 0..rows {
            let mut row = flat[r * cols..(r + 1) * cols].to_vec();
            fwht(&mut row);
            assert_eq!(&batch[r * cols..(r + 1) * cols], &row[..]);
        }
    }

    #[test]
    fn size_one_is_identity() {
        let mut x = vec![3.5f32];
        fwht(&mut x);
        assert_eq!(x, vec![3.5]);
    }

    #[test]
    #[should_panic]
    fn non_pow2_panics() {
        let mut x = vec![0.0f32; 12];
        fwht(&mut x);
    }

    #[test]
    fn engine_parse_roundtrip() {
        for e in Engine::ALL {
            assert_eq!(Engine::parse(e.name()), Some(e));
        }
        assert_eq!(Engine::parse("optimized"), Some(Engine::Optimized));
        // Reference oracles are not production engines.
        assert_eq!(Engine::parse("naive"), None);
        assert_eq!(Engine::parse("recursive"), None);
        assert_eq!(Engine::parse("bogus"), None);
    }
}
