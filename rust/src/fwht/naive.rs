//! `O(n²)` Walsh–Hadamard by explicit matrix entries — the correctness
//! oracle for the fast engines (paper §4: "a naïve implementation
//! results in complexity O(n²)").
//!
//! Entry `(i, j)` of `H_n` is `(-1)^{popcount(i & j)}` (Sylvester
//! ordering, the same ordering the butterfly engines produce).

/// In-place `O(n²)` Walsh–Hadamard transform.
pub fn fwht(data: &mut [f32]) {
    let n = data.len();
    assert!(n.is_power_of_two(), "FWHT length must be a power of two");
    let x = data.to_vec();
    for (i, out) in data.iter_mut().enumerate() {
        let mut acc = 0.0f64;
        for (j, &v) in x.iter().enumerate() {
            if (i & j).count_ones() & 1 == 0 {
                acc += v as f64;
            } else {
                acc -= v as f64;
            }
        }
        *out = acc as f32;
    }
}

/// The explicit Hadamard matrix entry `H[i][j] ∈ {+1, -1}`.
pub fn entry(i: usize, j: usize) -> f32 {
    if (i & j).count_ones() & 1 == 0 {
        1.0
    } else {
        -1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_recursive_definition_small() {
        // H_1 = [[1,1],[1,-1]]
        assert_eq!(entry(0, 0), 1.0);
        assert_eq!(entry(0, 1), 1.0);
        assert_eq!(entry(1, 0), 1.0);
        assert_eq!(entry(1, 1), -1.0);
        // H_2 block structure: H[2..4][2..4] = -H[0..2][0..2]
        for i in 0..2 {
            for j in 0..2 {
                assert_eq!(entry(i + 2, j + 2), -entry(i, j));
                assert_eq!(entry(i + 2, j), entry(i, j));
                assert_eq!(entry(i, j + 2), entry(i, j));
            }
        }
    }

    #[test]
    fn rows_are_orthogonal() {
        let n = 64;
        for i in 0..n {
            for j in (i + 1)..n {
                let dot: f32 = (0..n).map(|k| entry(i, k) * entry(j, k)).sum();
                assert_eq!(dot, 0.0, "rows {i},{j}");
            }
        }
    }

    #[test]
    fn transform_of_ones_is_scaled_impulse() {
        let n = 128;
        let mut x = vec![1.0f32; n];
        fwht(&mut x);
        assert_eq!(x[0], n as f32);
        assert!(x[1..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn size_two_by_hand() {
        let mut x = vec![3.0f32, 5.0];
        fwht(&mut x);
        assert_eq!(x, vec![8.0, -2.0]);
    }

    #[test]
    fn size_four_by_hand() {
        let mut x = vec![1.0f32, 2.0, 3.0, 4.0];
        fwht(&mut x);
        // H_2 · [1,2,3,4] = [10, -2, -4, 0]
        assert_eq!(x, vec![10.0, -2.0, -4.0, 0.0]);
    }
}
