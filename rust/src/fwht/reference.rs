//! Reference FWHT implementations — **test oracles only**, never
//! selected by the expansion plan.
//!
//! * [`fwht_naive`] — `O(n²)` by explicit sign computation (paper §4:
//!   "a naïve implementation results in complexity O(n²)"). The ground
//!   truth every fast engine is pinned against; f64 accumulation so the
//!   oracle itself carries no rounding surprises.
//! * [`fwht_recursive`] / [`Plan`] — plan-based divide-and-conquer in
//!   the style of Spiral [Johnson & Püschel 2000]; the paper's
//!   comparison baseline in Table 1 / Figure 2. `O(n log n)`, so it
//!   doubles as the oracle at sizes where the naïve transform is too
//!   slow to run in tests.
//!
//! The production engines the plan selects between live in
//! [`super::iterative`], [`super::optimized`] and [`super::batch`];
//! the one place that chooses among them is
//! `mckernel::plan::ExpansionPlan`.

/// In-place `O(n²)` Walsh–Hadamard transform (sign-matrix oracle).
///
/// Entry `(i, j)` of `H_n` is `(-1)^{popcount(i & j)}` (Sylvester
/// ordering, the same ordering the butterfly engines produce).
pub fn fwht_naive(data: &mut [f32]) {
    let n = data.len();
    assert!(n.is_power_of_two(), "FWHT length must be a power of two");
    let x = data.to_vec();
    for (i, out) in data.iter_mut().enumerate() {
        let mut acc = 0.0f64;
        for (j, &v) in x.iter().enumerate() {
            if (i & j).count_ones() & 1 == 0 {
                acc += v as f64;
            } else {
                acc -= v as f64;
            }
        }
        *out = acc as f32;
    }
}

/// The explicit Hadamard matrix entry `H[i][j] ∈ {+1, -1}`.
pub fn entry(i: usize, j: usize) -> f32 {
    if (i & j).count_ones() & 1 == 0 {
        1.0
    } else {
        -1.0
    }
}

/// One node of a Spiral-style breakdown tree.
///
/// Spiral searches over recursive factorizations ("breakdown trees")
/// of the transform and executes the chosen plan by straight-line
/// recursion. We reproduce that execution model: a precomputed tree
/// describing the split at every level, walked by a recursive
/// interpreter with a scalar size-≤8 base codelet. This carries
/// Spiral's structural costs — call/plan-node overhead per region and
/// no cross-stage cache blocking — which is precisely what the
/// McKernel engine removes. (Spiral's published FWHT also caps at
/// `n = 2²⁰`; we note but do not impose the cap.)
#[derive(Debug)]
pub struct Plan {
    /// Transform size at this node (power of two).
    pub n: usize,
    /// `None` for a leaf codelet; `Some((left, right))` for the
    /// divide-and-conquer split into two half-size transforms.
    pub children: Option<Box<(Plan, Plan)>>,
}

/// Leaf codelet size: transforms of ≤ this size run straight-line.
const LEAF: usize = 8;

impl Plan {
    /// Build the balanced radix-2 breakdown tree for size `n`
    /// (Spiral's default FWHT rule `WHT_{2^k} → WHT_2 ⊗ WHT_{2^{k-1}}`
    /// evaluated as split-in-half recursion).
    pub fn build(n: usize) -> Plan {
        assert!(n.is_power_of_two(), "FWHT length must be a power of two");
        if n <= LEAF {
            Plan { n, children: None }
        } else {
            let half = Plan::build(n / 2);
            let half2 = Plan::build(n / 2);
            Plan { n, children: Some(Box::new((half, half2))) }
        }
    }

    /// Number of nodes in the plan (bench metadata: Spiral's
    /// "precompute trees" cost is proportional to this).
    pub fn node_count(&self) -> usize {
        1 + self
            .children
            .as_ref()
            .map_or(0, |c| c.0.node_count() + c.1.node_count())
    }

    /// Execute the plan in place.
    pub fn execute(&self, data: &mut [f32]) {
        debug_assert_eq!(data.len(), self.n);
        match &self.children {
            None => leaf_codelet(data),
            Some(c) => {
                let (lo, hi) = data.split_at_mut(self.n / 2);
                c.0.execute(lo);
                c.1.execute(hi);
                // combine: [lo+hi, lo-hi]  (paper Eq. 12)
                for (a, b) in lo.iter_mut().zip(hi.iter_mut()) {
                    let s = *a + *b;
                    let d = *a - *b;
                    *a = s;
                    *b = d;
                }
            }
        }
    }
}

/// Straight-line transform for n ∈ {1, 2, 4, 8}.
fn leaf_codelet(d: &mut [f32]) {
    match d.len() {
        1 => {}
        2 => {
            let (a, b) = (d[0], d[1]);
            d[0] = a + b;
            d[1] = a - b;
        }
        4 => {
            let (a, b, c, e) = (d[0], d[1], d[2], d[3]);
            let (s0, d0, s1, d1) = (a + b, a - b, c + e, c - e);
            d[0] = s0 + s1;
            d[1] = d0 + d1;
            d[2] = s0 - s1;
            d[3] = d0 - d1;
        }
        8 => {
            // two size-4 transforms + combine
            let (lo, hi) = d.split_at_mut(4);
            leaf_codelet(lo);
            leaf_codelet(hi);
            for i in 0..4 {
                let s = lo[i] + hi[i];
                let t = lo[i] - hi[i];
                lo[i] = s;
                hi[i] = t;
            }
        }
        _ => unreachable!("leaf codelet sizes are 1,2,4,8"),
    }
}

/// One-shot plan-build + execute (what the Table 1 baseline times; a
/// cached-plan variant is exposed for fairness in the bench harness).
pub fn fwht_recursive(data: &mut [f32]) {
    let plan = Plan::build(data.len());
    plan.execute(data);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn entry_matches_recursive_definition_small() {
        // H_1 = [[1,1],[1,-1]]
        assert_eq!(entry(0, 0), 1.0);
        assert_eq!(entry(0, 1), 1.0);
        assert_eq!(entry(1, 0), 1.0);
        assert_eq!(entry(1, 1), -1.0);
        // H_2 block structure: H[2..4][2..4] = -H[0..2][0..2]
        for i in 0..2 {
            for j in 0..2 {
                assert_eq!(entry(i + 2, j + 2), -entry(i, j));
                assert_eq!(entry(i + 2, j), entry(i, j));
                assert_eq!(entry(i, j + 2), entry(i, j));
            }
        }
    }

    #[test]
    fn naive_rows_are_orthogonal() {
        let n = 64;
        for i in 0..n {
            for j in (i + 1)..n {
                let dot: f32 = (0..n).map(|k| entry(i, k) * entry(j, k)).sum();
                assert_eq!(dot, 0.0, "rows {i},{j}");
            }
        }
    }

    #[test]
    fn naive_transform_of_ones_is_scaled_impulse() {
        let n = 128;
        let mut x = vec![1.0f32; n];
        fwht_naive(&mut x);
        assert_eq!(x[0], n as f32);
        assert!(x[1..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn naive_small_sizes_by_hand() {
        let mut x = vec![3.0f32, 5.0];
        fwht_naive(&mut x);
        assert_eq!(x, vec![8.0, -2.0]);
        let mut y = vec![1.0f32, 2.0, 3.0, 4.0];
        fwht_naive(&mut y);
        // H_2 · [1,2,3,4] = [10, -2, -4, 0]
        assert_eq!(y, vec![10.0, -2.0, -4.0, 0.0]);
    }

    #[test]
    fn recursive_matches_naive() {
        for log_n in 0..=12 {
            let n = 1usize << log_n;
            let x: Vec<f32> = (0..n).map(|i| ((i * 37 + 11) % 17) as f32 - 8.0).collect();
            let mut a = x.clone();
            let mut b = x;
            fwht_recursive(&mut a);
            fwht_naive(&mut b);
            for (u, v) in a.iter().zip(b.iter()) {
                assert!((u - v).abs() < 1e-3 * v.abs().max(1.0), "n={n}");
            }
        }
    }

    #[test]
    fn plan_node_count_grows_linearly() {
        // Balanced binary tree over n/LEAF leaves → ~2·n/LEAF − 1 nodes.
        let p = Plan::build(1 << 12);
        assert_eq!(p.node_count(), 2 * (1 << 12) / LEAF - 1);
    }

    #[test]
    fn plan_reuse_is_consistent() {
        let plan = Plan::build(256);
        let x: Vec<f32> = (0..256).map(|i| i as f32).collect();
        let mut a = x.clone();
        let mut b = x;
        plan.execute(&mut a);
        plan.execute(&mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn leaf_sizes_direct() {
        for n in [1usize, 2, 4, 8] {
            let x: Vec<f32> = (0..n).map(|i| (i as f32) - 1.5).collect();
            let mut a = x.clone();
            let mut b = x;
            fwht_recursive(&mut a);
            fwht_naive(&mut b);
            assert_eq!(a, b, "n={n}");
        }
    }
}
