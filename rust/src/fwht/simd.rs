//! Explicit-intrinsics FWHT butterflies (AVX2 / NEON), runtime
//! dispatched — the kernels behind `mckernel::plan::FwhtDispatch::Simd`.
//!
//! The scalar passes in [`super::optimized`] already walk contiguous
//! dual/quad streams precisely so the compiler *can* vectorize them;
//! this module removes the "can" by issuing the vector adds/subs
//! explicitly: 8 f32 lanes per op on AVX2, 4 on NEON, with a scalar
//! remainder loop for stream tails shorter than a register. Because a
//! butterfly is nothing but independent elementwise `x+y` / `x−y`
//! (IEEE ops identical scalar or vectorized, no re-association, no
//! FMA), every engine here is **bit-identical** to its scalar twin —
//! the differential tests assert exact equality, not a tolerance.
//!
//! Entry points mirror `fwht::batch`: [`fwht_colmajor`] runs the stage
//! schedule over a column-major `(n, lanes)` tile (stride = coefficient
//! stride × lane count, exactly like the scalar tile engine, so the
//! per-lane arithmetic DAG is unchanged), [`fwht`] is the single-row
//! form, [`fwht_batch`] streams row-major matrices through transpose
//! tiles. Each checks the cached [`crate::util::simd::level`] once and
//! falls back to the scalar engines when no vector unit is present, so
//! a *forced* SIMD dispatch still runs — and still matches the scalar
//! arm bit-for-bit — on machines without AVX2/NEON.

use super::batch;
use super::optimized::{radix2_pass as radix2_scalar, radix4_pass as radix4_scalar};
use crate::util::simd::{level, SimdLevel};

/// One radix-2 butterfly stage at stride `h`, vector-widened.
/// Bit-identical to [`super::optimized::radix2_pass`].
pub fn radix2_pass(data: &mut [f32], h: usize) {
    match level() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: level() == Avx2 only after is_x86_feature_detected!.
        SimdLevel::Avx2 => unsafe { avx2::radix2_pass(data, h) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: level() == Neon only after runtime NEON detection.
        SimdLevel::Neon => unsafe { neon::radix2_pass(data, h) },
        _ => radix2_scalar(data, h),
    }
}

/// Two fused butterfly stages (strides `h`, `2h`), vector-widened.
/// Bit-identical to [`super::optimized::radix4_pass`].
pub fn radix4_pass(data: &mut [f32], h: usize) {
    match level() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: level() == Avx2 only after is_x86_feature_detected!.
        SimdLevel::Avx2 => unsafe { avx2::radix4_pass(data, h) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: level() == Neon only after runtime NEON detection.
        SimdLevel::Neon => unsafe { neon::radix4_pass(data, h) },
        _ => radix4_scalar(data, h),
    }
}

/// All `log₂ n` butterfly stages over a column-major `(n, lanes)` tile
/// in place — the same stage schedule as [`batch::fwht_colmajor`]
/// (radix-2 parity pass, then fused radix-4 sweeps), driven through
/// the vector passes. Bit-identical to the scalar tile engine, and
/// therefore to the per-row optimized engine per lane.
pub fn fwht_colmajor(tile: &mut [f32], n: usize, lanes: usize) {
    assert!(n.is_power_of_two(), "FWHT length must be a power of two");
    assert_eq!(tile.len(), n * lanes, "tile shape mismatch");
    if n <= 1 || lanes == 0 {
        return;
    }
    match level() {
        #[cfg(target_arch = "x86_64")]
        // SAFETY: level() == Avx2 only after is_x86_feature_detected!.
        SimdLevel::Avx2 => unsafe { avx2::fwht_colmajor(tile, n, lanes) },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: level() == Neon only after runtime NEON detection.
        SimdLevel::Neon => unsafe { neon::fwht_colmajor(tile, n, lanes) },
        _ => batch::fwht_colmajor(tile, n, lanes),
    }
}

/// Single-row in-place FWHT through the vector passes (the CLI/bench
/// baseline form). A one-lane column-major tile *is* the row, so this
/// is [`fwht_colmajor`] with `lanes == 1` — bit-identical to
/// [`super::optimized::fwht`].
pub fn fwht(data: &mut [f32]) {
    let n = data.len();
    fwht_colmajor(data, n, 1);
}

/// FWHT of every row of a row-major `(rows, n)` matrix via transpose
/// tiles, exactly like [`batch::fwht_batch`] but with the vector
/// butterflies. Bit-identical to the scalar batch engine.
pub fn fwht_batch(data: &mut [f32], rows: usize, n: usize) {
    assert!(n.is_power_of_two(), "row length must be a power of two");
    assert_eq!(data.len(), rows * n, "buffer shape mismatch");
    if n <= 1 {
        return;
    }
    let lanes_max = batch::tile_lanes(n);
    let mut tile = vec![0.0f32; n * lanes_max];
    let mut base = 0;
    while base < rows {
        let lanes = lanes_max.min(rows - base);
        let rows_slice = &mut data[base * n..(base + lanes) * n];
        let tile = &mut tile[..n * lanes];
        batch::load_tile(rows_slice, n, lanes, tile);
        fwht_colmajor(tile, n, lanes);
        batch::store_tile(tile, n, lanes, rows_slice);
        base += lanes;
    }
}

/// Shared stage schedule: parity radix-2 pass, then fused radix-4
/// sweeps — identical to [`batch::fwht_colmajor`]'s loop. Generic over
/// the pass kernels so each arch module monomorphizes it inside its
/// `#[target_feature]` region.
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
macro_rules! stage_schedule {
    ($tile:expr, $n:expr, $lanes:expr, $r2:path, $r4:path) => {{
        let stages = $n.trailing_zeros();
        let mut h = $lanes;
        if stages % 2 == 1 {
            // SAFETY: expanded only inside the arch modules'
            // #[target_feature] fns; their callers proved the feature.
            unsafe { $r2($tile, h) };
            h *= 2;
        }
        while h < $n * $lanes {
            // SAFETY: same feature precondition as above.
            unsafe { $r4($tile, h) };
            h *= 4;
        }
    }};
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    /// # Safety
    /// Caller must guarantee the CPU supports AVX2.
    #[target_feature(enable = "avx2")]
    pub unsafe fn fwht_colmajor(tile: &mut [f32], n: usize, lanes: usize) {
        stage_schedule!(tile, n, lanes, radix2_pass, radix4_pass);
    }

    /// # Safety
    /// Caller must guarantee the CPU supports AVX2.
    #[target_feature(enable = "avx2")]
    pub unsafe fn radix2_pass(data: &mut [f32], h: usize) {
        for pair in data.chunks_exact_mut(2 * h) {
            let (a, b) = pair.split_at_mut(h);
            let (ap, bp) = (a.as_mut_ptr(), b.as_mut_ptr());
            let mut i = 0;
            while i + 8 <= h {
                // SAFETY: i + 8 <= h bounds both 8-float loads/stores;
                // ap/bp point into the live disjoint halves of `pair`.
                unsafe {
                    let x = _mm256_loadu_ps(ap.add(i));
                    let y = _mm256_loadu_ps(bp.add(i));
                    _mm256_storeu_ps(ap.add(i), _mm256_add_ps(x, y));
                    _mm256_storeu_ps(bp.add(i), _mm256_sub_ps(x, y));
                }
                i += 8;
            }
            while i < h {
                let (x, y) = (a[i], b[i]);
                a[i] = x + y;
                b[i] = x - y;
                i += 1;
            }
        }
    }

    /// # Safety
    /// Caller must guarantee the CPU supports AVX2.
    #[target_feature(enable = "avx2")]
    pub unsafe fn radix4_pass(data: &mut [f32], h: usize) {
        for quad in data.chunks_exact_mut(4 * h) {
            let (ab, cd) = quad.split_at_mut(2 * h);
            let (a, b) = ab.split_at_mut(h);
            let (c, d) = cd.split_at_mut(h);
            let (ap, bp, cp, dp) =
                (a.as_mut_ptr(), b.as_mut_ptr(), c.as_mut_ptr(), d.as_mut_ptr());
            let mut i = 0;
            while i + 8 <= h {
                // SAFETY: i + 8 <= h bounds all four 8-float streams;
                // the split_at_mut chain keeps them disjoint and live.
                unsafe {
                    let va = _mm256_loadu_ps(ap.add(i));
                    let vb = _mm256_loadu_ps(bp.add(i));
                    let vc = _mm256_loadu_ps(cp.add(i));
                    let vd = _mm256_loadu_ps(dp.add(i));
                    let t0 = _mm256_add_ps(va, vb);
                    let t1 = _mm256_sub_ps(va, vb);
                    let t2 = _mm256_add_ps(vc, vd);
                    let t3 = _mm256_sub_ps(vc, vd);
                    _mm256_storeu_ps(ap.add(i), _mm256_add_ps(t0, t2));
                    _mm256_storeu_ps(bp.add(i), _mm256_add_ps(t1, t3));
                    _mm256_storeu_ps(cp.add(i), _mm256_sub_ps(t0, t2));
                    _mm256_storeu_ps(dp.add(i), _mm256_sub_ps(t1, t3));
                }
                i += 8;
            }
            while i < h {
                let t0 = a[i] + b[i];
                let t1 = a[i] - b[i];
                let t2 = c[i] + d[i];
                let t3 = c[i] - d[i];
                a[i] = t0 + t2;
                b[i] = t1 + t3;
                c[i] = t0 - t2;
                d[i] = t1 - t3;
                i += 1;
            }
        }
    }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    use std::arch::aarch64::*;

    /// # Safety
    /// Caller must guarantee the CPU supports NEON.
    #[target_feature(enable = "neon")]
    pub unsafe fn fwht_colmajor(tile: &mut [f32], n: usize, lanes: usize) {
        stage_schedule!(tile, n, lanes, radix2_pass, radix4_pass);
    }

    /// # Safety
    /// Caller must guarantee the CPU supports NEON.
    #[target_feature(enable = "neon")]
    pub unsafe fn radix2_pass(data: &mut [f32], h: usize) {
        for pair in data.chunks_exact_mut(2 * h) {
            let (a, b) = pair.split_at_mut(h);
            let (ap, bp) = (a.as_mut_ptr(), b.as_mut_ptr());
            let mut i = 0;
            while i + 4 <= h {
                // SAFETY: i + 4 <= h bounds both 4-float loads/stores;
                // ap/bp point into the live disjoint halves of `pair`.
                unsafe {
                    let x = vld1q_f32(ap.add(i));
                    let y = vld1q_f32(bp.add(i));
                    vst1q_f32(ap.add(i), vaddq_f32(x, y));
                    vst1q_f32(bp.add(i), vsubq_f32(x, y));
                }
                i += 4;
            }
            while i < h {
                let (x, y) = (a[i], b[i]);
                a[i] = x + y;
                b[i] = x - y;
                i += 1;
            }
        }
    }

    /// # Safety
    /// Caller must guarantee the CPU supports NEON.
    #[target_feature(enable = "neon")]
    pub unsafe fn radix4_pass(data: &mut [f32], h: usize) {
        for quad in data.chunks_exact_mut(4 * h) {
            let (ab, cd) = quad.split_at_mut(2 * h);
            let (a, b) = ab.split_at_mut(h);
            let (c, d) = cd.split_at_mut(h);
            let (ap, bp, cp, dp) =
                (a.as_mut_ptr(), b.as_mut_ptr(), c.as_mut_ptr(), d.as_mut_ptr());
            let mut i = 0;
            while i + 4 <= h {
                // SAFETY: i + 4 <= h bounds all four 4-float streams;
                // the split_at_mut chain keeps them disjoint and live.
                unsafe {
                    let va = vld1q_f32(ap.add(i));
                    let vb = vld1q_f32(bp.add(i));
                    let vc = vld1q_f32(cp.add(i));
                    let vd = vld1q_f32(dp.add(i));
                    let t0 = vaddq_f32(va, vb);
                    let t1 = vsubq_f32(va, vb);
                    let t2 = vaddq_f32(vc, vd);
                    let t3 = vsubq_f32(vc, vd);
                    vst1q_f32(ap.add(i), vaddq_f32(t0, t2));
                    vst1q_f32(bp.add(i), vaddq_f32(t1, t3));
                    vst1q_f32(cp.add(i), vsubq_f32(t0, t2));
                    vst1q_f32(dp.add(i), vsubq_f32(t1, t3));
                }
                i += 4;
            }
            while i < h {
                let t0 = a[i] + b[i];
                let t1 = a[i] - b[i];
                let t2 = c[i] + d[i];
                let t3 = c[i] - d[i];
                a[i] = t0 + t2;
                b[i] = t1 + t3;
                c[i] = t0 - t2;
                d[i] = t1 - t3;
                i += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fwht;
    use crate::hash::HashRng;

    fn random_vec(n: usize, seed: u64) -> Vec<f32> {
        let mut r = HashRng::new(seed, 0x51);
        (0..n).map(|_| r.next_f32() * 2.0 - 1.0).collect()
    }

    /// THE bit-identity pin: butterflies are adds/subs, so the SIMD
    /// engines must equal the scalar engines exactly — including odd
    /// stream tails shorter than a vector register (h % width != 0).
    #[test]
    fn passes_match_scalar_exactly() {
        for h in [1usize, 2, 3, 4, 5, 7, 8, 9, 15, 16, 17, 32, 100] {
            let x2 = random_vec(2 * h * 3, h as u64);
            let mut a = x2.clone();
            let mut b = x2;
            radix2_pass(&mut a, h);
            radix2_scalar(&mut b, h);
            assert_eq!(a, b, "radix2 h={h}");

            let x4 = random_vec(4 * h * 2, 100 + h as u64);
            let mut a = x4.clone();
            let mut b = x4;
            radix4_pass(&mut a, h);
            radix4_scalar(&mut b, h);
            assert_eq!(a, b, "radix4 h={h}");
        }
    }

    #[test]
    fn colmajor_matches_scalar_tile_engine_exactly() {
        for (n, lanes) in [(1usize, 3usize), (2, 5), (16, 1), (16, 7), (64, 3), (1024, 32)] {
            let x = random_vec(n * lanes, (n * 100 + lanes) as u64);
            let mut a = x.clone();
            let mut b = x;
            fwht_colmajor(&mut a, n, lanes);
            batch::fwht_colmajor(&mut b, n, lanes);
            assert_eq!(a, b, "n={n} lanes={lanes}");
        }
    }

    #[test]
    fn single_row_matches_optimized_exactly() {
        for n in [1usize, 2, 8, 64, 512, 4096] {
            let x = random_vec(n, n as u64 + 7);
            let mut a = x.clone();
            let mut b = x;
            fwht(&mut a);
            fwht::fwht(&mut b);
            assert_eq!(a, b, "n={n}");
        }
    }

    #[test]
    fn batch_matches_scalar_batch_exactly() {
        for (rows, n) in [(1usize, 64usize), (3, 256), (33, 1024), (7, 16)] {
            let x = random_vec(rows * n, (rows + n) as u64);
            let mut a = x.clone();
            let mut b = x;
            fwht_batch(&mut a, rows, n);
            batch::fwht_batch(&mut b, rows, n);
            assert_eq!(a, b, "rows={rows} n={n}");
        }
    }

    #[test]
    #[should_panic]
    fn non_pow2_rejected() {
        let mut x = vec![0.0f32; 12];
        fwht(&mut x);
    }
}
