//! PJRT-backed training coordinator: the end-to-end three-layer path.
//!
//! The compiled train-step artifact (L2 JAX + L1 Pallas) runs under
//! the PJRT CPU client while this coordinator (L3) owns epochs,
//! prefetching, evaluation and reporting — mirroring
//! [`crate::train::Trainer`]'s native loop so the two backends are
//! directly comparable (`--backend native|pjrt` in the examples).

use super::pipeline::Prefetcher;
use crate::data::{Batcher, Dataset};
use crate::mckernel::McKernel;
use crate::model::SoftmaxRegression;
use crate::runtime::{Predictor, Runtime, TrainStep};
use crate::train::metrics::{accuracy, EpochRecord};
use crate::train::trainer::{TrainConfig, TrainReport};
use anyhow::Result;
use std::sync::Arc;
use std::time::Instant;

/// Coordinator for training over the compiled artifacts.
pub struct PjrtTrainer<'rt> {
    runtime: &'rt Runtime,
    config: TrainConfig,
    /// `Some` → McKernel path; `None` → LR baseline path.
    map: Option<Arc<McKernel>>,
    /// Prefetch depth (batches in flight).
    pub prefetch_depth: usize,
}

impl<'rt> PjrtTrainer<'rt> {
    pub fn new(runtime: &'rt Runtime, config: TrainConfig, map: Option<Arc<McKernel>>) -> Self {
        PjrtTrainer { runtime, config, map, prefetch_depth: 4 }
    }

    fn featurizer_name(&self) -> &'static str {
        if self.map.is_some() {
            "mckernel-pjrt"
        } else {
            "identity-pjrt"
        }
    }

    /// Train on `train`, evaluating on `test`; returns the learned
    /// host-side model + per-epoch history.
    pub fn fit(&self, train: &Arc<Dataset>, test: &Dataset) -> Result<(SoftmaxRegression, TrainReport)> {
        let featurizer = if self.map.is_some() { "mckernel" } else { "identity" };
        let mut step = TrainStep::new(self.runtime, featurizer, self.map.as_deref())?;
        let predictor = Predictor::new(self.runtime, featurizer, self.map.as_deref())?;
        anyhow::ensure!(
            step.entry().batch == self.config.batch_size,
            "artifact batch {} != configured batch {} (regenerate artifacts)",
            step.entry().batch,
            self.config.batch_size
        );
        let mut history = Vec::with_capacity(self.config.epochs);
        for epoch in 0..self.config.epochs {
            let t0 = Instant::now();
            // PJRT graphs are fixed-shape: drop the ragged tail batch.
            let prefetch = Prefetcher::spawn(
                Arc::clone(train),
                self.config.batch_size,
                self.config.seed,
                epoch,
                self.prefetch_depth,
                true,
                None, // featurization happens in-graph
            );
            let mut loss_sum = 0.0f64;
            let mut batches = 0usize;
            let mut rows_done = 0usize;
            for fb in prefetch.iter() {
                let loss = step.step(&fb.features, &fb.labels, self.config.sgd.lr)?;
                loss_sum += loss as f64;
                batches += 1;
                rows_done += fb.labels.len();
            }
            let train_secs = t0.elapsed().as_secs_f64();
            let model = step.export_model()?;
            let test_acc = if self.config.eval_every_epoch || epoch + 1 == self.config.epochs {
                self.evaluate_with(&predictor, &model, test)?
            } else {
                f64::NAN
            };
            let rec = EpochRecord {
                epoch,
                train_loss: loss_sum / batches.max(1) as f64,
                train_accuracy: f64::NAN, // not tracked on-device
                test_accuracy: test_acc,
                seconds: t0.elapsed().as_secs_f64(),
                rows_per_s: EpochRecord::throughput(rows_done, train_secs),
            };
            if self.config.verbose {
                eprintln!(
                    "[{}] epoch {:>3}  loss {:.4}  test-acc {:.4}  ({:.2}s)",
                    self.featurizer_name(),
                    rec.epoch,
                    rec.train_loss,
                    rec.test_accuracy,
                    rec.seconds
                );
            }
            history.push(rec);
        }
        let model = step.export_model()?;
        let final_test_accuracy = history.last().map(|r| r.test_accuracy).unwrap_or(f64::NAN);
        Ok((
            model.clone(),
            TrainReport {
                history,
                final_test_accuracy,
                param_count: model.param_count(),
                featurizer: self.featurizer_name(),
            },
        ))
    }

    /// Evaluate `model` on `data` through the compiled predictor.
    pub fn evaluate(&self, model: &SoftmaxRegression, data: &Dataset) -> Result<f64> {
        let featurizer = if self.map.is_some() { "mckernel" } else { "identity" };
        let predictor = Predictor::new(self.runtime, featurizer, self.map.as_deref())?;
        self.evaluate_with(&predictor, model, data)
    }

    fn evaluate_with(
        &self,
        predictor: &Predictor,
        model: &SoftmaxRegression,
        data: &Dataset,
    ) -> Result<f64> {
        let eval_batch = predictor.entry().batch;
        let batcher = Batcher::new(eval_batch, 0).sequential();
        let mut preds = Vec::with_capacity(data.len());
        for batch in batcher.epoch(data, 0) {
            preds.extend(predictor.predict(model, &batch.images)?);
        }
        Ok(accuracy(&preds, data.labels()))
    }
}
