//! Layer-3 coordination: the mini-batch training orchestrator over the
//! PJRT runtime and the dynamic-batching feature server.
//!
//! Rust owns the event loop, the data pipeline (prefetch threads with
//! bounded-channel backpressure), process lifecycle and metrics; the
//! compiled XLA artifacts own the math. Python never runs here.

pub mod pipeline;
pub mod pjrt_trainer;
pub mod server;

pub use pipeline::{FeaturizedBatch, Prefetcher};
pub use pjrt_trainer::PjrtTrainer;
pub use server::{FeatureClient, FeatureServer, PendingReply, Reply, ServerConfig, ServerStats};
