//! Dynamic-batching feature server — the paper's "drop-in generator of
//! features for linear methods where attributes are generated
//! on-the-fly" (§1), coordinated vLLM-router-style: clients submit
//! single vectors, the server coalesces them into batches (size- or
//! deadline-triggered), featurizes once per batch, and scatters the
//! rows back to the callers.
//!
//! Throughput/latency accounting lives in the observability registry
//! (`server.*` metrics); [`ServerStats`] is the typed compatibility
//! view over those handles. These are once-per-request /
//! once-per-batch updates, so they record unconditionally — the
//! enabled flag only gates the fine-grained engine/trainer timers.

use crate::linalg::Matrix;
use crate::mckernel::{ExpansionEngine, McKernel};
use crate::obs::{self, Counter, Gauge, Hist, HistSnapshot, MetricsRegistry};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// One in-flight request.
struct Request {
    x: Vec<f32>,
    reply: Sender<Vec<f32>>,
    /// Submission time — measured end to end at the reply scatter.
    t0: Instant,
}

/// Channel message: a job, or the shutdown poison pill (so `shutdown`
/// terminates the loop even while client handles are still alive).
enum Msg {
    Job(Request),
    Shutdown,
}

/// Server metrics: a compatibility view over handles registered in a
/// [`MetricsRegistry`] under `server.*` (the pre-observability
/// `ServerStats` carried its own ad-hoc atomics; they now live in the
/// registry so `mckernel stats` snapshots and these accessors always
/// agree). Cloning the view clones the `Arc` handles — all clones
/// observe the same metrics.
#[derive(Debug, Clone)]
pub struct ServerStats {
    requests: Arc<Counter>,
    batches: Arc<Counter>,
    /// Sum of batch sizes (for mean batch occupancy).
    batched_rows: Arc<Counter>,
    /// Batches flushed by the `max_wait` deadline while still short of
    /// `max_batch`.
    deadline_miss: Arc<Counter>,
    /// Requests submitted but not yet replied to.
    queue_depth: Arc<Gauge>,
    /// End-to-end request latency (submit → reply scatter).
    latency_ns: Arc<Hist>,
    /// Rows per executed batch (occupancy distribution).
    batch_fill: Arc<Hist>,
}

impl ServerStats {
    /// Resolve the `server.*` handles in `reg`.
    pub fn register(reg: &MetricsRegistry) -> ServerStats {
        ServerStats {
            requests: reg.counter("server.requests"),
            batches: reg.counter("server.batches"),
            batched_rows: reg.counter("server.batched_rows"),
            deadline_miss: reg.counter("server.deadline_miss"),
            queue_depth: reg.gauge("server.queue_depth"),
            latency_ns: reg.histogram("server.latency_ns"),
            batch_fill: reg.histogram("server.batch_fill"),
        }
    }

    /// Total requests replied to.
    pub fn requests(&self) -> u64 {
        self.requests.get()
    }

    /// Total batches executed.
    pub fn batches(&self) -> u64 {
        self.batches.get()
    }

    /// Sum of executed batch sizes.
    pub fn batched_rows(&self) -> u64 {
        self.batched_rows.get()
    }

    /// Batches flushed by deadline while under `max_batch`.
    pub fn deadline_misses(&self) -> u64 {
        self.deadline_miss.get()
    }

    /// Requests currently submitted and unanswered.
    pub fn queue_depth(&self) -> i64 {
        self.queue_depth.get()
    }

    /// Request-latency summary (nanoseconds).
    pub fn latency(&self) -> HistSnapshot {
        self.latency_ns.snapshot()
    }

    /// Mean rows per executed batch.
    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.get();
        if b == 0 {
            return 0.0;
        }
        self.batched_rows.get() as f64 / b as f64
    }
}

/// Handle to a running feature server.
pub struct FeatureServer {
    tx: Option<Sender<Msg>>,
    handle: Option<JoinHandle<()>>,
    stats: ServerStats,
    input_dim: usize,
    feature_dim: usize,
}

impl FeatureServer {
    /// Start the server thread, reporting into the global registry.
    ///
    /// * `max_batch`: coalesce at most this many requests per batch.
    /// * `max_wait`: flush a partial batch after this deadline.
    pub fn start(map: Arc<McKernel>, max_batch: usize, max_wait: Duration) -> FeatureServer {
        FeatureServer::start_with_registry(map, max_batch, max_wait, obs::global())
    }

    /// Like [`FeatureServer::start`] but reporting into `registry` —
    /// the injection seam tests use for isolated, deterministic
    /// counts (two servers on the *global* registry share metrics).
    pub fn start_with_registry(
        map: Arc<McKernel>,
        max_batch: usize,
        max_wait: Duration,
        registry: &MetricsRegistry,
    ) -> FeatureServer {
        assert!(max_batch > 0);
        let (tx, rx) = channel::<Msg>();
        let stats = ServerStats::register(registry);
        let stats2 = stats.clone();
        let input_dim = map.input_dim();
        let feature_dim = map.feature_dim();
        let handle = std::thread::Builder::new()
            .name("mckernel-feature-server".into())
            .spawn(move || Self::serve(map, rx, max_batch, max_wait, stats2))
            .expect("spawn server thread");
        FeatureServer { tx: Some(tx), handle: Some(handle), stats, input_dim, feature_dim }
    }

    /// The batching event loop.
    fn serve(
        map: Arc<McKernel>,
        rx: Receiver<Msg>,
        max_batch: usize,
        max_wait: Duration,
        stats: ServerStats,
    ) {
        // One compiled engine for the server's lifetime: scratch and
        // feature buffer pooled across every coalesced batch.
        let mut engine = ExpansionEngine::new(&map, max_batch);
        let mut feats = Matrix::zeros(0, 0);
        let mut shutting_down = false;
        loop {
            // Block for the first request of a batch.
            let first = match rx.recv() {
                Ok(Msg::Job(r)) => r,
                Ok(Msg::Shutdown) | Err(_) => return,
            };
            let mut pending = vec![first];
            let deadline = Instant::now() + max_wait;
            let mut deadline_hit = false;
            // Coalesce until full or deadline.
            while pending.len() < max_batch {
                let now = Instant::now();
                if now >= deadline {
                    deadline_hit = true;
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(Msg::Job(r)) => pending.push(r),
                    Ok(Msg::Shutdown) => {
                        shutting_down = true;
                        break;
                    }
                    Err(RecvTimeoutError::Timeout) => {
                        deadline_hit = true;
                        break;
                    }
                    Err(RecvTimeoutError::Disconnected) => break,
                }
            }
            stats.batches.inc();
            stats.batched_rows.add(pending.len() as u64);
            stats.batch_fill.record(pending.len() as u64);
            if deadline_hit && pending.len() < max_batch {
                stats.deadline_miss.inc();
            }
            // Featurize the coalesced batch in ONE engine pass — this
            // is where coalescing pays: the tile-vectorized pipeline
            // turns every butterfly, gather and trig evaluation into a
            // wide stream across the whole batch.
            let rows = pending.len();
            let mut xb = Matrix::zeros(rows, map.input_dim());
            for (r, req) in pending.iter().enumerate() {
                xb.row_mut(r).copy_from_slice(&req.x);
            }
            feats.resize(rows, map.feature_dim());
            engine.execute_matrix(&map, &xb, &mut feats);
            for (r, req) in pending.into_iter().enumerate() {
                stats.requests.inc();
                stats.latency_ns.record(req.t0.elapsed().as_nanos() as u64);
                stats.queue_depth.add(-1);
                let _ = req.reply.send(feats.row(r).to_vec()); // client may have left
            }
            if shutting_down {
                return;
            }
        }
    }

    /// Expected input width.
    pub fn input_dim(&self) -> usize {
        self.input_dim
    }

    /// Produced feature width.
    pub fn feature_dim(&self) -> usize {
        self.feature_dim
    }

    /// Metric accessors.
    pub fn stats(&self) -> &ServerStats {
        &self.stats
    }

    /// Synchronous call: featurize one vector.
    pub fn transform(&self, x: Vec<f32>) -> Option<Vec<f32>> {
        assert_eq!(x.len(), self.input_dim, "input width");
        let (reply_tx, reply_rx) = channel();
        let req = Request { x, reply: reply_tx, t0: Instant::now() };
        self.stats.queue_depth.add(1);
        if self.tx.as_ref().and_then(|tx| tx.send(Msg::Job(req)).ok()).is_none() {
            self.stats.queue_depth.add(-1);
            return None;
        }
        reply_rx.recv().ok()
    }

    /// A cloneable client handle usable from other threads.
    pub fn client(&self) -> FeatureClient {
        FeatureClient {
            tx: self.tx.as_ref().expect("server running").clone(),
            stats: self.stats.clone(),
            input_dim: self.input_dim,
        }
    }

    /// Stop the server (drains requests already queued ahead of the
    /// poison pill; safe even while client handles are still alive).
    pub fn shutdown(mut self) {
        if let Some(tx) = self.tx.take() {
            let _ = tx.send(Msg::Shutdown);
        }
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for FeatureServer {
    fn drop(&mut self) {
        if let Some(tx) = self.tx.take() {
            let _ = tx.send(Msg::Shutdown);
        }
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Cheap cloneable submission handle.
#[derive(Clone)]
pub struct FeatureClient {
    tx: Sender<Msg>,
    stats: ServerStats,
    input_dim: usize,
}

impl FeatureClient {
    /// Synchronous featurize (None if the server shut down).
    pub fn transform(&self, x: Vec<f32>) -> Option<Vec<f32>> {
        assert_eq!(x.len(), self.input_dim, "input width");
        let (reply_tx, reply_rx) = channel();
        let req = Request { x, reply: reply_tx, t0: Instant::now() };
        self.stats.queue_depth.add(1);
        if self.tx.send(Msg::Job(req)).is_err() {
            self.stats.queue_depth.add(-1);
            return None;
        }
        reply_rx.recv().ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mckernel::McKernelFactory;

    fn test_map() -> Arc<McKernel> {
        Arc::new(McKernelFactory::new(16).expansions(1).seed(4).build())
    }

    /// Each test server gets its own registry: counts are per-server
    /// and immune to other tests running in the same process.
    fn server(max_batch: usize) -> FeatureServer {
        FeatureServer::start_with_registry(
            test_map(),
            max_batch,
            Duration::from_millis(2),
            &MetricsRegistry::new(),
        )
    }

    #[test]
    fn single_request_roundtrip() {
        let s = server(8);
        let x: Vec<f32> = (0..16).map(|i| i as f32 * 0.1).collect();
        let f = s.transform(x.clone()).unwrap();
        assert_eq!(f.len(), s.feature_dim());
        // must equal the direct batched map output (tile grouping is
        // irrelevant: lanes never interact)
        let map = McKernelFactory::new(16).expansions(1).seed(4).build();
        let want = map.transform_batch(&Matrix::from_vec(1, 16, x));
        assert_eq!(&f[..], want.row(0));
        s.shutdown();
    }

    #[test]
    fn concurrent_clients_get_correct_rows() {
        let s = server(4);
        let client = s.client();
        let map = test_map();
        let handles: Vec<_> = (0..12)
            .map(|k| {
                let c = client.clone();
                let m = Arc::clone(&map);
                std::thread::spawn(move || {
                    let x: Vec<f32> = (0..16).map(|i| (i + k) as f32 * 0.3).collect();
                    let got = c.transform(x.clone()).unwrap();
                    let want = m.transform_batch(&Matrix::from_vec(1, 16, x));
                    assert_eq!(&got[..], want.row(0), "client {k}");
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.stats().requests(), 12);
        assert!(s.stats().batches() <= 12);
        assert_eq!(s.stats().latency().count, 12);
        s.shutdown();
    }

    #[test]
    fn batching_actually_coalesces() {
        let s = server(16);
        let client = s.client();
        // Burst of 16 concurrent requests with a 2ms window: expect
        // far fewer than 16 batches.
        let handles: Vec<_> = (0..16)
            .map(|k| {
                let c = client.clone();
                std::thread::spawn(move || {
                    let x: Vec<f32> = (0..16).map(|i| (i * k) as f32).collect();
                    c.transform(x).unwrap();
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let batches = s.stats().batches();
        assert!(batches < 16, "no coalescing happened: {batches} batches");
        assert!(s.stats().mean_batch_size() > 1.0);
        s.shutdown();
    }

    #[test]
    fn shutdown_is_clean_with_no_requests() {
        let s = server(2);
        s.shutdown();
    }

    #[test]
    #[should_panic]
    fn wrong_width_rejected() {
        let s = server(2);
        let _ = s.transform(vec![0.0; 3]);
    }

    #[test]
    fn deadline_flush_counts_as_miss() {
        // max_batch 8 but a single request: the 2ms deadline flushes a
        // 1-row batch → exactly one deadline miss, deterministically.
        let s = server(8);
        let x: Vec<f32> = vec![0.25; 16];
        s.transform(x).unwrap();
        assert_eq!(s.stats().deadline_misses(), 1);
        assert_eq!(s.stats().batches(), 1);
        assert_eq!(s.stats().batched_rows(), 1);
        s.shutdown();
    }

    #[test]
    fn transform_after_shutdown_returns_none() {
        let s = server(4);
        let client = s.client();
        assert!(client.transform(vec![0.0; 16]).is_some());
        s.shutdown();
        assert!(client.transform(vec![0.0; 16]).is_none());
    }

    #[test]
    fn registry_snapshot_reflects_request_counts() {
        let reg = MetricsRegistry::new();
        let s = FeatureServer::start_with_registry(test_map(), 4, Duration::from_millis(1), &reg);
        for i in 0..5 {
            let x: Vec<f32> = (0..16).map(|j| (i * j) as f32 * 0.1).collect();
            s.transform(x).unwrap();
        }
        let view = s.stats().clone();
        s.shutdown();
        let snap = reg.snapshot_json();
        let counters = snap.get("counters").unwrap();
        assert_eq!(counters.get("server.requests").unwrap().as_usize(), Some(5));
        assert_eq!(counters.get("server.batches").unwrap().as_usize(), Some(5));
        // sequential callers: every reply is in before the next submit
        let depth = snap.get("gauges").unwrap().get("server.queue_depth").unwrap();
        assert_eq!(depth.as_usize(), Some(0));
        let lat = snap.get("histograms").unwrap().get("server.latency_ns").unwrap();
        assert_eq!(lat.get("count").unwrap().as_usize(), Some(5));
        assert!(lat.get("p95").unwrap().as_f64().unwrap() > 0.0);
        // and the typed view reads the same registry
        assert_eq!(view.requests(), 5);
        assert_eq!(view.queue_depth(), 0);
    }
}
