//! Dynamic-batching feature server — the paper's "drop-in generator of
//! features for linear methods where attributes are generated
//! on-the-fly" (§1), coordinated vLLM-router-style: clients submit
//! single vectors, the server coalesces them into batches (size- or
//! deadline-triggered), featurizes once per batch, and scatters the
//! rows back to the callers.
//!
//! Fault posture ([`crate::fault`]): requests are validated at submit
//! (width + finiteness), admission is bounded (`Overloaded` beyond
//! [`ServerConfig::max_queue`], counted in `server.rejected`), every
//! wait carries a deadline (`Timeout`, counted in `server.timeouts`),
//! and the serve loop runs under `catch_unwind` supervision — a
//! panicking batch is quarantined (its requests get `WorkerPanic`,
//! the engine is rebuilt, `server.restarts` counts it) and the loop
//! keeps serving. Every admitted request gets exactly one reply or
//! typed error.
//!
//! Throughput/latency accounting lives in the observability registry
//! (`server.*` metrics); [`ServerStats`] is the typed compatibility
//! view over those handles. These are once-per-request /
//! once-per-batch updates, so they record unconditionally — the
//! enabled flag only gates the fine-grained engine/trainer timers.

use crate::fault::{FaultPlan, FaultSite, McError};
use crate::linalg::Matrix;
use crate::mckernel::cache::DEFAULT_SHARDS;
use crate::mckernel::{CacheKey, ExpansionEngine, FeatureCache, McKernel};
use crate::obs::{self, Counter, Gauge, Hist, HistSnapshot, MetricsRegistry};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// What a request resolves to: a feature row or a typed error.
pub type Reply = Result<Vec<f32>, McError>;

/// Serving policy knobs (see module docs for the fault posture).
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Coalesce at most this many requests per batch.
    pub max_batch: usize,
    /// Flush a partial batch after this deadline.
    pub max_wait: Duration,
    /// Admission bound: submissions beyond this many in-flight
    /// requests are shed with [`McError::Overloaded`].
    pub max_queue: usize,
    /// Per-request deadline (submit → reply wait); an elapsed wait
    /// returns [`McError::Timeout`].
    pub deadline: Duration,
    /// Deterministic chaos schedule (None in production: one pointer
    /// test per batch).
    pub faults: Option<Arc<FaultPlan>>,
    /// Opt-in content-addressed feature cache
    /// ([`crate::mckernel::FeatureCache`]): byte budget for memoizing
    /// feature rows of repeated inputs. `None` disables caching.
    pub cache_bytes: Option<usize>,
}

impl ServerConfig {
    /// Policy with the given batching knobs and lenient defaults for
    /// the rest (1024-deep admission, 30s deadline, no faults).
    pub fn new(max_batch: usize, max_wait: Duration) -> ServerConfig {
        ServerConfig {
            max_batch,
            max_wait,
            max_queue: 1024,
            deadline: Duration::from_secs(30),
            faults: None,
            cache_bytes: None,
        }
    }

    /// Set the admission bound.
    pub fn max_queue(mut self, n: usize) -> ServerConfig {
        self.max_queue = n;
        self
    }

    /// Set the per-request deadline.
    pub fn deadline(mut self, d: Duration) -> ServerConfig {
        self.deadline = d;
        self
    }

    /// Install a chaos schedule.
    pub fn faults(mut self, plan: Arc<FaultPlan>) -> ServerConfig {
        self.faults = Some(plan);
        self
    }

    /// Enable the content-addressed feature cache with this byte
    /// budget.
    pub fn cache_bytes(mut self, bytes: usize) -> ServerConfig {
        self.cache_bytes = Some(bytes);
        self
    }
}

/// Strict in-flight accounting shared by the server handle and every
/// client: admission happens against `inflight` with a CAS (the gauge
/// is a mirror for snapshots, not the source of truth), and release
/// happens in [`InflightGuard::drop`] — exactly once per admitted
/// request on *every* path (reply scatter, quarantine, shutdown
/// drain, or a panicking loop dropping the request).
struct Shared {
    stats: ServerStats,
    inflight: AtomicUsize,
    input_dim: usize,
    max_queue: usize,
    deadline: Duration,
}

/// Releases one admission slot when the request it rides in drops.
struct InflightGuard(Arc<Shared>);

impl Drop for InflightGuard {
    fn drop(&mut self) {
        self.0.inflight.fetch_sub(1, Ordering::AcqRel);
        self.0.stats.queue_depth.add(-1);
    }
}

/// One in-flight request.
struct Request {
    x: Vec<f32>,
    reply: Sender<Reply>,
    /// Submission time — measured end to end at the reply scatter.
    t0: Instant,
    _guard: InflightGuard,
}

/// Channel message: a job, or the shutdown poison pill (so `shutdown`
/// terminates the loop even while client handles are still alive).
enum Msg {
    Job(Request),
    Shutdown,
}

/// Server metrics: a compatibility view over handles registered in a
/// [`MetricsRegistry`] under `server.*` (the pre-observability
/// `ServerStats` carried its own ad-hoc atomics; they now live in the
/// registry so `mckernel stats` snapshots and these accessors always
/// agree). Cloning the view clones the `Arc` handles — all clones
/// observe the same metrics.
#[derive(Debug, Clone)]
pub struct ServerStats {
    requests: Arc<Counter>,
    batches: Arc<Counter>,
    /// Sum of batch sizes (for mean batch occupancy).
    batched_rows: Arc<Counter>,
    /// Batches flushed by the `max_wait` deadline while still short of
    /// `max_batch`.
    deadline_miss: Arc<Counter>,
    /// Requests shed at admission (`Overloaded`).
    rejected: Arc<Counter>,
    /// Requests whose reply wait hit the per-request deadline.
    timeouts: Arc<Counter>,
    /// Serve-loop recoveries: quarantined batches + supervisor
    /// restarts after a panic escaped the batch region.
    restarts: Arc<Counter>,
    /// Requests submitted but not yet replied to.
    queue_depth: Arc<Gauge>,
    /// End-to-end request latency (submit → reply scatter).
    latency_ns: Arc<Hist>,
    /// Rows per executed batch (occupancy distribution).
    batch_fill: Arc<Hist>,
}

impl ServerStats {
    /// Resolve the `server.*` handles in `reg`.
    pub fn register(reg: &MetricsRegistry) -> ServerStats {
        ServerStats {
            requests: reg.counter("server.requests"),
            batches: reg.counter("server.batches"),
            batched_rows: reg.counter("server.batched_rows"),
            deadline_miss: reg.counter("server.deadline_miss"),
            rejected: reg.counter("server.rejected"),
            timeouts: reg.counter("server.timeouts"),
            restarts: reg.counter("server.restarts"),
            queue_depth: reg.gauge("server.queue_depth"),
            latency_ns: reg.histogram("server.latency_ns"),
            batch_fill: reg.histogram("server.batch_fill"),
        }
    }

    /// Total requests replied to (feature rows *and* typed errors).
    pub fn requests(&self) -> u64 {
        self.requests.get()
    }

    /// Total batches executed.
    pub fn batches(&self) -> u64 {
        self.batches.get()
    }

    /// Sum of executed batch sizes.
    pub fn batched_rows(&self) -> u64 {
        self.batched_rows.get()
    }

    /// Batches flushed by deadline while under `max_batch`.
    pub fn deadline_misses(&self) -> u64 {
        self.deadline_miss.get()
    }

    /// Requests shed at admission.
    pub fn rejected(&self) -> u64 {
        self.rejected.get()
    }

    /// Reply waits that hit the per-request deadline.
    pub fn timeouts(&self) -> u64 {
        self.timeouts.get()
    }

    /// Serve-loop recoveries after a panic.
    pub fn restarts(&self) -> u64 {
        self.restarts.get()
    }

    /// Requests currently submitted and unanswered.
    pub fn queue_depth(&self) -> i64 {
        self.queue_depth.get()
    }

    /// Request-latency summary (nanoseconds).
    pub fn latency(&self) -> HistSnapshot {
        self.latency_ns.snapshot()
    }

    /// Mean rows per executed batch.
    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.get();
        if b == 0 {
            return 0.0;
        }
        self.batched_rows.get() as f64 / b as f64
    }
}

/// Validate, admit, and enqueue one request; shared by the server
/// handle and every client clone.
fn submit(tx: &Sender<Msg>, shared: &Arc<Shared>, x: Vec<f32>) -> Result<PendingReply, McError> {
    if x.len() != shared.input_dim {
        return Err(McError::DimMismatch { expected: shared.input_dim, got: x.len() });
    }
    if let Some(index) = x.iter().position(|v| !v.is_finite()) {
        return Err(McError::NonFinite { index });
    }
    let admitted = shared
        .inflight
        .fetch_update(Ordering::AcqRel, Ordering::Acquire, |n| {
            (n < shared.max_queue).then_some(n + 1)
        })
        .is_ok();
    if !admitted {
        shared.stats.rejected.inc();
        return Err(McError::Overloaded { limit: shared.max_queue });
    }
    shared.stats.queue_depth.add(1);
    let guard = InflightGuard(Arc::clone(shared));
    let (reply_tx, reply_rx) = channel();
    let req = Request { x, reply: reply_tx, t0: Instant::now(), _guard: guard };
    // A failed send returns the message, so the dropped guard releases
    // the admission slot we just took.
    tx.send(Msg::Job(req)).map_err(|_| McError::ShuttingDown)?;
    Ok(PendingReply {
        rx: reply_rx,
        deadline: shared.deadline,
        timeouts: Arc::clone(&shared.stats.timeouts),
    })
}

/// An admitted request awaiting its reply — the asynchronous half of
/// [`FeatureClient::submit`]. Dropping it abandons the reply (the
/// server's send becomes a no-op).
pub struct PendingReply {
    rx: Receiver<Reply>,
    deadline: Duration,
    timeouts: Arc<Counter>,
}

impl PendingReply {
    /// Block until the reply or the per-request deadline, whichever
    /// comes first.
    pub fn wait(self) -> Reply {
        match self.rx.recv_timeout(self.deadline) {
            Ok(reply) => reply,
            Err(RecvTimeoutError::Timeout) => {
                self.timeouts.inc();
                Err(McError::Timeout { waited: self.deadline })
            }
            // The request was dropped without a reply: only a panic
            // unwinding the serve loop does that (an orderly shutdown
            // drains the queue with ShuttingDown replies).
            Err(RecvTimeoutError::Disconnected) => Err(McError::WorkerPanic),
        }
    }
}

/// Handle to a running feature server.
pub struct FeatureServer {
    tx: Option<Sender<Msg>>,
    handle: Option<JoinHandle<()>>,
    shared: Arc<Shared>,
    feature_dim: usize,
}

impl FeatureServer {
    /// Start the server thread, reporting into the global registry.
    pub fn start(map: Arc<McKernel>, config: ServerConfig) -> FeatureServer {
        FeatureServer::start_with_registry(map, config, obs::global())
    }

    /// Like [`FeatureServer::start`] but reporting into `registry` —
    /// the injection seam tests use for isolated, deterministic
    /// counts (two servers on the *global* registry share metrics).
    pub fn start_with_registry(
        map: Arc<McKernel>,
        config: ServerConfig,
        registry: &MetricsRegistry,
    ) -> FeatureServer {
        assert!(config.max_batch > 0);
        assert!(config.max_queue > 0);
        let (tx, rx) = channel::<Msg>();
        let stats = ServerStats::register(registry);
        let shared = Arc::new(Shared {
            stats: stats.clone(),
            inflight: AtomicUsize::new(0),
            input_dim: map.input_dim(),
            max_queue: config.max_queue,
            deadline: config.deadline,
        });
        let feature_dim = map.feature_dim();
        // The cache is built against the same registry as the stats so
        // `cache.*` and `server.*` land in one snapshot.
        let cache = config
            .cache_bytes
            .map(|b| Arc::new(FeatureCache::with_registry(b, DEFAULT_SHARDS, registry)));
        let handle = std::thread::Builder::new()
            .name("mckernel-feature-server".into())
            .spawn(move || Self::serve(map, rx, config, stats, cache))
            // analyze: allow(no-panic-serving) -- OS refusing the one server thread at startup is unrecoverable
            .expect("spawn server thread");
        FeatureServer { tx: Some(tx), handle: Some(handle), shared, feature_dim }
    }

    /// Supervisor: run the batching loop, restarting it whenever a
    /// panic escapes the per-batch quarantine (requests held by the
    /// dying iteration are dropped — their clients observe
    /// `WorkerPanic` — and later requests are served by the restarted
    /// loop). On orderly exit, drain still-queued requests with
    /// `ShuttingDown` so no admitted request is left waiting.
    fn serve(
        map: Arc<McKernel>,
        rx: Receiver<Msg>,
        config: ServerConfig,
        stats: ServerStats,
        cache: Option<Arc<FeatureCache>>,
    ) {
        loop {
            let exit = catch_unwind(AssertUnwindSafe(|| {
                Self::serve_loop(&map, &rx, &config, &stats, cache.as_deref())
            }));
            match exit {
                Ok(()) => break,
                Err(_) => stats.restarts.inc(),
            }
        }
        loop {
            match rx.try_recv() {
                Ok(Msg::Job(req)) => {
                    stats.requests.inc();
                    let _ = req.reply.send(Err(McError::ShuttingDown));
                }
                Ok(Msg::Shutdown) => continue,
                Err(_) => break,
            }
        }
    }

    /// The batching event loop.
    fn serve_loop(
        map: &Arc<McKernel>,
        rx: &Receiver<Msg>,
        config: &ServerConfig,
        stats: &ServerStats,
        cache: Option<&FeatureCache>,
    ) {
        // One compiled engine for the loop's lifetime: scratch and
        // feature buffer pooled across every coalesced batch.
        let mut engine = ExpansionEngine::new(map, config.max_batch);
        // Cache id, fixed for the loop: quarantine rebuilds the engine
        // with the same (config, rows hint), so the plan — and the
        // key — never changes.
        let cache_key = CacheKey::new(map.config(), engine.plan());
        let mut feats = Matrix::zeros(0, 0);
        let mut shutting_down = false;
        loop {
            // Block for the first request of a batch.
            let first = match rx.recv() {
                Ok(Msg::Job(r)) => r,
                Ok(Msg::Shutdown) | Err(_) => return,
            };
            let mut pending = vec![first];
            let deadline = Instant::now() + config.max_wait;
            let mut deadline_hit = false;
            // Coalesce until full or deadline.
            while pending.len() < config.max_batch {
                let now = Instant::now();
                if now >= deadline {
                    deadline_hit = true;
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(Msg::Job(r)) => pending.push(r),
                    Ok(Msg::Shutdown) => {
                        shutting_down = true;
                        break;
                    }
                    Err(RecvTimeoutError::Timeout) => {
                        deadline_hit = true;
                        break;
                    }
                    Err(RecvTimeoutError::Disconnected) => break,
                }
            }
            stats.batches.inc();
            stats.batched_rows.add(pending.len() as u64);
            stats.batch_fill.record(pending.len() as u64);
            if deadline_hit && pending.len() < config.max_batch {
                stats.deadline_miss.inc();
            }
            // Featurize the coalesced batch in ONE engine pass — this
            // is where coalescing pays: the tile-vectorized pipeline
            // turns every butterfly, gather and trig evaluation into a
            // wide stream across the whole batch.
            let rows = pending.len();
            let mut xb = Matrix::zeros(rows, map.input_dim());
            for (r, req) in pending.iter().enumerate() {
                xb.row_mut(r).copy_from_slice(&req.x);
            }
            feats.resize(rows, map.feature_dim());
            if let Some(plan) = &config.faults {
                if plan.fires(FaultSite::Latency) {
                    std::thread::sleep(plan.latency());
                }
            }
            // Execute under a per-batch unwind boundary: a panic here
            // poisons only this batch, not the loop.
            let run = catch_unwind(AssertUnwindSafe(|| {
                if let Some(plan) = &config.faults {
                    if plan.fires(FaultSite::WorkerPanic) {
                        // analyze: allow(no-panic-serving) -- deliberate chaos injection; the catch_unwind above quarantines it
                        panic!("injected fault: serve-loop worker panic");
                    }
                }
                match cache {
                    Some(c) => c.execute_matrix(cache_key, &mut engine, map, &xb, &mut feats),
                    None => engine.execute_matrix(map, &xb, &mut feats),
                }
            }));
            if run.is_err() {
                // Quarantine: the batch's requests get WorkerPanic,
                // the engine is rebuilt (its pooled state is suspect
                // mid-unwind), and the loop keeps serving. Counted as
                // a restart — this *is* the worker recovery.
                stats.restarts.inc();
                engine = ExpansionEngine::new(map, config.max_batch);
                feats = Matrix::zeros(0, 0);
                for req in pending {
                    stats.requests.inc();
                    stats.latency_ns.record(obs::elapsed_ns(req.t0));
                    let _ = req.reply.send(Err(McError::WorkerPanic));
                }
                if shutting_down {
                    return;
                }
                continue;
            }
            if let Some(plan) = &config.faults {
                if plan.fires(FaultSite::EngineFault) {
                    // Poison the first output row; the finiteness scan
                    // below must catch it and degrade to a typed error
                    // for that row only.
                    for v in feats.row_mut(0) {
                        *v = f32::NAN;
                    }
                }
            }
            for (r, req) in pending.into_iter().enumerate() {
                stats.requests.inc();
                stats.latency_ns.record(obs::elapsed_ns(req.t0));
                let row = feats.row(r);
                let reply = match row.iter().position(|v| !v.is_finite()) {
                    Some(index) => Err(McError::NonFinite { index }),
                    None => Ok(row.to_vec()),
                };
                let _ = req.reply.send(reply); // client may have left
            }
            if shutting_down {
                return;
            }
        }
    }

    /// Expected input width.
    pub fn input_dim(&self) -> usize {
        self.shared.input_dim
    }

    /// Produced feature width.
    pub fn feature_dim(&self) -> usize {
        self.feature_dim
    }

    /// Metric accessors.
    pub fn stats(&self) -> &ServerStats {
        &self.shared.stats
    }

    /// Synchronous call: featurize one vector, or a typed error
    /// (invalid request, shed, deadline, quarantined batch, shutdown).
    pub fn transform(&self, x: Vec<f32>) -> Reply {
        let tx = self.tx.as_ref().ok_or(McError::ShuttingDown)?;
        submit(tx, &self.shared, x)?.wait()
    }

    /// A cloneable client handle usable from other threads.
    pub fn client(&self) -> FeatureClient {
        FeatureClient {
            // analyze: allow(no-panic-serving) -- tx is Some until shutdown(), which takes &mut self; a &self caller cannot race it
            tx: self.tx.as_ref().expect("server running").clone(),
            shared: Arc::clone(&self.shared),
        }
    }

    /// Stop the server. Requests already queued ahead of the poison
    /// pill are served; requests behind it get `ShuttingDown` replies.
    /// Safe even while client handles are still alive.
    pub fn shutdown(mut self) {
        if let Some(tx) = self.tx.take() {
            let _ = tx.send(Msg::Shutdown);
        }
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for FeatureServer {
    fn drop(&mut self) {
        if let Some(tx) = self.tx.take() {
            let _ = tx.send(Msg::Shutdown);
        }
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Cheap cloneable submission handle.
#[derive(Clone)]
pub struct FeatureClient {
    tx: Sender<Msg>,
    shared: Arc<Shared>,
}

impl FeatureClient {
    /// Asynchronous submit: validate + admit now, wait later. Lets a
    /// caller pipeline requests (and makes admission-control behaviour
    /// deterministic to test: fill the queue without waiting).
    pub fn submit(&self, x: Vec<f32>) -> Result<PendingReply, McError> {
        submit(&self.tx, &self.shared, x)
    }

    /// Synchronous featurize: submit and wait for the reply or a
    /// typed error.
    pub fn transform(&self, x: Vec<f32>) -> Reply {
        self.submit(x)?.wait()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mckernel::McKernelFactory;

    fn test_map() -> Arc<McKernel> {
        Arc::new(McKernelFactory::new(16).expansions(1).seed(4).build())
    }

    /// Each test server gets its own registry: counts are per-server
    /// and immune to other tests running in the same process.
    fn server(max_batch: usize) -> FeatureServer {
        FeatureServer::start_with_registry(
            test_map(),
            ServerConfig::new(max_batch, Duration::from_millis(2)),
            &MetricsRegistry::new(),
        )
    }

    #[test]
    fn single_request_roundtrip() {
        let s = server(8);
        let x: Vec<f32> = (0..16).map(|i| i as f32 * 0.1).collect();
        let f = s.transform(x.clone()).unwrap();
        assert_eq!(f.len(), s.feature_dim());
        // must equal the direct batched map output (tile grouping is
        // irrelevant: lanes never interact)
        let map = McKernelFactory::new(16).expansions(1).seed(4).build();
        let want = map.transform_batch(&Matrix::from_vec(1, 16, x));
        assert_eq!(&f[..], want.row(0));
        s.shutdown();
    }

    #[test]
    fn concurrent_clients_get_correct_rows() {
        let s = server(4);
        let client = s.client();
        let map = test_map();
        let handles: Vec<_> = (0..12)
            .map(|k| {
                let c = client.clone();
                let m = Arc::clone(&map);
                std::thread::spawn(move || {
                    let x: Vec<f32> = (0..16).map(|i| (i + k) as f32 * 0.3).collect();
                    let got = c.transform(x.clone()).unwrap();
                    let want = m.transform_batch(&Matrix::from_vec(1, 16, x));
                    assert_eq!(&got[..], want.row(0), "client {k}");
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.stats().requests(), 12);
        assert!(s.stats().batches() <= 12);
        assert_eq!(s.stats().latency().count, 12);
        s.shutdown();
    }

    #[test]
    fn batching_actually_coalesces() {
        let s = server(16);
        let client = s.client();
        // Burst of 16 concurrent requests with a 2ms window: expect
        // far fewer than 16 batches.
        let handles: Vec<_> = (0..16)
            .map(|k| {
                let c = client.clone();
                std::thread::spawn(move || {
                    let x: Vec<f32> = (0..16).map(|i| (i * k) as f32).collect();
                    c.transform(x).unwrap();
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let batches = s.stats().batches();
        assert!(batches < 16, "no coalescing happened: {batches} batches");
        assert!(s.stats().mean_batch_size() > 1.0);
        s.shutdown();
    }

    #[test]
    fn shutdown_is_clean_with_no_requests() {
        let s = server(2);
        s.shutdown();
    }

    #[test]
    fn wrong_width_is_typed_error_not_panic() {
        let s = server(2);
        assert_eq!(
            s.transform(vec![0.0; 3]),
            Err(McError::DimMismatch { expected: 16, got: 3 })
        );
        // the rejected request never entered the queue
        assert_eq!(s.stats().queue_depth(), 0);
        assert_eq!(s.stats().requests(), 0);
        s.shutdown();
    }

    #[test]
    fn non_finite_input_is_rejected_at_submit() {
        let s = server(2);
        let mut x = vec![0.5f32; 16];
        x[7] = f32::NAN;
        assert_eq!(s.transform(x), Err(McError::NonFinite { index: 7 }));
        let mut y = vec![0.5f32; 16];
        y[3] = f32::INFINITY;
        assert_eq!(s.transform(y), Err(McError::NonFinite { index: 3 }));
        // server still healthy
        assert!(s.transform(vec![0.5; 16]).is_ok());
        s.shutdown();
    }

    #[test]
    fn deadline_flush_counts_as_miss() {
        // max_batch 8 but a single request: the 2ms deadline flushes a
        // 1-row batch → exactly one deadline miss, deterministically.
        let s = server(8);
        let x: Vec<f32> = vec![0.25; 16];
        s.transform(x).unwrap();
        assert_eq!(s.stats().deadline_misses(), 1);
        assert_eq!(s.stats().batches(), 1);
        assert_eq!(s.stats().batched_rows(), 1);
        s.shutdown();
    }

    #[test]
    fn transform_after_shutdown_is_shutting_down_error() {
        let s = server(4);
        let client = s.client();
        assert!(client.transform(vec![0.0; 16]).is_ok());
        s.shutdown();
        assert_eq!(client.transform(vec![0.0; 16]), Err(McError::ShuttingDown));
    }

    #[test]
    fn registry_snapshot_reflects_request_counts() {
        let reg = MetricsRegistry::new();
        let s = FeatureServer::start_with_registry(
            test_map(),
            ServerConfig::new(4, Duration::from_millis(1)),
            &reg,
        );
        for i in 0..5 {
            let x: Vec<f32> = (0..16).map(|j| (i * j) as f32 * 0.1).collect();
            s.transform(x).unwrap();
        }
        let view = s.stats().clone();
        s.shutdown();
        let snap = reg.snapshot_json();
        let counters = snap.get("counters").unwrap();
        assert_eq!(counters.get("server.requests").unwrap().as_usize(), Some(5));
        assert_eq!(counters.get("server.batches").unwrap().as_usize(), Some(5));
        assert_eq!(counters.get("server.rejected").unwrap().as_usize(), Some(0));
        assert_eq!(counters.get("server.restarts").unwrap().as_usize(), Some(0));
        // sequential callers: every reply is in before the next submit
        let depth = snap.get("gauges").unwrap().get("server.queue_depth").unwrap();
        assert_eq!(depth.as_usize(), Some(0));
        let lat = snap.get("histograms").unwrap().get("server.latency_ns").unwrap();
        assert_eq!(lat.get("count").unwrap().as_usize(), Some(5));
        assert!(lat.get("p95").unwrap().as_f64().unwrap() > 0.0);
        // and the typed view reads the same registry
        assert_eq!(view.requests(), 5);
        assert_eq!(view.queue_depth(), 0);
    }
}
