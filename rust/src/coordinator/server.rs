//! Dynamic-batching feature server — the paper's "drop-in generator of
//! features for linear methods where attributes are generated
//! on-the-fly" (§1), coordinated vLLM-router-style: clients submit
//! single vectors, the server coalesces them into batches (size- or
//! deadline-triggered), featurizes once per batch, and scatters the
//! rows back to the callers.

use crate::linalg::Matrix;
use crate::mckernel::{ExpansionEngine, McKernel};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// One in-flight request.
struct Request {
    x: Vec<f32>,
    reply: Sender<Vec<f32>>,
}

/// Channel message: a job, or the shutdown poison pill (so `shutdown`
/// terminates the loop even while client handles are still alive).
enum Msg {
    Job(Request),
    Shutdown,
}

/// Server throughput/latency counters.
#[derive(Debug, Default)]
pub struct ServerStats {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    /// Sum of batch sizes (for mean batch occupancy).
    pub batched_rows: AtomicU64,
}

impl ServerStats {
    /// Mean rows per executed batch.
    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.batched_rows.load(Ordering::Relaxed) as f64 / b as f64
    }
}

/// Handle to a running feature server.
pub struct FeatureServer {
    tx: Option<Sender<Msg>>,
    handle: Option<JoinHandle<()>>,
    stats: Arc<ServerStats>,
    input_dim: usize,
    feature_dim: usize,
}

impl FeatureServer {
    /// Start the server thread.
    ///
    /// * `max_batch`: coalesce at most this many requests per batch.
    /// * `max_wait`: flush a partial batch after this deadline.
    pub fn start(map: Arc<McKernel>, max_batch: usize, max_wait: Duration) -> FeatureServer {
        assert!(max_batch > 0);
        let (tx, rx) = channel::<Msg>();
        let stats = Arc::new(ServerStats::default());
        let stats2 = Arc::clone(&stats);
        let input_dim = map.input_dim();
        let feature_dim = map.feature_dim();
        let handle = std::thread::Builder::new()
            .name("mckernel-feature-server".into())
            .spawn(move || Self::serve(map, rx, max_batch, max_wait, stats2))
            .expect("spawn server thread");
        FeatureServer { tx: Some(tx), handle: Some(handle), stats, input_dim, feature_dim }
    }

    /// The batching event loop.
    fn serve(
        map: Arc<McKernel>,
        rx: Receiver<Msg>,
        max_batch: usize,
        max_wait: Duration,
        stats: Arc<ServerStats>,
    ) {
        // One compiled engine for the server's lifetime: scratch and
        // feature buffer pooled across every coalesced batch.
        let mut engine = ExpansionEngine::new(&map, max_batch);
        let mut feats = Matrix::zeros(0, 0);
        let mut shutting_down = false;
        loop {
            // Block for the first request of a batch.
            let first = match rx.recv() {
                Ok(Msg::Job(r)) => r,
                Ok(Msg::Shutdown) | Err(_) => return,
            };
            let mut pending = vec![first];
            let deadline = Instant::now() + max_wait;
            // Coalesce until full or deadline.
            while pending.len() < max_batch {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(Msg::Job(r)) => pending.push(r),
                    Ok(Msg::Shutdown) => {
                        shutting_down = true;
                        break;
                    }
                    Err(RecvTimeoutError::Timeout) => break,
                    Err(RecvTimeoutError::Disconnected) => break,
                }
            }
            stats.batches.fetch_add(1, Ordering::Relaxed);
            stats
                .batched_rows
                .fetch_add(pending.len() as u64, Ordering::Relaxed);
            // Featurize the coalesced batch in ONE engine pass — this
            // is where coalescing pays: the tile-vectorized pipeline
            // turns every butterfly, gather and trig evaluation into a
            // wide stream across the whole batch.
            let rows = pending.len();
            let mut xb = Matrix::zeros(rows, map.input_dim());
            for (r, req) in pending.iter().enumerate() {
                xb.row_mut(r).copy_from_slice(&req.x);
            }
            feats.resize(rows, map.feature_dim());
            engine.execute_matrix(&map, &xb, &mut feats);
            for (r, req) in pending.into_iter().enumerate() {
                stats.requests.fetch_add(1, Ordering::Relaxed);
                let _ = req.reply.send(feats.row(r).to_vec()); // client may have left
            }
            if shutting_down {
                return;
            }
        }
    }

    /// Expected input width.
    pub fn input_dim(&self) -> usize {
        self.input_dim
    }

    /// Produced feature width.
    pub fn feature_dim(&self) -> usize {
        self.feature_dim
    }

    /// Counters.
    pub fn stats(&self) -> &ServerStats {
        &self.stats
    }

    /// Synchronous call: featurize one vector.
    pub fn transform(&self, x: Vec<f32>) -> Option<Vec<f32>> {
        assert_eq!(x.len(), self.input_dim, "input width");
        let (reply_tx, reply_rx) = channel();
        self.tx
            .as_ref()?
            .send(Msg::Job(Request { x, reply: reply_tx }))
            .ok()?;
        reply_rx.recv().ok()
    }

    /// A cloneable client handle usable from other threads.
    pub fn client(&self) -> FeatureClient {
        FeatureClient {
            tx: self.tx.as_ref().expect("server running").clone(),
            input_dim: self.input_dim,
        }
    }

    /// Stop the server (drains requests already queued ahead of the
    /// poison pill; safe even while client handles are still alive).
    pub fn shutdown(mut self) {
        if let Some(tx) = self.tx.take() {
            let _ = tx.send(Msg::Shutdown);
        }
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for FeatureServer {
    fn drop(&mut self) {
        if let Some(tx) = self.tx.take() {
            let _ = tx.send(Msg::Shutdown);
        }
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Cheap cloneable submission handle.
#[derive(Clone)]
pub struct FeatureClient {
    tx: Sender<Msg>,
    input_dim: usize,
}

impl FeatureClient {
    /// Synchronous featurize (None if the server shut down).
    pub fn transform(&self, x: Vec<f32>) -> Option<Vec<f32>> {
        assert_eq!(x.len(), self.input_dim, "input width");
        let (reply_tx, reply_rx) = channel();
        self.tx
            .send(Msg::Job(Request { x, reply: reply_tx }))
            .ok()?;
        reply_rx.recv().ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mckernel::McKernelFactory;

    fn server(max_batch: usize) -> FeatureServer {
        let map = Arc::new(McKernelFactory::new(16).expansions(1).seed(4).build());
        FeatureServer::start(map, max_batch, Duration::from_millis(2))
    }

    #[test]
    fn single_request_roundtrip() {
        let s = server(8);
        let x: Vec<f32> = (0..16).map(|i| i as f32 * 0.1).collect();
        let f = s.transform(x.clone()).unwrap();
        assert_eq!(f.len(), s.feature_dim());
        // must equal the direct batched map output (tile grouping is
        // irrelevant: lanes never interact)
        let map = McKernelFactory::new(16).expansions(1).seed(4).build();
        let want = map.transform_batch(&Matrix::from_vec(1, 16, x));
        assert_eq!(&f[..], want.row(0));
        s.shutdown();
    }

    #[test]
    fn concurrent_clients_get_correct_rows() {
        let s = server(4);
        let client = s.client();
        let map = Arc::new(McKernelFactory::new(16).expansions(1).seed(4).build());
        let handles: Vec<_> = (0..12)
            .map(|k| {
                let c = client.clone();
                let m = Arc::clone(&map);
                std::thread::spawn(move || {
                    let x: Vec<f32> = (0..16).map(|i| (i + k) as f32 * 0.3).collect();
                    let got = c.transform(x.clone()).unwrap();
                    let want = m.transform_batch(&Matrix::from_vec(1, 16, x));
                    assert_eq!(&got[..], want.row(0), "client {k}");
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(s.stats().requests.load(Ordering::Relaxed), 12);
        assert!(s.stats().batches.load(Ordering::Relaxed) <= 12);
        s.shutdown();
    }

    #[test]
    fn batching_actually_coalesces() {
        let s = server(16);
        let client = s.client();
        // Burst of 16 concurrent requests with a 2ms window: expect
        // far fewer than 16 batches.
        let handles: Vec<_> = (0..16)
            .map(|k| {
                let c = client.clone();
                std::thread::spawn(move || {
                    let x: Vec<f32> = (0..16).map(|i| (i * k) as f32).collect();
                    c.transform(x).unwrap();
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let batches = s.stats().batches.load(Ordering::Relaxed);
        assert!(batches < 16, "no coalescing happened: {batches} batches");
        assert!(s.stats().mean_batch_size() > 1.0);
        s.shutdown();
    }

    #[test]
    fn shutdown_is_clean_with_no_requests() {
        let s = server(2);
        s.shutdown();
    }

    #[test]
    #[should_panic]
    fn wrong_width_rejected() {
        let s = server(2);
        let _ = s.transform(vec![0.0; 3]);
    }
}
