//! Prefetching batch pipeline: a producer thread materializes (and
//! optionally featurizes) mini-batches ahead of the training loop,
//! with a bounded channel providing backpressure so memory stays
//! constant — the coordinator never blocks on data unless the
//! producer genuinely falls behind.

use crate::data::{Batcher, Dataset};
use crate::fault::McError;
use crate::linalg::Matrix;
use crate::mckernel::{ExpansionEngine, McKernel};
use crate::obs::{self, MetricsRegistry};
use std::sync::mpsc::{sync_channel, Receiver};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

/// A batch ready for the consumer: featurized (native map applied in
/// the producer) or raw pixels (PJRT path featurizes in-graph).
#[derive(Debug)]
pub struct FeaturizedBatch {
    pub features: Matrix,
    pub labels: Vec<u8>,
    pub index: usize,
}

/// Handle to a running prefetch pipeline (one epoch).
pub struct Prefetcher {
    rx: Receiver<FeaturizedBatch>,
    handle: Option<JoinHandle<()>>,
}

impl Prefetcher {
    /// Spawn a producer for `epoch` over `data`.
    ///
    /// * `map`: `Some` → features computed in the producer thread
    ///   (native path); `None` → raw batches (PJRT path).
    /// * `depth`: channel capacity (batches in flight).
    /// * `drop_last`: required by fixed-shape PJRT train graphs.
    pub fn spawn(
        data: Arc<Dataset>,
        batch_size: usize,
        seed: u64,
        epoch: usize,
        depth: usize,
        drop_last: bool,
        map: Option<Arc<McKernel>>,
    ) -> Prefetcher {
        Prefetcher::spawn_with_registry(
            data,
            batch_size,
            seed,
            epoch,
            depth,
            drop_last,
            map,
            obs::global(),
        )
    }

    /// Like [`Prefetcher::spawn`] but reporting into `registry` — the
    /// test-isolation seam for the `prefetch.*` counters.
    #[allow(clippy::too_many_arguments)] // spawn's signature + the seam
    pub fn spawn_with_registry(
        data: Arc<Dataset>,
        batch_size: usize,
        seed: u64,
        epoch: usize,
        depth: usize,
        drop_last: bool,
        map: Option<Arc<McKernel>>,
        registry: &MetricsRegistry,
    ) -> Prefetcher {
        let (tx, rx) = sync_channel(depth.max(1));
        // Queue-stall accounting: how long each `send` blocked on the
        // bounded channel (≈0 while the consumer keeps up; grows when
        // the producer outruns it and backpressure engages). Once per
        // batch, so it records unconditionally like the server stats.
        let stall_ns = registry.histogram("prefetch.stall_ns");
        // Early-abort accounting: epochs cut short because the
        // consumer went away before draining the pipeline.
        let aborted = registry.counter("prefetch.aborted");
        let handle = std::thread::Builder::new()
            .name(format!("mckernel-prefetch-{epoch}"))
            .spawn(move || {
                let mut batcher = Batcher::new(batch_size, seed);
                if drop_last {
                    batcher = batcher.drop_last();
                }
                let mut engine = map.as_ref().map(|m| ExpansionEngine::new(m, batch_size));
                for batch in batcher.epoch(&data, epoch) {
                    let features = match (&map, &mut engine) {
                        (Some(m), Some(eng)) => {
                            // whole mini-batch through the compiled
                            // engine in one call (scratch pooled for
                            // the epoch; the output matrix is moved
                            // downstream, so it is per-batch)
                            let mut out = Matrix::zeros(batch.images.rows(), m.feature_dim());
                            eng.execute_matrix(m, &batch.images, &mut out);
                            out
                        }
                        _ => batch.images,
                    };
                    let fb = FeaturizedBatch { features, labels: batch.labels, index: batch.index };
                    let t_send = Instant::now();
                    if tx.send(fb).is_err() {
                        // Consumer dropped: the channel is closed, so
                        // stop producing instead of blocking forever —
                        // `Drop` joins this thread promptly.
                        aborted.inc();
                        return;
                    }
                    stall_ns.record(obs::elapsed_ns(t_send));
                }
            })
            // analyze: allow(no-panic-serving) -- OS refusing the one prefetch thread at startup is unrecoverable
            .expect("spawn prefetch thread");
        Prefetcher { rx, handle: Some(handle) }
    }

    /// Blocking receive of the next batch (None = epoch finished).
    pub fn next(&self) -> Option<FeaturizedBatch> {
        self.rx.recv().ok()
    }

    /// Iterator adapter.
    pub fn iter(&self) -> impl Iterator<Item = FeaturizedBatch> + '_ {
        std::iter::from_fn(move || self.next())
    }

    /// Join the producer and surface how it ended: `Ok` for a clean
    /// epoch, `Err(WorkerPanic)` if the producer thread panicked — a
    /// channel close alone cannot distinguish "epoch finished" from
    /// "producer died", so callers that must not silently truncate an
    /// epoch check this after draining.
    pub fn finish(mut self) -> Result<(), McError> {
        // Drain so a blocked producer unblocks, then close and join.
        while self.rx.try_recv().is_ok() {}
        drop(std::mem::replace(&mut self.rx, sync_channel(1).1));
        match self.handle.take() {
            Some(h) => h.join().map_err(|_| McError::WorkerPanic),
            None => Ok(()),
        }
    }
}

impl Drop for Prefetcher {
    fn drop(&mut self) {
        // Drain so the producer unblocks (it detects the closed
        // channel, counts `prefetch.aborted`, and returns), then join.
        while self.rx.try_recv().is_ok() {}
        drop(std::mem::replace(&mut self.rx, sync_channel(1).1));
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticSpec;
    use crate::mckernel::McKernelFactory;

    fn data(n: usize) -> Arc<Dataset> {
        Arc::new(Dataset::synthetic(5, &SyntheticSpec::mnist(), "train", n))
    }

    #[test]
    fn raw_pipeline_delivers_all_batches() {
        let d = data(45);
        let p = Prefetcher::spawn(d, 10, 1, 0, 2, false, None);
        let batches: Vec<_> = p.iter().collect();
        assert_eq!(batches.len(), 5);
        assert_eq!(batches[4].features.rows(), 5);
        let total: usize = batches.iter().map(|b| b.labels.len()).sum();
        assert_eq!(total, 45);
    }

    #[test]
    fn drop_last_gives_fixed_shapes() {
        let d = data(45);
        let p = Prefetcher::spawn(d, 10, 1, 0, 2, true, None);
        let batches: Vec<_> = p.iter().collect();
        assert_eq!(batches.len(), 4);
        assert!(batches.iter().all(|b| b.features.rows() == 10));
    }

    #[test]
    fn featurizing_producer_matches_direct_transform() {
        let d = data(12);
        let map = Arc::new(McKernelFactory::new(784).expansions(1).seed(2).build());
        let p = Prefetcher::spawn(
            Arc::clone(&d),
            12,
            3,
            0,
            1,
            false,
            Some(Arc::clone(&map)),
        );
        let b = p.next().unwrap();
        assert_eq!(b.features.cols(), map.feature_dim());
        // row 0 of the shuffled batch equals transform of some dataset row
        let direct = map.transform_batch(d.images());
        let row = b.features.row(0);
        assert!((0..12).any(|i| direct.row(i) == row));
    }

    #[test]
    fn early_drop_does_not_hang() {
        let d = data(100);
        let p = Prefetcher::spawn(d, 5, 1, 0, 1, false, None);
        let _one = p.next();
        drop(p); // must join cleanly even with batches pending
    }

    #[test]
    fn early_drop_counts_as_aborted() {
        let reg = MetricsRegistry::new();
        let d = data(100);
        // depth 1 with 20 batches: the producer is guaranteed to still
        // be mid-epoch when the consumer walks away.
        let p = Prefetcher::spawn_with_registry(d, 5, 1, 0, 1, false, None, &reg);
        let _one = p.next();
        drop(p); // joins the producer, which detects the closed channel
        assert_eq!(reg.counter("prefetch.aborted").get(), 1);
    }

    #[test]
    fn finish_reports_clean_epoch() {
        let reg = MetricsRegistry::new();
        let d = data(30);
        let p = Prefetcher::spawn_with_registry(d, 10, 1, 0, 2, false, None, &reg);
        assert_eq!(p.iter().count(), 3);
        p.finish().unwrap();
        assert_eq!(reg.counter("prefetch.aborted").get(), 0);
    }

    #[test]
    fn epochs_differ() {
        let d = data(20);
        let p0 = Prefetcher::spawn(Arc::clone(&d), 20, 7, 0, 1, false, None);
        let p1 = Prefetcher::spawn(d, 20, 7, 1, 1, false, None);
        let a = p0.next().unwrap().labels;
        let b = p1.next().unwrap().labels;
        assert_ne!(a, b);
    }
}
