//! Optimizers: SGD (paper Eq. 21) with optional momentum and gradient
//! clipping — the knobs the paper's DL framework exposes (§6).

pub mod sgd;

pub use sgd::{Sgd, SgdConfig};
