//! Stochastic Gradient Descent: `w_{t+1} = w_t − γ·∇g_{c_t}(w_t)`
//! (paper Eq. 21), with optional classical momentum and global-norm
//! gradient clipping (features of the paper's framework, §6).

use crate::linalg::Matrix;
use crate::model::softmax_reg::{Gradients, SoftmaxRegression};

/// SGD hyper-parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SgdConfig {
    /// Learning rate γ.
    pub lr: f32,
    /// Momentum coefficient (0 = plain SGD, the paper's setting).
    pub momentum: f32,
    /// Global-norm clip threshold (`None` = no clipping).
    pub clip: Option<f32>,
}

impl Default for SgdConfig {
    fn default() -> Self {
        SgdConfig { lr: 0.001, momentum: 0.0, clip: None }
    }
}

/// SGD state (velocity buffers allocated lazily on first step).
#[derive(Debug)]
pub struct Sgd {
    cfg: SgdConfig,
    vw: Option<Matrix>,
    vb: Option<Vec<f32>>,
    steps: u64,
}

impl Sgd {
    pub fn new(cfg: SgdConfig) -> Sgd {
        assert!(cfg.lr > 0.0, "learning rate must be positive");
        assert!((0.0..1.0).contains(&cfg.momentum), "momentum in [0,1)");
        Sgd { cfg, vw: None, vb: None, steps: 0 }
    }

    pub fn config(&self) -> SgdConfig {
        self.cfg
    }

    /// Steps taken so far.
    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// Global gradient norm (over W and b jointly).
    pub fn grad_norm(g: &Gradients) -> f32 {
        let sw: f64 = g.dw.data().iter().map(|v| (*v as f64).powi(2)).sum();
        let sb: f64 = g.db.iter().map(|v| (*v as f64).powi(2)).sum();
        (sw + sb).sqrt() as f32
    }

    /// Scale factor global-norm clipping applies to `g` (`1` =
    /// untouched): `min(1, clip/‖g‖)`. Exposed so the property tests
    /// can check the clipping contract without reading weights back.
    pub fn clip_factor(&self, g: &Gradients) -> f32 {
        match self.cfg.clip {
            Some(c) => {
                let n = Self::grad_norm(g);
                if n > c {
                    c / n
                } else {
                    1.0
                }
            }
            None => 1.0,
        }
    }

    /// Apply one update to `model` from gradients `g`. In the
    /// data-parallel trainer this runs exactly once per step, on the
    /// tree-merged (and sum→mean scaled) gradients.
    pub fn step(&mut self, model: &mut SoftmaxRegression, g: &Gradients) {
        let scale = self.clip_factor(g);
        let lr = self.cfg.lr;
        let mu = self.cfg.momentum;
        if mu == 0.0 {
            model.w_mut().axpy(-lr * scale, &g.dw);
            for (b, d) in model.b_mut().iter_mut().zip(&g.db) {
                *b -= lr * scale * d;
            }
        } else {
            let vw = self
                .vw
                .get_or_insert_with(|| Matrix::zeros(g.dw.rows(), g.dw.cols()));
            let vb = self.vb.get_or_insert_with(|| vec![0.0; g.db.len()]);
            for (v, d) in vw.data_mut().iter_mut().zip(g.dw.data()) {
                *v = mu * *v + scale * d;
            }
            for (v, d) in vb.iter_mut().zip(&g.db) {
                *v = mu * *v + scale * d;
            }
            model.w_mut().axpy(-lr, vw);
            for (b, v) in model.b_mut().iter_mut().zip(vb.iter()) {
                *b -= lr * v;
            }
        }
        self.steps += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;

    fn grad(val: f32, classes: usize, feats: usize) -> Gradients {
        Gradients {
            dw: Matrix::from_fn(classes, feats, |_, _| val),
            db: vec![val; classes],
        }
    }

    #[test]
    fn plain_sgd_update_rule() {
        let mut m = SoftmaxRegression::zeros(2, 3);
        let mut opt = Sgd::new(SgdConfig { lr: 0.1, momentum: 0.0, clip: None });
        opt.step(&mut m, &grad(1.0, 2, 3));
        assert!(m.w().data().iter().all(|&v| (v + 0.1).abs() < 1e-7));
        assert!(m.b().iter().all(|&v| (v + 0.1).abs() < 1e-7));
        assert_eq!(opt.steps(), 1);
    }

    #[test]
    fn momentum_accumulates() {
        let mut m = SoftmaxRegression::zeros(1, 1);
        let mut opt = Sgd::new(SgdConfig { lr: 1.0, momentum: 0.5, clip: None });
        opt.step(&mut m, &grad(1.0, 1, 1)); // v=1, w=-1
        opt.step(&mut m, &grad(1.0, 1, 1)); // v=1.5, w=-2.5
        assert!((m.w()[(0, 0)] + 2.5).abs() < 1e-6, "{}", m.w()[(0, 0)]);
    }

    #[test]
    fn clipping_bounds_update() {
        let mut m = SoftmaxRegression::zeros(1, 4);
        let mut opt = Sgd::new(SgdConfig { lr: 1.0, momentum: 0.0, clip: Some(1.0) });
        // gradient norm = sqrt(5·100) > 1 → scaled to unit norm
        opt.step(&mut m, &grad(10.0, 1, 4));
        let norm: f32 = m
            .w()
            .data()
            .iter()
            .chain(m.b())
            .map(|v| v * v)
            .sum::<f32>()
            .sqrt();
        assert!((norm - 1.0).abs() < 1e-5, "update norm {norm}");
    }

    #[test]
    fn small_gradients_not_clipped() {
        let g = grad(0.01, 2, 2);
        assert!(Sgd::grad_norm(&g) < 1.0);
        let mut m = SoftmaxRegression::zeros(2, 2);
        let mut opt = Sgd::new(SgdConfig { lr: 1.0, momentum: 0.0, clip: Some(1.0) });
        opt.step(&mut m, &g);
        assert!((m.w()[(0, 0)] + 0.01).abs() < 1e-7);
    }

    fn gen_gradients(g: &mut crate::proplite::Gen, classes: usize, feats: usize) -> Gradients {
        let dw = g.vec_f32(classes * feats, -3.0, 3.0);
        let db = g.vec_f32(classes, -3.0, 3.0);
        Gradients { dw: Matrix::from_vec(classes, feats, dw), db }
    }

    #[test]
    fn prop_zero_gradient_is_fixed_point() {
        crate::proplite::check("zero gradient is a fixed point", 40, |g| {
            let classes = g.usize_in(1, 4);
            let feats = g.usize_in(1, 6);
            let lr = g.f32_in(1e-4, 1.0);
            let momentum = if g.bool() { g.f32_in(0.0, 0.95) } else { 0.0 };
            let clip = if g.bool() { Some(g.f32_in(0.1, 5.0)) } else { None };
            let mut m = SoftmaxRegression::init(classes, feats, g.u64());
            let w0 = m.w().data().to_vec();
            let b0 = m.b().to_vec();
            let mut opt = Sgd::new(SgdConfig { lr, momentum, clip });
            for _ in 0..3 {
                opt.step(&mut m, &Gradients::zeros(classes, feats));
            }
            crate::proplite::prop(
                m.w().data() == &w0[..] && m.b() == &b0[..],
                format!("weights moved under zero gradient (lr={lr}, momentum={momentum})"),
            )
        });
    }

    #[test]
    fn prop_momentum_zero_matches_closed_form() {
        crate::proplite::check("momentum=0 matches w' = w − lr·g", 40, |g| {
            let classes = g.usize_in(1, 4);
            let feats = g.usize_in(1, 6);
            let lr = g.f32_in(1e-4, 0.5);
            let mut m = SoftmaxRegression::init(classes, feats, g.u64());
            let w0 = m.w().data().to_vec();
            let b0 = m.b().to_vec();
            let grads = gen_gradients(g, classes, feats);
            let mut opt = Sgd::new(SgdConfig { lr, momentum: 0.0, clip: None });
            opt.step(&mut m, &grads);
            for (k, (w, w_before)) in m.w().data().iter().zip(&w0).enumerate() {
                let want = w_before + (-lr) * grads.dw.data()[k];
                if (w - want).abs() > 1e-7 * (1.0 + want.abs()) {
                    return crate::proplite::prop(false, format!("w[{k}] = {w}, want {want}"));
                }
            }
            for (c, (b, b_before)) in m.b().iter().zip(&b0).enumerate() {
                let want = b_before + (-lr) * grads.db[c];
                if (b - want).abs() > 1e-7 * (1.0 + want.abs()) {
                    return crate::proplite::prop(false, format!("b[{c}] = {b}, want {want}"));
                }
            }
            Outcome::Pass
        });
    }

    #[test]
    fn prop_clip_never_increases_gradient_norm() {
        crate::proplite::check("clip factor bounds the applied norm", 60, |g| {
            let classes = g.usize_in(1, 4);
            let feats = g.usize_in(1, 8);
            let clip = g.f32_in(0.05, 4.0);
            let grads = gen_gradients(g, classes, feats);
            let norm = Sgd::grad_norm(&grads);
            let opt = Sgd::new(SgdConfig { lr: 0.1, momentum: 0.0, clip: Some(clip) });
            let factor = opt.clip_factor(&grads);
            let applied = factor * norm;
            let ok = factor <= 1.0
                && applied <= norm * (1.0 + 1e-6)
                && applied <= clip.min(norm) * (1.0 + 1e-5);
            crate::proplite::prop(
                ok,
                format!("norm {norm}, clip {clip}, factor {factor}, applied {applied}"),
            )
        });
    }

    use crate::proplite::Outcome;

    #[test]
    #[should_panic]
    fn bad_lr_rejected() {
        Sgd::new(SgdConfig { lr: 0.0, momentum: 0.0, clip: None });
    }

    #[test]
    #[should_panic]
    fn bad_momentum_rejected() {
        Sgd::new(SgdConfig { lr: 0.1, momentum: 1.0, clip: None });
    }
}
