//! Stochastic Gradient Descent: `w_{t+1} = w_t − γ·∇g_{c_t}(w_t)`
//! (paper Eq. 21), with optional classical momentum and global-norm
//! gradient clipping (features of the paper's framework, §6).

use crate::linalg::Matrix;
use crate::model::softmax_reg::{Gradients, SoftmaxRegression};

/// SGD hyper-parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SgdConfig {
    /// Learning rate γ.
    pub lr: f32,
    /// Momentum coefficient (0 = plain SGD, the paper's setting).
    pub momentum: f32,
    /// Global-norm clip threshold (`None` = no clipping).
    pub clip: Option<f32>,
}

impl Default for SgdConfig {
    fn default() -> Self {
        SgdConfig { lr: 0.001, momentum: 0.0, clip: None }
    }
}

/// SGD state (velocity buffers allocated lazily on first step).
#[derive(Debug)]
pub struct Sgd {
    cfg: SgdConfig,
    vw: Option<Matrix>,
    vb: Option<Vec<f32>>,
    steps: u64,
}

impl Sgd {
    pub fn new(cfg: SgdConfig) -> Sgd {
        assert!(cfg.lr > 0.0, "learning rate must be positive");
        assert!((0.0..1.0).contains(&cfg.momentum), "momentum in [0,1)");
        Sgd { cfg, vw: None, vb: None, steps: 0 }
    }

    pub fn config(&self) -> SgdConfig {
        self.cfg
    }

    /// Steps taken so far.
    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// Global gradient norm (over W and b jointly).
    pub fn grad_norm(g: &Gradients) -> f32 {
        let sw: f64 = g.dw.data().iter().map(|v| (*v as f64).powi(2)).sum();
        let sb: f64 = g.db.iter().map(|v| (*v as f64).powi(2)).sum();
        (sw + sb).sqrt() as f32
    }

    /// Apply one update to `model` from gradients `g`.
    pub fn step(&mut self, model: &mut SoftmaxRegression, g: &Gradients) {
        let mut scale = 1.0f32;
        if let Some(c) = self.cfg.clip {
            let n = Self::grad_norm(g);
            if n > c {
                scale = c / n;
            }
        }
        let lr = self.cfg.lr;
        let mu = self.cfg.momentum;
        if mu == 0.0 {
            model.w_mut().axpy(-lr * scale, &g.dw);
            for (b, d) in model.b_mut().iter_mut().zip(&g.db) {
                *b -= lr * scale * d;
            }
        } else {
            let vw = self
                .vw
                .get_or_insert_with(|| Matrix::zeros(g.dw.rows(), g.dw.cols()));
            let vb = self.vb.get_or_insert_with(|| vec![0.0; g.db.len()]);
            for (v, d) in vw.data_mut().iter_mut().zip(g.dw.data()) {
                *v = mu * *v + scale * d;
            }
            for (v, d) in vb.iter_mut().zip(&g.db) {
                *v = mu * *v + scale * d;
            }
            model.w_mut().axpy(-lr, vw);
            for (b, v) in model.b_mut().iter_mut().zip(vb.iter()) {
                *b -= lr * v;
            }
        }
        self.steps += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;

    fn grad(val: f32, classes: usize, feats: usize) -> Gradients {
        Gradients {
            dw: Matrix::from_fn(classes, feats, |_, _| val),
            db: vec![val; classes],
        }
    }

    #[test]
    fn plain_sgd_update_rule() {
        let mut m = SoftmaxRegression::zeros(2, 3);
        let mut opt = Sgd::new(SgdConfig { lr: 0.1, momentum: 0.0, clip: None });
        opt.step(&mut m, &grad(1.0, 2, 3));
        assert!(m.w().data().iter().all(|&v| (v + 0.1).abs() < 1e-7));
        assert!(m.b().iter().all(|&v| (v + 0.1).abs() < 1e-7));
        assert_eq!(opt.steps(), 1);
    }

    #[test]
    fn momentum_accumulates() {
        let mut m = SoftmaxRegression::zeros(1, 1);
        let mut opt = Sgd::new(SgdConfig { lr: 1.0, momentum: 0.5, clip: None });
        opt.step(&mut m, &grad(1.0, 1, 1)); // v=1, w=-1
        opt.step(&mut m, &grad(1.0, 1, 1)); // v=1.5, w=-2.5
        assert!((m.w()[(0, 0)] + 2.5).abs() < 1e-6, "{}", m.w()[(0, 0)]);
    }

    #[test]
    fn clipping_bounds_update() {
        let mut m = SoftmaxRegression::zeros(1, 4);
        let mut opt = Sgd::new(SgdConfig { lr: 1.0, momentum: 0.0, clip: Some(1.0) });
        // gradient norm = sqrt(5·100) > 1 → scaled to unit norm
        opt.step(&mut m, &grad(10.0, 1, 4));
        let norm: f32 = m
            .w()
            .data()
            .iter()
            .chain(m.b())
            .map(|v| v * v)
            .sum::<f32>()
            .sqrt();
        assert!((norm - 1.0).abs() < 1e-5, "update norm {norm}");
    }

    #[test]
    fn small_gradients_not_clipped() {
        let g = grad(0.01, 2, 2);
        assert!(Sgd::grad_norm(&g) < 1.0);
        let mut m = SoftmaxRegression::zeros(2, 2);
        let mut opt = Sgd::new(SgdConfig { lr: 1.0, momentum: 0.0, clip: Some(1.0) });
        opt.step(&mut m, &g);
        assert!((m.w()[(0, 0)] + 0.01).abs() < 1e-7);
    }

    #[test]
    #[should_panic]
    fn bad_lr_rejected() {
        Sgd::new(SgdConfig { lr: 0.0, momentum: 0.0, clip: None });
    }

    #[test]
    #[should_panic]
    fn bad_momentum_rejected() {
        Sgd::new(SgdConfig { lr: 0.1, momentum: 1.0, clip: None });
    }
}
