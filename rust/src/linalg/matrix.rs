//! Row-major dense f32 matrix.

use std::fmt;
use std::ops::{Index, IndexMut};

/// A row-major `rows × cols` matrix of f32.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Matrix {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Build from an existing buffer (`data.len() == rows*cols`).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Matrix {
        assert_eq!(data.len(), rows * cols, "buffer shape mismatch");
        Matrix { rows, cols, data }
    }

    /// Build by evaluating `f(r, c)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Matrix {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Matrix { rows, cols, data }
    }

    /// Identity.
    pub fn eye(n: usize) -> Matrix {
        Matrix::from_fn(n, n, |r, c| if r == c { 1.0 } else { 0.0 })
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// `(rows, cols)`.
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// Flat row-major data.
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Mutable flat data.
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Consume into the flat buffer.
    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Row slice.
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Mutable row slice.
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Reshape in place to `rows × cols`, reusing the existing buffer
    /// capacity (new cells are zero; surviving cells keep whatever
    /// they held — callers that reuse a matrix as an output buffer
    /// overwrite every element anyway). Shrinking then growing back
    /// never reallocates, which is what makes a pooled output matrix
    /// allocation-free across ragged mini-batches.
    pub fn resize(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.resize(rows * cols, 0.0);
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                t.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        t
    }

    /// `self += alpha * other` (same shape).
    pub fn axpy(&mut self, alpha: f32, other: &Matrix) {
        assert_eq!(self.shape(), other.shape());
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += alpha * b;
        }
    }

    /// Multiply every entry by `s`.
    pub fn scale(&mut self, s: f32) {
        for v in self.data.iter_mut() {
            *v *= s;
        }
    }

    /// Frobenius norm.
    pub fn frobenius(&self) -> f32 {
        self.data.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>().sqrt() as f32
    }

    /// Max |entry|.
    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, v| m.max(v.abs()))
    }
}

impl Index<(usize, usize)> for Matrix {
    type Output = f32;
    fn index(&self, (r, c): (usize, usize)) -> &f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &self.data[r * self.cols + c]
    }
}

impl IndexMut<(usize, usize)> for Matrix {
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Matrix({}x{})", self.rows, self.cols)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_indexing() {
        let mut m = Matrix::zeros(2, 3);
        m[(0, 0)] = 1.0;
        m[(1, 2)] = 5.0;
        assert_eq!(m[(0, 0)], 1.0);
        assert_eq!(m[(1, 2)], 5.0);
        assert_eq!(m.shape(), (2, 3));
        assert_eq!(m.row(1), &[0.0, 0.0, 5.0]);
    }

    #[test]
    fn from_fn_layout_row_major() {
        let m = Matrix::from_fn(2, 2, |r, c| (10 * r + c) as f32);
        assert_eq!(m.data(), &[0.0, 1.0, 10.0, 11.0]);
    }

    #[test]
    fn transpose_involutive() {
        let m = Matrix::from_fn(3, 5, |r, c| (r * 7 + c) as f32);
        assert_eq!(m.transpose().transpose(), m);
        assert_eq!(m.transpose()[(4, 2)], m[(2, 4)]);
    }

    #[test]
    fn eye_is_identity_under_gemm() {
        let m = Matrix::from_fn(4, 4, |r, c| (r + 2 * c) as f32);
        let mut out = Matrix::zeros(4, 4);
        crate::linalg::gemm(&m, &Matrix::eye(4), &mut out);
        assert_eq!(out, m);
    }

    #[test]
    fn axpy_and_scale() {
        let mut a = Matrix::from_vec(1, 3, vec![1.0, 2.0, 3.0]);
        let b = Matrix::from_vec(1, 3, vec![10.0, 20.0, 30.0]);
        a.axpy(0.5, &b);
        assert_eq!(a.data(), &[6.0, 12.0, 18.0]);
        a.scale(2.0);
        assert_eq!(a.data(), &[12.0, 24.0, 36.0]);
    }

    #[test]
    fn norms() {
        let m = Matrix::from_vec(1, 2, vec![3.0, -4.0]);
        assert!((m.frobenius() - 5.0).abs() < 1e-6);
        assert_eq!(m.max_abs(), 4.0);
    }

    #[test]
    #[should_panic]
    fn from_vec_shape_checked() {
        Matrix::from_vec(2, 2, vec![0.0; 3]);
    }

    #[test]
    fn resize_reuses_capacity() {
        let mut m = Matrix::zeros(8, 4);
        let cap = m.data.capacity();
        let ptr = m.data.as_ptr();
        m.resize(3, 4);
        assert_eq!(m.shape(), (3, 4));
        m.resize(8, 4);
        assert_eq!(m.shape(), (8, 4));
        assert_eq!(m.data.capacity(), cap);
        assert!(std::ptr::eq(ptr, m.data.as_ptr()));
    }
}
