//! Linear-algebra kernels: blocked GEMM, GEMV, numerically stable
//! softmax / log-sum-exp, and reductions.

use super::matrix::Matrix;

/// Cache-block edge for GEMM (MC×KC panel of A ~ 64·256·4 B = 64 KiB).
const MC: usize = 64;
const KC: usize = 256;

/// `out = a · b` (shapes `(m,k)·(k,n) → (m,n)`), blocked over K and M
/// with a unit-stride inner loop over N (auto-vectorizes).
pub fn gemm(a: &Matrix, b: &Matrix, out: &mut Matrix) {
    let (m, k) = a.shape();
    let (k2, n) = b.shape();
    assert_eq!(k, k2, "inner dimensions differ");
    assert_eq!(out.shape(), (m, n), "output shape");
    out.data_mut().fill(0.0);
    let (ad, bd) = (a.data(), b.data());
    let od = out.data_mut();
    let mut k0 = 0;
    while k0 < k {
        let kb = KC.min(k - k0);
        let mut m0 = 0;
        while m0 < m {
            let mb = MC.min(m - m0);
            for i in m0..m0 + mb {
                let arow = &ad[i * k + k0..i * k + k0 + kb];
                let orow = &mut od[i * n..(i + 1) * n];
                for (p, &av) in arow.iter().enumerate() {
                    if av == 0.0 {
                        continue; // padded features are exactly zero often
                    }
                    let brow = &bd[(k0 + p) * n..(k0 + p + 1) * n];
                    for (o, &bv) in orow.iter_mut().zip(brow.iter()) {
                        *o += av * bv;
                    }
                }
            }
            m0 += mb;
        }
        k0 += kb;
    }
}

/// `out = a · bᵀ` taking `b` as `(n, k)` — the classifier's logits
/// `X·Wᵀ` with unit-stride dot products (no transpose materialized).
pub fn gemm_nt(a: &Matrix, b: &Matrix, out: &mut Matrix) {
    let (m, k) = a.shape();
    let (n, k2) = b.shape();
    assert_eq!(k, k2, "inner dimensions differ");
    assert_eq!(out.shape(), (m, n), "output shape");
    let (ad, bd) = (a.data(), b.data());
    let od = out.data_mut();
    for i in 0..m {
        let arow = &ad[i * k..(i + 1) * k];
        for j in 0..n {
            let brow = &bd[j * k..(j + 1) * k];
            od[i * n + j] = dot(arow, brow);
        }
    }
}

/// Vectorizable dot product: 8 independent accumulator lanes so the
/// compiler can keep SIMD registers full (a single sequential f32
/// accumulator forbids reassociation and stays scalar — §Perf).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 8];
    let mut ac = a.chunks_exact(8);
    let mut bc = b.chunks_exact(8);
    for (xa, xb) in (&mut ac).zip(&mut bc) {
        for l in 0..8 {
            acc[l] += xa[l] * xb[l];
        }
    }
    let mut tail = 0.0f32;
    for (x, y) in ac.remainder().iter().zip(bc.remainder()) {
        tail += x * y;
    }
    acc.iter().sum::<f32>() + tail
}

/// `out = aᵀ · b` taking `a` as `(k, m)`, `b` as `(k, n)` — the
/// gradient contraction `∂L/∂W = δᵀ·X` without materializing δᵀ.
pub fn gemm_tn(a: &Matrix, b: &Matrix, out: &mut Matrix) {
    let (k, m) = a.shape();
    let (k2, n) = b.shape();
    assert_eq!(k, k2, "inner dimensions differ");
    assert_eq!(out.shape(), (m, n), "output shape");
    out.data_mut().fill(0.0);
    let (ad, bd) = (a.data(), b.data());
    let od = out.data_mut();
    for p in 0..k {
        let arow = &ad[p * m..(p + 1) * m];
        let brow = &bd[p * n..(p + 1) * n];
        for (i, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let orow = &mut od[i * n..(i + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow.iter()) {
                *o += av * bv;
            }
        }
    }
}

/// `y = M · x` (matrix–vector).
pub fn gemv(m: &Matrix, x: &[f32], y: &mut [f32]) {
    let (rows, cols) = m.shape();
    assert_eq!(x.len(), cols);
    assert_eq!(y.len(), rows);
    for (r, out) in y.iter_mut().enumerate() {
        let row = m.row(r);
        let mut acc = 0.0f64;
        for (a, b) in row.iter().zip(x.iter()) {
            acc += (*a as f64) * (*b as f64);
        }
        *out = acc as f32;
    }
}

/// Numerically stable `log Σ exp(x_i)`.
pub fn logsumexp(x: &[f32]) -> f32 {
    let m = x.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    if m == f32::NEG_INFINITY {
        return f32::NEG_INFINITY;
    }
    let s: f64 = x.iter().map(|&v| ((v - m) as f64).exp()).sum();
    m + (s.ln() as f32)
}

/// Row-wise in-place softmax of a `(rows, cols)` matrix.
pub fn softmax_rows(m: &mut Matrix) {
    let cols = m.cols();
    for r in 0..m.rows() {
        let row = m.row_mut(r);
        let mx = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let mut sum = 0.0f64;
        for v in row.iter_mut() {
            let e = ((*v - mx) as f64).exp();
            *v = e as f32;
            sum += e;
        }
        let inv = (1.0 / sum) as f32;
        for v in row.iter_mut() {
            *v *= inv;
        }
        debug_assert_eq!(row.len(), cols);
    }
}

/// Row-wise in-place log-softmax.
pub fn log_softmax_rows(m: &mut Matrix) {
    for r in 0..m.rows() {
        let row = m.row_mut(r);
        let lse = logsumexp(row);
        for v in row.iter_mut() {
            *v -= lse;
        }
    }
}

/// Index of the maximum element (first on ties).
pub fn argmax(x: &[f32]) -> usize {
    let mut best = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &v) in x.iter().enumerate() {
        if v > bv {
            bv = v;
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_gemm(a: &Matrix, b: &Matrix) -> Matrix {
        let (m, k) = a.shape();
        let (_, n) = b.shape();
        Matrix::from_fn(m, n, |i, j| (0..k).map(|p| a[(i, p)] * b[(p, j)]).sum())
    }

    fn rand_matrix(r: usize, c: usize, seed: u64) -> Matrix {
        let mut rng = crate::hash::HashRng::new(seed, 0x6e);
        Matrix::from_fn(r, c, |_, _| rng.next_f32() * 2.0 - 1.0)
    }

    #[test]
    fn gemm_matches_naive() {
        for (m, k, n) in [(1, 1, 1), (3, 4, 5), (17, 33, 9), (65, 300, 10)] {
            let a = rand_matrix(m, k, 1);
            let b = rand_matrix(k, n, 2);
            let mut out = Matrix::zeros(m, n);
            gemm(&a, &b, &mut out);
            let want = naive_gemm(&a, &b);
            for (x, y) in out.data().iter().zip(want.data().iter()) {
                assert!((x - y).abs() < 1e-3, "{m}x{k}x{n}");
            }
        }
    }

    #[test]
    fn gemm_nt_matches_transpose() {
        let a = rand_matrix(6, 20, 3);
        let b = rand_matrix(7, 20, 4); // (n, k)
        let mut out = Matrix::zeros(6, 7);
        gemm_nt(&a, &b, &mut out);
        let want = naive_gemm(&a, &b.transpose());
        for (x, y) in out.data().iter().zip(want.data().iter()) {
            assert!((x - y).abs() < 1e-3);
        }
    }

    #[test]
    fn gemm_tn_matches_transpose() {
        let a = rand_matrix(20, 6, 5); // (k, m)
        let b = rand_matrix(20, 7, 6); // (k, n)
        let mut out = Matrix::zeros(6, 7);
        gemm_tn(&a, &b, &mut out);
        let want = naive_gemm(&a.transpose(), &b);
        for (x, y) in out.data().iter().zip(want.data().iter()) {
            assert!((x - y).abs() < 1e-3);
        }
    }

    #[test]
    fn gemv_matches_gemm() {
        let m = rand_matrix(9, 31, 7);
        let x: Vec<f32> = (0..31).map(|i| (i as f32) / 31.0).collect();
        let mut y = vec![0.0f32; 9];
        gemv(&m, &x, &mut y);
        let xm = Matrix::from_vec(31, 1, x);
        let want = naive_gemm(&m, &xm);
        for (a, b) in y.iter().zip(want.data().iter()) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn softmax_rows_sums_to_one_and_orders() {
        let mut m = Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, -1.0, -1.0, -1.0]);
        softmax_rows(&mut m);
        for r in 0..2 {
            let s: f32 = m.row(r).iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
        assert!(m[(0, 2)] > m[(0, 1)] && m[(0, 1)] > m[(0, 0)]);
        assert!((m[(1, 0)] - 1.0 / 3.0).abs() < 1e-5);
    }

    #[test]
    fn softmax_stable_under_large_logits() {
        let mut m = Matrix::from_vec(1, 3, vec![1000.0, 1001.0, 999.0]);
        softmax_rows(&mut m);
        assert!(m.data().iter().all(|v| v.is_finite()));
        let s: f32 = m.row(0).iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
    }

    #[test]
    fn logsumexp_values() {
        assert!((logsumexp(&[0.0, 0.0]) - std::f32::consts::LN_2).abs() < 1e-6);
        assert_eq!(logsumexp(&[f32::NEG_INFINITY]), f32::NEG_INFINITY);
        assert!((logsumexp(&[5.0]) - 5.0).abs() < 1e-6);
    }

    #[test]
    fn log_softmax_matches_softmax_log() {
        let src = vec![0.5f32, -1.0, 2.0, 0.0];
        let mut a = Matrix::from_vec(1, 4, src.clone());
        let mut b = Matrix::from_vec(1, 4, src);
        log_softmax_rows(&mut a);
        softmax_rows(&mut b);
        for (x, y) in a.data().iter().zip(b.data().iter()) {
            assert!((x - y.ln()).abs() < 1e-5);
        }
    }

    #[test]
    fn argmax_first_on_ties() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[-1.0]), 0);
    }
}
