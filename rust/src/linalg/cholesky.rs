//! Cholesky factorization / SPD solve — the substrate for the paper's
//! §2 exact learning-with-kernels formulation `(nγI + K)t = y`
//! (Eq. 2), which is strictly positive definite.

use super::matrix::Matrix;
use anyhow::{ensure, Result};

/// Lower-triangular Cholesky factor of an SPD matrix (`A = L·Lᵀ`).
pub fn cholesky(a: &Matrix) -> Result<Matrix> {
    let (n, m) = a.shape();
    ensure!(n == m, "Cholesky needs a square matrix");
    let mut l = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a[(i, j)] as f64;
            for k in 0..j {
                sum -= (l[(i, k)] as f64) * (l[(j, k)] as f64);
            }
            if i == j {
                ensure!(sum > 0.0, "matrix not positive definite at pivot {i}");
                l[(i, j)] = (sum.sqrt()) as f32;
            } else {
                l[(i, j)] = (sum / l[(j, j)] as f64) as f32;
            }
        }
    }
    Ok(l)
}

/// Solve `A x = b` for SPD `A` via Cholesky (forward + back substitution).
pub fn solve_spd(a: &Matrix, b: &[f32]) -> Result<Vec<f32>> {
    let l = cholesky(a)?;
    let n = b.len();
    ensure!(a.rows() == n, "dimension mismatch");
    // forward: L z = b
    let mut z = vec![0.0f32; n];
    for i in 0..n {
        let mut s = b[i] as f64;
        for k in 0..i {
            s -= (l[(i, k)] as f64) * (z[k] as f64);
        }
        z[i] = (s / l[(i, i)] as f64) as f32;
    }
    // back: Lᵀ x = z
    let mut x = vec![0.0f32; n];
    for i in (0..n).rev() {
        let mut s = z[i] as f64;
        for k in (i + 1)..n {
            s -= (l[(k, i)] as f64) * (x[k] as f64);
        }
        x[i] = (s / l[(i, i)] as f64) as f32;
    }
    Ok(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spd(n: usize, seed: u64) -> Matrix {
        // A = BᵀB + n·I is SPD
        let mut rng = crate::hash::HashRng::new(seed, 0xC0);
        let b = Matrix::from_fn(n, n, |_, _| rng.next_f32() - 0.5);
        let mut a = Matrix::zeros(n, n);
        crate::linalg::ops::gemm_tn(&b, &b, &mut a);
        for i in 0..n {
            a[(i, i)] += n as f32;
        }
        a
    }

    #[test]
    fn factor_reconstructs() {
        let a = spd(12, 1);
        let l = cholesky(&a).unwrap();
        // L·Lᵀ == A
        for i in 0..12 {
            for j in 0..12 {
                let mut s = 0.0f64;
                for k in 0..12 {
                    s += (l[(i, k)] as f64) * (l[(j, k)] as f64);
                }
                assert!((s - a[(i, j)] as f64).abs() < 1e-3, "({i},{j})");
            }
            // strictly lower-triangular above diagonal
            for j in (i + 1)..12 {
                assert_eq!(l[(i, j)], 0.0);
            }
        }
    }

    #[test]
    fn solve_roundtrip() {
        let a = spd(20, 2);
        let x_true: Vec<f32> = (0..20).map(|i| (i as f32 - 10.0) / 7.0).collect();
        let mut b = vec![0.0f32; 20];
        crate::linalg::gemv(&a, &x_true, &mut b);
        let x = solve_spd(&a, &b).unwrap();
        for (got, want) in x.iter().zip(&x_true) {
            assert!((got - want).abs() < 1e-3, "{got} vs {want}");
        }
    }

    #[test]
    fn identity_is_its_own_factor() {
        let l = cholesky(&Matrix::eye(5)).unwrap();
        assert_eq!(l, Matrix::eye(5));
    }

    #[test]
    fn rejects_indefinite() {
        let mut a = Matrix::eye(3);
        a[(2, 2)] = -1.0;
        assert!(cholesky(&a).is_err());
    }

    #[test]
    fn rejects_non_square() {
        assert!(cholesky(&Matrix::zeros(2, 3)).is_err());
    }
}
