//! Dense linear algebra substrate (no BLAS reachable offline).
//!
//! Row-major [`Matrix`] plus the handful of kernels the classifier
//! needs: a cache-blocked GEMM, GEMV, softmax/log-sum-exp and
//! reductions. Everything is f32 with f64 accumulation where it
//! matters for stability.

pub mod cholesky;
pub mod matrix;
pub mod ops;

pub use matrix::Matrix;
pub use cholesky::{cholesky, solve_spd};
pub use ops::{argmax, gemm, gemv, log_softmax_rows, logsumexp, softmax_rows};
