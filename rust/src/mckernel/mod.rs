//! The McKernel feature map — the paper's primary contribution.
//!
//! Computes the Fastfood factorization (paper Eq. 8)
//!
//! ```text
//! Ẑ := (1/(σ√n)) · C · H · G · Π · H · B
//! ```
//!
//! and the real feature map (paper Eq. 9) `φ(x) = [cos(Ẑx̂), sin(Ẑx̂)]`
//! where `x̂` is the input padded to the next power of two. `E`
//! independent expansions are stacked to reach any target feature
//! dimension ("whenever the number of rows in W exceeds the
//! dimensionality of the data, we can simply generate multiple
//! instances of Ẑ, drawn i.i.d.").
//!
//! Every random coefficient is hash-derived (see [`crate::hash`]), so
//! a trained model is reproduced from `(seed, config)` alone — the
//! paper's compact-distribution story (§7).

pub mod cache;
pub mod diag;
pub mod engine;
pub mod expansion;
pub mod factory;
pub mod feature_map;
pub mod kernel;
pub mod mmd;
pub mod plan;

pub use cache::{CacheKey, FeatureCache};
pub use engine::ExpansionEngine;
pub use expansion::FastfoodBlock;
pub use factory::{McKernelConfig, McKernelFactory};
pub use feature_map::McKernel;
pub use kernel::Kernel;
pub use plan::{dispatch_force, set_dispatch_force, DispatchForce, ExpansionPlan, FwhtDispatch};
