//! The expansion executor: one engine behind every consumer of the
//! McKernel feature map.
//!
//! An [`ExpansionEngine`] carries a compiled [`ExpansionPlan`] plus a
//! single exactly-sized scratch pool, and executes `φ(X)` for **any**
//! row count — 1 (the serving path), a shard (the data-parallel
//! trainer), or a full mini-batch — through the one pipeline the plan
//! compiled to. `McKernel`'s public transform methods, the
//! `Featurizer`, the KRR solver, the prefetch pipeline, the feature
//! server and the bench harness are all thin wrappers over
//! [`ExpansionEngine::execute`]; none of them sizes scratch or picks
//! an FWHT path anymore.
//!
//! The engine does not own the feature map: coefficients live in
//! [`McKernel`] (shared freely via `Arc`), the engine owns only the
//! mutable execution state. `execute` verifies plan/map geometry
//! agreement, so a plan compiled for one map cannot silently run
//! against another.

use super::feature_map::McKernel;
use super::plan::{ExpansionPlan, FwhtDispatch};
use crate::linalg::Matrix;
use crate::obs;
use crate::util::fastmath;
use std::sync::Arc;
use std::time::Instant;

/// Per-execute stage-time accumulators, in nanoseconds. Stays all
/// zeros when the engine is untimed.
#[derive(Debug, Default, Clone, Copy)]
struct StageTimes {
    fwht: u64,
    trig: u64,
    write: u64,
}

/// `Instant::now()` only when timing — the disabled path never reads
/// the clock.
#[inline]
fn stamp(on: bool) -> Option<Instant> {
    if on {
        Some(Instant::now())
    } else {
        None
    }
}

/// Accumulate the elapsed time of a [`stamp`], if one was taken.
#[inline]
fn lap(t: Option<Instant>, acc: &mut u64) {
    if let Some(t) = t {
        *acc += obs::elapsed_ns(t);
    }
}

/// Handles into the global registry for one plan fingerprint,
/// resolved once at engine construction (`engine.<fingerprint>.*`).
#[derive(Debug, Clone)]
struct EngineMetrics {
    rows: Arc<obs::Counter>,
    execute_ns: Arc<obs::Hist>,
    fwht_ns: Arc<obs::Hist>,
    trig_ns: Arc<obs::Hist>,
    write_ns: Arc<obs::Hist>,
}

impl EngineMetrics {
    fn for_plan(plan: &ExpansionPlan) -> EngineMetrics {
        let reg = obs::global();
        let fp = plan.fingerprint();
        EngineMetrics {
            rows: reg.counter(&format!("engine.{fp}.rows")),
            execute_ns: reg.histogram(&format!("engine.{fp}.execute_ns")),
            fwht_ns: reg.histogram(&format!("engine.{fp}.fwht_ns")),
            trig_ns: reg.histogram(&format!("engine.{fp}.trig_ns")),
            write_ns: reg.histogram(&format!("engine.{fp}.write_ns")),
        }
    }
}

/// Executor for one [`ExpansionPlan`]: owns the plan plus its scratch
/// pool, allocated once at construction and never grown. Hot paths
/// (`execute`, `execute_matrix`) are allocation-free.
#[derive(Debug, Clone)]
pub struct ExpansionEngine {
    plan: ExpansionPlan,
    scratch: Vec<f32>,
    metrics: Option<EngineMetrics>,
}

impl ExpansionEngine {
    /// Engine for an already-compiled plan.
    ///
    /// Observability binds here: when the global registry is enabled
    /// at construction, the engine resolves its `engine.<fingerprint>`
    /// metric handles and times each pipeline stage; when disabled
    /// (the default), it carries `None` and `execute` pays one branch.
    pub fn with_plan(plan: ExpansionPlan) -> ExpansionEngine {
        let scratch = vec![0.0; plan.scratch_floats()];
        let metrics = if obs::enabled() { Some(EngineMetrics::for_plan(&plan)) } else { None };
        ExpansionEngine { plan, scratch, metrics }
    }

    /// Compile-and-build for `map`, expecting ~`rows_hint` rows per
    /// call (see [`ExpansionPlan::new`]).
    pub fn new(map: &McKernel, rows_hint: usize) -> ExpansionEngine {
        ExpansionEngine::with_plan(ExpansionPlan::new(map.config(), rows_hint))
    }

    /// Like [`ExpansionEngine::new`] with the `1/√(n·E)` estimator
    /// scaling folded into the feature write.
    pub fn normalized(map: &McKernel, rows_hint: usize) -> ExpansionEngine {
        ExpansionEngine::with_plan(ExpansionPlan::new(map.config(), rows_hint).normalized())
    }

    /// Engine forced onto the per-row libm path — the correctness
    /// oracle for the batched pipeline and the bench baseline.
    pub fn per_row_oracle(map: &McKernel) -> ExpansionEngine {
        ExpansionEngine::with_plan(ExpansionPlan::per_row(map.config()))
    }

    /// The compiled plan this engine executes.
    pub fn plan(&self) -> &ExpansionPlan {
        &self.plan
    }

    /// Current scratch-pool size in f32 elements (always exactly
    /// [`ExpansionPlan::scratch_floats`]; checked on every execute).
    pub fn scratch_floats(&self) -> usize {
        self.scratch.len()
    }

    /// Compute `φ` for `rows` row-major inputs (`xs` is
    /// `(rows, src_cols)` with `src_cols` = the plan's input dim —
    /// zero-padded internally — or exactly the padded dim) into `out`
    /// (`(rows, feature_dim)`). Output layout per row, expansion `e`:
    /// `out[e·2n .. e·2n+n] = cos(Ẑ_e x̂)·s`,
    /// `out[e·2n+n .. (e+1)·2n] = sin(Ẑ_e x̂)·s` with `s` the plan's
    /// folded post-scale.
    ///
    /// Works for any `rows` (1, a shard, a full batch) and is
    /// invariant to how rows are split across calls: executing
    /// disjoint shards into the same buffer is bit-identical to one
    /// full-batch call.
    pub fn execute(
        &mut self,
        map: &McKernel,
        xs: &[f32],
        rows: usize,
        src_cols: usize,
        out: &mut [f32],
    ) {
        assert!(
            self.plan.matches(map),
            "plan geometry (S={}, n={}, E={}) does not match the map (S={}, n={}, E={})",
            self.plan.input_dim(),
            self.plan.padded_dim(),
            self.plan.expansions(),
            map.input_dim(),
            map.padded_dim(),
            map.expansions()
        );
        let n = self.plan.padded_dim();
        assert!(
            src_cols == self.plan.input_dim() || src_cols == n,
            "input width {} (expect {} or {})",
            src_cols,
            self.plan.input_dim(),
            n
        );
        assert_eq!(xs.len(), rows * src_cols, "input length");
        assert_eq!(out.len(), rows * self.plan.feature_dim(), "output length");
        // No-realloc invariant: the pool was sized exactly at build
        // time and execute only ever slices into it.
        assert_eq!(
            self.scratch.len(),
            self.plan.scratch_floats(),
            "engine scratch does not match its plan"
        );
        let scratch_ptr = self.scratch.as_ptr();
        let timed = self.metrics.is_some();
        let t_exec = stamp(timed);
        let stages = match self.plan.dispatch() {
            FwhtDispatch::PerRow => self.run_per_row(map, xs, rows, src_cols, out, timed),
            // One tiled pipeline, two kernel sets: run_tiled reads the
            // scalar-vs-SIMD choice back off the plan.
            FwhtDispatch::Batched | FwhtDispatch::Simd => {
                self.run_tiled(map, xs, rows, src_cols, out, timed)
            }
        };
        debug_assert!(
            std::ptr::eq(scratch_ptr, self.scratch.as_ptr()),
            "engine scratch reallocated during execute"
        );
        if let Some(m) = &self.metrics {
            let mut total = 0u64;
            lap(t_exec, &mut total);
            m.rows.add(rows as u64);
            m.execute_ns.record(total);
            m.fwht_ns.record(stages.fwht);
            m.trig_ns.record(stages.trig);
            m.write_ns.record(stages.write);
        }
    }

    /// Matrix-shaped convenience over [`ExpansionEngine::execute`].
    pub fn execute_matrix(&mut self, map: &McKernel, x: &Matrix, out: &mut Matrix) {
        assert_eq!(out.shape(), (x.rows(), self.plan.feature_dim()), "output shape");
        let (rows, src_cols) = x.shape();
        self.execute(map, x.data(), rows, src_cols, out.data_mut());
    }

    /// The per-row path: pad, `Ẑx̂` per expansion, libm `sin_cos`,
    /// post-scale fused into the feature write. This is the pipeline
    /// the batched path is validated against (≤1e-6 abs on tested
    /// shapes; the only difference is the trig kernel).
    ///
    /// Stage accounting: the Fastfood passes land in `fwht`; the
    /// trig+write loop is fused here, so its time lands in `trig` and
    /// `write` stays 0 on this path.
    fn run_per_row(
        &mut self,
        map: &McKernel,
        xs: &[f32],
        rows: usize,
        src_cols: usize,
        out: &mut [f32],
        timed: bool,
    ) -> StageTimes {
        let mut st = StageTimes::default();
        let n = self.plan.padded_dim();
        let fd = self.plan.feature_dim();
        let post_scale = self.plan.post_scale();
        let (padded, tmp) = self.scratch.split_at_mut(n);
        for r in 0..rows {
            padded[..src_cols].copy_from_slice(&xs[r * src_cols..(r + 1) * src_cols]);
            padded[src_cols..].fill(0.0);
            let row_out = &mut out[r * fd..(r + 1) * fd];
            for (e, block) in map.blocks().iter().enumerate() {
                let seg = &mut row_out[e * 2 * n..(e + 1) * 2 * n];
                let (cos_half, sin_half) = seg.split_at_mut(n);
                // Ẑx̂ into cos_half (as scratch), then write the pair.
                // sin_cos computes both trig values in one libm call —
                // the trig map dominates the per-sample profile.
                let t = stamp(timed);
                block.apply(padded, cos_half, tmp);
                lap(t, &mut st.fwht);
                let t = stamp(timed);
                for (cv, sv) in cos_half.iter_mut().zip(sin_half.iter_mut()) {
                    let (s, c) = cv.sin_cos();
                    *sv = s * post_scale;
                    *cv = c * post_scale;
                }
                lap(t, &mut st.trig);
            }
        }
        st
    }

    /// The tiled pipeline (`Batched` and `Simd` arms): row-tiles of
    /// `plan.lanes()` rows stream through the fused Fastfood passes
    /// (B on the transpose-in load, Π∘G as contiguous stream copies),
    /// the calibration diagonal, the polynomial trig map, and a
    /// transpose-out write with the post-scale fused in — no separate
    /// normalization pass. Lanes never interact, so results are
    /// independent of the tile grouping.
    ///
    /// The `Simd` arm is the same pipeline with the FWHT butterflies
    /// and the trig map swapped for their `std::arch` twins; the FWHT
    /// swap is bit-identical (adds/subs), the trig swap agrees ≤1e-6.
    fn run_tiled(
        &mut self,
        map: &McKernel,
        xs: &[f32],
        rows: usize,
        src_cols: usize,
        out: &mut [f32],
        timed: bool,
    ) -> StageTimes {
        let simd = self.plan.dispatch() == FwhtDispatch::Simd;
        let mut st = StageTimes::default();
        let n = self.plan.padded_dim();
        let fd = self.plan.feature_dim();
        let post_scale = self.plan.post_scale();
        let lanes_max = self.plan.lanes();
        let (tin, rest) = self.scratch.split_at_mut(n * lanes_max);
        let (z, sin) = rest.split_at_mut(n * lanes_max);
        let mut base = 0;
        while base < rows {
            let lanes = lanes_max.min(rows - base);
            let nl = n * lanes;
            let xslice = &xs[base * src_cols..(base + lanes) * src_cols];
            for (e, block) in map.blocks().iter().enumerate() {
                let t = stamp(timed);
                block.apply_tile_with(xslice, src_cols, lanes, tin, z, simd);
                // calibration diagonal: contiguous per-coefficient runs
                let scale = block.scale();
                for j in 0..n {
                    let sj = scale[j];
                    for v in &mut z[j * lanes..(j + 1) * lanes] {
                        *v *= sj;
                    }
                }
                lap(t, &mut st.fwht);
                // polynomial trig over the whole tile; tin is free by
                // now and becomes the cosine buffer
                let t = stamp(timed);
                if simd {
                    fastmath::sin_cos_batch_simd(&z[..nl], &mut sin[..nl], &mut tin[..nl]);
                } else {
                    fastmath::sin_cos_batch(&z[..nl], &mut sin[..nl], &mut tin[..nl]);
                }
                lap(t, &mut st.trig);
                // transpose-out into the (cos, sin) halves, any output
                // normalization fused into this single write
                let t = stamp(timed);
                for l in 0..lanes {
                    let seg = &mut out[(base + l) * fd + e * 2 * n..][..2 * n];
                    let (cos_half, sin_half) = seg.split_at_mut(n);
                    for j in 0..n {
                        cos_half[j] = tin[j * lanes + l] * post_scale;
                        sin_half[j] = sin[j * lanes + l] * post_scale;
                    }
                }
                lap(t, &mut st.write);
            }
            base += lanes;
        }
        st
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mckernel::McKernelFactory;

    fn map(dim: usize, e: usize) -> McKernel {
        McKernelFactory::new(dim).expansions(e).sigma(1.0).rbf().seed(11).build()
    }

    #[test]
    fn engine_matches_thin_wrappers() {
        let m = map(12, 2);
        let x = Matrix::from_fn(5, 12, |r, c| ((r * 7 + c) % 9) as f32 * 0.1);
        let mut eng = ExpansionEngine::new(&m, 5);
        let mut out = Matrix::zeros(5, m.feature_dim());
        eng.execute_matrix(&m, &x, &mut out);
        assert_eq!(out.data(), m.transform_batch(&x).data());
    }

    #[test]
    fn shard_splits_are_bit_identical_to_full_batch() {
        let m = map(20, 1);
        let x = Matrix::from_fn(9, 20, |r, c| ((r * 13 + c) % 11) as f32 * 0.05);
        let fd = m.feature_dim();
        let mut full = vec![0.0f32; 9 * fd];
        let mut eng = ExpansionEngine::new(&m, 9);
        eng.execute(&m, x.data(), 9, 20, &mut full);
        let mut sharded = vec![0.0f32; 9 * fd];
        for (lo, hi) in [(0usize, 4usize), (4, 7), (7, 9)] {
            eng.execute(
                &m,
                &x.data()[lo * 20..hi * 20],
                hi - lo,
                20,
                &mut sharded[lo * fd..hi * fd],
            );
        }
        assert_eq!(full, sharded);
    }

    #[test]
    fn zero_rows_is_a_no_op() {
        let m = map(8, 1);
        let mut eng = ExpansionEngine::new(&m, 4);
        let mut out: Vec<f32> = vec![];
        eng.execute(&m, &[], 0, 8, &mut out);
    }

    #[test]
    fn stage_metrics_record_when_enabled() {
        // the global registry stays enabled for the rest of this test
        // process; assertions are therefore `>=` (other tests may add)
        crate::obs::enable();
        let m = map(12, 2);
        let mut eng = ExpansionEngine::new(&m, 5);
        let x = Matrix::from_fn(5, 12, |r, c| ((r * 7 + c) % 9) as f32 * 0.1);
        let mut out = Matrix::zeros(5, m.feature_dim());
        eng.execute_matrix(&m, &x, &mut out);
        let fp = eng.plan().fingerprint();
        let reg = crate::obs::global();
        assert!(reg.counter(&format!("engine.{fp}.rows")).get() >= 5);
        for stage in ["execute_ns", "fwht_ns", "trig_ns", "write_ns"] {
            let snap = reg.histogram(&format!("engine.{fp}.{stage}")).snapshot();
            assert!(snap.count >= 1, "engine.{fp}.{stage} never recorded");
        }
        // instrumentation must not perturb the numerics
        assert_eq!(out.data(), m.transform_batch(&x).data());
    }

    #[test]
    #[should_panic(expected = "plan geometry")]
    fn mismatched_map_rejected() {
        let a = map(12, 2);
        let b = map(16, 2);
        let mut eng = ExpansionEngine::new(&a, 4);
        let mut out = vec![0.0f32; b.feature_dim()];
        let x = vec![0.0f32; 16];
        eng.execute(&b, &x, 1, 16, &mut out);
    }
}
