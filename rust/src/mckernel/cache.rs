//! Content-addressed feature cache in front of the expansion engine.
//!
//! The expansion `φ(x)` is a *deterministic* function of
//! `(McKernelConfig, x)` — every coefficient is hash-derived (paper
//! §3/§7), so for workloads with repeated inputs the FWHT + trig
//! pipeline recomputes bit-identical rows per request. A
//! [`FeatureCache`] memoizes whole feature rows keyed by
//! `(expansion identity, row content)`:
//!
//! * the **cache id** ([`CacheKey`]) hashes the full config
//!   (dimensions, expansions, σ, kernel, seed) plus the plan facts
//!   that reach the output bits (padded dim, dispatch, normalization).
//!   Tile lane count is deliberately excluded: the engine is
//!   bit-invariant to row grouping, so engines compiled with different
//!   row hints share entries;
//! * the **row hash** is seeded MurmurHash3 over the id and the row's
//!   `f32::to_bits` image. The hash is never trusted alone — every
//!   entry stores its key bytes and a lookup verifies id and row
//!   bit-for-bit before serving, so a (vanishingly unlikely) 128-bit
//!   collision degrades to a miss, never to wrong features.
//!
//! Entries hold verbatim engine output (post-scale folded and all), so
//! a cache-enabled path is bit-identical to the uncached engine: hits
//! replay stored rows, misses are gathered into one engine call — row
//! grouping is execution-invariant — and scattered back. Capacity is
//! bounded in **bytes**; each of the `shards` independently holds an
//! exact-LRU list under its own mutex (the server's concurrent submit
//! path never serializes on one lock) and evicts from its tail, so
//! total residency never exceeds the configured budget. Accounting is
//! exported as `cache.{hits,misses,evictions,bytes}` through the
//! `obs` registry; like the server counters these record
//! unconditionally — the cache itself is opt-in.

use super::engine::ExpansionEngine;
use super::factory::McKernelConfig;
use super::feature_map::McKernel;
use super::kernel::Kernel;
use super::plan::{ExpansionPlan, FwhtDispatch};
use crate::hash::hash_rng::streams;
use crate::hash::murmur3_x64_128;
use crate::linalg::Matrix;
use crate::obs::{self, Counter, Gauge, MetricsRegistry};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Default shard count (8 strikes a balance: enough locks that the
/// server's batch loop and a worker pool rarely collide, few enough
/// that a small byte budget is not fragmented into useless slices).
pub const DEFAULT_SHARDS: usize = 8;

/// Sentinel index for the intrusive LRU list.
const NIL: usize = usize::MAX;

/// Fixed per-entry bookkeeping charge (slot struct, map entry, two
/// box headers) added on top of the key/value payload when an entry
/// is billed against the byte budget. An estimate, deliberately on
/// the generous side — the budget is a residency bound, not an
/// allocator audit.
const ENTRY_OVERHEAD: usize = 96;

/// The expansion-identity half of a cache key: one hash word covering
/// everything that determines output bits for a given input row.
///
/// Computed once per consumer (engine setup), copied into every
/// lookup. Two maps differing in any coefficient-relevant field —
/// seed, σ, kernel, dimensions, expansions — or in output treatment —
/// dispatch, normalization — get disjoint ids and therefore never
/// share entries, even inside one shared [`FeatureCache`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheKey {
    id: u64,
}

impl CacheKey {
    /// Derive the id for `config` executed under `plan`.
    pub fn new(config: &McKernelConfig, plan: &ExpansionPlan) -> CacheKey {
        let (ktag, kt) = match config.kernel {
            Kernel::Rbf => (0u64, 0u64),
            Kernel::RbfMatern { t } => (1u64, t as u64),
        };
        // Simd gets its own word: its trig rounding differs from the
        // scalar arms, so cached rows must never cross arms.
        let dispatch = match plan.dispatch() {
            FwhtDispatch::Batched => 0u64,
            FwhtDispatch::PerRow => 1u64,
            FwhtDispatch::Simd => 2u64,
        };
        let words = [
            config.input_dim as u64,
            config.expansions as u64,
            config.sigma.to_bits(),
            ktag,
            kt,
            config.seed,
            plan.padded_dim() as u64,
            dispatch,
            plan.is_normalized() as u64,
        ];
        let mut buf = [0u8; 9 * 8];
        for (i, w) in words.iter().enumerate() {
            buf[i * 8..(i + 1) * 8].copy_from_slice(&w.to_le_bytes());
        }
        let (id, _) = murmur3_x64_128(&buf, streams::CACHE);
        CacheKey { id }
    }

    /// The raw id word (stable for equal `(config, plan)` inputs).
    pub fn id(&self) -> u64 {
        self.id
    }
}

/// One cached feature row plus its verification key and LRU links.
struct Slot {
    hash: (u64, u64),
    id: u64,
    row: Box<[f32]>,
    feats: Box<[f32]>,
    prev: usize,
    next: usize,
}

impl Slot {
    fn cost(&self) -> usize {
        entry_cost(self.row.len(), self.feats.len())
    }
}

/// Byte charge for one entry with the given key/value widths.
pub fn entry_cost(row_len: usize, feat_len: usize) -> usize {
    ENTRY_OVERHEAD + 4 * (row_len + feat_len)
}

/// Bit-exact row comparison (the collision check: `to_bits` equality,
/// so `-0.0` and `0.0` are distinct keys and NaN payloads compare by
/// representation — exactly how the engine would see them).
fn rows_equal(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// What one shard-level insert did (rolled up into the counters once
/// per `execute` call).
#[derive(Default)]
struct InsertOutcome {
    evicted: u64,
    bytes_delta: i64,
}

/// One lock's worth of cache: slab-backed slots threaded on an
/// intrusive doubly-linked list (head = MRU, tail = LRU) plus a
/// hash → slot index map. All list surgery is O(1); eviction order is
/// exact, not sampled.
struct Shard {
    map: HashMap<(u64, u64), usize>,
    slots: Vec<Option<Slot>>,
    free: Vec<usize>,
    head: usize,
    tail: usize,
    bytes: usize,
}

impl Shard {
    fn new() -> Shard {
        Shard {
            map: HashMap::new(),
            slots: Vec::new(),
            free: Vec::new(),
            head: NIL,
            tail: NIL,
            bytes: 0,
        }
    }

    fn slot(&self, i: usize) -> &Slot {
        self.slots[i].as_ref().expect("linked slot occupied")
    }

    fn slot_mut(&mut self, i: usize) -> &mut Slot {
        self.slots[i].as_mut().expect("linked slot occupied")
    }

    fn detach(&mut self, i: usize) {
        let (prev, next) = {
            let s = self.slot(i);
            (s.prev, s.next)
        };
        match prev {
            NIL => self.head = next,
            p => self.slot_mut(p).next = next,
        }
        match next {
            NIL => self.tail = prev,
            n => self.slot_mut(n).prev = prev,
        }
    }

    fn push_front(&mut self, i: usize) {
        let old_head = self.head;
        {
            let s = self.slot_mut(i);
            s.prev = NIL;
            s.next = old_head;
        }
        match old_head {
            NIL => self.tail = i,
            h => self.slot_mut(h).prev = i,
        }
        self.head = i;
    }

    /// Serve a hit into `out` (verifying id + row bits first) and
    /// promote the entry to MRU. Returns false on miss — including
    /// the verified-collision case, which must not touch LRU order.
    fn get_into(&mut self, hash: (u64, u64), id: u64, row: &[f32], out: &mut [f32]) -> bool {
        let Some(&i) = self.map.get(&hash) else { return false };
        {
            let s = self.slot(i);
            if s.id != id || !rows_equal(&s.row, row) || s.feats.len() != out.len() {
                return false;
            }
            out.copy_from_slice(&s.feats);
        }
        if self.head != i {
            self.detach(i);
            self.push_front(i);
        }
        true
    }

    /// Insert (or refresh) an entry, then evict from the LRU tail
    /// until this shard is back under `budget`. Entries that alone
    /// exceed the budget are skipped — caching them would evict the
    /// whole shard for a row unlikely to repeat before its own
    /// eviction.
    fn insert(
        &mut self,
        hash: (u64, u64),
        id: u64,
        row: &[f32],
        feats: &[f32],
        budget: usize,
    ) -> InsertOutcome {
        let mut outcome = InsertOutcome::default();
        let cost = entry_cost(row.len(), feats.len());
        if cost > budget {
            return outcome;
        }
        if let Some(&i) = self.map.get(&hash) {
            // Same 128-bit hash already resident: refresh in place
            // (the common case is the same row re-inserted by a
            // concurrent miss; the pathological case is a true
            // collision, where last-writer-wins is still correct
            // because every lookup verifies the stored key).
            let old = self.slot(i).cost();
            {
                let s = self.slot_mut(i);
                s.id = id;
                s.row = row.into();
                s.feats = feats.into();
            }
            self.bytes = self.bytes - old + cost;
            outcome.bytes_delta += cost as i64 - old as i64;
            if self.head != i {
                self.detach(i);
                self.push_front(i);
            }
        } else {
            let slot = Slot {
                hash,
                id,
                row: row.into(),
                feats: feats.into(),
                prev: NIL,
                next: NIL,
            };
            let i = match self.free.pop() {
                Some(i) => {
                    self.slots[i] = Some(slot);
                    i
                }
                None => {
                    self.slots.push(Some(slot));
                    self.slots.len() - 1
                }
            };
            self.map.insert(hash, i);
            self.push_front(i);
            self.bytes += cost;
            outcome.bytes_delta += cost as i64;
        }
        while self.bytes > budget {
            let victim = self.tail;
            debug_assert_ne!(victim, NIL, "over budget with empty LRU list");
            self.detach(victim);
            let slot = self.slots[victim].take().expect("tail slot occupied");
            self.map.remove(&slot.hash);
            self.free.push(victim);
            self.bytes -= slot.cost();
            outcome.evicted += 1;
            outcome.bytes_delta -= slot.cost() as i64;
        }
        outcome
    }
}

/// Metric handles for the cache, registered under `cache.*` — the
/// same compatibility-view pattern as `coordinator::ServerStats`, so
/// a `MetricsRegistry::snapshot_json` consumer and these accessors
/// always agree.
#[derive(Debug, Clone)]
struct CacheMetrics {
    hits: Arc<Counter>,
    misses: Arc<Counter>,
    evictions: Arc<Counter>,
    bytes: Arc<Gauge>,
}

impl CacheMetrics {
    fn register(reg: &MetricsRegistry) -> CacheMetrics {
        CacheMetrics {
            hits: reg.counter("cache.hits"),
            misses: reg.counter("cache.misses"),
            evictions: reg.counter("cache.evictions"),
            bytes: reg.gauge("cache.bytes"),
        }
    }
}

/// Sharded, byte-bounded, content-addressed LRU over feature rows.
/// See the module docs for the key scheme and the bit-identity
/// argument. One instance may be shared by any number of consumers
/// and configs — entry isolation rides on the [`CacheKey`] id.
pub struct FeatureCache {
    shards: Vec<Mutex<Shard>>,
    /// Per-shard byte budget (`capacity / shards`, floor — the total
    /// can only undershoot the configured capacity, never exceed it).
    shard_budget: usize,
    capacity: usize,
    metrics: CacheMetrics,
}

impl std::fmt::Debug for FeatureCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FeatureCache")
            .field("capacity", &self.capacity)
            .field("shards", &self.shards.len())
            .field("bytes", &self.bytes())
            .finish()
    }
}

impl FeatureCache {
    /// Cache with `capacity_bytes` total budget, [`DEFAULT_SHARDS`]
    /// shards, reporting into the global registry.
    pub fn new(capacity_bytes: usize) -> FeatureCache {
        FeatureCache::with_registry(capacity_bytes, DEFAULT_SHARDS, obs::global())
    }

    /// Fully-specified constructor — the test-isolation seam (inject
    /// a private registry for deterministic counts, shards = 1 for
    /// exact whole-cache LRU order).
    pub fn with_registry(
        capacity_bytes: usize,
        shards: usize,
        registry: &MetricsRegistry,
    ) -> FeatureCache {
        let shards = shards.max(1);
        FeatureCache {
            shards: (0..shards).map(|_| Mutex::new(Shard::new())).collect(),
            shard_budget: capacity_bytes / shards,
            capacity: capacity_bytes,
            metrics: CacheMetrics::register(registry),
        }
    }

    /// Configured total byte budget.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Current resident payload bytes across all shards.
    pub fn bytes(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().bytes).sum()
    }

    /// Current entry count across all shards.
    pub fn entries(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().map.len()).sum()
    }

    /// Lifetime hit count.
    pub fn hits(&self) -> u64 {
        self.metrics.hits.get()
    }

    /// Lifetime miss count.
    pub fn misses(&self) -> u64 {
        self.metrics.misses.get()
    }

    /// Lifetime eviction count.
    pub fn evictions(&self) -> u64 {
        self.metrics.evictions.get()
    }

    fn row_hash(&self, key: CacheKey, row: &[f32], buf: &mut Vec<u8>) -> (u64, u64) {
        buf.clear();
        buf.extend_from_slice(&key.id.to_le_bytes());
        for v in row {
            buf.extend_from_slice(&v.to_bits().to_le_bytes());
        }
        murmur3_x64_128(buf, streams::CACHE)
    }

    fn shard_of(&self, hash: (u64, u64)) -> usize {
        // High word of the second hash half: the map key uses the full
        // 128 bits, so reusing low bits for shard choice is harmless,
        // but the high word keeps the two selections independent.
        ((hash.1 >> 32) as usize) % self.shards.len()
    }

    /// Cache-fronted [`ExpansionEngine::execute`]: serve every row
    /// already resident (bit-verbatim), gather the misses into one
    /// engine call, scatter the fresh rows back into `out`, and insert
    /// them. Bit-identical to the uncached engine for any mix of hits
    /// and misses — the engine pipeline is invariant to row grouping.
    #[allow(clippy::too_many_arguments)] // mirrors ExpansionEngine::execute + the key
    pub fn execute(
        &self,
        key: CacheKey,
        engine: &mut ExpansionEngine,
        map: &McKernel,
        xs: &[f32],
        rows: usize,
        src_cols: usize,
        out: &mut [f32],
    ) {
        let fd = engine.plan().feature_dim();
        assert_eq!(xs.len(), rows * src_cols, "input length");
        assert_eq!(out.len(), rows * fd, "output length");
        if rows == 0 {
            return;
        }
        let mut keybuf: Vec<u8> = Vec::with_capacity(8 + src_cols * 4);
        let mut misses: Vec<(usize, (u64, u64))> = Vec::new();
        let mut hits = 0u64;
        for r in 0..rows {
            let row = &xs[r * src_cols..(r + 1) * src_cols];
            let hash = self.row_hash(key, row, &mut keybuf);
            let served = self.shards[self.shard_of(hash)].lock().unwrap().get_into(
                hash,
                key.id,
                row,
                &mut out[r * fd..(r + 1) * fd],
            );
            if served {
                hits += 1;
            } else {
                misses.push((r, hash));
            }
        }
        let miss_count = misses.len() as u64;
        if !misses.is_empty() {
            let mut miss_x: Vec<f32> = Vec::with_capacity(misses.len() * src_cols);
            for &(r, _) in &misses {
                miss_x.extend_from_slice(&xs[r * src_cols..(r + 1) * src_cols]);
            }
            let mut miss_out = vec![0.0f32; misses.len() * fd];
            engine.execute(map, &miss_x, misses.len(), src_cols, &mut miss_out);
            let mut evicted = 0u64;
            let mut bytes_delta = 0i64;
            for (k, &(r, hash)) in misses.iter().enumerate() {
                let feats = &miss_out[k * fd..(k + 1) * fd];
                out[r * fd..(r + 1) * fd].copy_from_slice(feats);
                let row = &xs[r * src_cols..(r + 1) * src_cols];
                let outcome = self.shards[self.shard_of(hash)].lock().unwrap().insert(
                    hash,
                    key.id,
                    row,
                    feats,
                    self.shard_budget,
                );
                evicted += outcome.evicted;
                bytes_delta += outcome.bytes_delta;
            }
            if evicted > 0 {
                self.metrics.evictions.add(evicted);
            }
            self.metrics.bytes.add(bytes_delta);
        }
        self.metrics.hits.add(hits);
        self.metrics.misses.add(miss_count);
    }

    /// Matrix-shaped convenience over [`FeatureCache::execute`].
    pub fn execute_matrix(
        &self,
        key: CacheKey,
        engine: &mut ExpansionEngine,
        map: &McKernel,
        x: &Matrix,
        out: &mut Matrix,
    ) {
        assert_eq!(out.shape(), (x.rows(), engine.plan().feature_dim()), "output shape");
        let (rows, src_cols) = x.shape();
        self.execute(key, engine, map, x.data(), rows, src_cols, out.data_mut());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mckernel::McKernelFactory;

    fn map(dim: usize) -> McKernel {
        McKernelFactory::new(dim).expansions(1).sigma(1.0).rbf().seed(5).build()
    }

    fn cache(capacity: usize) -> (FeatureCache, MetricsRegistry) {
        let reg = MetricsRegistry::new();
        let c = FeatureCache::with_registry(capacity, 1, &reg);
        (c, reg)
    }

    #[test]
    fn repeat_rows_hit_and_match_engine_output() {
        let m = map(12);
        let fd = m.feature_dim();
        let mut eng = ExpansionEngine::new(&m, 4);
        let key = CacheKey::new(m.config(), eng.plan());
        let (c, _) = cache(1 << 20);
        let xs: Vec<f32> = (0..3 * 12).map(|i| (i % 7) as f32 * 0.1).collect();
        let mut want = vec![0.0f32; 3 * fd];
        ExpansionEngine::new(&m, 4).execute(&m, &xs, 3, 12, &mut want);
        let mut got = vec![0.0f32; 3 * fd];
        c.execute(key, &mut eng, &m, &xs, 3, 12, &mut got);
        assert_eq!(got, want);
        assert_eq!((c.hits(), c.misses()), (0, 3));
        got.fill(0.0);
        c.execute(key, &mut eng, &m, &xs, 3, 12, &mut got);
        assert_eq!(got, want);
        assert_eq!((c.hits(), c.misses()), (3, 3));
        assert_eq!(c.entries(), 3);
    }

    #[test]
    fn oversized_entries_are_not_cached() {
        let m = map(12);
        let fd = m.feature_dim();
        let mut eng = ExpansionEngine::new(&m, 1);
        let key = CacheKey::new(m.config(), eng.plan());
        // budget below one entry's cost: nothing sticks, nothing evicts
        let (c, _) = cache(entry_cost(12, fd) - 1);
        let xs: Vec<f32> = (0..12).map(|i| i as f32).collect();
        let mut out = vec![0.0f32; fd];
        c.execute(key, &mut eng, &m, &xs, 1, 12, &mut out);
        c.execute(key, &mut eng, &m, &xs, 1, 12, &mut out);
        assert_eq!((c.hits(), c.misses(), c.evictions()), (0, 2, 0));
        assert_eq!((c.entries(), c.bytes()), (0, 0));
    }

    #[test]
    fn cache_ids_separate_configs_and_plans() {
        let a = map(12);
        let b = McKernelFactory::new(12).expansions(1).sigma(1.0).rbf().seed(6).build();
        let pa = ExpansionPlan::new(a.config(), 4);
        let pb = ExpansionPlan::new(b.config(), 4);
        assert_ne!(CacheKey::new(a.config(), &pa), CacheKey::new(b.config(), &pb));
        // lanes excluded: different row hints share an id
        let pa_wide = ExpansionPlan::new(a.config(), 64);
        assert_eq!(CacheKey::new(a.config(), &pa), CacheKey::new(a.config(), &pa_wide));
        // normalization reaches the output bits, so it splits the id
        let pn = ExpansionPlan::new(a.config(), 4).normalized();
        assert_ne!(CacheKey::new(a.config(), &pa), CacheKey::new(a.config(), &pn));
        // so does the dispatch arm: SIMD trig rounds differently from
        // scalar, so the three arms get three disjoint ids
        use crate::mckernel::plan::DispatchForce;
        let ps = ExpansionPlan::new_forced(a.config(), 4, DispatchForce::Scalar);
        let pv = ExpansionPlan::new_forced(a.config(), 4, DispatchForce::Simd);
        let pr = ExpansionPlan::per_row(a.config());
        assert_ne!(CacheKey::new(a.config(), &ps), CacheKey::new(a.config(), &pv));
        assert_ne!(CacheKey::new(a.config(), &pv), CacheKey::new(a.config(), &pr));
        assert_ne!(CacheKey::new(a.config(), &ps), CacheKey::new(a.config(), &pr));
    }

    #[test]
    fn zero_rows_is_a_no_op() {
        let m = map(8);
        let mut eng = ExpansionEngine::new(&m, 2);
        let key = CacheKey::new(m.config(), eng.plan());
        let (c, _) = cache(1 << 16);
        let mut out: Vec<f32> = vec![];
        c.execute(key, &mut eng, &m, &[], 0, 8, &mut out);
        assert_eq!((c.hits(), c.misses()), (0, 0));
    }
}
