//! The three diagonal operators of Eq. 8, hash-derived so they are
//! never stored with a model (paper §3).

use super::kernel::Kernel;
use crate::hash::hash_rng::streams;
use crate::hash::HashRng;
use crate::rand::BoxMuller;

/// Binary diagonal `B`: entries `±1` uniform, "extract bits from
/// h(k, x)" — here, bit 0 of the k-th hash word.
pub fn binary_diag(root: &HashRng, n: usize) -> Vec<f32> {
    let rng = root.derive(streams::BINARY);
    (0..n as u64).map(|k| rng.at_sign(k)).collect()
}

/// Gaussian diagonal `G`: i.i.d. N(0,1) via Box–Muller on hash draws.
pub fn gauss_diag(root: &HashRng, n: usize) -> Vec<f32> {
    let rng = root.derive(streams::GAUSS);
    (0..n as u64).map(|k| BoxMuller::at(&rng, k) as f32).collect()
}

/// Calibration diagonal `C` for the chosen kernel, already folded
/// together with the global `1/(σ√n)` factor of Eq. 8 and the
/// `1/‖g‖` row-norm correction of Fastfood:
///
/// ```text
/// scale_i = r_i / (‖g‖₂ · σ · √n)
/// ```
///
/// where `r_i` is the kernel's radial draw ([`Kernel::radius`]). With
/// this choice the rows of `Ẑ` have norms distributed exactly like the
/// rows of the dense Gaussian matrix `W ~ N(0, σ⁻²)` that Random
/// Kitchen Sinks would sample.
pub fn calibration_diag(
    root: &HashRng,
    n: usize,
    kernel: Kernel,
    sigma: f64,
    g: &[f32],
) -> Vec<f32> {
    assert_eq!(g.len(), n);
    assert!(sigma > 0.0, "sigma must be positive");
    let g_norm = g.iter().map(|v| (*v as f64) * (*v as f64)).sum::<f64>().sqrt();
    assert!(g_norm > 0.0, "degenerate Gaussian diagonal");
    let cal = root.derive(streams::CALIBRATION);
    let denom = g_norm * sigma * (n as f64).sqrt();
    (0..n)
        .map(|i| {
            // Independent derived streams per entry keep each radius
            // i.i.d. while staying random-access (order-free).
            let entry = cal.derive(i as u64);
            let mut bm = BoxMuller::new(entry.derive(0));
            let mut uni = entry.derive(1);
            (kernel.radius(n, &mut bm, &mut uni) / denom) as f32
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn root(seed: u64) -> HashRng {
        HashRng::new(seed, 0)
    }

    #[test]
    fn binary_entries_are_signs() {
        let b = binary_diag(&root(1), 1024);
        assert_eq!(b.len(), 1024);
        assert!(b.iter().all(|&v| v == 1.0 || v == -1.0));
        // roughly balanced
        let sum: f32 = b.iter().sum();
        assert!(sum.abs() < 120.0, "sum {sum}");
    }

    #[test]
    fn gauss_entries_standard_normal() {
        let g = gauss_diag(&root(2), 50_000);
        let mean: f64 = g.iter().map(|v| *v as f64).sum::<f64>() / g.len() as f64;
        let var: f64 = g.iter().map(|v| (*v as f64 - mean).powi(2)).sum::<f64>() / g.len() as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn diagonals_deterministic_per_seed() {
        let a = binary_diag(&root(3), 256);
        let b = binary_diag(&root(3), 256);
        assert_eq!(a, b);
        let c = binary_diag(&root(4), 256);
        assert_ne!(a, c);
        let g1 = gauss_diag(&root(3), 64);
        let g2 = gauss_diag(&root(3), 64);
        assert_eq!(g1, g2);
    }

    #[test]
    fn prefix_stability() {
        // Random access ⇒ the first k entries don't depend on n.
        let short = gauss_diag(&root(5), 16);
        let long = gauss_diag(&root(5), 256);
        assert_eq!(&short[..], &long[..16]);
    }

    #[test]
    fn calibration_positive_and_scaled() {
        let n = 64;
        let r = root(6);
        let g = gauss_diag(&r, n);
        let c = calibration_diag(&r, n, Kernel::Rbf, 1.0, &g);
        assert_eq!(c.len(), n);
        assert!(c.iter().all(|&v| v > 0.0 && v.is_finite()));
        // E[r_i] ≈ √n ⇒ E[scale_i] ≈ 1/(‖g‖σ). With ‖g‖ ≈ √n:
        let g_norm = g.iter().map(|v| (*v as f64).powi(2)).sum::<f64>().sqrt();
        let mean: f64 = c.iter().map(|v| *v as f64).sum::<f64>() / n as f64;
        let expect = 1.0 / (g_norm * 1.0);
        assert!((mean - expect).abs() < 0.25 * expect, "mean {mean} expect {expect}");
    }

    #[test]
    fn calibration_sigma_inverse_scaling() {
        let n = 32;
        let r = root(7);
        let g = gauss_diag(&r, n);
        let c1 = calibration_diag(&r, n, Kernel::Rbf, 1.0, &g);
        let c2 = calibration_diag(&r, n, Kernel::Rbf, 2.0, &g);
        for (a, b) in c1.iter().zip(c2.iter()) {
            assert!((a / b - 2.0).abs() < 1e-4);
        }
    }

    #[test]
    fn calibration_kernel_changes_distribution() {
        let n = 32;
        let r = root(8);
        let g = gauss_diag(&r, n);
        let rbf = calibration_diag(&r, n, Kernel::Rbf, 1.0, &g);
        let mat = calibration_diag(&r, n, Kernel::RbfMatern { t: 40 }, 1.0, &g);
        assert_ne!(rbf, mat);
    }

    #[test]
    #[should_panic]
    fn zero_sigma_rejected() {
        let r = root(9);
        let g = gauss_diag(&r, 8);
        calibration_diag(&r, 8, Kernel::Rbf, 0.0, &g);
    }
}
