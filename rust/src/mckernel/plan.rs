//! Compiled expansion plans: every layout/dispatch decision of the
//! feature pipeline, resolved **once** per (config, row-count hint)
//! instead of ad hoc at each call site.
//!
//! Prior to this module the batch-vs-per-row fallback, the tile lane
//! count, the scratch sizing and the normalization folding were each
//! re-derived independently by `McKernel`, the `Featurizer`, the
//! shard trainer, the KRR solver, the prefetch pipeline and the
//! feature server. An [`ExpansionPlan`] pins them all down up front;
//! `mckernel::engine::ExpansionEngine` is the single executor that
//! carries a plan plus its exactly-sized scratch pool. Future
//! backends (SIMD intrinsics, GPU, quantized features) add a
//! [`FwhtDispatch`] variant here and an executor arm there — no
//! consumer changes.

use super::factory::McKernelConfig;
use super::feature_map::McKernel;
use crate::fwht::tile_lanes;
use crate::util::pow2::next_pow2;

/// Which execution path the plan compiled to — **the** batch-vs-row
/// fallback decision point. Nothing outside `mckernel::{plan, engine}`
/// may choose an FWHT engine for the expansion pipeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FwhtDispatch {
    /// Column-major row-tiles through `fwht::batch` with the
    /// polynomial trig map — the mini-batch hot path.
    Batched,
    /// Per-row cache-blocked `fwht::optimized` with libm trig — the
    /// correctness oracle, and the fallback when the transform is too
    /// large to tile (`tile_lanes(n) == 1`: lane-1 transposes would
    /// only add copies around the per-row engine's own cache
    /// blocking).
    PerRow,
}

/// A compiled execution plan for one feature-map geometry.
///
/// Built from a [`McKernelConfig`] plus a row-count hint; resolves
/// padding, tile lanes, the FWHT dispatch, exact scratch sizes and
/// whether the `1/√(n·E)` estimator normalization is folded into the
/// feature write. Plans are cheap plain data (no coefficient
/// materialization) and deterministic: equal inputs compile to equal
/// plans on any machine.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ExpansionPlan {
    input_dim: usize,
    padded_dim: usize,
    expansions: usize,
    lanes: usize,
    dispatch: FwhtDispatch,
    normalized: bool,
}

impl ExpansionPlan {
    /// Compile a plan for `config`, expecting calls of about
    /// `rows_hint` rows (the hint caps the tile width so scratch never
    /// outgrows the workload; any actual row count still executes
    /// correctly — the batched pipeline is invariant to how rows are
    /// grouped into tiles).
    ///
    /// This constructor is the codebase's **only** batch-vs-per-row
    /// dispatch decision.
    pub fn new(config: &McKernelConfig, rows_hint: usize) -> ExpansionPlan {
        config.validate();
        let n = next_pow2(config.input_dim);
        let full = tile_lanes(n);
        let (dispatch, lanes) = if full <= 1 {
            (FwhtDispatch::PerRow, 1)
        } else {
            (FwhtDispatch::Batched, full.min(rows_hint.max(1)))
        };
        ExpansionPlan {
            input_dim: config.input_dim,
            padded_dim: n,
            expansions: config.expansions,
            lanes,
            dispatch,
            normalized: false,
        }
    }

    /// Compile a plan forced onto the per-row libm path — the
    /// correctness oracle the batched pipeline is validated against,
    /// and the per-row baseline the bench harness times. An explicit
    /// override, not a second decision point.
    pub fn per_row(config: &McKernelConfig) -> ExpansionPlan {
        config.validate();
        ExpansionPlan {
            input_dim: config.input_dim,
            padded_dim: next_pow2(config.input_dim),
            expansions: config.expansions,
            lanes: 1,
            dispatch: FwhtDispatch::PerRow,
            normalized: false,
        }
    }

    /// Fold the `1/√(n·E)` Rahimi–Recht estimator scaling into the
    /// feature write (one pass over the output, no post-scaling pass).
    pub fn normalized(mut self) -> ExpansionPlan {
        self.normalized = true;
        self
    }

    /// Raw input dimension `S`.
    pub fn input_dim(&self) -> usize {
        self.input_dim
    }

    /// Padded dimension `[S]₂`.
    pub fn padded_dim(&self) -> usize {
        self.padded_dim
    }

    /// Number of expansions `E`.
    pub fn expansions(&self) -> usize {
        self.expansions
    }

    /// Output feature dimension `2·[S]₂·E`.
    pub fn feature_dim(&self) -> usize {
        2 * self.padded_dim * self.expansions
    }

    /// Rows per tile (1 on the per-row path).
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// The compiled execution path.
    pub fn dispatch(&self) -> FwhtDispatch {
        self.dispatch
    }

    /// Whether the `1/√(n·E)` normalization is folded into the write.
    pub fn is_normalized(&self) -> bool {
        self.normalized
    }

    /// The scale folded into every feature write (`1.0` when not
    /// normalized).
    pub fn post_scale(&self) -> f32 {
        if self.normalized {
            1.0 / ((self.padded_dim * self.expansions) as f32).sqrt()
        } else {
            1.0
        }
    }

    /// Exact scratch requirement of the executor, in f32 elements:
    /// three `(n, lanes)` tiles for the batched path (transpose-in /
    /// Ẑx / sine; the first doubles as the cosine buffer), or the
    /// `(padded, tmp)` pair for the per-row path. The engine allocates
    /// exactly this once and never reallocates during `execute`.
    pub fn scratch_floats(&self) -> usize {
        match self.dispatch {
            FwhtDispatch::Batched => 3 * self.padded_dim * self.lanes,
            FwhtDispatch::PerRow => 2 * self.padded_dim,
        }
    }

    /// Stable short identifier for this plan's compiled shape — the
    /// key the engine's observability metrics are grouped under
    /// (`engine.<fingerprint>.*`), e.g. `s784_n1024_e2_b32` for a
    /// batched 784→1024 two-expansion plan tiling 32 lanes, with a
    /// `_norm` suffix when normalization is folded in. Equal plans
    /// fingerprint equally on any machine.
    pub fn fingerprint(&self) -> String {
        let d = match self.dispatch {
            FwhtDispatch::Batched => "b",
            FwhtDispatch::PerRow => "r",
        };
        let norm = if self.normalized { "_norm" } else { "" };
        format!(
            "s{}_n{}_e{}_{}{}{}",
            self.input_dim, self.padded_dim, self.expansions, d, self.lanes, norm
        )
    }

    /// Whether this plan describes `map`'s geometry (guards against
    /// executing a plan compiled for a different feature map).
    pub fn matches(&self, map: &McKernel) -> bool {
        self.input_dim == map.input_dim()
            && self.padded_dim == map.padded_dim()
            && self.expansions == map.expansions()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mckernel::kernel::Kernel;

    fn config(input_dim: usize) -> McKernelConfig {
        McKernelConfig {
            input_dim,
            expansions: 2,
            sigma: 1.0,
            kernel: Kernel::Rbf,
            seed: 1,
        }
    }

    #[test]
    fn small_geometry_compiles_to_batched() {
        let p = ExpansionPlan::new(&config(784), 64);
        assert_eq!(p.padded_dim(), 1024);
        assert_eq!(p.feature_dim(), 2 * 1024 * 2);
        assert_eq!(p.dispatch(), FwhtDispatch::Batched);
        assert_eq!(p.lanes(), tile_lanes(1024));
        assert_eq!(p.scratch_floats(), 3 * 1024 * p.lanes());
        assert_eq!(p.post_scale(), 1.0);
    }

    #[test]
    fn rows_hint_caps_lanes_but_not_dispatch() {
        let p = ExpansionPlan::new(&config(784), 4);
        assert_eq!(p.dispatch(), FwhtDispatch::Batched);
        assert_eq!(p.lanes(), 4);
        // hint 0 degrades to 1 lane, still batched
        let p0 = ExpansionPlan::new(&config(784), 0);
        assert_eq!(p0.lanes(), 1);
        assert_eq!(p0.dispatch(), FwhtDispatch::Batched);
    }

    #[test]
    fn huge_transform_compiles_to_per_row() {
        // next_pow2(40_000) = 65536 ⇒ tile_lanes == 1 ⇒ per-row path
        let p = ExpansionPlan::new(&config(40_000), 64);
        assert_eq!(p.dispatch(), FwhtDispatch::PerRow);
        assert_eq!(p.lanes(), 1);
        assert_eq!(p.scratch_floats(), 2 * 65536);
    }

    #[test]
    fn per_row_override_and_normalization_fold() {
        let p = ExpansionPlan::per_row(&config(784));
        assert_eq!(p.dispatch(), FwhtDispatch::PerRow);
        assert_eq!(p.scratch_floats(), 2 * 1024);
        assert!(!p.is_normalized());
        let pn = p.normalized();
        assert!(pn.is_normalized());
        let want = 1.0 / ((1024.0f32 * 2.0).sqrt());
        assert_eq!(pn.post_scale(), want);
    }

    #[test]
    fn fingerprint_encodes_shape_and_dispatch() {
        let p = ExpansionPlan::new(&config(784), 4);
        assert_eq!(p.fingerprint(), "s784_n1024_e2_b4");
        let r = ExpansionPlan::per_row(&config(784));
        assert_eq!(r.fingerprint(), "s784_n1024_e2_r1");
        assert_eq!(r.normalized().fingerprint(), "s784_n1024_e2_r1_norm");
        // equal plans fingerprint equally; distinct shapes don't collide
        assert_eq!(
            ExpansionPlan::new(&config(784), 4).fingerprint(),
            ExpansionPlan::new(&config(784), 4).fingerprint()
        );
        assert_ne!(
            ExpansionPlan::new(&config(300), 4).fingerprint(),
            ExpansionPlan::new(&config(784), 4).fingerprint()
        );
    }

    #[test]
    fn plans_are_deterministic_plain_data() {
        let a = ExpansionPlan::new(&config(300), 10);
        let b = ExpansionPlan::new(&config(300), 10);
        assert_eq!(a, b);
        assert_ne!(a, ExpansionPlan::new(&config(300), 11));
    }
}
