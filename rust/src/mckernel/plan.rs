//! Compiled expansion plans: every layout/dispatch decision of the
//! feature pipeline, resolved **once** per (config, row-count hint)
//! instead of ad hoc at each call site.
//!
//! Prior to this module the batch-vs-per-row fallback, the tile lane
//! count, the scratch sizing and the normalization folding were each
//! re-derived independently by `McKernel`, the `Featurizer`, the
//! shard trainer, the KRR solver, the prefetch pipeline and the
//! feature server. An [`ExpansionPlan`] pins them all down up front;
//! `mckernel::engine::ExpansionEngine` is the single executor that
//! carries a plan plus its exactly-sized scratch pool. The SIMD
//! backend (PR 9) is exactly that shape: [`FwhtDispatch::Simd`] here,
//! one executor arm there, no consumer changes. Future backends (GPU,
//! quantized features) follow the same seam.

use std::sync::atomic::{AtomicU8, Ordering};

use super::factory::McKernelConfig;
use super::feature_map::McKernel;
use crate::fwht::tile_lanes;
use crate::util::pow2::next_pow2;

/// Which execution path the plan compiled to — **the** dispatch
/// decision point. Nothing outside `mckernel::{plan, engine}` may
/// choose an FWHT engine for the expansion pipeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FwhtDispatch {
    /// Column-major row-tiles through `fwht::batch` with the
    /// polynomial trig map — the scalar mini-batch hot path.
    Batched,
    /// Per-row cache-blocked `fwht::optimized` with libm trig — the
    /// correctness oracle, and the fallback when the transform is too
    /// large to tile (`tile_lanes(n) == 1`: lane-1 transposes would
    /// only add copies around the per-row engine's own cache
    /// blocking).
    PerRow,
    /// The tiled path driven through explicit AVX2/NEON intrinsics
    /// (`fwht::simd` butterflies + `fastmath::sin_cos_batch_simd`).
    /// Auto-selected when the CPU supports a vector extension; the
    /// kernels themselves carry scalar fallbacks, so a *forced* Simd
    /// plan still executes correctly on machines without one.
    Simd,
}

/// The forced-dispatch knob: overrides the plan's tiled-path choice
/// for tests, the CLI (`--dispatch`), and CI matrix legs. `Auto` is
/// runtime feature detection; `Scalar`/`Simd` pin the arm. The
/// too-large-to-tile `PerRow` fallback and the explicit
/// [`ExpansionPlan::per_row`] oracle are **not** affected — forcing
/// selects between tiled arms, it never turns the oracle into
/// something else.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DispatchForce {
    /// Pick `Simd` when the CPU supports it, else `Batched`.
    Auto,
    /// Always the scalar `Batched` arm.
    Scalar,
    /// Always the `Simd` arm (its kernels fall back internally on
    /// non-vector CPUs, so the arm's selection logic is exercised
    /// everywhere).
    Simd,
}

impl DispatchForce {
    /// Parse a knob value (CLI `--dispatch`, `MCKERNEL_DISPATCH` env).
    pub fn parse(s: &str) -> Option<DispatchForce> {
        match s {
            "auto" => Some(DispatchForce::Auto),
            "scalar" | "batched" => Some(DispatchForce::Scalar),
            "simd" => Some(DispatchForce::Simd),
            _ => None,
        }
    }

    /// Stable name (CLI help, bench labels).
    pub fn name(self) -> &'static str {
        match self {
            DispatchForce::Auto => "auto",
            DispatchForce::Scalar => "scalar",
            DispatchForce::Simd => "simd",
        }
    }
}

const FORCE_UNSET: u8 = u8::MAX;
static FORCE: AtomicU8 = AtomicU8::new(FORCE_UNSET);

fn encode_force(f: DispatchForce) -> u8 {
    match f {
        DispatchForce::Auto => 0,
        DispatchForce::Scalar => 1,
        DispatchForce::Simd => 2,
    }
}

fn decode_force(v: u8) -> DispatchForce {
    match v {
        1 => DispatchForce::Scalar,
        2 => DispatchForce::Simd,
        _ => DispatchForce::Auto,
    }
}

/// The process-wide dispatch force consulted by [`ExpansionPlan::new`].
/// Seeded lazily from the `MCKERNEL_DISPATCH` environment variable
/// (`auto` | `scalar` | `simd`; unset or unparseable → `Auto`) so CI
/// matrix legs can pin the arm without plumbing a flag through every
/// consumer; overridable at runtime via [`set_dispatch_force`].
pub fn dispatch_force() -> DispatchForce {
    let v = FORCE.load(Ordering::Relaxed);
    if v != FORCE_UNSET {
        return decode_force(v);
    }
    let f = std::env::var("MCKERNEL_DISPATCH")
        .ok()
        .and_then(|s| DispatchForce::parse(&s))
        .unwrap_or(DispatchForce::Auto);
    // Benign race: every contender reads the same environment.
    FORCE.store(encode_force(f), Ordering::Relaxed);
    f
}

/// Set the process-wide dispatch force (CLI `--dispatch`, tests).
/// Affects plans compiled *after* the call; existing plans keep the
/// arm they compiled to.
pub fn set_dispatch_force(f: DispatchForce) {
    FORCE.store(encode_force(f), Ordering::Relaxed);
}

/// A compiled execution plan for one feature-map geometry.
///
/// Built from a [`McKernelConfig`] plus a row-count hint; resolves
/// padding, tile lanes, the FWHT dispatch, exact scratch sizes and
/// whether the `1/√(n·E)` estimator normalization is folded into the
/// feature write. Plans are cheap plain data (no coefficient
/// materialization) and deterministic: equal inputs compile to equal
/// plans on any machine.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ExpansionPlan {
    input_dim: usize,
    padded_dim: usize,
    expansions: usize,
    lanes: usize,
    dispatch: FwhtDispatch,
    normalized: bool,
}

impl ExpansionPlan {
    /// Compile a plan for `config`, expecting calls of about
    /// `rows_hint` rows (the hint caps the tile width so scratch never
    /// outgrows the workload; any actual row count still executes
    /// correctly — the batched pipeline is invariant to how rows are
    /// grouped into tiles).
    ///
    /// This constructor (via [`ExpansionPlan::new_forced`]) is the
    /// codebase's **only** dispatch decision; it honors the
    /// process-wide [`dispatch_force`] knob.
    pub fn new(config: &McKernelConfig, rows_hint: usize) -> ExpansionPlan {
        ExpansionPlan::new_forced(config, rows_hint, dispatch_force())
    }

    /// [`ExpansionPlan::new`] with an explicit force, bypassing the
    /// process-wide knob — what the differential tests use to pin both
    /// tiled arms side by side without global state.
    ///
    /// The too-large-to-tile geometry (`tile_lanes(n) == 1`) compiles
    /// to `PerRow` under every force: there is no tiled arm to choose
    /// between when tiling itself is off the table.
    pub fn new_forced(
        config: &McKernelConfig,
        rows_hint: usize,
        force: DispatchForce,
    ) -> ExpansionPlan {
        config.validate();
        let n = next_pow2(config.input_dim);
        let full = tile_lanes(n);
        let (dispatch, lanes) = if full <= 1 {
            (FwhtDispatch::PerRow, 1)
        } else {
            let arm = match force {
                DispatchForce::Scalar => FwhtDispatch::Batched,
                DispatchForce::Simd => FwhtDispatch::Simd,
                DispatchForce::Auto => {
                    if crate::util::simd::available() {
                        FwhtDispatch::Simd
                    } else {
                        FwhtDispatch::Batched
                    }
                }
            };
            (arm, full.min(rows_hint.max(1)))
        };
        ExpansionPlan {
            input_dim: config.input_dim,
            padded_dim: n,
            expansions: config.expansions,
            lanes,
            dispatch,
            normalized: false,
        }
    }

    /// Compile a plan forced onto the per-row libm path — the
    /// correctness oracle the batched pipeline is validated against,
    /// and the per-row baseline the bench harness times. An explicit
    /// override, not a second decision point.
    pub fn per_row(config: &McKernelConfig) -> ExpansionPlan {
        config.validate();
        ExpansionPlan {
            input_dim: config.input_dim,
            padded_dim: next_pow2(config.input_dim),
            expansions: config.expansions,
            lanes: 1,
            dispatch: FwhtDispatch::PerRow,
            normalized: false,
        }
    }

    /// Fold the `1/√(n·E)` Rahimi–Recht estimator scaling into the
    /// feature write (one pass over the output, no post-scaling pass).
    pub fn normalized(mut self) -> ExpansionPlan {
        self.normalized = true;
        self
    }

    /// Raw input dimension `S`.
    pub fn input_dim(&self) -> usize {
        self.input_dim
    }

    /// Padded dimension `[S]₂`.
    pub fn padded_dim(&self) -> usize {
        self.padded_dim
    }

    /// Number of expansions `E`.
    pub fn expansions(&self) -> usize {
        self.expansions
    }

    /// Output feature dimension `2·[S]₂·E`.
    pub fn feature_dim(&self) -> usize {
        2 * self.padded_dim * self.expansions
    }

    /// Rows per tile (1 on the per-row path).
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// The compiled execution path.
    pub fn dispatch(&self) -> FwhtDispatch {
        self.dispatch
    }

    /// Whether the plan compiled to a tiled arm (`Batched` or `Simd`)
    /// rather than the per-row fallback/oracle.
    pub fn is_tiled(&self) -> bool {
        self.dispatch != FwhtDispatch::PerRow
    }

    /// Whether the `1/√(n·E)` normalization is folded into the write.
    pub fn is_normalized(&self) -> bool {
        self.normalized
    }

    /// The scale folded into every feature write (`1.0` when not
    /// normalized).
    pub fn post_scale(&self) -> f32 {
        if self.normalized {
            1.0 / ((self.padded_dim * self.expansions) as f32).sqrt()
        } else {
            1.0
        }
    }

    /// Exact scratch requirement of the executor, in f32 elements:
    /// three `(n, lanes)` tiles for the tiled paths (transpose-in /
    /// Ẑx / sine; the first doubles as the cosine buffer — Simd shares
    /// the layout, it only changes the kernels), or the `(padded, tmp)`
    /// pair for the per-row path. The engine allocates exactly this
    /// once and never reallocates during `execute`.
    pub fn scratch_floats(&self) -> usize {
        match self.dispatch {
            FwhtDispatch::Batched | FwhtDispatch::Simd => 3 * self.padded_dim * self.lanes,
            FwhtDispatch::PerRow => 2 * self.padded_dim,
        }
    }

    /// Stable short identifier for this plan's compiled shape — the
    /// key the engine's observability metrics are grouped under
    /// (`engine.<fingerprint>.*`), e.g. `s784_n1024_e2_b32` for a
    /// batched 784→1024 two-expansion plan tiling 32 lanes, with a
    /// `_norm` suffix when normalization is folded in. The dispatch
    /// tag (`b` / `r` / `s`) keeps metrics and cache keys from
    /// colliding across arms whose rounding differs. Equal plans
    /// fingerprint equally on any machine.
    pub fn fingerprint(&self) -> String {
        let d = match self.dispatch {
            FwhtDispatch::Batched => "b",
            FwhtDispatch::PerRow => "r",
            FwhtDispatch::Simd => "s",
        };
        let norm = if self.normalized { "_norm" } else { "" };
        format!(
            "s{}_n{}_e{}_{}{}{}",
            self.input_dim, self.padded_dim, self.expansions, d, self.lanes, norm
        )
    }

    /// Whether this plan describes `map`'s geometry (guards against
    /// executing a plan compiled for a different feature map).
    pub fn matches(&self, map: &McKernel) -> bool {
        self.input_dim == map.input_dim()
            && self.padded_dim == map.padded_dim()
            && self.expansions == map.expansions()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mckernel::kernel::Kernel;

    fn config(input_dim: usize) -> McKernelConfig {
        McKernelConfig {
            input_dim,
            expansions: 2,
            sigma: 1.0,
            kernel: Kernel::Rbf,
            seed: 1,
        }
    }

    #[test]
    fn small_geometry_compiles_to_a_tiled_arm() {
        // `new` honors the process-wide force (CI pins it via
        // MCKERNEL_DISPATCH), so assert the force-invariant facts here
        // and pin exact arms with `new_forced` below.
        let p = ExpansionPlan::new(&config(784), 64);
        assert_eq!(p.padded_dim(), 1024);
        assert_eq!(p.feature_dim(), 2 * 1024 * 2);
        assert!(p.is_tiled());
        assert_eq!(p.lanes(), tile_lanes(1024));
        assert_eq!(p.scratch_floats(), 3 * 1024 * p.lanes());
        assert_eq!(p.post_scale(), 1.0);
    }

    #[test]
    fn forced_dispatch_pins_the_tiled_arm() {
        let s = ExpansionPlan::new_forced(&config(784), 64, DispatchForce::Scalar);
        assert_eq!(s.dispatch(), FwhtDispatch::Batched);
        let v = ExpansionPlan::new_forced(&config(784), 64, DispatchForce::Simd);
        assert_eq!(v.dispatch(), FwhtDispatch::Simd);
        // Simd shares the tiled layout: same lanes, same scratch.
        assert_eq!(v.lanes(), s.lanes());
        assert_eq!(v.scratch_floats(), s.scratch_floats());
        // Auto = feature detection.
        let a = ExpansionPlan::new_forced(&config(784), 64, DispatchForce::Auto);
        let want = if crate::util::simd::available() {
            FwhtDispatch::Simd
        } else {
            FwhtDispatch::Batched
        };
        assert_eq!(a.dispatch(), want);
    }

    #[test]
    fn rows_hint_caps_lanes_but_not_dispatch() {
        for force in [DispatchForce::Scalar, DispatchForce::Simd] {
            let p = ExpansionPlan::new_forced(&config(784), 4, force);
            assert!(p.is_tiled());
            assert_eq!(p.lanes(), 4);
            // hint 0 degrades to 1 lane, still tiled
            let p0 = ExpansionPlan::new_forced(&config(784), 0, force);
            assert_eq!(p0.lanes(), 1);
            assert!(p0.is_tiled());
        }
    }

    #[test]
    fn huge_transform_compiles_to_per_row_under_every_force() {
        // next_pow2(40_000) = 65536 ⇒ tile_lanes == 1 ⇒ per-row path
        for force in [DispatchForce::Auto, DispatchForce::Scalar, DispatchForce::Simd] {
            let p = ExpansionPlan::new_forced(&config(40_000), 64, force);
            assert_eq!(p.dispatch(), FwhtDispatch::PerRow);
            assert!(!p.is_tiled());
            assert_eq!(p.lanes(), 1);
            assert_eq!(p.scratch_floats(), 2 * 65536);
        }
    }

    #[test]
    fn force_parse_roundtrip() {
        for f in [DispatchForce::Auto, DispatchForce::Scalar, DispatchForce::Simd] {
            assert_eq!(DispatchForce::parse(f.name()), Some(f));
        }
        // "batched" is an accepted alias for the scalar tiled arm.
        assert_eq!(DispatchForce::parse("batched"), Some(DispatchForce::Scalar));
        assert_eq!(DispatchForce::parse("avx2"), None);
        assert_eq!(DispatchForce::parse(""), None);
    }

    #[test]
    fn per_row_override_and_normalization_fold() {
        let p = ExpansionPlan::per_row(&config(784));
        assert_eq!(p.dispatch(), FwhtDispatch::PerRow);
        assert_eq!(p.scratch_floats(), 2 * 1024);
        assert!(!p.is_normalized());
        let pn = p.normalized();
        assert!(pn.is_normalized());
        let want = 1.0 / ((1024.0f32 * 2.0).sqrt());
        assert_eq!(pn.post_scale(), want);
    }

    #[test]
    fn fingerprint_encodes_shape_and_dispatch() {
        let p = ExpansionPlan::new_forced(&config(784), 4, DispatchForce::Scalar);
        assert_eq!(p.fingerprint(), "s784_n1024_e2_b4");
        let v = ExpansionPlan::new_forced(&config(784), 4, DispatchForce::Simd);
        assert_eq!(v.fingerprint(), "s784_n1024_e2_s4");
        let r = ExpansionPlan::per_row(&config(784));
        assert_eq!(r.fingerprint(), "s784_n1024_e2_r1");
        assert_eq!(r.normalized().fingerprint(), "s784_n1024_e2_r1_norm");
        // All three arms of one geometry are pairwise distinct — the
        // metrics/cache-key separation the dispatch tag exists for.
        assert_ne!(p.fingerprint(), v.fingerprint());
        assert_ne!(p.fingerprint(), r.fingerprint());
        assert_ne!(v.fingerprint(), r.fingerprint());
        // equal plans fingerprint equally; distinct shapes don't collide
        assert_eq!(
            ExpansionPlan::new(&config(784), 4).fingerprint(),
            ExpansionPlan::new(&config(784), 4).fingerprint()
        );
        assert_ne!(
            ExpansionPlan::new(&config(300), 4).fingerprint(),
            ExpansionPlan::new(&config(784), 4).fingerprint()
        );
    }

    #[test]
    fn plans_are_deterministic_plain_data() {
        let a = ExpansionPlan::new(&config(300), 10);
        let b = ExpansionPlan::new(&config(300), 10);
        assert_eq!(a, b);
        assert_ne!(a, ExpansionPlan::new(&config(300), 11));
        // `new` is `new_forced` under the process-wide knob.
        assert_eq!(a, ExpansionPlan::new_forced(&config(300), 10, dispatch_force()));
    }
}
