//! Two-sample testing with McKernel features — the paper's §1
//! application list: "a drop-in generator of features … such as for
//! regression, classification, or two-sample tests".
//!
//! Linear-time MMD: with `μ̂_P = mean φ̄(x_i)` and `μ̂_Q = mean φ̄(y_j)`,
//! `MMD²(P,Q) ≈ ‖μ̂_P − μ̂_Q‖²` — O((m+n)·D) instead of the quadratic
//! exact estimator, exactly the speedup random features buy.

use super::engine::ExpansionEngine;
use super::feature_map::McKernel;
use crate::hash::HashRng;
use crate::linalg::Matrix;

/// Mean embedding of a sample under the normalized feature map.
///
/// Rows stream through the engine one full tile at a time, so memory
/// stays `O(lanes · D)` while the trig map and butterflies still run
/// as wide multi-row sweeps.
pub fn mean_embedding(map: &McKernel, x: &Matrix) -> Vec<f32> {
    let n = x.rows();
    assert!(n > 0, "empty sample");
    let fd = map.feature_dim();
    let mut acc = vec![0.0f64; fd];
    let mut engine = ExpansionEngine::new(map, n);
    let lanes = engine.plan().lanes().max(1);
    let mut out = vec![0.0f32; lanes * fd];
    let mut base = 0;
    while base < n {
        let rows = lanes.min(n - base);
        let chunk = &x.data()[base * x.cols()..(base + rows) * x.cols()];
        let out = &mut out[..rows * fd];
        engine.execute(map, chunk, rows, x.cols(), out);
        for row in out.chunks_exact(fd) {
            for (a, v) in acc.iter_mut().zip(row) {
                *a += *v as f64;
            }
        }
        base += rows;
    }
    let norm = 1.0 / (n as f64 * ((map.padded_dim() * map.expansions()) as f64).sqrt());
    acc.into_iter().map(|v| (v * norm) as f32).collect()
}

/// Squared MMD estimate `‖μ̂_P − μ̂_Q‖²`.
pub fn mmd2(map: &McKernel, x: &Matrix, y: &Matrix) -> f64 {
    let mx = mean_embedding(map, x);
    let my = mean_embedding(map, y);
    mx.iter()
        .zip(&my)
        .map(|(a, b)| {
            let d = (*a - *b) as f64;
            d * d
        })
        .sum()
}

/// Permutation two-sample test: returns `(mmd2, p_value)` under
/// `permutations` label shufflings (hash-seeded, deterministic).
pub fn permutation_test(
    map: &McKernel,
    x: &Matrix,
    y: &Matrix,
    permutations: usize,
    seed: u64,
) -> (f64, f64) {
    assert_eq!(x.cols(), y.cols());
    let observed = mmd2(map, x, y);
    let (nx, d) = x.shape();
    let ny = y.rows();
    // pooled sample
    let mut pool = Vec::with_capacity((nx + ny) * d);
    pool.extend_from_slice(x.data());
    pool.extend_from_slice(y.data());
    let pooled = Matrix::from_vec(nx + ny, d, pool);
    let mut rng = HashRng::new(seed, 0x7e57);
    let mut at_least = 1usize; // observed counts itself (standard correction)
    for _ in 0..permutations {
        let perm = crate::rand::random_permutation(nx + ny, &mut rng);
        let mut xa = Matrix::zeros(nx, d);
        let mut ya = Matrix::zeros(ny, d);
        for (r, &p) in perm.iter().take(nx).enumerate() {
            xa.row_mut(r).copy_from_slice(pooled.row(p as usize));
        }
        for (r, &p) in perm.iter().skip(nx).enumerate() {
            ya.row_mut(r).copy_from_slice(pooled.row(p as usize));
        }
        if mmd2(map, &xa, &ya) >= observed {
            at_least += 1;
        }
    }
    (observed, at_least as f64 / (permutations + 1) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mckernel::McKernelFactory;

    fn sample(n: usize, d: usize, shift: f32, seed: u64) -> Matrix {
        let mut rng = crate::hash::HashRng::new(seed, 0x5a);
        let mut bm = crate::rand::BoxMuller::new(rng.derive(1));
        Matrix::from_fn(n, d, |_, _| bm.next() as f32 * 0.5 + shift)
    }

    fn map(d: usize) -> McKernel {
        McKernelFactory::new(d).expansions(8).sigma(1.0).rbf().seed(3).build()
    }

    #[test]
    fn mmd_near_zero_for_same_distribution() {
        let m = map(4);
        let x = sample(120, 4, 0.0, 1);
        let y = sample(120, 4, 0.0, 2);
        let v = mmd2(&m, &x, &y);
        assert!(v < 0.02, "same-dist mmd² {v}");
    }

    #[test]
    fn mmd_large_for_shifted_distribution() {
        let m = map(4);
        let x = sample(120, 4, 0.0, 1);
        let y = sample(120, 4, 1.0, 2);
        let v = mmd2(&m, &x, &y);
        assert!(v > 0.1, "shifted mmd² {v}");
    }

    #[test]
    fn mmd_orders_by_shift() {
        let m = map(4);
        let x = sample(100, 4, 0.0, 1);
        let near = sample(100, 4, 0.25, 2);
        let far = sample(100, 4, 1.5, 3);
        assert!(mmd2(&m, &x, &far) > mmd2(&m, &x, &near));
    }

    #[test]
    fn permutation_test_rejects_shift() {
        let m = map(3);
        let x = sample(60, 3, 0.0, 4);
        let y = sample(60, 3, 0.8, 5);
        let (v, p) = permutation_test(&m, &x, &y, 50, 9);
        assert!(v > 0.0);
        assert!(p < 0.05, "p={p} should reject");
    }

    #[test]
    fn permutation_test_accepts_null() {
        let m = map(3);
        let x = sample(60, 3, 0.0, 6);
        let y = sample(60, 3, 0.0, 7);
        let (_, p) = permutation_test(&m, &x, &y, 50, 9);
        assert!(p > 0.05, "p={p} should not reject the null");
    }

    #[test]
    fn deterministic() {
        let m = map(2);
        let x = sample(30, 2, 0.0, 8);
        let y = sample(30, 2, 0.3, 9);
        let a = permutation_test(&m, &x, &y, 20, 42);
        let b = permutation_test(&m, &x, &y, 20, 42);
        assert_eq!(a, b);
    }
}
