//! Factory API (paper §6: "the API follows the design pattern in
//! factory … a means of instantiating the parameters according to
//! pre-specified sets of parameters, e.g. a RBF Kernel or a RBF MATÉRN
//! Kernel. The so-chosen parameters are deterministic, given by the
//! values of a function of hashing.")

use super::feature_map::McKernel;
use super::kernel::Kernel;

/// Complete specification of a feature map. Two equal configs build
/// byte-identical maps on any machine — this is the whole model
/// "checkpoint" for the feature layer.
#[derive(Debug, Clone, PartialEq)]
pub struct McKernelConfig {
    /// Raw input dimension `S` (padded internally to `[S]₂`).
    pub input_dim: usize,
    /// Number of kernel expansions `E`.
    pub expansions: usize,
    /// Kernel bandwidth σ.
    pub sigma: f64,
    /// Kernel family for the calibration `C`.
    pub kernel: Kernel,
    /// Root seed (the paper's experiments use 1398239763).
    pub seed: u64,
}

impl McKernelConfig {
    /// Panics on degenerate configurations.
    pub fn validate(&self) {
        assert!(self.input_dim > 0, "input_dim must be positive");
        assert!(self.expansions > 0, "need at least one expansion");
        assert!(self.sigma > 0.0 && self.sigma.is_finite(), "sigma must be positive");
    }
}

impl Default for McKernelConfig {
    fn default() -> Self {
        McKernelConfig {
            input_dim: 784,
            expansions: 1,
            sigma: 1.0,
            kernel: Kernel::RbfMatern { t: 40 },
            seed: crate::PAPER_SEED,
        }
    }
}

/// Builder-style factory for [`McKernel`] instances.
///
/// ```
/// use mckernel::mckernel::McKernelFactory;
/// let fm = McKernelFactory::new(784)
///     .expansions(4)
///     .sigma(1.0)
///     .rbf_matern(40)
///     .seed(1398239763)
///     .build();
/// assert_eq!(fm.feature_dim(), 2 * 1024 * 4);
/// ```
#[derive(Debug, Clone)]
pub struct McKernelFactory {
    config: McKernelConfig,
}

impl McKernelFactory {
    /// Start from the input dimension.
    pub fn new(input_dim: usize) -> McKernelFactory {
        McKernelFactory { config: McKernelConfig { input_dim, ..Default::default() } }
    }

    /// Set the number of expansions `E`.
    pub fn expansions(mut self, e: usize) -> Self {
        self.config.expansions = e;
        self
    }

    /// Set the bandwidth σ.
    pub fn sigma(mut self, s: f64) -> Self {
        self.config.sigma = s;
        self
    }

    /// Use the Gaussian RBF kernel.
    pub fn rbf(mut self) -> Self {
        self.config.kernel = Kernel::Rbf;
        self
    }

    /// Use the RBF Matérn kernel with `t` ball summands.
    pub fn rbf_matern(mut self, t: u32) -> Self {
        self.config.kernel = Kernel::RbfMatern { t };
        self
    }

    /// Set the root seed.
    pub fn seed(mut self, s: u64) -> Self {
        self.config.seed = s;
        self
    }

    /// The config built so far.
    pub fn config(&self) -> &McKernelConfig {
        &self.config
    }

    /// Materialize the feature map.
    pub fn build(self) -> McKernel {
        McKernel::new(self.config)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_sets_everything() {
        let f = McKernelFactory::new(100)
            .expansions(3)
            .sigma(2.5)
            .rbf()
            .seed(77);
        let c = f.config();
        assert_eq!(c.input_dim, 100);
        assert_eq!(c.expansions, 3);
        assert_eq!(c.sigma, 2.5);
        assert_eq!(c.kernel, Kernel::Rbf);
        assert_eq!(c.seed, 77);
    }

    #[test]
    fn default_matches_paper_hypers() {
        let c = McKernelConfig::default();
        assert_eq!(c.sigma, 1.0);
        assert_eq!(c.kernel, Kernel::RbfMatern { t: 40 });
        assert_eq!(c.seed, 1398239763);
    }

    #[test]
    fn same_config_same_map() {
        let x: Vec<f32> = (0..50).map(|i| i as f32 * 0.02).collect();
        let a = McKernelFactory::new(50).expansions(2).seed(5).build();
        let b = McKernelFactory::new(50).expansions(2).seed(5).build();
        assert_eq!(a.transform(&x), b.transform(&x));
    }

    #[test]
    #[should_panic]
    fn zero_expansions_rejected() {
        McKernelFactory::new(10).expansions(0).build();
    }

    #[test]
    #[should_panic]
    fn negative_sigma_rejected() {
        McKernelFactory::new(10).sigma(-1.0).build();
    }
}
