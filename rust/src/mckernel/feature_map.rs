//! The full McKernel feature map: `E` stacked Fastfood expansions +
//! the real feature map `φ(x) = [cos(Ẑx̂), sin(Ẑx̂)]` (paper Eq. 9,
//! Figure 1).

use super::expansion::FastfoodBlock;
use super::factory::McKernelConfig;
use crate::fwht::batch::tile_lanes;
use crate::linalg::Matrix;
use crate::util::fastmath;
use crate::util::pow2::next_pow2;

/// Reusable scratch for the batched feature path: three column-major
/// `(n, lanes)` tiles sized to stay L2-resident together. `tin`
/// doubles as the cosine buffer once the second FWHT has consumed it.
#[derive(Debug, Clone)]
pub struct BatchScratch {
    lanes: usize,
    tin: Vec<f32>,
    z: Vec<f32>,
    sin: Vec<f32>,
}

impl BatchScratch {
    fn new(n: usize) -> BatchScratch {
        let lanes = tile_lanes(n);
        BatchScratch {
            lanes,
            tin: vec![0.0; n * lanes],
            z: vec![0.0; n * lanes],
            sin: vec![0.0; n * lanes],
        }
    }

    /// Rows processed per tile.
    pub fn lanes(&self) -> usize {
        self.lanes
    }
}

/// The McKernel feature generator (paper Figure 1's `mckernel(x)`).
///
/// Output layout for expansion `e` (0-based), padded dim `n`:
/// `out[e·2n .. e·2n+n] = cos(Ẑ_e x̂)`, `out[e·2n+n .. (e+1)·2n] = sin(Ẑ_e x̂)`.
#[derive(Debug, Clone)]
pub struct McKernel {
    config: McKernelConfig,
    /// Padded dimension `[S]₂`.
    n: usize,
    blocks: Vec<FastfoodBlock>,
}

impl McKernel {
    /// Materialize the feature map for `config` (deterministic in
    /// `config.seed`).
    pub fn new(config: McKernelConfig) -> McKernel {
        config.validate();
        let n = next_pow2(config.input_dim);
        let blocks = (0..config.expansions)
            .map(|e| FastfoodBlock::new(config.seed, e, n, config.kernel, config.sigma))
            .collect();
        McKernel { config, n, blocks }
    }

    /// The configuration this map was built from.
    pub fn config(&self) -> &McKernelConfig {
        &self.config
    }

    /// Padded input dimension `[S]₂`.
    pub fn padded_dim(&self) -> usize {
        self.n
    }

    /// Raw input dimension `S`.
    pub fn input_dim(&self) -> usize {
        self.config.input_dim
    }

    /// Output feature dimension `2·[S]₂·E` (paper Eq. 22's feature
    /// term).
    pub fn feature_dim(&self) -> usize {
        2 * self.n * self.blocks.len()
    }

    /// Number of expansions `E`.
    pub fn expansions(&self) -> usize {
        self.blocks.len()
    }

    /// Per-expansion blocks (for cross-layer coefficient checks).
    pub fn blocks(&self) -> &[FastfoodBlock] {
        &self.blocks
    }

    /// Scratch buffer pair sized for [`McKernel::transform_into`].
    pub fn make_scratch(&self) -> (Vec<f32>, Vec<f32>) {
        (vec![0.0; self.n], vec![0.0; self.n])
    }

    /// Compute `φ(x)` into `out` (`len == feature_dim()`), using the
    /// caller's scratch (allocation-free hot path). `x.len()` must be
    /// `input_dim` (padding applied internally) or exactly `n`.
    ///
    /// This is the per-row path with libm trig — the correctness
    /// oracle the batched [`McKernel::transform_batch_into`] pipeline
    /// is validated against (≤1e-5 abs).
    pub fn transform_into(
        &self,
        x: &[f32],
        out: &mut [f32],
        scratch: &mut (Vec<f32>, Vec<f32>),
    ) {
        self.transform_into_scaled(x, out, scratch, 1.0);
    }

    /// Per-row transform with `post_scale` fused into the feature
    /// write — one pass over the output whether or not the caller
    /// wants the `1/√(n·E)` estimator scaling.
    fn transform_into_scaled(
        &self,
        x: &[f32],
        out: &mut [f32],
        scratch: &mut (Vec<f32>, Vec<f32>),
        post_scale: f32,
    ) {
        let n = self.n;
        assert!(
            x.len() == self.config.input_dim || x.len() == n,
            "input length {} (expect {} or {})",
            x.len(),
            self.config.input_dim,
            n
        );
        assert_eq!(out.len(), self.feature_dim(), "output length");
        let (padded, tmp) = scratch;
        padded[..x.len()].copy_from_slice(x);
        padded[x.len()..].fill(0.0);
        for (e, block) in self.blocks.iter().enumerate() {
            let seg = &mut out[e * 2 * n..(e + 1) * 2 * n];
            let (cos_half, sin_half) = seg.split_at_mut(n);
            // Ẑx̂ into cos_half (as scratch), then write the pair.
            // sin_cos computes both trig values in one libm call —
            // the trig map dominates the per-sample profile (§Perf).
            block.apply(padded, cos_half, tmp);
            for i in 0..n {
                let (s, c) = cos_half[i].sin_cos();
                sin_half[i] = s * post_scale;
                cos_half[i] = c * post_scale;
            }
        }
    }

    /// Allocating convenience wrapper over [`McKernel::transform_into`].
    pub fn transform(&self, x: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0; self.feature_dim()];
        let mut scratch = self.make_scratch();
        self.transform_into(x, &mut out, &mut scratch);
        out
    }

    /// Scratch for the batched path ([`McKernel::transform_batch_into`]).
    pub fn make_batch_scratch(&self) -> BatchScratch {
        BatchScratch::new(self.n)
    }

    /// Batched `φ(X)` into a preallocated matrix — the hot path for
    /// the trainer, the prefetch pipeline and the feature server.
    /// Allocation-free; matches the per-row oracle within 1e-5 abs
    /// (polynomial trig), and is invariant to how rows are grouped
    /// into tiles (lanes never interact).
    pub fn transform_batch_into(&self, x: &Matrix, out: &mut Matrix, scratch: &mut BatchScratch) {
        assert_eq!(out.shape(), (x.rows(), self.feature_dim()), "output shape");
        let (rows, src_cols) = x.shape();
        self.batch_into_scaled(x.data(), rows, src_cols, out.data_mut(), scratch, 1.0);
    }

    /// Batched `φ` over raw row-major slices: `xs` is `(rows,
    /// src_cols)` with `src_cols` = `input_dim` (padded internally) or
    /// `n`; `out` is `(rows, feature_dim)`. This is the core the
    /// parallel featurizer drives with disjoint row ranges.
    pub fn transform_batch_slice_into(
        &self,
        xs: &[f32],
        rows: usize,
        src_cols: usize,
        out: &mut [f32],
        scratch: &mut BatchScratch,
    ) {
        self.batch_into_scaled(xs, rows, src_cols, out, scratch, 1.0);
    }

    /// The batched pipeline: row-tiles of `scratch.lanes()` rows
    /// stream through the fused Fastfood passes (B on the transpose-in
    /// load, Π∘G as contiguous stream copies), the calibration
    /// diagonal, the polynomial trig map, and a transpose-out write
    /// with `post_scale` fused in — no separate normalization pass.
    fn batch_into_scaled(
        &self,
        xs: &[f32],
        rows: usize,
        src_cols: usize,
        out: &mut [f32],
        scratch: &mut BatchScratch,
        post_scale: f32,
    ) {
        let n = self.n;
        assert!(
            src_cols == self.config.input_dim || src_cols == n,
            "input width {} (expect {} or {})",
            src_cols,
            self.config.input_dim,
            n
        );
        assert_eq!(xs.len(), rows * src_cols, "input length");
        let fd = self.feature_dim();
        assert_eq!(out.len(), rows * fd, "output length");
        let lanes_max = scratch.lanes;
        if lanes_max <= 1 {
            // Transform too large to tile (tile_lanes(n) == 1): the
            // per-row engine's cache-blocked bottom phase is the right
            // shape, and lane-1 transposes would only add copies.
            // (`FastfoodBlock::apply_batch` mirrors this tiling loop
            // and fallback for the linear stage; keep them in sync.)
            let mut row_scratch = self.make_scratch();
            for r in 0..rows {
                self.transform_into_scaled(
                    &xs[r * src_cols..(r + 1) * src_cols],
                    &mut out[r * fd..(r + 1) * fd],
                    &mut row_scratch,
                    post_scale,
                );
            }
            return;
        }
        let mut base = 0;
        while base < rows {
            let lanes = lanes_max.min(rows - base);
            let nl = n * lanes;
            let xslice = &xs[base * src_cols..(base + lanes) * src_cols];
            for (e, block) in self.blocks.iter().enumerate() {
                block.apply_tile(xslice, src_cols, lanes, &mut scratch.tin, &mut scratch.z);
                let z = &mut scratch.z[..nl];
                // calibration diagonal: contiguous per-coefficient runs
                let scale = block.scale();
                for j in 0..n {
                    let sj = scale[j];
                    for v in &mut z[j * lanes..(j + 1) * lanes] {
                        *v *= sj;
                    }
                }
                // polynomial trig over the whole tile; tin is free by
                // now and becomes the cosine buffer
                let sin_t = &mut scratch.sin[..nl];
                let cos_t = &mut scratch.tin[..nl];
                fastmath::sin_cos_batch(z, sin_t, cos_t);
                // transpose-out into the (cos, sin) halves, any output
                // normalization fused into this single write
                for l in 0..lanes {
                    let seg = &mut out[(base + l) * fd + e * 2 * n..][..2 * n];
                    let (cos_half, sin_half) = seg.split_at_mut(n);
                    for j in 0..n {
                        cos_half[j] = cos_t[j * lanes + l] * post_scale;
                        sin_half[j] = sin_t[j * lanes + l] * post_scale;
                    }
                }
            }
            base += lanes;
        }
    }

    /// Transform every row of `(batch, input_dim)` into
    /// `(batch, feature_dim)` via the batched pipeline.
    pub fn transform_batch(&self, x: &Matrix) -> Matrix {
        assert_eq!(x.cols(), self.config.input_dim, "batch feature width");
        let mut out = Matrix::zeros(x.rows(), self.feature_dim());
        let mut scratch = self.make_batch_scratch();
        self.transform_batch_into(x, &mut out, &mut scratch);
        out
    }

    /// Batched `φ̄(X)` with the `1/√(n·E)` estimator scaling fused
    /// into the feature write (no second pass over the output).
    pub fn transform_batch_normalized(&self, x: &Matrix) -> Matrix {
        assert_eq!(x.cols(), self.config.input_dim, "batch feature width");
        let s = 1.0 / ((self.n * self.expansions()) as f32).sqrt();
        let mut out = Matrix::zeros(x.rows(), self.feature_dim());
        let mut scratch = self.make_batch_scratch();
        self.batch_into_scaled(x.data(), x.rows(), x.cols(), out.data_mut(), &mut scratch, s);
        out
    }

    /// Kernel-approximation form: features scaled by `1/√(n·E)` so
    /// that `⟨φ̄(x), φ̄(y)⟩ ≈ k(x, y)` (Rahimi–Recht estimator — the
    /// normalization is absorbed by `W` in the learning setting, but
    /// needed to *validate* the approximation). The scaling is fused
    /// into the feature write, not a second pass.
    pub fn transform_normalized(&self, x: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0; self.feature_dim()];
        let mut scratch = self.make_scratch();
        let s = 1.0 / ((self.n * self.expansions()) as f32).sqrt();
        self.transform_into_scaled(x, &mut out, &mut scratch, s);
        out
    }

    /// `Ẑ_e x̂` alone (the linear stage) — used by tests and the
    /// Python cross-check.
    pub fn zx(&self, e: usize, x: &[f32]) -> Vec<f32> {
        let mut padded = vec![0.0f32; self.n];
        padded[..x.len()].copy_from_slice(x);
        let mut out = vec![0.0f32; self.n];
        let mut tmp = vec![0.0f32; self.n];
        self.blocks[e].apply(&padded, &mut out, &mut tmp);
        out
    }

    /// Learned-parameter count for a `classes`-way linear head on top
    /// of this map (paper Eq. 22: `C·(2·[S]₂·E + 1)`).
    pub fn head_param_count(&self, classes: usize) -> usize {
        classes * (self.feature_dim() + 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mckernel::factory::McKernelConfig;
    use crate::mckernel::kernel::Kernel;

    fn map(input_dim: usize, e: usize, sigma: f64, seed: u64) -> McKernel {
        McKernel::new(McKernelConfig {
            input_dim,
            expansions: e,
            sigma,
            kernel: Kernel::Rbf,
            seed,
        })
    }

    #[test]
    fn dimensions() {
        let m = map(784, 3, 1.0, 1);
        assert_eq!(m.padded_dim(), 1024);
        assert_eq!(m.feature_dim(), 2 * 1024 * 3);
        assert_eq!(m.head_param_count(10), 10 * (2 * 1024 * 3 + 1));
    }

    #[test]
    fn eq22_parameter_count_paper_example() {
        // MNIST: S=784 → [S]₂=1024; C=10.  Eq. 22: 10·(2·1024·E + 1).
        for e in [1usize, 2, 4, 8] {
            let m = map(784, e, 1.0, 1);
            assert_eq!(m.head_param_count(10), 10 * (2 * 1024 * e + 1));
        }
    }

    #[test]
    fn output_in_unit_box() {
        let m = map(20, 2, 1.0, 2);
        let x: Vec<f32> = (0..20).map(|i| i as f32 / 20.0).collect();
        let f = m.transform(&x);
        assert!(f.iter().all(|v| (-1.0..=1.0).contains(v)));
    }

    #[test]
    fn cos_sin_blocks_consistent() {
        // cos²+sin² = 1 element-wise within each expansion.
        let m = map(16, 2, 1.0, 3);
        let x: Vec<f32> = (0..16).map(|i| (i as f32).sin()).collect();
        let f = m.transform(&x);
        let n = m.padded_dim();
        for e in 0..2 {
            for i in 0..n {
                let c = f[e * 2 * n + i];
                let s = f[e * 2 * n + n + i];
                assert!((c * c + s * s - 1.0).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn deterministic_and_seed_sensitive() {
        let x: Vec<f32> = (0..30).map(|i| i as f32 * 0.1).collect();
        let a = map(30, 1, 1.0, 5).transform(&x);
        let b = map(30, 1, 1.0, 5).transform(&x);
        let c = map(30, 1, 1.0, 6).transform(&x);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn kernel_approximation_rbf() {
        // THE core validity test: ⟨φ̄(x), φ̄(y)⟩ → exp(-‖x−y‖²/(2σ²)).
        let d = 24;
        let sigma = 2.0;
        let m = map(d, 16, sigma, 7); // 16 expansions → 32·32=… features
        let mut rng = crate::hash::HashRng::new(99, 0);
        let mut max_err = 0.0f64;
        for _ in 0..8 {
            let x: Vec<f32> = (0..d).map(|_| rng.next_f32() - 0.5).collect();
            let y: Vec<f32> = (0..d).map(|_| rng.next_f32() - 0.5).collect();
            let fx = m.transform_normalized(&x);
            let fy = m.transform_normalized(&y);
            let dot: f64 = fx.iter().zip(&fy).map(|(a, b)| (*a as f64) * (*b as f64)).sum();
            let exact = Kernel::Rbf.exact(&x, &y, sigma);
            max_err = max_err.max((dot - exact).abs());
        }
        assert!(max_err < 0.08, "kernel approx error {max_err}");
    }

    #[test]
    fn self_similarity_is_one() {
        // k(x,x)=1 exactly: cos²+sin² sums give ⟨φ̄(x),φ̄(x)⟩ = 1.
        let m = map(10, 4, 1.0, 8);
        let x: Vec<f32> = (0..10).map(|i| i as f32).collect();
        let f = m.transform_normalized(&x);
        let dot: f64 = f.iter().map(|v| (*v as f64).powi(2)).sum();
        assert!((dot - 1.0).abs() < 1e-4, "self-sim {dot}");
    }

    #[test]
    fn batch_matches_single_within_trig_budget() {
        // batched path uses the polynomial trig kernel; the per-row
        // libm oracle must agree within the 1e-5 pipeline budget
        let m = map(12, 2, 1.0, 9);
        let x = Matrix::from_fn(3, 12, |r, c| (r * 12 + c) as f32 * 0.01);
        let batch = m.transform_batch(&x);
        for r in 0..3 {
            let single = m.transform(x.row(r));
            for (i, (a, b)) in batch.row(r).iter().zip(&single).enumerate() {
                assert!((a - b).abs() < 1e-5, "row {r} col {i}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn batch_lane_grouping_invariant() {
        // lanes never interact: transforming rows together or one at a
        // time is bit-identical, so tiling/parallel splits are safe
        let m = map(12, 2, 1.0, 9);
        let x = Matrix::from_fn(5, 12, |r, c| ((r * 17 + c) % 11) as f32 * 0.03);
        let all = m.transform_batch(&x);
        for r in 0..5 {
            let one = m.transform_batch(&Matrix::from_vec(1, 12, x.row(r).to_vec()));
            assert_eq!(all.row(r), one.row(0), "row {r}");
        }
    }

    #[test]
    fn batch_into_handles_tail_tiles() {
        // rows not a multiple of the tile width exercise the tail path
        let m = map(12, 1, 1.0, 14);
        let scratch_lanes = m.make_batch_scratch().lanes();
        let rows = scratch_lanes + 3;
        let x = Matrix::from_fn(rows, 12, |r, c| ((r + 3 * c) % 7) as f32 * 0.05);
        let mut out = Matrix::zeros(rows, m.feature_dim());
        let mut scratch = m.make_batch_scratch();
        m.transform_batch_into(&x, &mut out, &mut scratch);
        let mut row_scratch = m.make_scratch();
        let mut want = vec![0.0; m.feature_dim()];
        for r in 0..rows {
            m.transform_into(x.row(r), &mut want, &mut row_scratch);
            for (a, b) in out.row(r).iter().zip(&want) {
                assert!((a - b).abs() < 1e-5, "row {r}");
            }
        }
    }

    #[test]
    fn normalized_variants_agree() {
        let m = map(10, 2, 1.0, 15);
        let x = Matrix::from_fn(4, 10, |r, c| (r * 10 + c) as f32 * 0.02);
        let batch = m.transform_batch_normalized(&x);
        let s = 1.0 / ((m.padded_dim() * m.expansions()) as f32).sqrt();
        for r in 0..4 {
            let row = m.transform_normalized(x.row(r));
            let plain = m.transform(x.row(r));
            for i in 0..m.feature_dim() {
                // per-row: scaling fused into the write, same products
                assert_eq!(row[i], plain[i] * s);
                // batched vs per-row: trig-kernel budget
                assert!((batch.row(r)[i] - row[i]).abs() < 1e-5, "row {r} col {i}");
            }
        }
    }

    #[test]
    fn padding_is_zero_extension() {
        // Same content padded by hand must give identical features.
        let m = map(12, 1, 1.0, 10);
        let x: Vec<f32> = (0..12).map(|i| i as f32).collect();
        let mut xp = x.clone();
        xp.resize(16, 0.0);
        assert_eq!(m.transform(&x), m.transform(&xp));
    }

    #[test]
    fn zx_matches_transform_prefix() {
        let m = map(8, 2, 1.0, 11);
        let x: Vec<f32> = (0..8).map(|i| (i as f32) * 0.3).collect();
        let z1 = m.zx(1, &x);
        let f = m.transform(&x);
        let n = m.padded_dim();
        for i in 0..n {
            assert!((f[2 * n + i] - z1[i].cos()).abs() < 1e-6);
            assert!((f[2 * n + n + i] - z1[i].sin()).abs() < 1e-6);
        }
    }
}
