//! The full McKernel feature map: `E` stacked Fastfood expansions +
//! the real feature map `φ(x) = [cos(Ẑx̂), sin(Ẑx̂)]` (paper Eq. 9,
//! Figure 1).
//!
//! `McKernel` owns the hash-derived coefficients; *how* `φ` is
//! computed — tile lanes, batch-vs-per-row dispatch, scratch sizing,
//! normalization folding — is compiled once by
//! [`crate::mckernel::plan::ExpansionPlan`] and executed by
//! [`crate::mckernel::engine::ExpansionEngine`]. The transform
//! methods here are thin wrappers that build a one-shot engine; hot
//! paths hold a long-lived engine instead.

use super::engine::ExpansionEngine;
use super::expansion::FastfoodBlock;
use super::factory::McKernelConfig;
use crate::linalg::Matrix;
use crate::util::pow2::next_pow2;

/// The McKernel feature generator (paper Figure 1's `mckernel(x)`).
///
/// Output layout for expansion `e` (0-based), padded dim `n`:
/// `out[e·2n .. e·2n+n] = cos(Ẑ_e x̂)`, `out[e·2n+n .. (e+1)·2n] = sin(Ẑ_e x̂)`.
#[derive(Debug, Clone)]
pub struct McKernel {
    config: McKernelConfig,
    /// Padded dimension `[S]₂`.
    n: usize,
    blocks: Vec<FastfoodBlock>,
}

impl McKernel {
    /// Materialize the feature map for `config` (deterministic in
    /// `config.seed`).
    pub fn new(config: McKernelConfig) -> McKernel {
        config.validate();
        let n = next_pow2(config.input_dim);
        let blocks = (0..config.expansions)
            .map(|e| FastfoodBlock::new(config.seed, e, n, config.kernel, config.sigma))
            .collect();
        McKernel { config, n, blocks }
    }

    /// The configuration this map was built from.
    pub fn config(&self) -> &McKernelConfig {
        &self.config
    }

    /// Padded input dimension `[S]₂`.
    pub fn padded_dim(&self) -> usize {
        self.n
    }

    /// Raw input dimension `S`.
    pub fn input_dim(&self) -> usize {
        self.config.input_dim
    }

    /// Output feature dimension `2·[S]₂·E` (paper Eq. 22's feature
    /// term).
    pub fn feature_dim(&self) -> usize {
        2 * self.n * self.blocks.len()
    }

    /// Number of expansions `E`.
    pub fn expansions(&self) -> usize {
        self.blocks.len()
    }

    /// Per-expansion blocks (for cross-layer coefficient checks and
    /// the expansion engine).
    pub fn blocks(&self) -> &[FastfoodBlock] {
        &self.blocks
    }

    /// `φ(x)` through the per-row libm pipeline — the correctness
    /// oracle the batched engine path is validated against (≤1e-6 abs
    /// on tested shapes). `x.len()` must be `input_dim` (padding
    /// applied internally) or exactly `n`. Allocating convenience;
    /// hot paths hold an [`ExpansionEngine`] instead.
    pub fn transform(&self, x: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0; self.feature_dim()];
        ExpansionEngine::per_row_oracle(self).execute(self, x, 1, x.len(), &mut out);
        out
    }

    /// Kernel-approximation form: features scaled by `1/√(n·E)` so
    /// that `⟨φ̄(x), φ̄(y)⟩ ≈ k(x, y)` (Rahimi–Recht estimator — the
    /// normalization is absorbed by `W` in the learning setting, but
    /// needed to *validate* the approximation). The scaling is fused
    /// into the feature write, not a second pass.
    pub fn transform_normalized(&self, x: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0; self.feature_dim()];
        ExpansionEngine::with_plan(
            super::plan::ExpansionPlan::per_row(&self.config).normalized(),
        )
        .execute(self, x, 1, x.len(), &mut out);
        out
    }

    /// Batched `φ(X)` into a preallocated matrix through the caller's
    /// engine — the hot path for the trainer, the prefetch pipeline
    /// and the feature server. Allocation-free; matches the per-row
    /// oracle within the trig-kernel budget and is invariant to how
    /// rows are grouped into tiles (lanes never interact).
    pub fn transform_batch_into(&self, x: &Matrix, out: &mut Matrix, engine: &mut ExpansionEngine) {
        engine.execute_matrix(self, x, out);
    }

    /// Transform every row of `(batch, input_dim)` into
    /// `(batch, feature_dim)` via the compiled engine path
    /// (allocating convenience wrapper).
    pub fn transform_batch(&self, x: &Matrix) -> Matrix {
        assert_eq!(x.cols(), self.config.input_dim, "batch feature width");
        let mut out = Matrix::zeros(x.rows(), self.feature_dim());
        let mut engine = ExpansionEngine::new(self, x.rows());
        engine.execute_matrix(self, x, &mut out);
        out
    }

    /// Batched `φ̄(X)` with the `1/√(n·E)` estimator scaling fused
    /// into the feature write (no second pass over the output).
    pub fn transform_batch_normalized(&self, x: &Matrix) -> Matrix {
        assert_eq!(x.cols(), self.config.input_dim, "batch feature width");
        let mut out = Matrix::zeros(x.rows(), self.feature_dim());
        let mut engine = ExpansionEngine::normalized(self, x.rows());
        engine.execute_matrix(self, x, &mut out);
        out
    }

    /// `Ẑ_e x̂` alone (the linear stage) — used by tests and the
    /// Python cross-check.
    pub fn zx(&self, e: usize, x: &[f32]) -> Vec<f32> {
        let mut padded = vec![0.0f32; self.n];
        padded[..x.len()].copy_from_slice(x);
        let mut out = vec![0.0f32; self.n];
        let mut tmp = vec![0.0f32; self.n];
        self.blocks[e].apply(&padded, &mut out, &mut tmp);
        out
    }

    /// Learned-parameter count for a `classes`-way linear head on top
    /// of this map (paper Eq. 22: `C·(2·[S]₂·E + 1)`).
    pub fn head_param_count(&self, classes: usize) -> usize {
        classes * (self.feature_dim() + 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mckernel::factory::McKernelConfig;
    use crate::mckernel::kernel::Kernel;

    fn map(input_dim: usize, e: usize, sigma: f64, seed: u64) -> McKernel {
        McKernel::new(McKernelConfig {
            input_dim,
            expansions: e,
            sigma,
            kernel: Kernel::Rbf,
            seed,
        })
    }

    #[test]
    fn dimensions() {
        let m = map(784, 3, 1.0, 1);
        assert_eq!(m.padded_dim(), 1024);
        assert_eq!(m.feature_dim(), 2 * 1024 * 3);
        assert_eq!(m.head_param_count(10), 10 * (2 * 1024 * 3 + 1));
    }

    #[test]
    fn eq22_parameter_count_paper_example() {
        // MNIST: S=784 → [S]₂=1024; C=10.  Eq. 22: 10·(2·1024·E + 1).
        for e in [1usize, 2, 4, 8] {
            let m = map(784, e, 1.0, 1);
            assert_eq!(m.head_param_count(10), 10 * (2 * 1024 * e + 1));
        }
    }

    #[test]
    fn output_in_unit_box() {
        let m = map(20, 2, 1.0, 2);
        let x: Vec<f32> = (0..20).map(|i| i as f32 / 20.0).collect();
        let f = m.transform(&x);
        assert!(f.iter().all(|v| (-1.0..=1.0).contains(v)));
    }

    #[test]
    fn cos_sin_blocks_consistent() {
        // cos²+sin² = 1 element-wise within each expansion.
        let m = map(16, 2, 1.0, 3);
        let x: Vec<f32> = (0..16).map(|i| (i as f32).sin()).collect();
        let f = m.transform(&x);
        let n = m.padded_dim();
        for e in 0..2 {
            for i in 0..n {
                let c = f[e * 2 * n + i];
                let s = f[e * 2 * n + n + i];
                assert!((c * c + s * s - 1.0).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn deterministic_and_seed_sensitive() {
        let x: Vec<f32> = (0..30).map(|i| i as f32 * 0.1).collect();
        let a = map(30, 1, 1.0, 5).transform(&x);
        let b = map(30, 1, 1.0, 5).transform(&x);
        let c = map(30, 1, 1.0, 6).transform(&x);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn kernel_approximation_rbf() {
        // THE core validity test: ⟨φ̄(x), φ̄(y)⟩ → exp(-‖x−y‖²/(2σ²)).
        let d = 24;
        let sigma = 2.0;
        let m = map(d, 16, sigma, 7); // 16 expansions → 32·32=… features
        let mut rng = crate::hash::HashRng::new(99, 0);
        let mut max_err = 0.0f64;
        for _ in 0..8 {
            let x: Vec<f32> = (0..d).map(|_| rng.next_f32() - 0.5).collect();
            let y: Vec<f32> = (0..d).map(|_| rng.next_f32() - 0.5).collect();
            let fx = m.transform_normalized(&x);
            let fy = m.transform_normalized(&y);
            let dot: f64 = fx.iter().zip(&fy).map(|(a, b)| (*a as f64) * (*b as f64)).sum();
            let exact = Kernel::Rbf.exact(&x, &y, sigma);
            max_err = max_err.max((dot - exact).abs());
        }
        assert!(max_err < 0.08, "kernel approx error {max_err}");
    }

    #[test]
    fn self_similarity_is_one() {
        // k(x,x)=1 exactly: cos²+sin² sums give ⟨φ̄(x),φ̄(x)⟩ = 1.
        let m = map(10, 4, 1.0, 8);
        let x: Vec<f32> = (0..10).map(|i| i as f32).collect();
        let f = m.transform_normalized(&x);
        let dot: f64 = f.iter().map(|v| (*v as f64).powi(2)).sum();
        assert!((dot - 1.0).abs() < 1e-4, "self-sim {dot}");
    }

    #[test]
    fn batch_matches_single_within_trig_budget() {
        // batched path uses the polynomial trig kernel; the per-row
        // libm oracle must agree within the 1e-5 pipeline budget
        let m = map(12, 2, 1.0, 9);
        let x = Matrix::from_fn(3, 12, |r, c| (r * 12 + c) as f32 * 0.01);
        let batch = m.transform_batch(&x);
        for r in 0..3 {
            let single = m.transform(x.row(r));
            for (i, (a, b)) in batch.row(r).iter().zip(&single).enumerate() {
                assert!((a - b).abs() < 1e-5, "row {r} col {i}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn batch_lane_grouping_invariant() {
        // lanes never interact: transforming rows together or one at a
        // time is bit-identical, so tiling/parallel splits are safe
        let m = map(12, 2, 1.0, 9);
        let x = Matrix::from_fn(5, 12, |r, c| ((r * 17 + c) % 11) as f32 * 0.03);
        let all = m.transform_batch(&x);
        for r in 0..5 {
            let one = m.transform_batch(&Matrix::from_vec(1, 12, x.row(r).to_vec()));
            assert_eq!(all.row(r), one.row(0), "row {r}");
        }
    }

    #[test]
    fn batch_into_handles_tail_tiles() {
        // rows not a multiple of the tile width exercise the tail path
        let m = map(12, 1, 1.0, 14);
        let mut engine = ExpansionEngine::new(&m, usize::MAX);
        let rows = engine.plan().lanes() + 3;
        let x = Matrix::from_fn(rows, 12, |r, c| ((r + 3 * c) % 7) as f32 * 0.05);
        let mut out = Matrix::zeros(rows, m.feature_dim());
        m.transform_batch_into(&x, &mut out, &mut engine);
        for r in 0..rows {
            let want = m.transform(x.row(r));
            for (a, b) in out.row(r).iter().zip(&want) {
                assert!((a - b).abs() < 1e-5, "row {r}");
            }
        }
    }

    #[test]
    fn normalized_variants_agree() {
        let m = map(10, 2, 1.0, 15);
        let x = Matrix::from_fn(4, 10, |r, c| (r * 10 + c) as f32 * 0.02);
        let batch = m.transform_batch_normalized(&x);
        let s = 1.0 / ((m.padded_dim() * m.expansions()) as f32).sqrt();
        for r in 0..4 {
            let row = m.transform_normalized(x.row(r));
            let plain = m.transform(x.row(r));
            for i in 0..m.feature_dim() {
                // per-row: scaling fused into the write, same products
                assert_eq!(row[i], plain[i] * s);
                // batched vs per-row: trig-kernel budget
                assert!((batch.row(r)[i] - row[i]).abs() < 1e-5, "row {r} col {i}");
            }
        }
    }

    #[test]
    fn padding_is_zero_extension() {
        // Same content padded by hand must give identical features.
        let m = map(12, 1, 1.0, 10);
        let x: Vec<f32> = (0..12).map(|i| i as f32).collect();
        let mut xp = x.clone();
        xp.resize(16, 0.0);
        assert_eq!(m.transform(&x), m.transform(&xp));
    }

    #[test]
    fn zx_matches_transform_prefix() {
        let m = map(8, 2, 1.0, 11);
        let x: Vec<f32> = (0..8).map(|i| (i as f32) * 0.3).collect();
        let z1 = m.zx(1, &x);
        let f = m.transform(&x);
        let n = m.padded_dim();
        for i in 0..n {
            assert!((f[2 * n + i] - z1[i].cos()).abs() < 1e-6);
            assert!((f[2 * n + n + i] - z1[i].sin()).abs() < 1e-6);
        }
    }
}
