//! One Fastfood expansion: the materialized diagonals + permutation of
//! a single `Ẑ` instance, and its application to vectors.

use super::diag::{binary_diag, calibration_diag, gauss_diag};
use super::kernel::Kernel;
use crate::fwht;
use crate::fwht::batch::fwht_colmajor;
use crate::hash::hash_rng::streams;
use crate::hash::HashRng;
use crate::rand::fisher_yates::random_permutation;

/// The per-expansion operators of `Ẑ = (1/(σ√n))·C·H·G·Π·H·B`,
/// materialized (`O(n)` memory each — or zero if regenerated, see
/// [`FastfoodBlock::regenerate`]).
#[derive(Debug, Clone)]
pub struct FastfoodBlock {
    /// Padded dimension (power of two).
    n: usize,
    /// `B` diagonal (±1).
    b: Vec<f32>,
    /// `Π` as an index vector: `y[i] = x[perm[i]]`.
    perm: Vec<u32>,
    /// `G` diagonal (i.i.d. N(0,1)).
    g: Vec<f32>,
    /// `C` merged with `1/(σ√n ‖g‖)` (see [`super::diag::calibration_diag`]).
    scale: Vec<f32>,
}

impl FastfoodBlock {
    /// Materialize expansion `index` of a feature map with root seed
    /// `seed` (each expansion derives an independent hash stream).
    pub fn new(seed: u64, index: usize, n: usize, kernel: Kernel, sigma: f64) -> FastfoodBlock {
        assert!(n.is_power_of_two(), "padded dimension must be a power of two");
        let root = HashRng::new(seed, 0).derive(index as u64);
        let b = binary_diag(&root, n);
        let g = gauss_diag(&root, n);
        let scale = calibration_diag(&root, n, kernel, sigma, &g);
        let mut perm_rng = root.derive(streams::PERMUTATION);
        let perm = random_permutation(n, &mut perm_rng);
        FastfoodBlock { n, b, perm, g, scale }
    }

    /// Padded dimension.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Apply `Ẑ` to padded input `x` (`len n`), writing into `out`
    /// (`len n`), using `tmp` (`len n`) as scratch. All in `O(n log n)`.
    pub fn apply(&self, x: &[f32], out: &mut [f32], tmp: &mut [f32]) {
        let n = self.n;
        assert_eq!(x.len(), n);
        assert_eq!(out.len(), n);
        assert_eq!(tmp.len(), n);
        // v = B x
        for ((t, &xv), &bv) in tmp.iter_mut().zip(x).zip(&self.b) {
            *t = xv * bv;
        }
        // v = H v
        fwht::fwht(tmp);
        // v = Π v, then fold G in during the gather (single pass)
        for ((o, &p), &gv) in out.iter_mut().zip(&self.perm).zip(&self.g) {
            *o = tmp[p as usize] * gv;
        }
        // v = H v
        fwht::fwht(out);
        // v = (C/(σ√n‖g‖)) v
        for (o, &sv) in out.iter_mut().zip(&self.scale) {
            *o *= sv;
        }
    }

    /// Apply everything of `Ẑ` except the final calibration diagonal
    /// to a row-tile of `lanes` inputs, batch-vectorized.
    ///
    /// `xs` is a row-major `(lanes, src_cols)` slice with
    /// `src_cols ≤ n`; rows are zero-padded to `n` as they stream in.
    /// On return `tout` — column-major `(n, lanes)`, lane `l` of
    /// coefficient `j` at `tout[j*lanes + l]` — holds `H·G·Π·H·B·x̂`
    /// per lane; callers fold [`FastfoodBlock::scale`] into their
    /// consuming pass. `tin` is scratch of at least the same size.
    ///
    /// Fusions (each one single pass over the tile):
    /// * the `B` diagonal rides the transpose-in load (the first and
    ///   only read of `x`),
    /// * the `Π` gather and the `G` diagonal share one sweep — in
    ///   column-major layout `y_j = g_j · v_{π(j)}` is a contiguous
    ///   `lanes`-float stream copy per coefficient, not a scalar
    ///   gather.
    pub fn apply_tile(
        &self,
        xs: &[f32],
        src_cols: usize,
        lanes: usize,
        tin: &mut [f32],
        tout: &mut [f32],
    ) {
        self.apply_tile_with(xs, src_cols, lanes, tin, tout, false);
    }

    /// [`FastfoodBlock::apply_tile`] with the FWHT kernel selectable:
    /// `simd == true` routes both Hadamard passes through the explicit
    /// `fwht::simd` butterflies (the plan's `FwhtDispatch::Simd` arm),
    /// `false` keeps the scalar tile engine. The two are bit-identical
    /// — butterflies are pure adds/subs — so this flag can never change
    /// results, only throughput; the diagonal/gather fusions are shared
    /// either way.
    pub fn apply_tile_with(
        &self,
        xs: &[f32],
        src_cols: usize,
        lanes: usize,
        tin: &mut [f32],
        tout: &mut [f32],
        simd: bool,
    ) {
        let n = self.n;
        assert!(src_cols <= n, "row width {src_cols} exceeds padded dim {n}");
        assert_eq!(xs.len(), lanes * src_cols, "tile input length");
        assert!(
            tin.len() >= n * lanes && tout.len() >= n * lanes,
            "tile scratch size"
        );
        let tin = &mut tin[..n * lanes];
        let tout = &mut tout[..n * lanes];
        // transpose-in with B fused
        for j in 0..src_cols {
            let bj = self.b[j];
            let dst = &mut tin[j * lanes..(j + 1) * lanes];
            for (l, d) in dst.iter_mut().enumerate() {
                *d = xs[l * src_cols + j] * bj;
            }
        }
        tin[src_cols * lanes..].fill(0.0);
        // v = H v, all lanes in lockstep
        if simd {
            fwht::simd::fwht_colmajor(tin, n, lanes);
        } else {
            fwht_colmajor(tin, n, lanes);
        }
        // v = G Π v in one sweep
        for j in 0..n {
            let src = &tin[self.perm[j] as usize * lanes..][..lanes];
            let gj = self.g[j];
            let dst = &mut tout[j * lanes..(j + 1) * lanes];
            for (d, s) in dst.iter_mut().zip(src) {
                *d = *s * gj;
            }
        }
        // v = H v
        if simd {
            fwht::simd::fwht_colmajor(tout, n, lanes);
        } else {
            fwht_colmajor(tout, n, lanes);
        }
    }

    /// Accessors for cross-layer tests (Python L1/L2 must derive
    /// identical operators).
    pub fn b(&self) -> &[f32] {
        &self.b
    }
    pub fn g(&self) -> &[f32] {
        &self.g
    }
    pub fn scale(&self) -> &[f32] {
        &self.scale
    }
    pub fn perm(&self) -> &[u32] {
        &self.perm
    }

    /// Regeneration check: rebuild from the seed and compare — the
    /// paper's "no need to store the coefficients" property, used by
    /// tests and the checkpoint loader.
    pub fn regenerate(seed: u64, index: usize, n: usize, kernel: Kernel, sigma: f64) -> FastfoodBlock {
        FastfoodBlock::new(seed, index, n, kernel, sigma)
    }

    /// Bytes of coefficient state this block holds (what the hash trick
    /// saves when shipping models).
    pub fn coefficient_bytes(&self) -> usize {
        self.b.len() * 4 + self.g.len() * 4 + self.scale.len() * 4 + self.perm.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rand::fisher_yates::is_permutation;

    fn block(seed: u64, n: usize) -> FastfoodBlock {
        FastfoodBlock::new(seed, 0, n, Kernel::Rbf, 1.0)
    }

    #[test]
    fn construction_shapes() {
        let fb = block(1, 64);
        assert_eq!(fb.n(), 64);
        assert_eq!(fb.b().len(), 64);
        assert_eq!(fb.g().len(), 64);
        assert_eq!(fb.scale().len(), 64);
        assert!(is_permutation(fb.perm()));
    }

    #[test]
    fn apply_is_linear() {
        let fb = block(2, 32);
        let mut rng = HashRng::new(5, 5);
        let x: Vec<f32> = (0..32).map(|_| rng.next_f32() - 0.5).collect();
        let y: Vec<f32> = (0..32).map(|_| rng.next_f32() - 0.5).collect();
        let mut zx = vec![0.0; 32];
        let mut zy = vec![0.0; 32];
        let mut zxy = vec![0.0; 32];
        let mut tmp = vec![0.0; 32];
        fb.apply(&x, &mut zx, &mut tmp);
        fb.apply(&y, &mut zy, &mut tmp);
        let xy: Vec<f32> = x.iter().zip(&y).map(|(a, b)| a + 2.0 * b).collect();
        fb.apply(&xy, &mut zxy, &mut tmp);
        for i in 0..32 {
            assert!((zxy[i] - (zx[i] + 2.0 * zy[i])).abs() < 1e-3);
        }
    }

    #[test]
    fn expansions_are_independent() {
        let a = FastfoodBlock::new(7, 0, 64, Kernel::Rbf, 1.0);
        let b = FastfoodBlock::new(7, 1, 64, Kernel::Rbf, 1.0);
        assert_ne!(a.b(), b.b());
        assert_ne!(a.g(), b.g());
        assert_ne!(a.perm(), b.perm());
    }

    #[test]
    fn regeneration_identical() {
        let a = block(9, 128);
        let b = FastfoodBlock::regenerate(9, 0, 128, Kernel::Rbf, 1.0);
        assert_eq!(a.b(), b.b());
        assert_eq!(a.g(), b.g());
        assert_eq!(a.scale(), b.scale());
        assert_eq!(a.perm(), b.perm());
    }

    #[test]
    fn row_norms_match_gaussian_matrix() {
        // The whole point of the calibration: rows of Ẑ must have
        // norms distributed like rows of the dense RKS matrix
        // W ~ N(0, σ⁻²)ⁿˣⁿ. For a fixed unit vector x this gives
        // E‖Ẑx‖² = Σᵢ E[(rowᵢ·x)²] = Σᵢ ‖rowᵢ‖²/n = E[chi²_n]/σ² = n/σ².
        let n = 256;
        let sigma = 1.0f64;
        let mut tmp = vec![0.0; n];
        let mut out = vec![0.0; n];
        let mut acc = 0.0f64;
        let trials = 40;
        for s in 0..trials {
            let fb = FastfoodBlock::new(s as u64, 0, n, Kernel::Rbf, sigma);
            let mut rng = HashRng::new(s as u64 + 1000, 3);
            let mut x: Vec<f32> = (0..n).map(|_| rng.next_f32() - 0.5).collect();
            let xn = x.iter().map(|v| (*v as f64).powi(2)).sum::<f64>().sqrt();
            for v in x.iter_mut() {
                *v /= xn as f32;
            }
            fb.apply(&x, &mut out, &mut tmp);
            acc += out.iter().map(|v| (*v as f64).powi(2)).sum::<f64>();
        }
        let mean = acc / trials as f64;
        let expect = n as f64 / (sigma * sigma);
        assert!(
            (mean / expect - 1.0).abs() < 0.15,
            "mean {mean} expect {expect}"
        );
    }

    #[test]
    #[should_panic]
    fn non_pow2_rejected() {
        FastfoodBlock::new(1, 0, 48, Kernel::Rbf, 1.0);
    }

    #[test]
    fn apply_tile_matches_apply_exactly() {
        // multi-lane tile of full-width rows vs the per-row chain —
        // lanes never interact, so agreement is exact (modulo the
        // calibration diagonal the tile leaves to its consumer)
        let n = 64;
        let fb = block(4, n);
        let lanes = 7;
        let mut rng = HashRng::new(11, 7);
        let xs: Vec<f32> = (0..lanes * n).map(|_| rng.next_f32() - 0.5).collect();
        let mut tin = vec![0.0; n * lanes];
        let mut tout = vec![0.0; n * lanes];
        fb.apply_tile(&xs, n, lanes, &mut tin, &mut tout);
        let mut out = vec![0.0; n];
        let mut tmp = vec![0.0; n];
        for l in 0..lanes {
            fb.apply(&xs[l * n..(l + 1) * n], &mut out, &mut tmp);
            for j in 0..n {
                assert_eq!(tout[j * lanes + l] * fb.scale()[j], out[j], "lane {l} coeff {j}");
            }
        }
    }

    #[test]
    fn apply_tile_with_simd_is_bit_identical() {
        let n = 64;
        let fb = block(6, n);
        for (src_cols, lanes) in [(n, 5usize), (10, 3), (n, 1)] {
            let mut rng = HashRng::new(13, 9);
            let xs: Vec<f32> = (0..lanes * src_cols).map(|_| rng.next_f32() - 0.5).collect();
            let mut tin_a = vec![0.0; n * lanes];
            let mut tout_a = vec![0.0; n * lanes];
            fb.apply_tile_with(&xs, src_cols, lanes, &mut tin_a, &mut tout_a, false);
            let mut tin_b = vec![0.0; n * lanes];
            let mut tout_b = vec![0.0; n * lanes];
            fb.apply_tile_with(&xs, src_cols, lanes, &mut tin_b, &mut tout_b, true);
            assert_eq!(tout_a, tout_b, "src_cols={src_cols} lanes={lanes}");
        }
    }

    #[test]
    fn apply_tile_zero_pads_short_rows() {
        let n = 32;
        let src_cols = 10;
        let lanes = 3;
        let fb = block(5, n);
        let mut rng = HashRng::new(12, 8);
        let xs: Vec<f32> = (0..lanes * src_cols).map(|_| rng.next_f32() - 0.5).collect();
        let mut tin = vec![0.0; n * lanes];
        let mut tout = vec![0.0; n * lanes];
        fb.apply_tile(&xs, src_cols, lanes, &mut tin, &mut tout);
        // oracle: hand-pad each row, run the per-row chain, undo scale
        let mut out = vec![0.0; n];
        let mut tmp = vec![0.0; n];
        for l in 0..lanes {
            let mut padded = xs[l * src_cols..(l + 1) * src_cols].to_vec();
            padded.resize(n, 0.0);
            fb.apply(&padded, &mut out, &mut tmp);
            for j in 0..n {
                let got = tout[j * lanes + l] * fb.scale()[j];
                assert_eq!(got, out[j], "lane {l} coeff {j}");
            }
        }
    }
}
