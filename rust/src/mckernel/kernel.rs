//! Kernel calibration (paper §3 "Calibration C" and §6.1).
//!
//! The choice of kernel only changes the *radial distribution* of the
//! calibration entries: the diagonal `C` rescales each row of the
//! structured matrix `H·G·Π·H·B` so row norms follow the spectral
//! distribution of the target kernel.
//!
//! * **RBF** (Gaussian): row norms of an i.i.d. Gaussian matrix are
//!   chi_n distributed → `r_i ~ chi_n` via [`crate::rand::chi`].
//! * **RBF Matérn**: the paper's recipe — "draw `t` i.i.d. samples from
//!   the n-dimensional unit ball, add them and compute its Euclidean
//!   norm" (§6.1, Eq. 14).

use crate::hash::HashRng;
use crate::rand::{ball, chi, BoxMuller};

/// Which kernel the calibration diagonal realizes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Kernel {
    /// Gaussian RBF, `k(x,x') = exp(-‖x−x'‖²/(2σ²))` (paper Eq. 3).
    Rbf,
    /// RBF Matérn with `t` ball-sample summands (paper §6.1; the
    /// figures use `t = 40`).
    RbfMatern { t: u32 },
}

impl Kernel {
    /// Parse a CLI name.
    pub fn parse(s: &str) -> Option<Kernel> {
        match s {
            "rbf" => Some(Kernel::Rbf),
            "matern" | "rbf_matern" | "rbf-matern" => Some(Kernel::RbfMatern { t: 40 }),
            _ => None,
        }
    }

    /// Human name.
    pub fn name(&self) -> &'static str {
        match self {
            Kernel::Rbf => "rbf",
            Kernel::RbfMatern { .. } => "rbf_matern",
        }
    }

    /// Draw the calibration radius `r_i` for one output dimension of
    /// an `n`-dimensional expansion. `bm`/`uni` must be dedicated
    /// derived streams so entries are i.i.d. and regenerable.
    pub fn radius(&self, n: usize, bm: &mut BoxMuller, uni: &mut HashRng) -> f64 {
        match *self {
            Kernel::Rbf => chi(n as f64, bm, uni),
            Kernel::RbfMatern { t } => {
                // Sum of t uniform draws in the unit n-ball, then norm.
                let mut acc = vec![0.0f64; n];
                for _ in 0..t {
                    let z = ball::sample_ball(n, 1.0, uni.next_f64(), bm);
                    for (a, v) in acc.iter_mut().zip(z) {
                        *a += v;
                    }
                }
                ball::norm(&acc)
            }
        }
    }

    /// The exact kernel value `k(x, x')` — the oracle the approximate
    /// feature map is validated against.
    pub fn exact(&self, x: &[f32], y: &[f32], sigma: f64) -> f64 {
        assert_eq!(x.len(), y.len());
        let d2: f64 = x
            .iter()
            .zip(y.iter())
            .map(|(a, b)| {
                let d = (*a - *b) as f64;
                d * d
            })
            .sum();
        match self {
            Kernel::Rbf => (-d2 / (2.0 * sigma * sigma)).exp(),
            // No closed form published for the paper's summed-ball
            // Matérn variant; the RBF bound is used for sanity only.
            Kernel::RbfMatern { .. } => (-d2 / (2.0 * sigma * sigma)).exp(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn streams(seed: u64) -> (BoxMuller, HashRng) {
        (
            BoxMuller::new(HashRng::new(seed, 1)),
            HashRng::new(seed, 2),
        )
    }

    #[test]
    fn rbf_radius_matches_chi_mean() {
        // E[chi_n] ≈ √n for large n.
        let n = 256;
        let (mut bm, mut uni) = streams(11);
        let trials = 2_000;
        let mean: f64 = (0..trials)
            .map(|_| Kernel::Rbf.radius(n, &mut bm, &mut uni))
            .sum::<f64>()
            / trials as f64;
        let expect = (n as f64).sqrt();
        assert!((mean - expect).abs() < 0.05 * expect, "mean {mean} vs {expect}");
    }

    #[test]
    fn matern_radius_positive_and_bounded() {
        // Sum of t unit-ball vectors has norm ≤ t.
        let (mut bm, mut uni) = streams(13);
        let k = Kernel::RbfMatern { t: 10 };
        for _ in 0..200 {
            let r = k.radius(16, &mut bm, &mut uni);
            assert!(r > 0.0 && r <= 10.0, "r={r}");
        }
    }

    #[test]
    fn matern_radius_scales_sub_linearly_in_t() {
        // Random-walk norm grows ~√t, far below the t upper bound.
        let (mut bm, mut uni) = streams(17);
        let n = 32;
        let trials = 300;
        let mean_t = |t: u32, bm: &mut BoxMuller, uni: &mut HashRng| -> f64 {
            (0..trials)
                .map(|_| Kernel::RbfMatern { t }.radius(n, bm, uni))
                .sum::<f64>()
                / trials as f64
        };
        let m4 = mean_t(4, &mut bm, &mut uni);
        let m64 = mean_t(64, &mut bm, &mut uni);
        assert!(m64 > m4, "norm should grow with t");
        assert!(m64 < m4 * 16.0 * 0.5, "should grow sub-linearly: {m4} {m64}");
    }

    #[test]
    fn exact_rbf_values() {
        let x = [0.0f32, 0.0];
        let y = [1.0f32, 0.0];
        assert!((Kernel::Rbf.exact(&x, &x, 1.0) - 1.0).abs() < 1e-12);
        assert!((Kernel::Rbf.exact(&x, &y, 1.0) - (-0.5f64).exp()).abs() < 1e-9);
        // larger sigma → closer to 1
        assert!(Kernel::Rbf.exact(&x, &y, 10.0) > Kernel::Rbf.exact(&x, &y, 0.1));
    }

    #[test]
    fn parse_names() {
        assert_eq!(Kernel::parse("rbf"), Some(Kernel::Rbf));
        assert_eq!(Kernel::parse("matern"), Some(Kernel::RbfMatern { t: 40 }));
        assert_eq!(Kernel::parse("poly"), None);
        assert_eq!(Kernel::Rbf.name(), "rbf");
    }
}
