//! Box–Muller transform [Box and Muller 1958] — the paper's §3 choice
//! for generating the Gaussian diagonal `G`, "while substituting the
//! generator of random numbers by calls to the function of hashing".
//!
//! Both a sequential sampler and a *random-access* form are provided;
//! the random-access form derives the k-th Gaussian purely from the
//! hash stream, so diagonal entries can be regenerated in any order.

use crate::hash::HashRng;

/// Sequential standard-normal sampler (caches the second variate of
/// each Box–Muller pair).
#[derive(Debug, Clone)]
pub struct BoxMuller {
    rng: HashRng,
    spare: Option<f64>,
}

impl BoxMuller {
    pub fn new(rng: HashRng) -> Self {
        BoxMuller { rng, spare: None }
    }

    /// Next N(0,1) variate.
    pub fn next(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        let (z0, z1) = Self::pair(self.rng.next_f64(), self.rng.next_f64());
        self.spare = Some(z1);
        z0
    }

    /// Next N(mu, sigma²) variate.
    pub fn next_scaled(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.next()
    }

    /// Fill a slice with i.i.d. N(0,1) f32s.
    pub fn fill(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.next() as f32;
        }
    }

    /// The Box–Muller map: two U(0,1) variates → two N(0,1) variates.
    ///
    /// `u0` is clamped away from zero so `ln` stays finite.
    #[inline]
    pub fn pair(u0: f64, u1: f64) -> (f64, f64) {
        let u0 = if u0 <= f64::MIN_POSITIVE { f64::MIN_POSITIVE } else { u0 };
        let r = (-2.0 * u0.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u1;
        (r * theta.cos(), r * theta.sin())
    }

    /// Random-access: the k-th N(0,1) variate of stream `rng`,
    /// independent of sequential state (uses hash words `2k`, `2k+1`).
    #[inline]
    pub fn at(rng: &HashRng, k: u64) -> f64 {
        Self::pair(rng.at_f64(2 * k), rng.at_f64(2 * k + 1)).0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hash::hash_rng::streams;

    fn sampler(seed: u64) -> BoxMuller {
        BoxMuller::new(HashRng::new(seed, streams::GAUSS))
    }

    #[test]
    fn mean_and_variance() {
        let mut bm = sampler(1398239763);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| bm.next()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn third_moment_near_zero() {
        let mut bm = sampler(7);
        let n = 200_000;
        let skew = (0..n).map(|_| bm.next().powi(3)).sum::<f64>() / n as f64;
        assert!(skew.abs() < 0.05, "skew {skew}");
    }

    #[test]
    fn tail_mass_sane() {
        // P(|Z| > 3) ≈ 0.0027
        let mut bm = sampler(3);
        let n = 100_000;
        let tail = (0..n).filter(|_| bm.next().abs() > 3.0).count() as f64 / n as f64;
        assert!(tail < 0.006, "tail {tail}");
        assert!(tail > 0.0005, "tail {tail}");
    }

    #[test]
    fn deterministic() {
        let mut a = sampler(5);
        let mut b = sampler(5);
        for _ in 0..100 {
            assert_eq!(a.next(), b.next());
        }
    }

    #[test]
    fn random_access_matches_itself_and_distribution() {
        let rng = HashRng::new(11, streams::GAUSS);
        assert_eq!(BoxMuller::at(&rng, 5), BoxMuller::at(&rng, 5));
        let n = 100_000u64;
        let mean: f64 = (0..n).map(|k| BoxMuller::at(&rng, k)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn pair_is_finite_even_at_zero() {
        let (a, b) = BoxMuller::pair(0.0, 0.25);
        assert!(a.is_finite() && b.is_finite());
    }

    #[test]
    fn scaled_moments() {
        let mut bm = sampler(13);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| bm.next_scaled(3.0, 2.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.05, "mean {mean}");
    }
}
