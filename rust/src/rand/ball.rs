//! Uniform sampling on the n-sphere and in the n-ball (paper §6.1,
//! Eq. 14), used to draw the RBF Matérn calibration entries.
//!
//! The paper's algorithm: draw `X ~ N(0, I_n)`, project to the sphere
//! `Y = X / ‖X‖`, then scale by `r · U^{1/n}` with `U ~ U(0,1)` to get
//! a uniform draw in the radius-`r` ball (`Z = r U^{1/n} X/‖X‖`).

use super::box_muller::BoxMuller;

/// Uniform sample on the surface of the unit (n-1)-sphere in ℝⁿ.
pub fn sample_sphere(n: usize, bm: &mut BoxMuller) -> Vec<f64> {
    assert!(n > 0);
    loop {
        let x: Vec<f64> = (0..n).map(|_| bm.next()).collect();
        let norm = x.iter().map(|v| v * v).sum::<f64>().sqrt();
        // Astronomically unlikely, but regenerate rather than divide by ~0.
        if norm > 1e-12 {
            return x.into_iter().map(|v| v / norm).collect();
        }
    }
}

/// Uniform sample in the radius-`r` n-ball (paper Eq. 14:
/// `Z = r U^{1/n} X/‖X‖`). `u` must be an independent U(0,1) draw.
pub fn sample_ball(n: usize, r: f64, u: f64, bm: &mut BoxMuller) -> Vec<f64> {
    let radius = r * u.powf(1.0 / n as f64);
    sample_sphere(n, bm).into_iter().map(|v| v * radius).collect()
}

/// Euclidean norm helper.
pub fn norm(x: &[f64]) -> f64 {
    x.iter().map(|v| v * v).sum::<f64>().sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hash::HashRng;

    fn bm(seed: u64) -> BoxMuller {
        BoxMuller::new(HashRng::new(seed, 0xBA11))
    }

    #[test]
    fn sphere_samples_have_unit_norm() {
        let mut g = bm(1);
        for n in [1usize, 2, 3, 10, 100] {
            let y = sample_sphere(n, &mut g);
            assert_eq!(y.len(), n);
            assert!((norm(&y) - 1.0).abs() < 1e-9, "n={n}");
        }
    }

    #[test]
    fn sphere_mean_is_origin() {
        let mut g = bm(2);
        let n = 5;
        let trials = 20_000;
        let mut acc = vec![0.0; n];
        for _ in 0..trials {
            let y = sample_sphere(n, &mut g);
            for (a, v) in acc.iter_mut().zip(y) {
                *a += v;
            }
        }
        for a in acc {
            assert!((a / trials as f64).abs() < 0.02);
        }
    }

    #[test]
    fn ball_samples_inside_radius() {
        let mut g = bm(3);
        let mut u = HashRng::new(3, 1);
        for _ in 0..1000 {
            let z = sample_ball(8, 2.5, u.next_f64(), &mut g);
            assert!(norm(&z) <= 2.5 + 1e-9);
        }
    }

    #[test]
    fn ball_radius_distribution() {
        // P(R ≤ r) = r^n for the unit ball; median radius = (1/2)^{1/n}.
        let mut g = bm(4);
        let mut u = HashRng::new(4, 1);
        let n = 3usize;
        let trials = 40_000;
        let med = 0.5f64.powf(1.0 / n as f64);
        let below = (0..trials)
            .filter(|_| norm(&sample_ball(n, 1.0, u.next_f64(), &mut g)) <= med)
            .count() as f64
            / trials as f64;
        assert!((below - 0.5).abs() < 0.02, "below={below}");
    }

    #[test]
    fn ball_nearly_uniform_octants_2d() {
        let mut g = bm(5);
        let mut u = HashRng::new(5, 1);
        let mut quad = [0u32; 4];
        let trials = 40_000;
        for _ in 0..trials {
            let z = sample_ball(2, 1.0, u.next_f64(), &mut g);
            let q = (z[0] >= 0.0) as usize * 2 + (z[1] >= 0.0) as usize;
            quad[q] += 1;
        }
        for &q in &quad {
            assert!((q as f64 - trials as f64 / 4.0).abs() < trials as f64 * 0.02);
        }
    }
}
