//! Random variate generation on top of the hash RNG.
//!
//! Everything here is *re-computable*: given `(seed, stream)` the same
//! variates are produced on every call, which is how the paper avoids
//! storing the random matrices of the feature map (§3, §7).

pub mod ball;
pub mod box_muller;
pub mod fisher_yates;
pub mod gamma;

pub use ball::{sample_ball, sample_sphere};
pub use box_muller::BoxMuller;
pub use fisher_yates::{apply_permutation, invert_permutation, random_permutation};
pub use gamma::{chi, gamma};
