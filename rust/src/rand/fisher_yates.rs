//! Fisher–Yates shuffle with hash-derived draws (paper §3, operator Π).
//!
//! "We generate a random permutation using the FISHER-YATES shuffle …
//! to obtain a deterministic mapping, replace the generator of random
//! numbers with calls to the function of hashing." Runs in `O(n)` time
//! and the permutation is stored in `O(n)` space as an index vector.

use crate::hash::HashRng;

/// A uniformly random permutation of `{0, …, n-1}` drawn from `rng`
/// (modern inside-out Fisher–Yates). `perm[i]` is the source index of
/// output position `i`: `y[i] = x[perm[i]]`.
pub fn random_permutation(n: usize, rng: &mut HashRng) -> Vec<u32> {
    assert!(n <= u32::MAX as usize, "permutation too large for u32 indices");
    let mut perm: Vec<u32> = (0..n as u32).collect();
    // classic Fisher–Yates: for i from n-1 down to 1, swap i with j ≤ i
    for i in (1..n).rev() {
        let j = rng.next_below((i + 1) as u64) as usize;
        perm.swap(i, j);
    }
    perm
}

/// Apply `perm` out-of-place: `out[i] = x[perm[i]]`.
pub fn apply_permutation(x: &[f32], perm: &[u32], out: &mut [f32]) {
    assert_eq!(x.len(), perm.len());
    assert_eq!(x.len(), out.len());
    for (o, &p) in out.iter_mut().zip(perm.iter()) {
        *o = x[p as usize];
    }
}

/// Inverse permutation: `inv[perm[i]] = i`.
pub fn invert_permutation(perm: &[u32]) -> Vec<u32> {
    let mut inv = vec![0u32; perm.len()];
    for (i, &p) in perm.iter().enumerate() {
        inv[p as usize] = i as u32;
    }
    inv
}

/// Check that `perm` is a valid permutation of `0..n`.
pub fn is_permutation(perm: &[u32]) -> bool {
    let n = perm.len();
    let mut seen = vec![false; n];
    for &p in perm {
        let p = p as usize;
        if p >= n || seen[p] {
            return false;
        }
        seen[p] = true;
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hash::hash_rng::streams;

    fn rng(seed: u64) -> HashRng {
        HashRng::new(seed, streams::PERMUTATION)
    }

    #[test]
    fn is_valid_permutation() {
        for n in [0usize, 1, 2, 3, 17, 256, 1024] {
            let p = random_permutation(n, &mut rng(42));
            assert!(is_permutation(&p), "n={n}");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = random_permutation(1000, &mut rng(1));
        let b = random_permutation(1000, &mut rng(1));
        assert_eq!(a, b);
        let c = random_permutation(1000, &mut rng(2));
        assert_ne!(a, c);
    }

    #[test]
    fn inverse_roundtrip() {
        let p = random_permutation(512, &mut rng(7));
        let inv = invert_permutation(&p);
        let x: Vec<f32> = (0..512).map(|i| i as f32).collect();
        let mut y = vec![0.0; 512];
        let mut z = vec![0.0; 512];
        apply_permutation(&x, &p, &mut y);
        apply_permutation(&y, &inv, &mut z);
        assert_eq!(x, z);
    }

    #[test]
    fn apply_moves_values_not_mass() {
        let p = random_permutation(64, &mut rng(3));
        let x: Vec<f32> = (0..64).map(|i| (i * i) as f32).collect();
        let mut y = vec![0.0; 64];
        apply_permutation(&x, &p, &mut y);
        let mut xs = x.clone();
        let mut ys = y.clone();
        xs.sort_by(f32::total_cmp);
        ys.sort_by(f32::total_cmp);
        assert_eq!(xs, ys);
    }

    #[test]
    fn uniformity_chi_square_small_n() {
        // n=4 has 24 permutations; draw many and check rough uniformity.
        let mut counts = std::collections::HashMap::new();
        let mut r = rng(99);
        let trials = 24_000;
        for _ in 0..trials {
            let p = random_permutation(4, &mut r);
            *counts.entry(p).or_insert(0u32) += 1;
        }
        assert_eq!(counts.len(), 24);
        let expect = trials as f64 / 24.0;
        for (_, &c) in counts.iter() {
            assert!((c as f64 - expect).abs() < expect * 0.2, "count {c} vs {expect}");
        }
    }

    #[test]
    fn fixed_points_rare_for_large_n() {
        // Expected number of fixed points of a uniform permutation is 1.
        let p = random_permutation(10_000, &mut rng(5));
        let fixed = p.iter().enumerate().filter(|(i, &v)| *i == v as usize).count();
        assert!(fixed < 10, "suspiciously many fixed points: {fixed}");
    }

    #[test]
    fn invert_detects_identity() {
        let id: Vec<u32> = (0..100).collect();
        assert_eq!(invert_permutation(&id), id);
    }

    #[test]
    fn non_permutation_rejected() {
        assert!(!is_permutation(&[0, 0, 2]));
        assert!(!is_permutation(&[0, 3]));
        assert!(is_permutation(&[1, 0]));
        assert!(is_permutation(&[]));
    }
}
