//! Gamma and chi variates (Marsaglia–Tsang squeeze method).
//!
//! The RBF calibration diagonal `C` (paper §3: "a random scaling
//! operator whose behavior depends on the type of kernel chosen")
//! needs radii distributed like the row norms of a Gaussian matrix,
//! i.e. chi with `n` degrees of freedom: `chi_n = √(2·Gamma(n/2, 1))`.

use super::box_muller::BoxMuller;
use crate::hash::HashRng;

/// One Gamma(shape, 1) variate via Marsaglia–Tsang (2000).
///
/// Valid for any `shape > 0`; shapes below 1 use the boosting identity
/// `Gamma(a) = Gamma(a+1) · U^{1/a}`.
pub fn gamma(shape: f64, bm: &mut BoxMuller, uni: &mut HashRng) -> f64 {
    assert!(shape > 0.0, "gamma shape must be positive");
    if shape < 1.0 {
        let boosted = gamma(shape + 1.0, bm, uni);
        let u = uni.next_f64().max(f64::MIN_POSITIVE);
        return boosted * u.powf(1.0 / shape);
    }
    let d = shape - 1.0 / 3.0;
    let c = 1.0 / (9.0 * d).sqrt();
    loop {
        let x = bm.next();
        let v = 1.0 + c * x;
        if v <= 0.0 {
            continue;
        }
        let v3 = v * v * v;
        let u = uni.next_f64();
        // squeeze test, then full acceptance test
        if u < 1.0 - 0.0331 * x * x * x * x {
            return d * v3;
        }
        if u > 0.0 && u.ln() < 0.5 * x * x + d * (1.0 - v3 + v3.ln()) {
            return d * v3;
        }
    }
}

/// One chi_k variate (the Euclidean norm of k i.i.d. standard
/// normals): `√(2·Gamma(k/2))`.
pub fn chi(k: f64, bm: &mut BoxMuller, uni: &mut HashRng) -> f64 {
    assert!(k > 0.0);
    (2.0 * gamma(k / 2.0, bm, uni)).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn samplers(seed: u64) -> (BoxMuller, HashRng) {
        (
            BoxMuller::new(HashRng::new(seed, 0x6AAA)),
            HashRng::new(seed, 0x0111),
        )
    }

    #[test]
    fn gamma_mean_and_variance() {
        // Gamma(a,1): mean a, var a.
        for &a in &[0.5f64, 1.0, 2.5, 8.0] {
            let (mut bm, mut u) = samplers(42);
            let n = 60_000;
            let xs: Vec<f64> = (0..n).map(|_| gamma(a, &mut bm, &mut u)).collect();
            let mean = xs.iter().sum::<f64>() / n as f64;
            let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
            assert!((mean - a).abs() < 0.05 * a.max(1.0), "a={a} mean={mean}");
            assert!((var - a).abs() < 0.1 * a.max(1.0), "a={a} var={var}");
        }
    }

    #[test]
    fn gamma_positive() {
        let (mut bm, mut u) = samplers(7);
        for _ in 0..10_000 {
            assert!(gamma(0.3, &mut bm, &mut u) > 0.0);
        }
    }

    #[test]
    fn chi_matches_gaussian_norm() {
        // chi_k mean ≈ √k·(1 − 1/(4k)); check against direct norm of k
        // gaussians for k = 16.
        let k = 16usize;
        let (mut bm, mut u) = samplers(3);
        let n = 30_000;
        let mean_chi: f64 = (0..n).map(|_| chi(k as f64, &mut bm, &mut u)).sum::<f64>() / n as f64;
        let (mut bm2, _) = samplers(4);
        let mean_norm: f64 = (0..n)
            .map(|_| {
                (0..k).map(|_| bm2.next().powi(2)).sum::<f64>().sqrt()
            })
            .sum::<f64>()
            / n as f64;
        assert!(
            (mean_chi - mean_norm).abs() < 0.02 * mean_norm,
            "chi {mean_chi} vs norm {mean_norm}"
        );
    }

    #[test]
    fn deterministic() {
        let (mut b1, mut u1) = samplers(5);
        let (mut b2, mut u2) = samplers(5);
        for _ in 0..50 {
            assert_eq!(gamma(2.0, &mut b1, &mut u1), gamma(2.0, &mut b2, &mut u2));
        }
    }

    #[test]
    #[should_panic]
    fn zero_shape_rejected() {
        let (mut bm, mut u) = samplers(1);
        gamma(0.0, &mut bm, &mut u);
    }
}
