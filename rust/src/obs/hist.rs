//! Fixed-layout log-scale histogram for latency/duration samples.
//!
//! The layout is HDR-style with a hardwired geometry so recording is a
//! handful of bit tricks and two atomic adds — no allocation, no
//! locking, safe to share across threads behind an `Arc`:
//!
//! * values `0..16` land in 16 exact linear buckets;
//! * every octave `[2^o, 2^(o+1))` with `o >= 4` is split into 4
//!   sub-buckets keyed by the two bits below the leading bit.
//!
//! That gives `16 + 4·60 = 256` buckets covering all of `u64`, with
//! relative quantile error bounded by the sub-bucket width: ≤ 25%
//! above 16, exact below. Good enough to tell "FWHT dominates" from
//! "the trig polynomial dominates", which is what the engine stage
//! timers exist to answer.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};

/// Total bucket count (16 linear + 4 per octave for octaves 4..=63).
pub const BUCKETS: usize = 256;

/// Values below this threshold get their own exact bucket.
const LINEAR: u64 = 16;

/// Bucket index for a recorded value.
#[inline]
pub fn bucket_index(v: u64) -> usize {
    if v < LINEAR {
        return v as usize;
    }
    let o = 63 - v.leading_zeros() as usize; // leading-bit position, >= 4
    let sub = ((v >> (o - 2)) & 3) as usize; // two bits below the leading bit
    16 + (o - 4) * 4 + sub
}

/// Inclusive lower bound of a bucket (the smallest value mapping to it).
pub fn bucket_lo(idx: usize) -> u64 {
    assert!(idx < BUCKETS);
    if idx < 16 {
        return idx as u64;
    }
    let k = idx - 16;
    let o = 4 + k / 4;
    let sub = (k % 4) as u64;
    (1u64 << o) + sub * (1u64 << (o - 2))
}

/// Exclusive upper bound of a bucket.
fn bucket_hi(idx: usize) -> u64 {
    if idx + 1 < BUCKETS {
        bucket_lo(idx + 1)
    } else {
        u64::MAX
    }
}

/// Concurrent log-scale histogram. All operations are `&self`; every
/// field is an atomic updated with `Relaxed` ordering (metric reads
/// tolerate being a few records behind concurrent writers).
#[derive(Debug)]
pub struct Hist {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicU64,
    min: AtomicU64,
    max: AtomicU64,
}

impl Default for Hist {
    fn default() -> Hist {
        Hist::new()
    }
}

impl Hist {
    pub fn new() -> Hist {
        Hist {
            buckets: (0..BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
            max: AtomicU64::new(0),
        }
    }

    /// Record one sample.
    #[inline]
    pub fn record(&self, v: u64) {
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.min.fetch_min(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    /// Zero every bucket and the summary atomics.
    pub fn reset(&self) {
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
        self.count.store(0, Ordering::Relaxed);
        self.sum.store(0, Ordering::Relaxed);
        self.min.store(u64::MAX, Ordering::Relaxed);
        self.max.store(0, Ordering::Relaxed);
    }

    /// Point-in-time summary with bucket-interpolated percentiles.
    /// NaN-free: an empty histogram snapshots to all zeros.
    pub fn snapshot(&self) -> HistSnapshot {
        let buckets: Vec<u64> = self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        let count = self.count.load(Ordering::Relaxed);
        if count == 0 {
            return HistSnapshot { count: 0, sum: 0, min: 0, max: 0, p50: 0.0, p95: 0.0, p99: 0.0 };
        }
        let mut min = self.min.load(Ordering::Relaxed);
        let mut max = self.max.load(Ordering::Relaxed);
        // A concurrent record() may have bumped `count` without having
        // stored its min/max yet, leaving the bounds inverted (fresh
        // histogram: min = u64::MAX > max = 0; or only one of the two
        // stores visible). `f64::clamp` panics on min > max, so repair
        // the pair from whichever store landed before clamping.
        if min > max {
            if min == u64::MAX {
                min = max;
            } else {
                max = min;
            }
        }
        // Bucket interpolation can land just outside the observed
        // range (e.g. one sample at 100 sits in bucket [96, 112), so
        // the raw p50 is 96); the true empirical percentile always
        // lies in [min, max], so clamp to it.
        let pct = |p: f64| percentile_from(&buckets, count, p).clamp(min as f64, max as f64);
        HistSnapshot {
            count,
            sum: self.sum.load(Ordering::Relaxed),
            min,
            max,
            p50: pct(50.0),
            p95: pct(95.0),
            p99: pct(99.0),
        }
    }
}

/// Nearest-rank percentile over bucket counts, linearly interpolated
/// inside the winning bucket. Exact for values below 16 when the
/// bucket holds one sample; otherwise bounded by the bucket width.
fn percentile_from(buckets: &[u64], count: u64, pct: f64) -> f64 {
    if count == 0 {
        return 0.0;
    }
    let target = ((pct / 100.0) * count as f64).ceil().max(1.0) as u64;
    let mut cum = 0u64;
    for (i, &c) in buckets.iter().enumerate() {
        if c == 0 {
            continue;
        }
        if cum + c >= target {
            let lo = bucket_lo(i) as f64;
            let hi = bucket_hi(i) as f64;
            let frac = if c > 1 { (target - cum - 1) as f64 / (c - 1) as f64 } else { 0.0 };
            return lo + frac * (hi - lo);
        }
        cum += c;
    }
    bucket_lo(BUCKETS - 1) as f64
}

/// One histogram's summary at snapshot time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HistSnapshot {
    pub count: u64,
    pub sum: u64,
    pub min: u64,
    pub max: u64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl HistSnapshot {
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Shared-schema JSON (see [`Dist`]).
    pub fn to_json(&self) -> Json {
        Dist {
            count: self.count,
            sum: self.sum as f64,
            min: self.min as f64,
            max: self.max as f64,
            mean: self.mean(),
            p50: self.p50,
            p95: self.p95,
            p99: self.p99,
        }
        .to_json()
    }
}

/// One distribution in the snapshot schema shared by the live metrics
/// registry and `benchkit`'s BENCH_*.json reports: both serialize
/// through this struct, so a consumer parsing `count/sum/min/max/mean/
/// p50/p95/p99` reads either source identically.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Dist {
    pub count: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Dist {
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("count".to_string(), Json::Num(self.count as f64));
        m.insert("sum".to_string(), Json::Num(self.sum));
        m.insert("min".to_string(), Json::Num(self.min));
        m.insert("max".to_string(), Json::Num(self.max));
        m.insert("mean".to_string(), Json::Num(self.mean));
        m.insert("p50".to_string(), Json::Num(self.p50));
        m.insert("p95".to_string(), Json::Num(self.p95));
        m.insert("p99".to_string(), Json::Num(self.p99));
        Json::Obj(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_buckets_are_exact() {
        for v in 0..16u64 {
            assert_eq!(bucket_index(v), v as usize);
            assert_eq!(bucket_lo(v as usize), v);
        }
    }

    #[test]
    fn bucket_bounds_are_monotonic_and_consistent() {
        for idx in 0..BUCKETS {
            let lo = bucket_lo(idx);
            assert_eq!(bucket_index(lo), idx, "lo of bucket {idx} maps back");
            if idx > 0 {
                assert!(bucket_lo(idx - 1) < lo);
            }
        }
        // spot checks on the log region: octave 4 = [16, 32) in 4 sub-buckets
        assert_eq!(bucket_index(16), 16);
        assert_eq!(bucket_index(19), 16);
        assert_eq!(bucket_index(20), 17);
        assert_eq!(bucket_index(31), 19);
        assert_eq!(bucket_index(32), 20);
        assert_eq!(bucket_index(u64::MAX), BUCKETS - 1);
    }

    #[test]
    fn relative_error_bounded() {
        // every value's bucket bounds bracket it within 25%
        for &v in &[16u64, 100, 1_000, 123_456, 1 << 40, u64::MAX / 3] {
            let idx = bucket_index(v);
            let lo = bucket_lo(idx);
            let hi = bucket_hi(idx);
            assert!(lo <= v && v < hi, "{v} not in [{lo}, {hi})");
            assert!((hi - lo) as f64 / lo as f64 <= 0.25 + 1e-9, "bucket too wide at {v}");
        }
    }

    #[test]
    fn percentiles_of_small_exact_values() {
        let h = Hist::new();
        for v in 0..10u64 {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 10);
        assert_eq!(s.sum, 45);
        assert_eq!(s.min, 0);
        assert_eq!(s.max, 9);
        // nearest-rank: p50 → 5th smallest = 4, p95/p99 → 10th = 9
        assert_eq!(s.p50, 4.0);
        assert_eq!(s.p95, 9.0);
        assert_eq!(s.p99, 9.0);
        assert!((s.mean() - 4.5).abs() < 1e-12);
    }

    #[test]
    fn percentiles_of_log_region_within_bucket_error() {
        let h = Hist::new();
        for i in 1..=1000u64 {
            h.record(i * 1000); // 1k..1M ns, say
        }
        let s = h.snapshot();
        assert_eq!(s.count, 1000);
        // true p50 = 500_000, p95 = 950_000; allow the 25% bucket width
        assert!((s.p50 - 500_000.0).abs() / 500_000.0 <= 0.25, "p50 = {}", s.p50);
        assert!((s.p95 - 950_000.0).abs() / 950_000.0 <= 0.25, "p95 = {}", s.p95);
        assert!(s.p50 <= s.p95 && s.p95 <= s.p99);
    }

    #[test]
    fn empty_snapshot_is_nan_free_zeros() {
        let s = Hist::new().snapshot();
        assert_eq!(s.count, 0);
        assert_eq!((s.min, s.max, s.sum), (0, 0, 0));
        assert_eq!((s.p50, s.p95, s.p99), (0.0, 0.0, 0.0));
        assert_eq!(s.mean(), 0.0);
        // and the JSON form carries finite numbers only
        let j = s.to_json();
        for k in ["count", "sum", "min", "max", "mean", "p50", "p95", "p99"] {
            assert!(j.get(k).unwrap().as_f64().unwrap().is_finite(), "{k}");
        }
    }

    #[test]
    fn reset_clears_everything() {
        let h = Hist::new();
        h.record(42);
        h.record(7);
        h.reset();
        let s = h.snapshot();
        assert_eq!(s.count, 0);
        assert_eq!(s.p95, 0.0);
        h.record(3);
        assert_eq!(h.snapshot().count, 1);
        assert_eq!(h.snapshot().min, 3);
    }

    #[test]
    fn percentiles_clamped_to_observed_range() {
        let h = Hist::new();
        h.record(100); // bucket [96, 112): raw interpolation says 96
        let s = h.snapshot();
        assert_eq!(s.p50, 100.0);
        assert_eq!(s.p99, 100.0);
        h.record(100_000);
        let s = h.snapshot();
        assert!(s.p50 >= s.min as f64 && s.p99 <= s.max as f64);
        assert!(s.p50 <= s.p95 && s.p95 <= s.p99);
    }

    #[test]
    fn snapshot_tolerates_half_published_record() {
        // Race regression: snapshot() between a record()'s count
        // increment and its min/max stores used to see count > 0 with
        // min = u64::MAX > max = 0 and panic inside f64::clamp. Spin
        // fresh histograms so every iteration crosses the window where
        // the summary atomics are still at their initial values.
        for round in 0..200u64 {
            let h = std::sync::Arc::new(Hist::new());
            let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
            let recorder = {
                let h = std::sync::Arc::clone(&h);
                let stop = std::sync::Arc::clone(&stop);
                std::thread::spawn(move || {
                    let mut i = 0u64;
                    while !stop.load(Ordering::Relaxed) {
                        // large values keep min/max far from their
                        // initial 0 / u64::MAX sentinels
                        h.record((1 << 40) + round * 1000 + i);
                        i += 1;
                    }
                })
            };
            for _ in 0..50 {
                let s = h.snapshot();
                assert!(s.min <= s.max, "inverted bounds escaped repair");
                for p in [s.p50, s.p95, s.p99] {
                    assert!(p.is_finite());
                }
            }
            stop.store(true, Ordering::Relaxed);
            recorder.join().unwrap();
        }
    }

    #[test]
    fn concurrent_records_all_land() {
        let h = std::sync::Arc::new(Hist::new());
        let mut joins = Vec::new();
        for t in 0..4 {
            let h = std::sync::Arc::clone(&h);
            joins.push(std::thread::spawn(move || {
                for i in 0..1000u64 {
                    h.record(t * 1_000_000 + i);
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        assert_eq!(h.snapshot().count, 4000);
    }
}
