//! The metrics registry: named counters, gauges, and histograms.
//!
//! Handles (`Arc<Counter>` etc.) are resolved once by name and then
//! recorded through lock-free atomics; the registry's maps are only
//! locked at registration and snapshot time, never on the hot path.
//! Two registrations of the same name return the same underlying
//! metric, so a "compatibility view" like `coordinator::ServerStats`
//! and a raw `snapshot_json()` consumer always agree.

use super::hist::Hist;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Monotonic event counter.
#[derive(Debug, Default)]
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    #[inline]
    pub fn inc(&self) {
        self.v.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.v.store(0, Ordering::Relaxed);
    }
}

/// Instantaneous signed level (queue depths, in-flight work).
#[derive(Debug, Default)]
pub struct Gauge {
    v: AtomicI64,
}

impl Gauge {
    #[inline]
    pub fn add(&self, delta: i64) {
        self.v.fetch_add(delta, Ordering::Relaxed);
    }

    pub fn set(&self, v: i64) {
        self.v.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.v.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.v.store(0, Ordering::Relaxed);
    }
}

/// A named collection of metrics plus an enabled flag.
///
/// The process-wide instance ([`super::global`]) starts **disabled**:
/// instrumented call sites that check [`MetricsRegistry::is_enabled`]
/// at setup time (the engine's stage timers, the trainers) then skip
/// all timestamping, so the disabled hot path costs one branch.
/// Freshly constructed registries start enabled — tests inject their
/// own (e.g. `FeatureServer::start_with_registry`) for deterministic,
/// isolated counts.
#[derive(Debug)]
pub struct MetricsRegistry {
    enabled: AtomicBool,
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    hists: Mutex<BTreeMap<String, Arc<Hist>>>,
}

impl Default for MetricsRegistry {
    fn default() -> MetricsRegistry {
        MetricsRegistry::new()
    }
}

impl MetricsRegistry {
    /// A fresh, enabled registry.
    pub fn new() -> MetricsRegistry {
        MetricsRegistry {
            enabled: AtomicBool::new(true),
            counters: Mutex::new(BTreeMap::new()),
            gauges: Mutex::new(BTreeMap::new()),
            hists: Mutex::new(BTreeMap::new()),
        }
    }

    /// A fresh registry with recording gates off (the global default).
    pub fn disabled() -> MetricsRegistry {
        let r = MetricsRegistry::new();
        r.enabled.store(false, Ordering::Relaxed);
        r
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    /// Resolve (creating if absent) the counter named `name`.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        Arc::clone(self.counters.lock().unwrap().entry(name.to_string()).or_default())
    }

    /// Resolve (creating if absent) the gauge named `name`.
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        Arc::clone(self.gauges.lock().unwrap().entry(name.to_string()).or_default())
    }

    /// Resolve (creating if absent) the histogram named `name`.
    pub fn histogram(&self, name: &str) -> Arc<Hist> {
        Arc::clone(self.hists.lock().unwrap().entry(name.to_string()).or_default())
    }

    /// Current value of a counter, if registered.
    pub fn counter_value(&self, name: &str) -> Option<u64> {
        self.counters.lock().unwrap().get(name).map(|c| c.get())
    }

    /// Zero every registered metric (names stay registered).
    pub fn reset(&self) {
        for c in self.counters.lock().unwrap().values() {
            c.reset();
        }
        for g in self.gauges.lock().unwrap().values() {
            g.reset();
        }
        for h in self.hists.lock().unwrap().values() {
            h.reset();
        }
    }

    /// Serialize every metric: `{"enabled": …, "counters": {name:
    /// value}, "gauges": {name: value}, "histograms": {name: dist}}`
    /// where `dist` is the shared schema of [`super::Dist`]. Key order
    /// is stable (BTreeMap), so snapshots diff cleanly.
    pub fn snapshot_json(&self) -> Json {
        let counters: BTreeMap<String, Json> = self
            .counters
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), Json::Num(v.get() as f64)))
            .collect();
        let gauges: BTreeMap<String, Json> = self
            .gauges
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), Json::Num(v.get() as f64)))
            .collect();
        let hists: BTreeMap<String, Json> = self
            .hists
            .lock()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.snapshot().to_json()))
            .collect();
        let mut root = BTreeMap::new();
        root.insert("enabled".to_string(), Json::Bool(self.is_enabled()));
        root.insert("counters".to_string(), Json::Obj(counters));
        root.insert("gauges".to_string(), Json::Obj(gauges));
        root.insert("histograms".to_string(), Json::Obj(hists));
        Json::Obj(root)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_name_resolves_same_metric() {
        let reg = MetricsRegistry::new();
        let a = reg.counter("x.hits");
        let b = reg.counter("x.hits");
        a.inc();
        b.add(2);
        assert_eq!(a.get(), 3);
        assert_eq!(reg.counter_value("x.hits"), Some(3));
        assert_eq!(reg.counter_value("x.misses"), None);
    }

    #[test]
    fn gauge_tracks_level() {
        let reg = MetricsRegistry::new();
        let g = reg.gauge("q.depth");
        g.add(3);
        g.add(-1);
        assert_eq!(g.get(), 2);
        g.set(-5);
        assert_eq!(g.get(), -5);
    }

    #[test]
    fn enabled_flag_defaults() {
        assert!(MetricsRegistry::new().is_enabled());
        let d = MetricsRegistry::disabled();
        assert!(!d.is_enabled());
        d.set_enabled(true);
        assert!(d.is_enabled());
    }

    #[test]
    fn snapshot_shape_and_stability() {
        let reg = MetricsRegistry::new();
        reg.counter("b.count").add(7);
        reg.gauge("a.depth").set(2);
        reg.histogram("c.lat_ns").record(1000);
        let s = reg.snapshot_json();
        assert_eq!(s.get("counters").unwrap().get("b.count").unwrap().as_usize(), Some(7));
        assert_eq!(s.get("gauges").unwrap().get("a.depth").unwrap().as_usize(), Some(2));
        let h = s.get("histograms").unwrap().get("c.lat_ns").unwrap();
        assert_eq!(h.get("count").unwrap().as_usize(), Some(1));
        assert!(h.get("p95").unwrap().as_f64().unwrap() >= 1000.0 * 0.75);
        // identical registries print identically (stable ordering)
        assert_eq!(s.to_string(), reg.snapshot_json().to_string());
    }

    #[test]
    fn reset_preserves_names() {
        let reg = MetricsRegistry::new();
        reg.counter("n").add(9);
        reg.histogram("h").record(5);
        reg.reset();
        assert_eq!(reg.counter_value("n"), Some(0));
        assert_eq!(reg.histogram("h").snapshot().count, 0);
    }
}
