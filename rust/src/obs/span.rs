//! Scoped timers ("spans") with a thread-local nesting stack and an
//! optional JSONL trace sink.
//!
//! `let _s = obs::span("phase");` times the enclosing scope: on drop
//! it records into the global histogram `span.<name>_ns` and — when a
//! trace file is open via [`trace_to`] — appends one JSON line with
//! the span's name, parent, depth, offset from process start, and
//! duration. When the global registry is disabled, `span()` returns
//! an inert guard that does nothing on drop.

use super::hist::Hist;
use crate::util::json::Json;
use std::cell::RefCell;
use std::collections::BTreeMap;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

thread_local! {
    /// Names of the spans currently open on this thread, outermost first.
    static STACK: RefCell<Vec<&'static str>> = const { RefCell::new(Vec::new()) };
}

/// Reference point for trace timestamps (first use of the obs layer).
static EPOCH: OnceLock<Instant> = OnceLock::new();

fn process_epoch() -> Instant {
    *EPOCH.get_or_init(Instant::now)
}

static TRACE: OnceLock<Mutex<Option<BufWriter<File>>>> = OnceLock::new();

fn trace_slot() -> &'static Mutex<Option<BufWriter<File>>> {
    TRACE.get_or_init(|| Mutex::new(None))
}

/// Open `path` as the JSONL trace sink (one JSON object per completed
/// span). Replaces any previously open sink.
pub fn trace_to(path: &str) -> std::io::Result<()> {
    let _ = process_epoch(); // pin the epoch before any span closes
    let f = File::create(path)?;
    *trace_slot().lock().unwrap() = Some(BufWriter::new(f));
    Ok(())
}

/// Flush and close the trace sink, if open.
pub fn trace_off() {
    if let Some(mut w) = trace_slot().lock().unwrap().take() {
        let _ = w.flush();
    }
}

/// Time a scope. Drop records; bind to a named `_guard` (a bare `_`
/// drops immediately and times nothing).
#[must_use = "the span records on drop; binding to `_` measures nothing"]
pub struct SpanGuard {
    name: &'static str,
    start: Option<Instant>,
    hist: Option<Arc<Hist>>,
}

/// Open a span named `name`. Inert (and nearly free) while the global
/// registry is disabled.
pub fn span(name: &'static str) -> SpanGuard {
    if !super::enabled() {
        return SpanGuard { name, start: None, hist: None };
    }
    let hist = super::global().histogram(&format!("span.{name}_ns"));
    STACK.with(|s| s.borrow_mut().push(name));
    SpanGuard { name, start: Some(Instant::now()), hist: Some(hist) }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let Some(start) = self.start else { return };
        let dur_ns = super::elapsed_ns(start);
        if let Some(h) = &self.hist {
            h.record(dur_ns);
        }
        let (depth, parent) = STACK.with(|s| {
            let mut st = s.borrow_mut();
            st.pop();
            (st.len(), st.last().copied())
        });
        trace_line(self.name, parent, depth, start, dur_ns);
    }
}

fn trace_line(name: &str, parent: Option<&'static str>, depth: usize, start: Instant, dur_ns: u64) {
    let mut guard = trace_slot().lock().unwrap();
    let Some(w) = guard.as_mut() else { return };
    let t_ns = start
        .checked_duration_since(process_epoch())
        // Saturate like `elapsed_ns` instead of `as`-truncating: a
        // u128 span past u64::MAX ns would otherwise wrap silently.
        .map(|d| u64::try_from(d.as_nanos()).unwrap_or(u64::MAX))
        .unwrap_or(0);
    let mut m = BTreeMap::new();
    m.insert("name".to_string(), Json::Str(name.to_string()));
    if let Some(p) = parent {
        m.insert("parent".to_string(), Json::Str(p.to_string()));
    }
    m.insert("depth".to_string(), Json::Num(depth as f64));
    m.insert("t_ns".to_string(), Json::Num(t_ns as f64));
    m.insert("dur_ns".to_string(), Json::Num(dur_ns as f64));
    let _ = writeln!(w, "{}", Json::Obj(m));
    let _ = w.flush();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs;

    #[test]
    fn span_records_into_named_histogram() {
        obs::enable();
        {
            let _g = obs::span("span_unit_test");
            std::hint::black_box(0u64);
        }
        let snap = obs::global().histogram("span.span_unit_test_ns").snapshot();
        assert!(snap.count >= 1);
    }

    #[test]
    fn nested_spans_trace_parent_and_depth() {
        obs::enable();
        let path =
            std::env::temp_dir().join(format!("mckernel_trace_{}.jsonl", std::process::id()));
        trace_to(path.to_str().unwrap()).unwrap();
        {
            let _outer = obs::span("trace_outer");
            let _inner = obs::span("trace_inner");
        }
        trace_off();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<Json> = text.lines().map(|l| Json::parse(l).unwrap()).collect();
        let inner = lines
            .iter()
            .find(|j| j.get("name").unwrap().as_str() == Some("trace_inner"))
            .expect("inner span traced");
        assert_eq!(inner.get("parent").unwrap().as_str(), Some("trace_outer"));
        assert_eq!(inner.get("depth").unwrap().as_usize(), Some(1));
        let outer = lines
            .iter()
            .find(|j| j.get("name").unwrap().as_str() == Some("trace_outer"))
            .expect("outer span traced");
        assert!(outer.get("parent").is_none());
        assert_eq!(outer.get("depth").unwrap().as_usize(), Some(0));
        assert!(
            outer.get("dur_ns").unwrap().as_f64().unwrap()
                >= inner.get("dur_ns").unwrap().as_f64().unwrap()
        );
        let _ = std::fs::remove_file(&path);
    }
}
