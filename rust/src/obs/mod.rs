//! Zero-dependency observability: an atomic metrics registry
//! ([`MetricsRegistry`]: counters, gauges, fixed-bucket log-scale
//! histograms with p50/p95/p99 snapshots), scoped timers with a
//! thread-local span stack and optional JSONL trace export
//! ([`span`], [`trace_to`]), and a process-wide registry that costs
//! one branch per instrumented call when disabled.
//!
//! ## Conventions
//!
//! * Durations are recorded in **nanoseconds**, metric names say so
//!   (`…_ns`); counters count events or rows; gauges are levels.
//! * Names are `layer.metric` or `layer.key.metric` — e.g.
//!   `server.latency_ns`, `engine.<plan-fingerprint>.fwht_ns`,
//!   `train.shard_ns`, `prefetch.stall_ns`, `span.<name>_ns`.
//! * Hot paths resolve their `Arc` handles once at setup; recording
//!   is lock-free atomics.
//! * The global registry starts **disabled**. Fine-grained timers
//!   (engine stages, trainer shards) check `enabled()` at setup and
//!   skip timestamping entirely when off; coarse once-per-request /
//!   once-per-batch metrics (the server, the prefetcher) record
//!   unconditionally so their compatibility views stay exact.
//!
//! `mckernel stats` (see `cli::commands`) enables the registry,
//! drives an instrumented workload, and writes
//! [`MetricsRegistry::snapshot_json`] — the same schema `benchkit`
//! reports distributions in (see [`Dist`]).

pub mod hist;
pub mod registry;
pub mod span;

pub use hist::{Dist, Hist, HistSnapshot};
pub use registry::{Counter, Gauge, MetricsRegistry};
pub use span::{span, trace_off, trace_to, SpanGuard};

use std::sync::OnceLock;
use std::time::Instant;

/// Nanoseconds elapsed since `t0` as the `u64` every histogram
/// records — the one place the `u128 → u64` cast lives. Saturates at
/// `u64::MAX` (≈584 years) instead of truncating high bits.
#[inline]
pub fn elapsed_ns(t0: Instant) -> u64 {
    u64::try_from(t0.elapsed().as_nanos()).unwrap_or(u64::MAX)
}

static GLOBAL: OnceLock<MetricsRegistry> = OnceLock::new();

/// The process-wide registry. Starts disabled; `mckernel stats` (or
/// any embedder) turns it on with [`enable`].
pub fn global() -> &'static MetricsRegistry {
    GLOBAL.get_or_init(MetricsRegistry::disabled)
}

/// Enable recording on the global registry.
pub fn enable() {
    global().set_enabled(true);
}

/// Disable recording on the global registry.
pub fn disable() {
    global().set_enabled(false);
}

/// Whether the global registry is currently recording.
pub fn enabled() -> bool {
    global().is_enabled()
}
