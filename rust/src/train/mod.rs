//! The training stack: featurizers, metrics, and the epoch-loop
//! trainer that reproduces the paper's mini-batch SGD experiments
//! (§7, §9).

pub mod featurizer;
pub mod metrics;
pub mod trainer;

pub use featurizer::{FeatureEngine, Featurizer};
pub use metrics::{accuracy, confusion_matrix, EpochRecord};
pub use trainer::{evaluate_with, ParallelTrainer, RetryPolicy, TrainConfig, Trainer, TrainReport};
