//! Featurizer abstraction: identity (the LR baseline feeds raw
//! pixels), the native McKernel map, or a parallel McKernel map over
//! the thread pool — the paper's two curves in Figures 3–5 differ
//! only in this choice.

use crate::fwht::batch::tile_lanes;
use crate::linalg::Matrix;
use crate::mckernel::{BatchScratch, McKernel};
use crate::util::ThreadPool;
use std::sync::Arc;

/// Per-worker featurization scratch for the shard-parallel trainer
/// (`None` for identity — raw pixels need no work buffers).
pub struct ShardScratch(Option<BatchScratch>);

/// Maps a `(batch, pixels)` matrix to the classifier's input space.
pub enum Featurizer {
    /// Raw input (logistic-regression baseline: `softmax(Wx + b)`).
    Identity,
    /// McKernel features, single-threaded (`softmax(W·mckernel(x)+b)`).
    McKernel(Arc<McKernel>),
    /// McKernel features computed across a thread pool (rows are
    /// independent — embarrassingly parallel).
    McKernelParallel(Arc<McKernel>, Arc<ThreadPool>),
}

impl Featurizer {
    /// Output width.
    pub fn feature_dim(&self, input_dim: usize) -> usize {
        match self {
            Featurizer::Identity => input_dim,
            Featurizer::McKernel(m) | Featurizer::McKernelParallel(m, _) => m.feature_dim(),
        }
    }

    /// Short name for logs.
    pub fn name(&self) -> &'static str {
        match self {
            Featurizer::Identity => "identity",
            Featurizer::McKernel(_) => "mckernel",
            Featurizer::McKernelParallel(..) => "mckernel-par",
        }
    }

    /// Scratch for [`Featurizer::apply_shard`], one per worker.
    pub fn make_shard_scratch(&self) -> ShardScratch {
        match self {
            Featurizer::Identity => ShardScratch(None),
            Featurizer::McKernel(m) | Featurizer::McKernelParallel(m, _) => {
                ShardScratch(Some(m.make_batch_scratch()))
            }
        }
    }

    /// Shard-aware apply: featurize `rows` raw rows (`xs`, row-major,
    /// width `d`) into the preallocated `out` (`rows × feature_dim`)
    /// without allocating — the data-parallel trainer calls this from
    /// every worker on its own shard with its own scratch. Same math
    /// as [`Featurizer::apply`]: the batched McKernel pipeline is
    /// invariant to how rows are grouped into tiles, so shard splits
    /// agree bit-for-bit with the full-batch path.
    pub fn apply_shard(
        &self,
        xs: &[f32],
        rows: usize,
        d: usize,
        out: &mut [f32],
        scratch: &mut ShardScratch,
    ) {
        assert_eq!(xs.len(), rows * d, "shard input length");
        assert_eq!(out.len(), rows * self.feature_dim(d), "shard output length");
        match self {
            Featurizer::Identity => out.copy_from_slice(xs),
            Featurizer::McKernel(m) | Featurizer::McKernelParallel(m, _) => {
                let scratch = scratch
                    .0
                    .as_mut()
                    .expect("shard scratch built for a different featurizer");
                m.transform_batch_slice_into(xs, rows, d, out, scratch);
            }
        }
    }

    /// Apply to a batch through the batch-vectorized pipeline. The
    /// parallel variant splits whole *row-tiles* — not single rows —
    /// across the pool, so every worker streams L2-resident tiles
    /// through the fused Fastfood passes.
    pub fn apply(&self, x: &Matrix) -> Matrix {
        match self {
            Featurizer::Identity => x.clone(),
            Featurizer::McKernel(m) => m.transform_batch(x),
            Featurizer::McKernelParallel(m, pool) => {
                let rows = x.rows();
                let d = x.cols();
                let fd = m.feature_dim();
                let mut out = Matrix::zeros(rows, fd);
                if rows == 0 {
                    return out;
                }
                // Whole tiles per task; tile grouping does not change
                // results (lanes never interact), so any split agrees
                // bit-for-bit with the serial batched path.
                let tile = tile_lanes(m.padded_dim());
                let tiles = rows.div_ceil(tile);
                let chunk = tiles.div_ceil(pool.size()).max(1) * tile;
                let tasks = rows.div_ceil(chunk);
                let out_ptr = SendPtr(out.data_mut().as_mut_ptr());
                let in_ptr = SendConstPtr(x.data().as_ptr());
                let m2 = Arc::clone(m);
                pool.scope_for_each(tasks, move |t| {
                    // force whole-struct capture (edition-2021 would
                    // otherwise capture the raw-pointer fields, which
                    // are not Send)
                    let out_ptr = out_ptr;
                    let in_ptr = in_ptr;
                    let lo = t * chunk;
                    let hi = ((t + 1) * chunk).min(rows);
                    let mut scratch = m2.make_batch_scratch();
                    // SAFETY: tasks own disjoint row ranges, and both
                    // the input batch and the output buffer outlive
                    // scope_for_each (it blocks until every task is
                    // done) — the batch is borrowed for the scope, not
                    // cloned into an Arc per call.
                    let xs = unsafe {
                        std::slice::from_raw_parts(in_ptr.0.add(lo * d), (hi - lo) * d)
                    };
                    let seg = unsafe {
                        std::slice::from_raw_parts_mut(out_ptr.0.add(lo * fd), (hi - lo) * fd)
                    };
                    m2.transform_batch_slice_into(xs, hi - lo, d, seg, &mut scratch);
                });
                out
            }
        }
    }
}

/// Raw pointer wrapper so the closure is Send (disjoint-write safety
/// is argued at the use site).
#[derive(Clone, Copy)]
struct SendPtr(*mut f32);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

/// Shared-read counterpart of [`SendPtr`]: lets workers borrow the
/// input batch for the blocking scope instead of cloning it.
#[derive(Clone, Copy)]
struct SendConstPtr(*const f32);
unsafe impl Send for SendConstPtr {}
unsafe impl Sync for SendConstPtr {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mckernel::McKernelFactory;

    fn map() -> Arc<McKernel> {
        Arc::new(McKernelFactory::new(12).expansions(2).seed(3).build())
    }

    fn batch() -> Matrix {
        Matrix::from_fn(9, 12, |r, c| ((r * 13 + c) % 7) as f32 * 0.1)
    }

    #[test]
    fn identity_passthrough() {
        let x = batch();
        let f = Featurizer::Identity;
        assert_eq!(f.apply(&x), x);
        assert_eq!(f.feature_dim(12), 12);
    }

    #[test]
    fn mckernel_dims() {
        let f = Featurizer::McKernel(map());
        assert_eq!(f.feature_dim(12), 2 * 16 * 2);
        let out = f.apply(&batch());
        assert_eq!(out.shape(), (9, 64));
    }

    #[test]
    fn parallel_matches_serial() {
        let m = map();
        let x = batch();
        let serial = Featurizer::McKernel(Arc::clone(&m)).apply(&x);
        let pool = Arc::new(ThreadPool::new(4));
        let par = Featurizer::McKernelParallel(m, pool).apply(&x);
        assert_eq!(serial.data(), par.data());
    }

    #[test]
    fn parallel_single_row() {
        let m = map();
        let x = Matrix::from_fn(1, 12, |_, c| c as f32);
        let pool = Arc::new(ThreadPool::new(8));
        let serial = Featurizer::McKernel(Arc::clone(&m)).apply(&x);
        let par = Featurizer::McKernelParallel(m, pool).apply(&x);
        assert_eq!(serial.data(), par.data());
    }

    #[test]
    fn parallel_many_rows_with_tail_tiles() {
        // more rows than one tile and not a multiple of the tile
        // width: tasks get whole tiles plus a ragged tail
        let m = map();
        let x = Matrix::from_fn(150, 12, |r, c| ((r * 7 + c) % 13) as f32 * 0.05);
        let pool = Arc::new(ThreadPool::new(3));
        let serial = Featurizer::McKernel(Arc::clone(&m)).apply(&x);
        let par = Featurizer::McKernelParallel(m, pool).apply(&x);
        assert_eq!(serial.data(), par.data());
    }

    #[test]
    fn shard_apply_matches_full_batch() {
        let m = map();
        let x = batch();
        let f = Featurizer::McKernel(Arc::clone(&m));
        let full = f.apply(&x);
        let fd = f.feature_dim(12);
        // ragged shard split (4 + 3 + 2 rows): must agree bit-for-bit
        let mut out = vec![0.0f32; 9 * fd];
        let mut scratch = f.make_shard_scratch();
        for (lo, hi) in [(0usize, 4usize), (4, 7), (7, 9)] {
            f.apply_shard(
                &x.data()[lo * 12..hi * 12],
                hi - lo,
                12,
                &mut out[lo * fd..hi * fd],
                &mut scratch,
            );
        }
        assert_eq!(full.data(), &out[..]);
    }

    #[test]
    fn shard_apply_identity_copies() {
        let x = batch();
        let f = Featurizer::Identity;
        let mut out = vec![0.0f32; 2 * 12];
        let mut scratch = f.make_shard_scratch();
        f.apply_shard(&x.data()[3 * 12..5 * 12], 2, 12, &mut out, &mut scratch);
        assert_eq!(&out[..12], x.row(3));
        assert_eq!(&out[12..], x.row(4));
    }

    #[test]
    fn parallel_empty_batch() {
        let m = map();
        let x = Matrix::zeros(0, 12);
        let pool = Arc::new(ThreadPool::new(2));
        let out = Featurizer::McKernelParallel(m, pool).apply(&x);
        assert_eq!(out.shape(), (0, 64));
    }
}
