//! Featurizer abstraction: identity (the LR baseline feeds raw
//! pixels), the native McKernel map, or a parallel McKernel map over
//! the thread pool — the paper's two curves in Figures 3–5 differ
//! only in this choice.
//!
//! All featurization executes through `mckernel::engine` — this layer
//! holds no scratch sizing or FWHT dispatch of its own. Consumers
//! build one [`FeatureEngine`] per worker/loop via
//! [`Featurizer::make_engine`] and reuse it every mini-batch.

use crate::linalg::Matrix;
use crate::mckernel::plan::ExpansionPlan;
use crate::mckernel::{CacheKey, ExpansionEngine, FeatureCache, McKernel, McKernelConfig};
use crate::util::ThreadPool;
use std::sync::Arc;

/// Per-consumer execution state for a [`Featurizer`]: the compiled
/// expansion engine, per-pool-task engines for the parallel variant,
/// and a pooled output matrix so [`Featurizer::apply_into`] is
/// allocation-free across mini-batches (ragged tail batches shrink
/// the pooled matrix without releasing capacity). Engines are built
/// lazily on the path that actually runs — identity never allocates,
/// and the parallel variant never carries a dead serial engine.
pub struct FeatureEngine {
    /// Row-count hint captured at [`Featurizer::make_engine`] time,
    /// used when an engine is first compiled.
    rows_hint: usize,
    engine: Option<ExpansionEngine>,
    workers: Vec<ExpansionEngine>,
    out: Matrix,
    /// Optional content-addressed feature cache and this map's cache
    /// id (see [`crate::mckernel::cache`]); every execute routes
    /// through the cache when present. The id excludes the lane
    /// count, so engines with different row hints share entries.
    cache: Option<(Arc<FeatureCache>, CacheKey)>,
}

/// Maps a `(batch, pixels)` matrix to the classifier's input space.
pub enum Featurizer {
    /// Raw input (logistic-regression baseline: `softmax(Wx + b)`).
    Identity,
    /// McKernel features, single-threaded (`softmax(W·mckernel(x)+b)`).
    McKernel(Arc<McKernel>),
    /// McKernel features computed across a thread pool (rows are
    /// independent — embarrassingly parallel).
    McKernelParallel(Arc<McKernel>, Arc<ThreadPool>),
}

impl Featurizer {
    /// Output width.
    pub fn feature_dim(&self, input_dim: usize) -> usize {
        match self {
            Featurizer::Identity => input_dim,
            Featurizer::McKernel(m) | Featurizer::McKernelParallel(m, _) => m.feature_dim(),
        }
    }

    /// Short name for logs.
    pub fn name(&self) -> &'static str {
        match self {
            Featurizer::Identity => "identity",
            Featurizer::McKernel(_) => "mckernel",
            Featurizer::McKernelParallel(..) => "mckernel-par",
        }
    }

    /// The feature-map config to persist in a checkpoint (`None` for
    /// the raw-pixel identity baseline) — the trainer's autosave path
    /// uses this so a resumed run rebuilds the identical map.
    pub fn config(&self) -> Option<McKernelConfig> {
        match self {
            Featurizer::Identity => None,
            Featurizer::McKernel(m) | Featurizer::McKernelParallel(m, _) => {
                Some(m.config().clone())
            }
        }
    }

    /// Build the execution state for this featurizer, expecting calls
    /// of about `rows_hint` rows — one per worker/loop, reused every
    /// mini-batch. Cheap: engines compile lazily on first use.
    pub fn make_engine(&self, rows_hint: usize) -> FeatureEngine {
        self.make_engine_cached(rows_hint, None)
    }

    /// Like [`Featurizer::make_engine`] but routing every execute
    /// through `cache` when one is given (identity ignores it — there
    /// is nothing to memoize). The cache id is derived eagerly from
    /// the map's plan; the batch-vs-row dispatch depends only on the
    /// geometry, never the row hint, so engines built with any hint —
    /// including the parallel variant's per-task engines — share one
    /// id and therefore one entry population.
    pub fn make_engine_cached(
        &self,
        rows_hint: usize,
        cache: Option<Arc<FeatureCache>>,
    ) -> FeatureEngine {
        let cache = match (self, cache) {
            (Featurizer::Identity, _) | (_, None) => None,
            (Featurizer::McKernel(m) | Featurizer::McKernelParallel(m, _), Some(c)) => {
                let key = CacheKey::new(m.config(), &ExpansionPlan::new(m.config(), rows_hint));
                Some((c, key))
            }
        };
        FeatureEngine {
            rows_hint,
            engine: None,
            workers: Vec::new(),
            out: Matrix::zeros(0, 0),
            cache,
        }
    }

    /// Shard-aware apply: featurize `rows` raw rows (`xs`, row-major,
    /// width `d`) into the preallocated `out` (`rows × feature_dim`)
    /// without allocating — the data-parallel trainer calls this from
    /// every worker on its own shard with its own engine. The engine
    /// pipeline is invariant to how rows are grouped into tiles, so
    /// shard splits agree bit-for-bit with the full-batch path.
    pub fn apply_shard(
        &self,
        xs: &[f32],
        rows: usize,
        d: usize,
        out: &mut [f32],
        engine: &mut FeatureEngine,
    ) {
        assert_eq!(xs.len(), rows * d, "shard input length");
        assert_eq!(out.len(), rows * self.feature_dim(d), "shard output length");
        match self {
            Featurizer::Identity => out.copy_from_slice(xs),
            Featurizer::McKernel(m) | Featurizer::McKernelParallel(m, _) => {
                let hint = engine.rows_hint;
                let eng = engine
                    .engine
                    .get_or_insert_with(|| ExpansionEngine::new(m, hint));
                match &engine.cache {
                    Some((c, key)) => c.execute(*key, eng, m, xs, rows, d, out),
                    None => eng.execute(m, xs, rows, d, out),
                }
            }
        }
    }

    /// Apply to a batch through the engine's pooled scratch and
    /// pooled output matrix — allocation-free after the first call at
    /// a given batch size (identity returns the input itself, zero
    /// copies). The parallel variant splits whole *row-tiles* — not
    /// single rows — across the pool, each task executing on its own
    /// long-lived engine, so every worker streams L2-resident tiles
    /// through the fused Fastfood passes.
    pub fn apply_into<'a>(&self, x: &'a Matrix, engine: &'a mut FeatureEngine) -> &'a Matrix {
        match self {
            Featurizer::Identity => x,
            Featurizer::McKernel(m) => {
                engine.out.resize(x.rows(), m.feature_dim());
                let hint = engine.rows_hint;
                let eng = engine
                    .engine
                    .get_or_insert_with(|| ExpansionEngine::new(m, hint));
                match &engine.cache {
                    Some((c, key)) => c.execute_matrix(*key, eng, m, x, &mut engine.out),
                    None => eng.execute_matrix(m, x, &mut engine.out),
                }
                &engine.out
            }
            Featurizer::McKernelParallel(m, pool) => {
                let rows = x.rows();
                let d = x.cols();
                let fd = m.feature_dim();
                engine.out.resize(rows, fd);
                if rows == 0 {
                    return &engine.out;
                }
                // One engine per pool task, built on first use and
                // reused across mini-batches (full tile width: tasks
                // stream whole tiles regardless of this batch's rows).
                if engine.workers.len() != pool.size() {
                    engine.workers =
                        (0..pool.size()).map(|_| ExpansionEngine::new(m, usize::MAX)).collect();
                }
                // Whole tiles per task; tile grouping does not change
                // results (lanes never interact), so any split agrees
                // bit-for-bit with the serial engine path.
                let tile = engine.workers[0].plan().lanes().max(1);
                let tiles = rows.div_ceil(tile);
                let chunk = tiles.div_ceil(pool.size()).max(1) * tile;
                let tasks = rows.div_ceil(chunk);
                let out_ptr = SendPtr(engine.out.data_mut().as_mut_ptr());
                let in_ptr = SendConstPtr(x.data().as_ptr());
                let eng_ptr = SendEnginePtr(engine.workers.as_mut_ptr());
                let m2 = Arc::clone(m);
                // Cache handle shared by every task: the id is lane-
                // independent and the per-shard locks absorb the
                // concurrent lookups/inserts.
                let cache = engine.cache.clone();
                pool.scope_for_each(tasks, move |t| {
                    // force whole-struct capture (edition-2021 would
                    // otherwise capture the raw-pointer fields, which
                    // are not Send)
                    let out_ptr = out_ptr;
                    let in_ptr = in_ptr;
                    let eng_ptr = eng_ptr;
                    let lo = t * chunk;
                    let hi = ((t + 1) * chunk).min(rows);
                    // SAFETY: task `t` touches only `workers[t]`
                    // (`tasks ≤ pool.size() == workers.len()`), and
                    // the engines outlive scope_for_each, which blocks
                    // until every task is done.
                    let eng = unsafe { &mut *eng_ptr.0.add(t) };
                    // SAFETY: rows `lo..hi` lie inside the input batch
                    // (`hi ≤ rows`), which this frame borrows for the
                    // whole blocking scope; tasks only read it.
                    let xs = unsafe {
                        std::slice::from_raw_parts(in_ptr.0.add(lo * d), (hi - lo) * d)
                    };
                    // SAFETY: tasks own disjoint `lo..hi` row ranges of
                    // the pooled output (sized `rows × fd` above), so
                    // these &mut segments never alias; the matrix
                    // outlives the blocking scope.
                    let seg = unsafe {
                        std::slice::from_raw_parts_mut(out_ptr.0.add(lo * fd), (hi - lo) * fd)
                    };
                    match &cache {
                        Some((c, key)) => c.execute(*key, eng, &m2, xs, hi - lo, d, seg),
                        None => eng.execute(&m2, xs, hi - lo, d, seg),
                    }
                })
                // `apply_into`'s contract has no error channel; a
                // panicking engine task here is an internal bug (the
                // output would be silently incomplete), so escalate
                // instead of returning partial features.
                // analyze: allow(no-panic-serving) -- no error channel in apply_into; partial features must abort
                .expect("parallel featurization task failed");
                &engine.out
            }
        }
    }

    /// Allocating convenience wrapper over [`Featurizer::apply_into`]
    /// (tests / one-shot callers; hot loops hold a [`FeatureEngine`]).
    pub fn apply(&self, x: &Matrix) -> Matrix {
        let mut engine = self.make_engine(x.rows());
        self.apply_into(x, &mut engine);
        match self {
            // identity's apply_into returns the input untouched
            Featurizer::Identity => x.clone(),
            // the one-shot engine is dropped right after, so its
            // pooled output moves out instead of being copied
            _ => std::mem::replace(&mut engine.out, Matrix::zeros(0, 0)),
        }
    }
}

/// Raw pointer wrapper so the closure is Send (disjoint-write safety
/// is argued at the use site).
#[derive(Clone, Copy)]
struct SendPtr(*mut f32);
// SAFETY: dereferenced only inside apply_into's blocking scope, where
// tasks write disjoint row segments of the pooled output (argued at
// the use site); the pointee outlives the scope.
unsafe impl Send for SendPtr {}
// SAFETY: shared across tasks but each writes a disjoint segment — no
// two tasks ever touch the same element.
unsafe impl Sync for SendPtr {}

/// Shared-read counterpart of [`SendPtr`]: lets workers borrow the
/// input batch for the blocking scope instead of cloning it.
#[derive(Clone, Copy)]
struct SendConstPtr(*const f32);
// SAFETY: points into the input batch, which the submitting frame
// borrows for the whole blocking scope; tasks only read through it.
unsafe impl Send for SendConstPtr {}
// SAFETY: read-only shared access to an immutably borrowed batch.
unsafe impl Sync for SendConstPtr {}

/// Per-task engine pointer (task `t` uses engine `t` exclusively).
#[derive(Clone, Copy)]
struct SendEnginePtr(*mut ExpansionEngine);
// SAFETY: task `t` dereferences only offset `t`, so each engine is
// exclusively owned by one task for the blocking scope's duration.
unsafe impl Send for SendEnginePtr {}
// SAFETY: shared capture by every task closure, but the per-offset
// exclusivity above means no engine is ever aliased mutably.
unsafe impl Sync for SendEnginePtr {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mckernel::McKernelFactory;

    fn map() -> Arc<McKernel> {
        Arc::new(McKernelFactory::new(12).expansions(2).seed(3).build())
    }

    fn batch() -> Matrix {
        Matrix::from_fn(9, 12, |r, c| ((r * 13 + c) % 7) as f32 * 0.1)
    }

    #[test]
    fn identity_passthrough() {
        let x = batch();
        let f = Featurizer::Identity;
        assert_eq!(f.apply(&x), x);
        assert_eq!(f.feature_dim(12), 12);
        // apply_into is zero-copy for identity: same allocation back
        let mut eng = f.make_engine(9);
        assert!(std::ptr::eq(f.apply_into(&x, &mut eng), &x));
    }

    #[test]
    fn mckernel_dims() {
        let f = Featurizer::McKernel(map());
        assert_eq!(f.feature_dim(12), 2 * 16 * 2);
        let out = f.apply(&batch());
        assert_eq!(out.shape(), (9, 64));
    }

    #[test]
    fn pooled_apply_is_stable_across_batch_sizes() {
        // one engine reused over full batches and a ragged tail must
        // give the same features as fresh one-shot applies
        let m = map();
        let f = Featurizer::McKernel(Arc::clone(&m));
        let mut eng = f.make_engine(9);
        let x9 = batch();
        let x3 = Matrix::from_fn(3, 12, |r, c| ((r * 5 + c) % 11) as f32 * 0.07);
        let a9 = f.apply_into(&x9, &mut eng).clone();
        let a3 = f.apply_into(&x3, &mut eng).clone();
        let again9 = f.apply_into(&x9, &mut eng).clone();
        assert_eq!(a9.data(), f.apply(&x9).data());
        assert_eq!(a3.data(), f.apply(&x3).data());
        assert_eq!(a9.data(), again9.data());
    }

    #[test]
    fn parallel_matches_serial() {
        let m = map();
        let x = batch();
        let serial = Featurizer::McKernel(Arc::clone(&m)).apply(&x);
        let pool = Arc::new(ThreadPool::new(4));
        let par = Featurizer::McKernelParallel(m, pool).apply(&x);
        assert_eq!(serial.data(), par.data());
    }

    #[test]
    fn parallel_single_row() {
        let m = map();
        let x = Matrix::from_fn(1, 12, |_, c| c as f32);
        let pool = Arc::new(ThreadPool::new(8));
        let serial = Featurizer::McKernel(Arc::clone(&m)).apply(&x);
        let par = Featurizer::McKernelParallel(m, pool).apply(&x);
        assert_eq!(serial.data(), par.data());
    }

    #[test]
    fn parallel_many_rows_with_tail_tiles() {
        // more rows than one tile and not a multiple of the tile
        // width: tasks get whole tiles plus a ragged tail; the worker
        // engines are built once and reused across both calls
        let m = map();
        let x = Matrix::from_fn(150, 12, |r, c| ((r * 7 + c) % 13) as f32 * 0.05);
        let serial = Featurizer::McKernel(Arc::clone(&m)).apply(&x);
        let fpar = Featurizer::McKernelParallel(m, Arc::new(ThreadPool::new(3)));
        let mut eng = fpar.make_engine(150);
        assert_eq!(serial.data(), fpar.apply_into(&x, &mut eng).data());
        assert_eq!(serial.data(), fpar.apply_into(&x, &mut eng).data());
    }

    #[test]
    fn shard_apply_matches_full_batch() {
        let m = map();
        let x = batch();
        let f = Featurizer::McKernel(Arc::clone(&m));
        let full = f.apply(&x);
        let fd = f.feature_dim(12);
        // ragged shard split (4 + 3 + 2 rows): must agree bit-for-bit
        let mut out = vec![0.0f32; 9 * fd];
        let mut engine = f.make_engine(4);
        for (lo, hi) in [(0usize, 4usize), (4, 7), (7, 9)] {
            f.apply_shard(
                &x.data()[lo * 12..hi * 12],
                hi - lo,
                12,
                &mut out[lo * fd..hi * fd],
                &mut engine,
            );
        }
        assert_eq!(full.data(), &out[..]);
    }

    #[test]
    fn shard_apply_identity_copies() {
        let x = batch();
        let f = Featurizer::Identity;
        let mut out = vec![0.0f32; 2 * 12];
        let mut engine = f.make_engine(2);
        f.apply_shard(&x.data()[3 * 12..5 * 12], 2, 12, &mut out, &mut engine);
        assert_eq!(&out[..12], x.row(3));
        assert_eq!(&out[12..], x.row(4));
    }

    #[test]
    fn parallel_empty_batch() {
        let m = map();
        let x = Matrix::zeros(0, 12);
        let pool = Arc::new(ThreadPool::new(2));
        let out = Featurizer::McKernelParallel(m, pool).apply(&x);
        assert_eq!(out.shape(), (0, 64));
    }
}
