//! Evaluation metrics: accuracy, confusion matrix, per-epoch records
//! (the series plotted in Figures 3–5).

/// Fraction of correct predictions.
pub fn accuracy(pred: &[u8], truth: &[u8]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    if pred.is_empty() {
        return 0.0;
    }
    let hits = pred.iter().zip(truth).filter(|(a, b)| a == b).count();
    hits as f64 / pred.len() as f64
}

/// `classes × classes` confusion matrix: `m[truth][pred]` counts.
pub fn confusion_matrix(pred: &[u8], truth: &[u8], classes: usize) -> Vec<Vec<u32>> {
    assert_eq!(pred.len(), truth.len());
    let mut m = vec![vec![0u32; classes]; classes];
    for (&p, &t) in pred.iter().zip(truth) {
        m[t as usize][p as usize] += 1;
    }
    m
}

/// One epoch's summary (one point of a Figure 3/4/5 curve).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EpochRecord {
    pub epoch: usize,
    pub train_loss: f64,
    pub train_accuracy: f64,
    pub test_accuracy: f64,
    /// Wall-clock seconds spent in this epoch (training + any eval).
    pub seconds: f64,
    /// Training-loop throughput in rows/second (excludes evaluation;
    /// 0.0 when the loop was too fast for the clock or saw no rows).
    pub rows_per_s: f64,
}

impl EpochRecord {
    /// CSV header matching [`EpochRecord::to_csv_row`].
    pub fn csv_header() -> &'static str {
        "epoch,train_loss,train_accuracy,test_accuracy,seconds,rows_per_s"
    }

    /// One CSV row.
    pub fn to_csv_row(&self) -> String {
        format!(
            "{},{:.6},{:.6},{:.6},{:.3},{:.1}",
            self.epoch,
            self.train_loss,
            self.train_accuracy,
            self.test_accuracy,
            self.seconds,
            self.rows_per_s
        )
    }

    /// Record for an epoch that processed **zero batches** (e.g.
    /// `drop_last` with fewer rows than a batch): every per-batch
    /// average is pinned to 0.0 instead of dividing 0/0 into NaN.
    /// Evaluation still runs, so `test_accuracy` and wall time are
    /// real measurements.
    pub fn empty(epoch: usize, test_accuracy: f64, seconds: f64) -> EpochRecord {
        EpochRecord {
            epoch,
            train_loss: 0.0,
            train_accuracy: 0.0,
            test_accuracy,
            seconds,
            rows_per_s: 0.0,
        }
    }

    /// `rows / secs`, guarded against zero/degenerate denominators.
    pub fn throughput(rows: usize, secs: f64) -> f64 {
        if secs > 0.0 {
            rows as f64 / secs
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_basic() {
        assert_eq!(accuracy(&[1, 2, 3], &[1, 2, 0]), 2.0 / 3.0);
        assert_eq!(accuracy(&[], &[]), 0.0);
        assert_eq!(accuracy(&[5], &[5]), 1.0);
    }

    #[test]
    fn confusion_layout() {
        let m = confusion_matrix(&[0, 1, 1, 2], &[0, 1, 2, 2], 3);
        assert_eq!(m[0][0], 1); // truth 0 predicted 0
        assert_eq!(m[1][1], 1);
        assert_eq!(m[2][1], 1); // truth 2 predicted 1
        assert_eq!(m[2][2], 1);
        // diagonal sum = correct count
        let diag: u32 = (0..3).map(|i| m[i][i]).sum();
        assert_eq!(diag, 3);
    }

    #[test]
    fn confusion_row_sums_are_class_counts() {
        let truth = [0u8, 0, 1, 1, 1, 2];
        let pred = [0u8, 1, 1, 1, 0, 2];
        let m = confusion_matrix(&pred, &truth, 3);
        assert_eq!(m[0].iter().sum::<u32>(), 2);
        assert_eq!(m[1].iter().sum::<u32>(), 3);
        assert_eq!(m[2].iter().sum::<u32>(), 1);
    }

    #[test]
    fn csv_row_format() {
        let r = EpochRecord {
            epoch: 3,
            train_loss: 0.5,
            train_accuracy: 0.9,
            test_accuracy: 0.85,
            seconds: 1.25,
            rows_per_s: 1234.56,
        };
        assert_eq!(r.to_csv_row(), "3,0.500000,0.900000,0.850000,1.250,1234.6");
        assert!(EpochRecord::csv_header().starts_with("epoch,"));
        assert_eq!(
            EpochRecord::csv_header().split(',').count(),
            r.to_csv_row().split(',').count()
        );
        assert!(EpochRecord::csv_header().ends_with(",rows_per_s"));
    }

    #[test]
    fn empty_record_is_finite_and_serializable() {
        let r = EpochRecord::empty(2, 0.1, 0.5);
        for v in [r.train_loss, r.train_accuracy, r.test_accuracy, r.seconds, r.rows_per_s] {
            assert!(v.is_finite());
        }
        assert_eq!(r.to_csv_row(), "2,0.000000,0.000000,0.100000,0.500,0.0");
    }

    #[test]
    fn throughput_guards_degenerate_denominators() {
        assert_eq!(EpochRecord::throughput(100, 2.0), 50.0);
        assert_eq!(EpochRecord::throughput(100, 0.0), 0.0);
        assert_eq!(EpochRecord::throughput(0, 1.0), 0.0);
        assert!(EpochRecord::throughput(100, -1.0) == 0.0);
    }

    #[test]
    #[should_panic]
    fn length_mismatch_rejected() {
        accuracy(&[1], &[1, 2]);
    }
}
