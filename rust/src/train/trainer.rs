//! The epoch-loop trainer: mini-batch SGD over a featurized dataset —
//! the engine behind Figures 3, 4 and 5. Works with any
//! [`Featurizer`]; every mini-batch executes through one long-lived
//! [`FeatureEngine`] (compiled plan + pooled scratch + pooled feature
//! matrix) via [`Featurizer::apply_into`]. The PJRT-backed path lives
//! in [`crate::coordinator`] (it owns device state).

use super::featurizer::{FeatureEngine, Featurizer};
use super::metrics::{accuracy, EpochRecord};
use crate::data::{Batcher, Dataset};
use crate::fault::{shard_key, FaultPlan, FaultSite, McError};
use crate::model::checkpoint::Checkpoint;
use crate::model::{Gradients, SoftmaxRegression};
use crate::obs;
use crate::optim::{Sgd, SgdConfig};
use crate::util::{tree_reduce_with, ThreadPool};
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Trainer metric handles, resolved from the global registry only
/// when observability is enabled at `fit` start — the disabled path
/// never reads the clock for them. Both trainers share the
/// `train.epoch_ns` / `train.rows` names; the shard/reduction pair is
/// parallel-only.
struct TrainerObs {
    epoch_ns: Arc<obs::Hist>,
    rows: Arc<obs::Counter>,
    shard_ns: Arc<obs::Hist>,
    reduce_ns: Arc<obs::Hist>,
}

impl TrainerObs {
    fn resolve_if_enabled() -> Option<TrainerObs> {
        if !obs::enabled() {
            return None;
        }
        let reg = obs::global();
        Some(TrainerObs {
            epoch_ns: reg.histogram("train.epoch_ns"),
            rows: reg.counter("train.rows"),
            shard_ns: reg.histogram("train.shard_ns"),
            reduce_ns: reg.histogram("train.reduce_ns"),
        })
    }
}

/// Trainer configuration (defaults = the paper's Figure 4/5 settings
/// for the McKernel curve).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub epochs: usize,
    pub batch_size: usize,
    pub sgd: SgdConfig,
    pub seed: u64,
    /// Evaluate on test data each epoch (off = only final).
    pub eval_every_epoch: bool,
    /// Print progress lines.
    pub verbose: bool,
    /// Data-parallel worker threads for [`ParallelTrainer`] (≥ 1).
    /// The serial [`Trainer`] ignores this — it is the 1-worker
    /// correctness oracle.
    pub workers: usize,
    /// Opt-in content-addressed feature cache
    /// ([`crate::mckernel::FeatureCache`]): byte budget for memoizing
    /// feature rows across epochs (the same rows recur every epoch, so
    /// epochs after the first can be nearly FWHT-free when the train
    /// set fits the budget). `None` disables caching. Bit-identical to
    /// the uncached path either way.
    pub cache_bytes: Option<usize>,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            epochs: 20,
            batch_size: 10,
            sgd: SgdConfig { lr: 0.001, momentum: 0.0, clip: None },
            seed: crate::PAPER_SEED,
            eval_every_epoch: true,
            verbose: false,
            workers: 1,
            cache_bytes: None,
        }
    }
}

/// Outcome of a training run.
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub history: Vec<EpochRecord>,
    pub final_test_accuracy: f64,
    pub param_count: usize,
    pub featurizer: &'static str,
}

impl TrainReport {
    /// History as CSV (one row per epoch).
    pub fn to_csv(&self) -> String {
        let mut out = String::from(EpochRecord::csv_header());
        out.push('\n');
        for r in &self.history {
            out.push_str(&r.to_csv_row());
            out.push('\n');
        }
        out
    }
}

/// Mini-batch SGD trainer.
pub struct Trainer {
    pub config: TrainConfig,
    pub featurizer: Featurizer,
}

impl Trainer {
    pub fn new(config: TrainConfig, featurizer: Featurizer) -> Trainer {
        Trainer { config, featurizer }
    }

    /// Train a fresh model on `train`, evaluating on `test`.
    pub fn fit(&self, train: &Dataset, test: &Dataset) -> (SoftmaxRegression, TrainReport) {
        let fdim = self.featurizer.feature_dim(train.dim());
        let mut model = SoftmaxRegression::zeros(train.classes(), fdim);
        let mut opt = Sgd::new(self.config.sgd);
        let batcher = Batcher::new(self.config.batch_size, self.config.seed);
        // One expansion engine for the whole run: pooled scratch and
        // pooled feature matrix, reused every mini-batch.
        let cache =
            self.config.cache_bytes.map(|b| Arc::new(crate::mckernel::FeatureCache::new(b)));
        let mut engine = self.featurizer.make_engine_cached(self.config.batch_size, cache);
        let mut history = Vec::with_capacity(self.config.epochs);
        let metrics = TrainerObs::resolve_if_enabled();

        for epoch in 0..self.config.epochs {
            let t0 = Instant::now();
            let mut loss_sum = 0.0f64;
            let mut loss_batches = 0usize;
            let mut train_hits = 0usize;
            let mut train_count = 0usize;
            for batch in batcher.epoch(train, epoch) {
                let feats = self.featurizer.apply_into(&batch.images, &mut engine);
                let (loss, grads) = model.loss_and_grad(feats, &batch.labels);
                // training accuracy from the same logits' argmax would
                // need another pass; use predictions on features:
                let preds = model.predict(feats);
                train_hits += preds
                    .iter()
                    .zip(&batch.labels)
                    .filter(|(a, b)| a == b)
                    .count();
                train_count += batch.labels.len();
                opt.step(&mut model, &grads);
                loss_sum += loss as f64;
                loss_batches += 1;
            }
            // One clock reading feeds both the ns histogram and the
            // seconds-based throughput (the old f64 round trip
            // `(secs * 1e9) as u64` lost ns precision).
            let train_ns = obs::elapsed_ns(t0);
            let train_secs = train_ns as f64 * 1e-9;
            if let Some(m) = &metrics {
                m.epoch_ns.record(train_ns);
                m.rows.add(train_count as u64);
            }
            let test_acc = if self.config.eval_every_epoch || epoch + 1 == self.config.epochs {
                self.evaluate(&model, test)
            } else {
                f64::NAN
            };
            let rec = if loss_batches == 0 {
                // drop_last (or an empty dataset) produced no batches:
                // emit an explicit empty record, never 0/0.
                EpochRecord::empty(epoch, test_acc, t0.elapsed().as_secs_f64())
            } else {
                EpochRecord {
                    epoch,
                    train_loss: loss_sum / loss_batches as f64,
                    train_accuracy: train_hits as f64 / train_count.max(1) as f64,
                    test_accuracy: test_acc,
                    seconds: t0.elapsed().as_secs_f64(),
                    rows_per_s: EpochRecord::throughput(train_count, train_secs),
                }
            };
            if self.config.verbose {
                eprintln!(
                    "[{}] epoch {:>3}  loss {:.4}  train-acc {:.4}  test-acc {:.4}  ({:.2}s)",
                    self.featurizer.name(),
                    rec.epoch,
                    rec.train_loss,
                    rec.train_accuracy,
                    rec.test_accuracy,
                    rec.seconds
                );
            }
            history.push(rec);
        }
        let final_test_accuracy = history
            .last()
            .map(|r| r.test_accuracy)
            .unwrap_or(f64::NAN);
        let report = TrainReport {
            final_test_accuracy,
            param_count: model.param_count(),
            featurizer: self.featurizer.name(),
            history,
        };
        (model, report)
    }

    /// Accuracy of `model` on `data` (featurized in eval batches).
    pub fn evaluate(&self, model: &SoftmaxRegression, data: &Dataset) -> f64 {
        evaluate_with(&self.featurizer, model, data)
    }
}

/// Accuracy of `model` on `data`, featurized in sequential eval
/// batches — shared by the serial and data-parallel trainers.
pub fn evaluate_with(featurizer: &Featurizer, model: &SoftmaxRegression, data: &Dataset) -> f64 {
    let batcher = Batcher::new(256, 0).sequential();
    let mut engine = featurizer.make_engine(256);
    let mut preds = Vec::with_capacity(data.len());
    for batch in batcher.epoch(data, 0) {
        let feats = featurizer.apply_into(&batch.images, &mut engine);
        preds.extend(model.predict(feats));
    }
    accuracy(&preds, data.labels())
}

/// Per-worker step state for the data-parallel trainer: featurization
/// output + expansion engine, the softmax delta buffer, and the
/// gradient-sum accumulator — allocated once per `fit`, reused every
/// step (the step loop itself never allocates).
struct WorkerSlot {
    /// This slot's shard index within the current batch (stable across
    /// retries — it keys fault injection and identifies the shard when
    /// only a subset is resubmitted).
    idx: usize,
    /// Row range of the current batch owned by this worker.
    lo: usize,
    hi: usize,
    feats: Vec<f32>,
    delta: Vec<f32>,
    grads: Gradients,
    engine: FeatureEngine,
    loss_sum: f64,
    hits: usize,
}

/// Retry policy for panicked shards: bounded exponential backoff
/// (`backoff · 2^(round−1)`, capped at `backoff_cap`), giving up with
/// [`McError::WorkerPanic`] after `max_retries` rounds.
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    /// Retry rounds before giving up.
    pub max_retries: u32,
    /// Backoff before the first retry round.
    pub backoff: Duration,
    /// Upper bound on any single backoff sleep.
    pub backoff_cap: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_retries: 3,
            backoff: Duration::from_millis(10),
            backoff_cap: Duration::from_millis(500),
        }
    }
}

impl RetryPolicy {
    /// The sleep before retry round `round` (1-based).
    fn delay(&self, round: u32) -> Duration {
        let mult = 1u32 << round.saturating_sub(1).min(16);
        self.backoff.saturating_mul(mult).min(self.backoff_cap)
    }
}

/// Data-parallel mini-batch SGD trainer (the paper's Eq. 21 step at
/// scale): every mini-batch is sharded across a fixed thread pool,
/// workers compute per-shard gradient *sums* into their own
/// [`WorkerSlot`]s, and the main thread combines them with a
/// fixed-order pairwise tree reduction before a single optimizer
/// step. Shard boundaries depend only on `(batch rows, workers)` and
/// the reduction order only on the shard count, so an N-worker run is
/// bit-identical across repeated runs regardless of thread
/// scheduling — and matches the serial [`Trainer`] oracle within a
/// tight tolerance (the only difference is summation order).
pub struct ParallelTrainer {
    pub config: TrainConfig,
    pub featurizer: Featurizer,
    pool: ThreadPool,
    retry: RetryPolicy,
    faults: Option<Arc<FaultPlan>>,
    autosave: Option<PathBuf>,
}

impl ParallelTrainer {
    /// Build a trainer with a pool of `config.workers` threads (and
    /// the default [`RetryPolicy`], no fault injection, no autosave).
    pub fn new(config: TrainConfig, featurizer: Featurizer) -> ParallelTrainer {
        assert!(config.workers >= 1, "workers must be ≥ 1");
        let pool = ThreadPool::new(config.workers);
        ParallelTrainer {
            config,
            featurizer,
            pool,
            retry: RetryPolicy::default(),
            faults: None,
            autosave: None,
        }
    }

    /// Override the shard retry/backoff policy.
    pub fn with_retry(mut self, retry: RetryPolicy) -> ParallelTrainer {
        self.retry = retry;
        self
    }

    /// Install a deterministic chaos schedule (worker panics are
    /// injected into shard jobs, keyed by (epoch, batch, shard,
    /// attempt) — retries draw fresh randomness, so recovery is
    /// reachable and bit-identical to a fault-free run).
    pub fn with_faults(mut self, plan: Arc<FaultPlan>) -> ParallelTrainer {
        self.faults = Some(plan);
        self
    }

    /// Save a checkpoint (with the resume cursor) to `path` after
    /// every completed epoch, so a killed run loses at most one epoch.
    pub fn with_autosave<P: Into<PathBuf>>(mut self, path: P) -> ParallelTrainer {
        self.autosave = Some(path.into());
        self
    }

    /// Train a fresh model on `train`, evaluating on `test`.
    pub fn fit(
        &self,
        train: &Dataset,
        test: &Dataset,
    ) -> Result<(SoftmaxRegression, TrainReport), McError> {
        let fdim = self.featurizer.feature_dim(train.dim());
        let model = SoftmaxRegression::zeros(train.classes(), fdim);
        self.fit_resume(model, 0, train, test)
    }

    /// Crash-recovery entry point: if a checkpoint exists at `path`,
    /// load it and resume from its epoch cursor (a fully-trained
    /// checkpoint just evaluates and returns); otherwise train from
    /// scratch. Either way, every completed epoch autosaves to `path`
    /// — so rerunning the same command after a kill picks up where the
    /// dead run left off.
    pub fn fit_auto<P: AsRef<Path>>(
        &self,
        path: P,
        train: &Dataset,
        test: &Dataset,
    ) -> Result<(SoftmaxRegression, TrainReport), McError> {
        let path = path.as_ref();
        if path.exists() {
            let ck = Checkpoint::load(path)
                .map_err(|e| McError::Io(format!("load {}: {e}", path.display())))?;
            let start = ck.epoch().unwrap_or(0);
            if start >= self.config.epochs {
                // nothing left to train: evaluate the stored model
                let acc = evaluate_with(&self.featurizer, &ck.model, test);
                let report = TrainReport {
                    history: Vec::new(),
                    final_test_accuracy: acc,
                    param_count: ck.model.param_count(),
                    featurizer: self.featurizer.name(),
                };
                return Ok((ck.model, report));
            }
            return self.fit_inner(ck.model, start, train, test, Some(path));
        }
        let fdim = self.featurizer.feature_dim(train.dim());
        let model = SoftmaxRegression::zeros(train.classes(), fdim);
        self.fit_inner(model, 0, train, test, Some(path))
    }

    /// Continue training `model` over epochs `start_epoch..config.epochs`
    /// — the checkpoint-resume path. Each epoch's shuffle is keyed by
    /// its absolute epoch index, so (with momentum 0, which carries no
    /// optimizer state across the restart) a resumed run replays
    /// exactly what the uninterrupted run would have done.
    pub fn fit_resume(
        &self,
        model: SoftmaxRegression,
        start_epoch: usize,
        train: &Dataset,
        test: &Dataset,
    ) -> Result<(SoftmaxRegression, TrainReport), McError> {
        self.fit_inner(model, start_epoch, train, test, self.autosave.as_deref())
    }

    fn fit_inner(
        &self,
        mut model: SoftmaxRegression,
        start_epoch: usize,
        train: &Dataset,
        test: &Dataset,
        autosave: Option<&Path>,
    ) -> Result<(SoftmaxRegression, TrainReport), McError> {
        let fdim = self.featurizer.feature_dim(train.dim());
        if model.features() != fdim {
            return Err(McError::DimMismatch { expected: fdim, got: model.features() });
        }
        // Optimizer velocity is not checkpointed, so a mid-training
        // restart can only replay the uninterrupted run when the
        // optimizer is stateless.
        assert!(
            start_epoch == 0 || self.config.sgd.momentum == 0.0,
            "resume requires momentum 0 (velocity is not checkpointed)"
        );
        // (start_epoch == 0 with epochs == 0 mirrors the serial
        // trainer's empty-run behaviour; an actual resume cursor at or
        // past the end would silently yield an empty history + NaN.)
        assert!(
            start_epoch == 0 || start_epoch < self.config.epochs,
            "resume cursor {start_epoch} is at/past config.epochs {}",
            self.config.epochs
        );
        let classes = model.classes();
        let workers = self.config.workers;
        let mut opt = Sgd::new(self.config.sgd);
        let batcher = Batcher::new(self.config.batch_size, self.config.seed);
        let max_shard = self.config.batch_size.div_ceil(workers);
        // One cache shared by every worker slot: the key excludes the
        // lane count, so all shard engines address the same entries,
        // and per-shard locks absorb the concurrent lookups.
        let cache =
            self.config.cache_bytes.map(|b| Arc::new(crate::mckernel::FeatureCache::new(b)));
        let mut slots: Vec<WorkerSlot> = (0..workers)
            .map(|_| WorkerSlot {
                idx: 0,
                lo: 0,
                hi: 0,
                feats: vec![0.0; max_shard * fdim],
                delta: vec![0.0; max_shard * classes],
                grads: Gradients::zeros(classes, fdim),
                engine: self.featurizer.make_engine_cached(max_shard, cache.clone()),
                loss_sum: 0.0,
                hits: 0,
            })
            .collect();
        let total_epochs = self.config.epochs;
        let mut history = Vec::with_capacity(total_epochs.saturating_sub(start_epoch));
        let metrics = TrainerObs::resolve_if_enabled();
        // Shard-timing handle cloned into the worker closure (timing
        // happens on pool threads; recording is lock-free).
        let shard_ns: Option<Arc<obs::Hist>> = metrics.as_ref().map(|m| Arc::clone(&m.shard_ns));
        // Retry accounting is a rare, coarse event — recorded
        // unconditionally like the server counters.
        let retries = obs::global().counter("train.retries");
        for epoch in start_epoch..total_epochs {
            let t0 = Instant::now();
            let mut loss_sum = 0.0f64;
            let mut loss_batches = 0usize;
            let mut train_hits = 0usize;
            let mut train_count = 0usize;
            for (bi, batch) in batcher.epoch(train, epoch).enumerate() {
                let rows = batch.images.rows();
                let d = batch.images.cols();
                // Deterministic shard boundaries: a function of
                // (rows, workers) only — the first `rows % shards`
                // shards take one extra row.
                let shards = workers.min(rows).max(1);
                let base = rows / shards;
                let rem = rows % shards;
                let mut lo = 0;
                for (s, slot) in slots[..shards].iter_mut().enumerate() {
                    let len = base + usize::from(s < rem);
                    slot.idx = s;
                    slot.lo = lo;
                    slot.hi = lo + len;
                    lo += len;
                }
                {
                    let featurizer = &self.featurizer;
                    let mref = &model;
                    let images = &batch.images;
                    let labels = &batch.labels;
                    let shard_ns = shard_ns.clone();
                    let faults = self.faults.as_deref();
                    // One shard's whole step — pure in the shard's
                    // inputs, so rerunning it (on any worker, any
                    // attempt) reproduces bit-identical sums.
                    let run_shard = move |slot: &mut WorkerSlot, attempt: u32| {
                        if let Some(plan) = faults {
                            let key = shard_key(epoch, bi, slot.idx, attempt);
                            if plan.fires_at(FaultSite::WorkerPanic, key) {
                                // analyze: allow(no-panic-serving) -- deliberate chaos injection; the pool's catch_unwind contains it
                                panic!("injected fault: shard {} attempt {attempt}", slot.idx);
                            }
                        }
                        let t_shard = shard_ns.as_ref().map(|_| Instant::now());
                        slot.grads.reset();
                        slot.loss_sum = 0.0;
                        slot.hits = 0;
                        let (lo, hi) = (slot.lo, slot.hi);
                        let srows = hi - lo;
                        let xs = &images.data()[lo * d..hi * d];
                        let feats = &mut slot.feats[..srows * fdim];
                        featurizer.apply_shard(xs, srows, d, feats, &mut slot.engine);
                        let (ls, h) = mref.shard_loss_grad_sums(
                            feats,
                            srows,
                            &labels[lo..hi],
                            &mut slot.delta[..srows * classes],
                            &mut slot.grads,
                        );
                        slot.loss_sum = ls;
                        slot.hits = h;
                        if let (Some(hist), Some(t)) = (&shard_ns, t_shard) {
                            hist.record(obs::elapsed_ns(t));
                        }
                    };
                    let mut failed = self
                        .pool
                        .scope_shards(&mut slots[..shards], |_s, slot| run_shard(slot, 0))?;
                    let mut attempt = 0u32;
                    while !failed.is_empty() {
                        attempt += 1;
                        if attempt > self.retry.max_retries {
                            return Err(McError::WorkerPanic);
                        }
                        retries.add(failed.len() as u64);
                        let delay = self.retry.delay(attempt);
                        if !delay.is_zero() {
                            std::thread::sleep(delay);
                        }
                        // Quarantine: a panic mid-featurization leaves
                        // the slot's pooled engine state suspect, so
                        // rebuild it; the shard math itself recomputes
                        // bit-identically from the inputs.
                        for &i in &failed {
                            // The shared cache survives quarantine: it
                            // only stores rows an execute *completed*,
                            // so its contents are never suspect.
                            slots[i].engine =
                                self.featurizer.make_engine_cached(max_shard, cache.clone());
                        }
                        // Resubmit exactly the failed shards to the
                        // surviving pool (panic-contained workers stay
                        // alive, so the full pool width remains).
                        let mut retry_idx = Vec::with_capacity(failed.len());
                        let mut retry_slots: Vec<&mut WorkerSlot> =
                            Vec::with_capacity(failed.len());
                        for (i, slot) in slots[..shards].iter_mut().enumerate() {
                            if failed.contains(&i) {
                                retry_idx.push(i);
                                retry_slots.push(slot);
                            }
                        }
                        let again = self.pool.scope_shards(&mut retry_slots, |_j, slot| {
                            run_shard(&mut **slot, attempt)
                        })?;
                        failed = again.into_iter().map(|j| retry_idx[j]).collect();
                    }
                }
                // Fixed-order tree reduction into slot 0: merge order
                // is a function of the shard count alone, never of
                // which worker finished first.
                let t_reduce = metrics.as_ref().map(|_| Instant::now());
                tree_reduce_with(&mut slots[..shards], |a, b| {
                    a.grads.merge(&b.grads);
                    a.loss_sum += b.loss_sum;
                    a.hits += b.hits;
                });
                let inv = 1.0 / rows as f32;
                slots[0].grads.scale(inv);
                if let (Some(m), Some(t)) = (&metrics, t_reduce) {
                    m.reduce_ns.record(obs::elapsed_ns(t));
                }
                loss_sum += slots[0].loss_sum / rows as f64;
                train_hits += slots[0].hits;
                train_count += rows;
                loss_batches += 1;
                opt.step(&mut model, &slots[0].grads);
            }
            // Single clock reading for both the ns histogram and the
            // seconds-based throughput (see the serial trainer).
            let train_ns = obs::elapsed_ns(t0);
            let train_secs = train_ns as f64 * 1e-9;
            if let Some(m) = &metrics {
                m.epoch_ns.record(train_ns);
                m.rows.add(train_count as u64);
            }
            let test_acc = if self.config.eval_every_epoch || epoch + 1 == total_epochs {
                evaluate_with(&self.featurizer, &model, test)
            } else {
                f64::NAN
            };
            let rec = if loss_batches == 0 {
                EpochRecord::empty(epoch, test_acc, t0.elapsed().as_secs_f64())
            } else {
                EpochRecord {
                    epoch,
                    train_loss: loss_sum / loss_batches as f64,
                    train_accuracy: train_hits as f64 / train_count.max(1) as f64,
                    test_accuracy: test_acc,
                    seconds: t0.elapsed().as_secs_f64(),
                    rows_per_s: EpochRecord::throughput(train_count, train_secs),
                }
            };
            if self.config.verbose {
                eprintln!(
                    "[{}×{}] epoch {:>3}  loss {:.4}  train-acc {:.4}  test-acc {:.4}  ({:.2}s)",
                    self.featurizer.name(),
                    workers,
                    rec.epoch,
                    rec.train_loss,
                    rec.train_accuracy,
                    rec.test_accuracy,
                    rec.seconds
                );
            }
            history.push(rec);
            // Autosave with the resume cursor: a kill after this point
            // loses at most the *next* epoch; `fit_auto` on the same
            // path replays the rest bit-identically (epoch-keyed
            // shuffles + stateless optimizer).
            if let Some(path) = autosave {
                Checkpoint::for_training(self.featurizer.config(), model.clone(), epoch + 1)
                    .save(path)
                    .map_err(|e| McError::Io(format!("autosave {}: {e}", path.display())))?;
            }
        }
        let final_test_accuracy = history
            .last()
            .map(|r| r.test_accuracy)
            .unwrap_or(f64::NAN);
        let report = TrainReport {
            final_test_accuracy,
            param_count: model.param_count(),
            featurizer: self.featurizer.name(),
            history,
        };
        Ok((model, report))
    }

    /// Accuracy of `model` on `data` (featurized in eval batches).
    pub fn evaluate(&self, model: &SoftmaxRegression, data: &Dataset) -> f64 {
        evaluate_with(&self.featurizer, model, data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticSpec;
    use crate::mckernel::McKernelFactory;
    use std::sync::Arc;

    fn datasets(n_train: usize, n_test: usize) -> (Dataset, Dataset) {
        let spec = SyntheticSpec::mnist();
        (
            Dataset::synthetic(11, &spec, "train", n_train),
            Dataset::synthetic(11, &spec, "test", n_test),
        )
    }

    fn quick_config(epochs: usize, lr: f32) -> TrainConfig {
        TrainConfig {
            epochs,
            batch_size: 10,
            sgd: SgdConfig { lr, momentum: 0.0, clip: None },
            seed: 1,
            eval_every_epoch: false,
            verbose: false,
            workers: 1,
            cache_bytes: None,
        }
    }

    #[test]
    fn lr_baseline_learns_synthetic_data() {
        let (train, test) = datasets(300, 100);
        let trainer = Trainer::new(quick_config(8, 0.05), Featurizer::Identity);
        let (_, report) = trainer.fit(&train, &test);
        assert!(
            report.final_test_accuracy > 0.5,
            "LR should beat chance: {}",
            report.final_test_accuracy
        );
        assert_eq!(report.history.len(), 8);
        assert_eq!(report.param_count, 10 * 785);
    }

    #[test]
    fn loss_decreases_over_epochs() {
        let (train, test) = datasets(200, 50);
        let trainer = Trainer::new(quick_config(6, 0.05), Featurizer::Identity);
        let (_, report) = trainer.fit(&train, &test);
        let first = report.history.first().unwrap().train_loss;
        let last = report.history.last().unwrap().train_loss;
        assert!(last < first, "loss {first} -> {last}");
    }

    #[test]
    fn mckernel_features_train_too() {
        let (train, test) = datasets(200, 60);
        // σ must match the data scale: image vectors have norm ≈ 9, so
        // σ=8 keeps typical pairwise kernel values informative. (The
        // paper's σ=1 works with Matérn t=40, whose radial draws are
        // ≈5× smaller than chi_n, i.e. an effective bandwidth ≈5.)
        let fm = Arc::new(
            McKernelFactory::new(784).expansions(1).sigma(8.0).rbf().seed(1).build(),
        );
        // ‖φ‖² ≈ n (cos²+sin²=1 per dim), so the kernel head needs the
        // paper's smaller lr (0.001-ish) where raw pixels take 0.05.
        let trainer = Trainer::new(quick_config(6, 0.002), Featurizer::McKernel(fm));
        let (model, report) = trainer.fit(&train, &test);
        assert!(report.final_test_accuracy > 0.4, "{}", report.final_test_accuracy);
        assert_eq!(model.features(), 2 * 1024);
        assert_eq!(report.param_count, 10 * (2 * 1024 + 1));
    }

    #[test]
    fn deterministic_given_seed() {
        let (train, test) = datasets(100, 30);
        let t1 = Trainer::new(quick_config(2, 0.05), Featurizer::Identity);
        let (m1, _) = t1.fit(&train, &test);
        let t2 = Trainer::new(quick_config(2, 0.05), Featurizer::Identity);
        let (m2, _) = t2.fit(&train, &test);
        assert_eq!(m1.w().data(), m2.w().data());
    }

    #[test]
    fn parallel_trainer_learns_and_shards_ragged_batches() {
        // 53 samples, batch 10 → a ragged 3-row tail batch; workers 4
        // shard 10 rows as 3/3/2/2 and the tail as 1/1/1.
        let (train, test) = datasets(53, 30);
        let mut cfg = quick_config(4, 0.05);
        cfg.workers = 4;
        let trainer = ParallelTrainer::new(cfg, Featurizer::Identity);
        let (model, report) = trainer.fit(&train, &test).unwrap();
        assert_eq!(report.history.len(), 4);
        assert!(report.history.iter().all(|r| r.train_loss.is_finite()));
        assert!(report.final_test_accuracy > 0.3, "{}", report.final_test_accuracy);
        assert_eq!(model.features(), 784);
    }

    #[test]
    fn parallel_trainer_resume_is_bit_identical() {
        let (train, test) = datasets(60, 20);
        let full = ParallelTrainer::new(quick_config(4, 0.05), Featurizer::Identity);
        let (m_full, _) = full.fit(&train, &test).unwrap();
        let half = ParallelTrainer::new(quick_config(2, 0.05), Featurizer::Identity);
        let (m_half, _) = half.fit(&train, &test).unwrap();
        let (m_res, rep) = full.fit_resume(m_half, 2, &train, &test).unwrap();
        assert_eq!(m_res.w().data(), m_full.w().data());
        assert_eq!(m_res.b(), m_full.b());
        assert_eq!(rep.history.len(), 2);
        assert_eq!(rep.history[0].epoch, 2);
    }

    #[test]
    fn cached_training_is_bit_identical_to_uncached() {
        let (train, test) = datasets(40, 10);
        let fm = Arc::new(
            McKernelFactory::new(784).expansions(1).sigma(8.0).rbf().seed(1).build(),
        );
        let plain = Trainer::new(quick_config(3, 0.002), Featurizer::McKernel(Arc::clone(&fm)));
        let (m_plain, _) = plain.fit(&train, &test);
        let mut cfg = quick_config(3, 0.002);
        cfg.cache_bytes = Some(32 << 20);
        let cached = Trainer::new(cfg, Featurizer::McKernel(fm));
        let (m_cached, _) = cached.fit(&train, &test);
        assert_eq!(m_plain.w().data(), m_cached.w().data());
        assert_eq!(m_plain.b(), m_cached.b());
    }

    #[test]
    fn empty_dataset_epochs_are_finite() {
        // Zero training rows → every epoch sees zero batches; the
        // report must carry explicit empty records, not NaN.
        let (train, test) = datasets(0, 20);
        let trainer = Trainer::new(quick_config(2, 0.05), Featurizer::Identity);
        let (_, report) = trainer.fit(&train, &test);
        assert_eq!(report.history.len(), 2);
        for r in &report.history {
            assert_eq!((r.train_loss, r.train_accuracy, r.rows_per_s), (0.0, 0.0, 0.0));
            assert!(r.seconds.is_finite());
        }
        let mut cfg = quick_config(2, 0.05);
        cfg.workers = 2;
        let par = ParallelTrainer::new(cfg, Featurizer::Identity);
        let (_, report) = par.fit(&train, &test).unwrap();
        assert!(report.history.iter().all(|r| r.train_loss == 0.0 && r.rows_per_s == 0.0));
    }

    #[test]
    fn drop_last_short_dataset_yields_empty_epochs() {
        // 5 rows with batch 10 under drop_last: batches_per_epoch = 0.
        let (train, test) = datasets(5, 10);
        assert_eq!(Batcher::new(10, 1).drop_last().batches_per_epoch(train.len()), 0);
        let trainer = Trainer::new(quick_config(1, 0.05), Featurizer::Identity);
        // the default batcher keeps the ragged tail, so this run still
        // trains; the explicit empty-record path is what we pin here
        let (_, report) = trainer.fit(&train, &test);
        assert!(report.history.iter().all(|r| r.train_loss.is_finite()));
        let empty = EpochRecord::empty(0, 0.5, 0.01);
        assert_eq!(empty.rows_per_s, 0.0);
        assert!(!empty.to_csv_row().contains("NaN"));
    }

    #[test]
    fn csv_export() {
        let (train, test) = datasets(60, 20);
        let trainer = Trainer::new(quick_config(2, 0.05), Featurizer::Identity);
        let (_, report) = trainer.fit(&train, &test);
        let csv = report.to_csv();
        assert_eq!(csv.lines().count(), 3); // header + 2 epochs
        assert!(csv.starts_with("epoch,"));
    }
}
