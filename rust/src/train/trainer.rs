//! The epoch-loop trainer: mini-batch SGD over a featurized dataset —
//! the engine behind Figures 3, 4 and 5. Works with any
//! [`Featurizer`]; every mini-batch goes through the batch-vectorized
//! McKernel pipeline ([`crate::mckernel::McKernel::transform_batch_into`])
//! via [`Featurizer::apply`]. The PJRT-backed path lives in
//! [`crate::coordinator`] (it owns device state).

use super::featurizer::Featurizer;
use super::metrics::{accuracy, EpochRecord};
use crate::data::{Batcher, Dataset};
use crate::model::SoftmaxRegression;
use crate::optim::{Sgd, SgdConfig};
use std::time::Instant;

/// Trainer configuration (defaults = the paper's Figure 4/5 settings
/// for the McKernel curve).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub epochs: usize,
    pub batch_size: usize,
    pub sgd: SgdConfig,
    pub seed: u64,
    /// Evaluate on test data each epoch (off = only final).
    pub eval_every_epoch: bool,
    /// Print progress lines.
    pub verbose: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            epochs: 20,
            batch_size: 10,
            sgd: SgdConfig { lr: 0.001, momentum: 0.0, clip: None },
            seed: crate::PAPER_SEED,
            eval_every_epoch: true,
            verbose: false,
        }
    }
}

/// Outcome of a training run.
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub history: Vec<EpochRecord>,
    pub final_test_accuracy: f64,
    pub param_count: usize,
    pub featurizer: &'static str,
}

impl TrainReport {
    /// History as CSV (one row per epoch).
    pub fn to_csv(&self) -> String {
        let mut out = String::from(EpochRecord::csv_header());
        out.push('\n');
        for r in &self.history {
            out.push_str(&r.to_csv_row());
            out.push('\n');
        }
        out
    }
}

/// Mini-batch SGD trainer.
pub struct Trainer {
    pub config: TrainConfig,
    pub featurizer: Featurizer,
}

impl Trainer {
    pub fn new(config: TrainConfig, featurizer: Featurizer) -> Trainer {
        Trainer { config, featurizer }
    }

    /// Train a fresh model on `train`, evaluating on `test`.
    pub fn fit(&self, train: &Dataset, test: &Dataset) -> (SoftmaxRegression, TrainReport) {
        let fdim = self.featurizer.feature_dim(train.dim());
        let mut model = SoftmaxRegression::zeros(train.classes(), fdim);
        let mut opt = Sgd::new(self.config.sgd);
        let batcher = Batcher::new(self.config.batch_size, self.config.seed);
        let mut history = Vec::with_capacity(self.config.epochs);

        for epoch in 0..self.config.epochs {
            let t0 = Instant::now();
            let mut loss_sum = 0.0f64;
            let mut loss_batches = 0usize;
            let mut train_hits = 0usize;
            let mut train_count = 0usize;
            for batch in batcher.epoch(train, epoch) {
                let feats = self.featurizer.apply(&batch.images);
                let (loss, grads) = model.loss_and_grad(&feats, &batch.labels);
                // training accuracy from the same logits' argmax would
                // need another pass; use predictions on features:
                let preds = model.predict(&feats);
                train_hits += preds
                    .iter()
                    .zip(&batch.labels)
                    .filter(|(a, b)| a == b)
                    .count();
                train_count += batch.labels.len();
                opt.step(&mut model, &grads);
                loss_sum += loss as f64;
                loss_batches += 1;
            }
            let test_acc = if self.config.eval_every_epoch || epoch + 1 == self.config.epochs {
                self.evaluate(&model, test)
            } else {
                f64::NAN
            };
            let rec = EpochRecord {
                epoch,
                train_loss: loss_sum / loss_batches.max(1) as f64,
                train_accuracy: train_hits as f64 / train_count.max(1) as f64,
                test_accuracy: test_acc,
                seconds: t0.elapsed().as_secs_f64(),
            };
            if self.config.verbose {
                eprintln!(
                    "[{}] epoch {:>3}  loss {:.4}  train-acc {:.4}  test-acc {:.4}  ({:.2}s)",
                    self.featurizer.name(),
                    rec.epoch,
                    rec.train_loss,
                    rec.train_accuracy,
                    rec.test_accuracy,
                    rec.seconds
                );
            }
            history.push(rec);
        }
        let final_test_accuracy = history
            .last()
            .map(|r| r.test_accuracy)
            .unwrap_or(f64::NAN);
        let report = TrainReport {
            final_test_accuracy,
            param_count: model.param_count(),
            featurizer: self.featurizer.name(),
            history,
        };
        (model, report)
    }

    /// Accuracy of `model` on `data` (featurized in eval batches).
    pub fn evaluate(&self, model: &SoftmaxRegression, data: &Dataset) -> f64 {
        let batcher = Batcher::new(256, 0).sequential();
        let mut preds = Vec::with_capacity(data.len());
        for batch in batcher.epoch(data, 0) {
            let feats = self.featurizer.apply(&batch.images);
            preds.extend(model.predict(&feats));
        }
        accuracy(&preds, data.labels())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticSpec;
    use crate::mckernel::McKernelFactory;
    use std::sync::Arc;

    fn datasets(n_train: usize, n_test: usize) -> (Dataset, Dataset) {
        let spec = SyntheticSpec::mnist();
        (
            Dataset::synthetic(11, &spec, "train", n_train),
            Dataset::synthetic(11, &spec, "test", n_test),
        )
    }

    fn quick_config(epochs: usize, lr: f32) -> TrainConfig {
        TrainConfig {
            epochs,
            batch_size: 10,
            sgd: SgdConfig { lr, momentum: 0.0, clip: None },
            seed: 1,
            eval_every_epoch: false,
            verbose: false,
        }
    }

    #[test]
    fn lr_baseline_learns_synthetic_data() {
        let (train, test) = datasets(300, 100);
        let trainer = Trainer::new(quick_config(8, 0.05), Featurizer::Identity);
        let (_, report) = trainer.fit(&train, &test);
        assert!(
            report.final_test_accuracy > 0.5,
            "LR should beat chance: {}",
            report.final_test_accuracy
        );
        assert_eq!(report.history.len(), 8);
        assert_eq!(report.param_count, 10 * 785);
    }

    #[test]
    fn loss_decreases_over_epochs() {
        let (train, test) = datasets(200, 50);
        let trainer = Trainer::new(quick_config(6, 0.05), Featurizer::Identity);
        let (_, report) = trainer.fit(&train, &test);
        let first = report.history.first().unwrap().train_loss;
        let last = report.history.last().unwrap().train_loss;
        assert!(last < first, "loss {first} -> {last}");
    }

    #[test]
    fn mckernel_features_train_too() {
        let (train, test) = datasets(200, 60);
        // σ must match the data scale: image vectors have norm ≈ 9, so
        // σ=8 keeps typical pairwise kernel values informative. (The
        // paper's σ=1 works with Matérn t=40, whose radial draws are
        // ≈5× smaller than chi_n, i.e. an effective bandwidth ≈5.)
        let fm = Arc::new(
            McKernelFactory::new(784).expansions(1).sigma(8.0).rbf().seed(1).build(),
        );
        // ‖φ‖² ≈ n (cos²+sin²=1 per dim), so the kernel head needs the
        // paper's smaller lr (0.001-ish) where raw pixels take 0.05.
        let trainer = Trainer::new(quick_config(6, 0.002), Featurizer::McKernel(fm));
        let (model, report) = trainer.fit(&train, &test);
        assert!(report.final_test_accuracy > 0.4, "{}", report.final_test_accuracy);
        assert_eq!(model.features(), 2 * 1024);
        assert_eq!(report.param_count, 10 * (2 * 1024 + 1));
    }

    #[test]
    fn deterministic_given_seed() {
        let (train, test) = datasets(100, 30);
        let t1 = Trainer::new(quick_config(2, 0.05), Featurizer::Identity);
        let (m1, _) = t1.fit(&train, &test);
        let t2 = Trainer::new(quick_config(2, 0.05), Featurizer::Identity);
        let (m2, _) = t2.fit(&train, &test);
        assert_eq!(m1.w().data(), m2.w().data());
    }

    #[test]
    fn csv_export() {
        let (train, test) = datasets(60, 20);
        let trainer = Trainer::new(quick_config(2, 0.05), Featurizer::Identity);
        let (_, report) = trainer.fit(&train, &test);
        let csv = report.to_csv();
        assert_eq!(csv.lines().count(), 3); // header + 2 epochs
        assert!(csv.starts_with("epoch,"));
    }
}
