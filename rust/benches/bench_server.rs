//! Feature-server coordination bench: throughput and batching
//! occupancy vs client concurrency and batching window — the L3
//! coordinator's own performance characteristics (backpressure,
//! dynamic batching), independent of the math.
//!
//! Usage: cargo bench --bench bench_server [-- --quick]

use mckernel::benchkit::Report;
use mckernel::coordinator::{FeatureServer, ServerConfig};
use mckernel::mckernel::McKernelFactory;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn run_load(clients: usize, per_client: usize, max_batch: usize, wait: Duration) -> (f64, f64) {
    let map = Arc::new(
        McKernelFactory::new(784).expansions(1).sigma(1.0).rbf_matern(40).seed(1).build(),
    );
    let server = FeatureServer::start(map, ServerConfig::new(max_batch, wait));
    let x: Vec<f32> = (0..784).map(|i| (i % 11) as f32 / 11.0).collect();
    let t0 = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|_| {
            let c = server.client();
            let x = x.clone();
            std::thread::spawn(move || {
                for _ in 0..per_client {
                    c.transform(x.clone()).unwrap();
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let secs = t0.elapsed().as_secs_f64();
    let rps = (clients * per_client) as f64 / secs;
    let occupancy = server.stats().mean_batch_size();
    server.shutdown();
    (rps, occupancy)
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let per_client = if quick { 50 } else { 300 };

    let mut by_clients = Report::new(
        "Feature server — throughput vs concurrency (batch 32, 200µs window)",
        &["req/s", "mean batch"],
    );
    for clients in [1usize, 2, 4, 8, 16] {
        let (rps, occ) = run_load(clients, per_client, 32, Duration::from_micros(200));
        by_clients.add_row(&format!("{clients} clients"), &[rps, occ]);
    }
    println!("{}", by_clients.to_table());
    by_clients.write_csv("bench_results/server_concurrency.csv").ok();

    let mut by_window = Report::new(
        "Feature server — batching window ablation (8 clients)",
        &["req/s", "mean batch"],
    );
    for wait_us in [0u64, 50, 200, 1000] {
        let (rps, occ) = run_load(8, per_client, 32, Duration::from_micros(wait_us));
        by_window.add_row(&format!("{wait_us}µs"), &[rps, occ]);
    }
    println!("{}", by_window.to_table());
    by_window.write_csv("bench_results/server_window.csv").ok();
}
