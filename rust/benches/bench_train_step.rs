//! Train-step latency: native Rust loop vs the compiled PJRT artifact
//! (the DESIGN.md §7 backend ablation). Requires `make artifacts` for
//! the PJRT rows (skipped otherwise).
//!
//! Usage: cargo bench --bench bench_train_step [-- --quick]

use mckernel::benchkit::{bench, BenchConfig, Report};
use mckernel::data::{Dataset, SyntheticSpec};
use mckernel::mckernel::McKernelFactory;
use mckernel::model::SoftmaxRegression;
use mckernel::optim::{Sgd, SgdConfig};
use mckernel::runtime::{Runtime, TrainStep};
use std::sync::Arc;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let cfg = if quick { BenchConfig::quick() } else { BenchConfig::default() };
    let batch = 10;
    let data = Dataset::synthetic(1, &SyntheticSpec::mnist(), "train", batch);
    let x = data.images().clone();
    let y = data.labels().to_vec();

    let mut report = Report::new(
        "SGD train-step latency per batch of 10 (ms)",
        &["native", "pjrt", "pjrt/native ×"],
    );

    let rt = Runtime::new("artifacts").ok();
    if rt.is_none() {
        eprintln!("NOTE: artifacts/ missing — PJRT columns will be NaN (run `make artifacts`)");
    }

    for e in [0usize, 1, 2, 4] {
        let (native_ms, pjrt_ms) = if e == 0 {
            // LR baseline
            let mut model = SoftmaxRegression::zeros(10, 784);
            let mut opt = Sgd::new(SgdConfig { lr: 0.01, momentum: 0.0, clip: None });
            let native = bench("native-lr", &cfg, |_| {
                let (_, g) = model.loss_and_grad(&x, &y);
                opt.step(&mut model, &g);
            });
            let pjrt = rt.as_ref().map(|rt| {
                let mut step = TrainStep::new(rt, "identity", None).unwrap();
                bench("pjrt-lr", &cfg, |_| {
                    step.step(&x, &y, 0.01).unwrap();
                })
            });
            (native.median_ms(), pjrt.map(|p| p.median_ms()).unwrap_or(f64::NAN))
        } else {
            let map = Arc::new(
                McKernelFactory::new(784).expansions(e).sigma(1.0).rbf_matern(40).seed(1).build(),
            );
            let mut model = SoftmaxRegression::zeros(10, map.feature_dim());
            let mut opt = Sgd::new(SgdConfig { lr: 0.001, momentum: 0.0, clip: None });
            let m2 = Arc::clone(&map);
            let xx = x.clone();
            let yy = y.clone();
            let native = bench("native-mck", &cfg, move |_| {
                let feats = m2.transform_batch(&xx);
                let (_, g) = model.loss_and_grad(&feats, &yy);
                opt.step(&mut model, &g);
            });
            let pjrt = rt.as_ref().map(|rt| {
                let mut step = TrainStep::new(rt, "mckernel", Some(&map)).unwrap();
                bench("pjrt-mck", &cfg, |_| {
                    step.step(&x, &y, 0.001).unwrap();
                })
            });
            (native.median_ms(), pjrt.map(|p| p.median_ms()).unwrap_or(f64::NAN))
        };
        report.add_row(
            &(if e == 0 { "LR".to_string() } else { format!("mck E={e}") }),
            &[native_ms, pjrt_ms, pjrt_ms / native_ms],
        );
    }
    println!("{}", report.to_table());
    report.write_csv("bench_results/train_step.csv").ok();
}
