//! Feature-cache payoff curve: cached vs uncached batch featurization
//! across hit-rate regimes (unique-stream worst case → full-replay
//! steady state). A hit costs one MurmurHash3 over the row plus a
//! memcpy; a miss costs that overhead *on top of* the FWHT pipeline —
//! so the table quantifies both the win and the worst-case tax (see
//! EXPERIMENTS.md "Feature cache").
//!
//! Usage: cargo bench --bench bench_cache [-- --quick]

use mckernel::benchkit::{bench, BenchConfig, Report};
use mckernel::hash::HashRng;
use mckernel::linalg::Matrix;
use mckernel::mckernel::cache::entry_cost;
use mckernel::mckernel::{CacheKey, ExpansionEngine, FeatureCache, McKernelFactory};
use mckernel::obs::MetricsRegistry;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let cfg = if quick { BenchConfig::quick() } else { BenchConfig::default() };
    let input_dim = 784; // MNIST geometry, pads to 1024
    let batch = 64usize;
    let batches = if quick { 8 } else { 32 };
    let e = 4usize;

    let map = McKernelFactory::new(input_dim)
        .expansions(e)
        .sigma(1.0)
        .rbf_matern(40)
        .seed(1)
        .build();
    let fd = map.feature_dim();
    let mut feats = Matrix::zeros(batch, fd);

    let mut eng_u = ExpansionEngine::new(&map, batch);
    let key = CacheKey::new(map.config(), eng_u.plan());

    // Regimes, shaped by replay fraction AND byte budget (a huge
    // budget would turn any cyclic replay into all-hits after one
    // pass): "all-miss" undersizes the cache so the cyclic unique
    // stream thrashes LRU — every lookup pays hash + probe + insert +
    // evict on top of the engine; "mixed" keeps the hot pool resident
    // while unique rows thrash; "steady" holds everything (serving
    // with repeated inputs, or training epochs after the first).
    let cost = entry_cost(input_dim, fd);
    let regimes: [(&str, f64, usize); 3] = [
        ("all-miss", 0.0, 32 * cost),
        ("mixed", 0.5, 4 * batch * cost),
        ("steady", 1.0, 256 << 20),
    ];
    let mut report = Report::new(
        &format!("Feature cache, 784→1024 E={e} batch={batch} (ms/batch)"),
        &["uncached", "cached", "speedup", "hit rate"],
    );
    for (label, replay, capacity) in regimes {
        let mut rng = HashRng::new(11, replay.to_bits());
        let pool = Matrix::from_fn(batch, input_dim, |_, _| rng.next_f32() - 0.5);
        let inputs: Vec<Matrix> = (0..batches)
            .map(|_| {
                Matrix::from_fn(batch, input_dim, |r, c| {
                    // per-row choice: replay from the hot pool or draw
                    // a row unique across the whole stream
                    if (r as f64 + 0.5) / batch as f64 <= replay {
                        pool.row(r)[c]
                    } else {
                        rng.next_f32() - 0.5
                    }
                })
            })
            .collect();

        let uncached = bench("cache/uncached", &cfg, |i| {
            eng_u.execute_matrix(&map, &inputs[i % batches], &mut feats);
        });

        let reg = MetricsRegistry::new();
        let cache = FeatureCache::with_registry(capacity, 8, &reg);
        let mut eng_c = ExpansionEngine::new(&map, batch);
        for xb in &inputs {
            cache.execute_matrix(key, &mut eng_c, &map, xb, &mut feats);
        }
        let cached = bench("cache/cached", &cfg, |i| {
            cache.execute_matrix(key, &mut eng_c, &map, &inputs[i % batches], &mut feats);
        });
        let total = cache.hits() + cache.misses();
        let hit_rate = if total > 0 { cache.hits() as f64 / total as f64 } else { 0.0 };
        report.add_row(
            &format!("{label} (replay={replay:.1})"),
            &[
                uncached.median_ms(),
                cached.median_ms(),
                uncached.stats.median / cached.stats.median,
                hit_rate,
            ],
        );
    }
    println!("{}", report.to_table());
    report.write_csv("bench_results/feature_cache.csv").ok();
}
