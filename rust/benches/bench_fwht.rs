//! Table 1 / Figure 2 regeneration: FWHT timing, McKernel engine vs
//! the Spiral-like recursive baseline, n = 2^10 … 2^20.
//!
//! Also: `--ablation` sweeps the engine set (naive excluded above
//! 2^13) and reports the iterative-vs-optimized and cached-plan
//! variants — the design-choice ablations DESIGN.md §7 calls out.
//!
//! Usage: cargo bench --bench bench_fwht [-- --ablation] [-- --quick]

use mckernel::benchkit::{bench, BenchConfig, Report};
use mckernel::fwht::{iterative, optimized, reference, simd};
use mckernel::hash::HashRng;
use mckernel::util::simd as simd_caps;

fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
    let mut r = HashRng::new(seed, 0xBE);
    (0..n).map(|_| r.next_f32() - 0.5).collect()
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let ablation = args.iter().any(|a| a == "--ablation");
    let quick = args.iter().any(|a| a == "--quick");
    let cfg = if quick { BenchConfig::quick() } else { BenchConfig::default() };

    // ---- Table 1: mckernel vs SIMD vs spiral-like baseline -----------
    let mut table1 = Report::new(
        "Table 1 — Fast Walsh Hadamard, time per transform (ms)",
        &["mckernel", "simd", "spiral(recursive)", "speedup", "simd speedup"],
    );
    println!(
        "running Table 1 sizes 2^10..2^20 … (simd level: {})",
        simd_caps::level().name()
    );
    for log_n in 10..=20 {
        let n = 1usize << log_n;
        let mut data = rand_vec(n, log_n as u64);
        let mck = bench("mckernel", &cfg, |_| optimized::fwht(&mut data));
        let mut data_s = rand_vec(n, log_n as u64 + 200);
        let vec = bench("simd", &cfg, |_| simd::fwht(&mut data_s));
        // Spiral executes a precomputed plan; timing plan-build each
        // call would be unfair — build once, execute per iteration
        // (matches Spiral's published methodology).
        let plan = reference::Plan::build(n);
        let mut data2 = rand_vec(n, log_n as u64 + 100);
        let spiral = bench("spiral", &cfg, |_| plan.execute(&mut data2));
        table1.add_row(
            &format!("{n}"),
            &[
                mck.median_ms(),
                vec.median_ms(),
                spiral.median_ms(),
                spiral.stats.median / mck.stats.median,
                mck.stats.median / vec.stats.median,
            ],
        );
    }
    println!("{}", table1.to_table());
    table1.write_csv("bench_results/table1_fwht.csv").ok();
    println!("(CSV for Figure 2 written to bench_results/table1_fwht.csv)\n");

    if !ablation {
        return;
    }

    // ---- Ablation: engine × size -------------------------------------
    let mut ab = Report::new(
        "Ablation — FWHT engines, time per transform (ms)",
        &["naive", "recursive", "iterative", "optimized"],
    );
    for log_n in [8usize, 10, 12, 14, 16] {
        let n = 1usize << log_n;
        let naive_ms = if log_n <= 12 {
            let mut d = rand_vec(n, 7);
            bench("naive", &cfg, |_| reference::fwht_naive(&mut d)).median_ms()
        } else {
            f64::NAN
        };
        let mut d1 = rand_vec(n, 8);
        let rec = bench("recursive", &cfg, |_| reference::fwht_recursive(&mut d1)).median_ms();
        let mut d2 = rand_vec(n, 9);
        let it = bench("iterative", &cfg, |_| iterative::fwht(&mut d2)).median_ms();
        let mut d3 = rand_vec(n, 10);
        let opt = bench("optimized", &cfg, |_| optimized::fwht(&mut d3)).median_ms();
        ab.add_row(&format!("2^{log_n}"), &[naive_ms, rec, it, opt]);
    }
    println!("{}", ab.to_table());
    ab.write_csv("bench_results/ablation_fwht_engines.csv").ok();

    // ---- Ablation: plan reuse (Spiral's tree-precompute cost) --------
    let mut plan_ab = Report::new(
        "Ablation — recursive baseline: plan build cost (ms)",
        &["execute-only", "build+execute", "build overhead %"],
    );
    for log_n in [12usize, 16, 20] {
        let n = 1usize << log_n;
        let plan = reference::Plan::build(n);
        let mut d = rand_vec(n, 11);
        let exec = bench("exec", &cfg, |_| plan.execute(&mut d));
        let mut d2 = rand_vec(n, 12);
        let full = bench("build+exec", &cfg, |_| reference::fwht_recursive(&mut d2));
        let overhead = (full.stats.median / exec.stats.median - 1.0) * 100.0;
        plan_ab.add_row(
            &format!("2^{log_n}"),
            &[exec.median_ms(), full.median_ms(), overhead],
        );
    }
    println!("{}", plan_ab.to_table());
    plan_ab.write_csv("bench_results/ablation_plan_reuse.csv").ok();
}
