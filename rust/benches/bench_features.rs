//! Feature-map throughput: native Rust pipeline vs its FWHT-only
//! lower bound, across expansions — quantifies the paper's claim that
//! the transform is the bottleneck and everything else is O(n) — plus
//! the per-row vs batch-vectorized pipeline comparison (the PR-gating
//! speedup number; see EXPERIMENTS.md).
//!
//! Usage: cargo bench --bench bench_features [-- --quick]

use mckernel::benchkit::{bench, compare_feature_paths, BenchConfig, Report};
use mckernel::fwht::optimized;
use mckernel::hash::HashRng;
use mckernel::linalg::Matrix;
use mckernel::mckernel::{ExpansionEngine, McKernelFactory};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let cfg = if quick { BenchConfig::quick() } else { BenchConfig::default() };
    let input_dim = 784; // MNIST geometry, pads to 1024
    let n = 1024;

    let mut r = HashRng::new(3, 3);
    let x: Vec<f32> = (0..input_dim).map(|_| r.next_f32()).collect();

    let mut report = Report::new(
        "Feature map cost per sample (ms) — 784→1024, by expansions E",
        &["mckernel(E)", "2E×FWHT bound", "overhead ×"],
    );
    for e in [1usize, 2, 4, 8, 16] {
        let map = McKernelFactory::new(input_dim)
            .expansions(e)
            .sigma(1.0)
            .rbf_matern(40)
            .seed(1)
            .build();
        let mut out = vec![0.0f32; map.feature_dim()];
        let mut oracle = ExpansionEngine::per_row_oracle(&map);
        let full = bench("feature_map", &cfg, |_| {
            oracle.execute(&map, &x, 1, x.len(), &mut out)
        });
        // lower bound: the 2E FWHTs alone
        let mut buf: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let fwht_one = bench("fwht", &cfg, |_| optimized::fwht(&mut buf));
        let bound = fwht_one.stats.median * (2 * e) as f64;
        report.add_row(
            &format!("E={e}"),
            &[full.median_ms(), bound * 1e3, full.stats.median / bound],
        );
    }
    println!("{}", report.to_table());
    report.write_csv("bench_results/feature_map.csv").ok();

    // throughput summary for the paper's "lightning expansions" claim
    let map = McKernelFactory::new(input_dim).expansions(4).rbf_matern(40).seed(1).build();
    let mut out = vec![0.0f32; map.feature_dim()];
    let mut oracle = ExpansionEngine::per_row_oracle(&map);
    let rfull = bench("E=4", &cfg, |_| oracle.execute(&map, &x, 1, x.len(), &mut out));
    println!(
        "E=4 throughput: {:.0} samples/s  ({:.1} MB/s of features)",
        rfull.throughput(1.0),
        rfull.throughput(1.0) * (map.feature_dim() * 4) as f64 / 1e6
    );

    // ---- batched pipeline vs per-row oracle (the PR-gating number) ---
    let batch = 64usize;
    let mut rb = HashRng::new(9, 9);
    let xb = Matrix::from_fn(batch, input_dim, |_, _| rb.next_f32() - 0.5);
    let cmp = compare_feature_paths(&map, &xb, &cfg);
    println!(
        "batch={batch}, n=1024, E=4: per-row {:.3} ms/batch  batched {:.3} ms/batch  \
         speedup {:.2}x  ({:.0} rows/s, max |err| {:.2e})",
        cmp.per_row.median_ms(),
        cmp.batched.median_ms(),
        cmp.speedup(),
        cmp.rows_per_s(),
        cmp.max_abs_err
    );
    println!(
        "simd ({}): {:.3} ms/batch  vs scalar tiled {:.2}x  (max |scalar−simd| {:.2e})",
        mckernel::util::simd::level().name(),
        cmp.simd.median_ms(),
        cmp.simd_speedup(),
        cmp.simd_max_abs_err
    );
}
