//! Tier-1 coverage for the batch-vectorized feature pipeline: the
//! batched path must track the per-row oracle across batch sizes,
//! tail tiles, non-power-of-two input dims and both kernels; the
//! batched FWHT must be bit-identical to the per-row engine; and the
//! fast trig kernel must stay within its accuracy budget vs libm.

use mckernel::fwht;
use mckernel::hash::HashRng;
use mckernel::linalg::Matrix;
use mckernel::mckernel::{ExpansionEngine, Kernel, McKernel, McKernelFactory};
use mckernel::train::Featurizer;
use mckernel::util::fastmath;
use mckernel::util::ThreadPool;
use std::sync::Arc;

/// Per-row libm reference (the plan's explicit per-row override).
fn oracle(map: &McKernel, x: &Matrix) -> Matrix {
    let mut out = Matrix::zeros(x.rows(), map.feature_dim());
    ExpansionEngine::per_row_oracle(map).execute_matrix(map, x, &mut out);
    out
}

fn max_abs_diff(a: &Matrix, b: &Matrix) -> f32 {
    assert_eq!(a.shape(), b.shape());
    a.data()
        .iter()
        .zip(b.data())
        .fold(0.0f32, |m, (x, y)| m.max((x - y).abs()))
}

#[test]
fn batched_matches_oracle_across_shapes_and_kernels() {
    // odd batch sizes × non-power-of-two input dims × both kernels
    for &(dim, e) in &[(12usize, 1usize), (20, 2)] {
        for kernel in [Kernel::Rbf, Kernel::RbfMatern { t: 40 }] {
            let factory = McKernelFactory::new(dim).expansions(e).sigma(1.5).seed(21);
            let factory = match kernel {
                Kernel::Rbf => factory.rbf(),
                Kernel::RbfMatern { t } => factory.rbf_matern(t),
            };
            let map = factory.build();
            for rows in [1usize, 3, 7, 33] {
                let mut rng = HashRng::new(rows as u64, 5);
                let x = Matrix::from_fn(rows, dim, |_, _| rng.next_f32() - 0.5);
                let mut out = Matrix::zeros(rows, map.feature_dim());
                let mut engine = ExpansionEngine::new(&map, rows);
                map.transform_batch_into(&x, &mut out, &mut engine);
                let err = max_abs_diff(&out, &oracle(&map, &x));
                assert!(
                    err < 1e-5,
                    "dim={dim} E={e} rows={rows} kernel={kernel:?}: err {err}"
                );
            }
        }
    }
}

#[test]
fn tail_tiles_at_mnist_geometry() {
    // tile_lanes(1024) = 32 → 33 rows is one full tile + a 1-row tail
    let map = McKernelFactory::new(784).expansions(1).sigma(8.0).rbf().seed(3).build();
    let rows = 33;
    let mut rng = HashRng::new(4, 6);
    let x = Matrix::from_fn(rows, 784, |_, _| rng.next_f32());
    let mut out = Matrix::zeros(rows, map.feature_dim());
    let mut engine = ExpansionEngine::new(&map, rows);
    map.transform_batch_into(&x, &mut out, &mut engine);
    let err = max_abs_diff(&out, &oracle(&map, &x));
    assert!(err < 1e-5, "tail-tile err {err}");
}

#[test]
fn fwht_batch_matches_per_row_exactly() {
    let mut rng = HashRng::new(5, 1);
    for &(rows, n) in &[(1usize, 256usize), (7, 128), (33, 1024), (5, 8)] {
        let flat: Vec<f32> = (0..rows * n).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
        let mut batch = flat.clone();
        fwht::fwht_batch(&mut batch, rows, n);
        for r in 0..rows {
            let mut row = flat[r * n..(r + 1) * n].to_vec();
            fwht::fwht(&mut row);
            assert_eq!(
                &batch[r * n..(r + 1) * n],
                &row[..],
                "rows={rows} n={n} r={r}"
            );
        }
    }
}

#[test]
fn fastmath_reduced_range_accuracy() {
    // over the reduced range the only error is the polynomial's
    let mut rng = HashRng::new(6, 2);
    let xs: Vec<f32> = (0..20_000)
        .map(|_| (rng.next_f32() - 0.5) * std::f32::consts::FRAC_PI_2)
        .collect();
    let mut s = vec![0.0f32; xs.len()];
    let mut c = vec![0.0f32; xs.len()];
    fastmath::sin_cos_batch(&xs, &mut s, &mut c);
    for (i, &x) in xs.iter().enumerate() {
        let xd = x as f64;
        assert!((s[i] as f64 - xd.sin()).abs() < 1e-6, "sin({x})");
        assert!((c[i] as f64 - xd.cos()).abs() < 1e-6, "cos({x})");
    }
}

#[test]
fn fastmath_post_scale_range_accuracy() {
    // the |Ẑx| magnitudes the feature map actually feeds the trig map
    let mut rng = HashRng::new(7, 2);
    let xs: Vec<f32> = (0..50_000).map(|_| (rng.next_f32() - 0.5) * 600.0).collect();
    let mut s = vec![0.0f32; xs.len()];
    let mut c = vec![0.0f32; xs.len()];
    fastmath::sin_cos_batch(&xs, &mut s, &mut c);
    for (i, &x) in xs.iter().enumerate() {
        let xd = x as f64;
        assert!((s[i] as f64 - xd.sin()).abs() < 1e-5, "sin({x})");
        assert!((c[i] as f64 - xd.cos()).abs() < 1e-5, "cos({x})");
    }
}

#[test]
fn normalized_batch_matches_normalized_oracle() {
    let map = McKernelFactory::new(24).expansions(4).sigma(2.0).rbf().seed(7).build();
    let mut rng = HashRng::new(8, 3);
    let x = Matrix::from_fn(9, 24, |_, _| rng.next_f32() - 0.5);
    let batch = map.transform_batch_normalized(&x);
    for r in 0..9 {
        let want = map.transform_normalized(x.row(r));
        for (i, (a, b)) in batch.row(r).iter().zip(&want).enumerate() {
            assert!((a - b).abs() < 1e-5, "row {r} col {i}: {a} vs {b}");
        }
    }
}

#[test]
fn kernel_approximation_survives_batched_path() {
    // the paper's core estimator property holds through the batched
    // normalized pipeline: ⟨φ̄(x), φ̄(y)⟩ ≈ k(x, y)
    let d = 24;
    let sigma = 2.0;
    let map = McKernelFactory::new(d).expansions(16).sigma(sigma).rbf().seed(7).build();
    let mut rng = HashRng::new(99, 0);
    let x = Matrix::from_fn(8, d, |_, _| rng.next_f32() - 0.5);
    let phi = map.transform_batch_normalized(&x);
    let mut max_err = 0.0f64;
    for i in 0..8 {
        for j in 0..8 {
            let dot: f64 = phi
                .row(i)
                .iter()
                .zip(phi.row(j))
                .map(|(a, b)| (*a as f64) * (*b as f64))
                .sum();
            let exact = Kernel::Rbf.exact(x.row(i), x.row(j), sigma);
            max_err = max_err.max((dot - exact).abs());
        }
    }
    assert!(max_err < 0.12, "kernel approx err {max_err}");
}

#[test]
fn parallel_featurizer_tiles_match_serial() {
    let map = Arc::new(McKernelFactory::new(30).expansions(2).seed(9).build());
    let mut rng = HashRng::new(10, 4);
    let x = Matrix::from_fn(101, 30, |_, _| rng.next_f32());
    let serial = Featurizer::McKernel(Arc::clone(&map)).apply(&x);
    let pool = Arc::new(ThreadPool::new(4));
    let par = Featurizer::McKernelParallel(map, pool).apply(&x);
    assert_eq!(serial.data(), par.data());
}
