//! SIMD dispatch invariants (PR 9): runtime detection picks the SIMD
//! arm exactly when the CPU supports it, a forced dispatch always wins
//! over detection, the per-row override survives any force, and the
//! two hot kernels honor their accuracy contracts under both forced
//! arms — FWHT bit-identical, sin_cos within 1e-6.
//!
//! Only `force_is_global_and_restorable` touches the process-global
//! dispatch force; every other test pins the arm via
//! `ExpansionPlan::new_forced` so this binary stays race-free under
//! the default parallel test runner.

use mckernel::fwht;
use mckernel::hash::HashRng;
use mckernel::mckernel::{
    CacheKey, DispatchForce, ExpansionPlan, FwhtDispatch, Kernel, McKernelConfig,
};
use mckernel::util::{fastmath, simd};

fn cfg(input_dim: usize) -> McKernelConfig {
    McKernelConfig { input_dim, expansions: 2, sigma: 1.0, kernel: Kernel::Rbf, seed: 7 }
}

fn rand_vec(len: usize, seed: u64) -> Vec<f32> {
    let mut r = HashRng::new(seed, 0xD1);
    (0..len).map(|_| r.next_f32() * 2.0 - 1.0).collect()
}

#[test]
fn auto_dispatch_matches_runtime_detection() {
    let plan = ExpansionPlan::new_forced(&cfg(100), 8, DispatchForce::Auto);
    let want = if simd::available() { FwhtDispatch::Simd } else { FwhtDispatch::Batched };
    assert_eq!(plan.dispatch(), want);
    // the detected level is stable and consistent with available()
    let (first, second) = (simd::level(), simd::level());
    assert_eq!(first, second);
    assert_eq!(simd::available(), first != simd::SimdLevel::Scalar);
}

#[test]
fn forced_dispatch_wins_over_detection() {
    // Simd is honored even on CPUs where detection would say scalar
    // (the kernels fall back internally); Scalar is honored even on
    // CPUs with vector units — the knob always wins.
    let s = ExpansionPlan::new_forced(&cfg(100), 8, DispatchForce::Scalar);
    assert_eq!(s.dispatch(), FwhtDispatch::Batched);
    let v = ExpansionPlan::new_forced(&cfg(100), 8, DispatchForce::Simd);
    assert_eq!(v.dispatch(), FwhtDispatch::Simd);
    // same geometry either way: only the kernel set differs
    assert_eq!(s.lanes(), v.lanes());
    assert_eq!(s.scratch_floats(), v.scratch_floats());
}

#[test]
fn per_row_override_survives_every_force() {
    let c = cfg(100);
    let pr = ExpansionPlan::per_row(&c);
    assert_eq!(pr.dispatch(), FwhtDispatch::PerRow);
    // huge transforms fall back to per-row no matter what is forced
    let huge = cfg(40_000);
    for force in [DispatchForce::Auto, DispatchForce::Scalar, DispatchForce::Simd] {
        let p = ExpansionPlan::new_forced(&huge, 8, force);
        assert_eq!(p.dispatch(), FwhtDispatch::PerRow, "{force:?}");
        assert_eq!(p.lanes(), 1);
    }
}

#[test]
fn force_is_global_and_restorable() {
    // the only test in this binary that mutates the process-global
    // force; restore it so a future in-binary reader sees no residue
    let prev = mckernel::mckernel::dispatch_force();
    for (force, want) in [
        (DispatchForce::Scalar, FwhtDispatch::Batched),
        (DispatchForce::Simd, FwhtDispatch::Simd),
    ] {
        mckernel::mckernel::set_dispatch_force(force);
        assert_eq!(mckernel::mckernel::dispatch_force(), force);
        let plan = ExpansionPlan::new(&cfg(100), 8);
        assert_eq!(plan.dispatch(), want, "{force:?}");
    }
    mckernel::mckernel::set_dispatch_force(prev);
}

#[test]
fn fingerprints_and_cache_keys_distinguish_the_arms() {
    let c = cfg(784);
    let s = ExpansionPlan::new_forced(&c, 4, DispatchForce::Scalar);
    let v = ExpansionPlan::new_forced(&c, 4, DispatchForce::Simd);
    let r = ExpansionPlan::per_row(&c);
    assert!(s.fingerprint().contains("_b"), "{}", s.fingerprint());
    assert!(v.fingerprint().contains("_s"), "{}", v.fingerprint());
    assert!(r.fingerprint().contains("_r"), "{}", r.fingerprint());
    let (ks, kv, kr) = (CacheKey::new(&c, &s), CacheKey::new(&c, &v), CacheKey::new(&c, &r));
    assert_ne!(ks, kv);
    assert_ne!(ks, kr);
    assert_ne!(kv, kr);
}

#[test]
fn simd_fwht_is_bit_identical_to_scalar() {
    // single transforms across sizes including n=1 and n=2
    for log_n in [0usize, 1, 3, 6, 10] {
        let n = 1usize << log_n;
        let base = rand_vec(n, log_n as u64);
        let mut a = base.clone();
        fwht::fwht_fast(&mut a);
        let mut b = base.clone();
        fwht::simd::fwht(&mut b);
        assert_eq!(a, b, "n={n}");
    }
    // batched column-major tiles: odd row counts force tail tiles
    for &(rows, n) in &[(1usize, 64usize), (3, 32), (7, 128), (37, 64)] {
        let base = rand_vec(rows * n, (rows * n) as u64);
        let mut a = base.clone();
        fwht::fwht_batch(&mut a, rows, n);
        let mut b = base;
        fwht::simd::fwht_batch(&mut b, rows, n);
        assert_eq!(a, b, "rows={rows} n={n}");
    }
}

#[test]
fn simd_sin_cos_stays_within_1e6_of_scalar() {
    // odd lengths hit the vector body, the scalar tail and lanes==1
    for len in [0usize, 1, 3, 7, 8, 9, 31, 257, 1000] {
        let x: Vec<f32> =
            rand_vec(len, len as u64 + 40).iter().map(|v| v * 300.0).collect();
        let (mut ss, mut cs) = (vec![0.0f32; len], vec![0.0f32; len]);
        fastmath::sin_cos_batch(&x, &mut ss, &mut cs);
        let (mut sv, mut cv) = (vec![0.0f32; len], vec![0.0f32; len]);
        fastmath::sin_cos_batch_simd(&x, &mut sv, &mut cv);
        for i in 0..len {
            assert!((ss[i] - sv[i]).abs() <= 1e-6, "sin len={len} i={i} x={}", x[i]);
            assert!((cs[i] - cv[i]).abs() <= 1e-6, "cos len={len} i={i} x={}", x[i]);
        }
    }
}
