//! Gradient-correctness suite: the analytic backward pass of
//! `SoftmaxRegression` against central finite differences at every
//! coordinate of W and b (rel-err ≤ 1e-3), and the shard-sum path +
//! fixed-order tree reduction against the full-batch oracle.

use mckernel::linalg::Matrix;
use mckernel::model::{Gradients, SoftmaxRegression};
use mckernel::util::tree_reduce_with;

const CLASSES: usize = 3;
const FEATS: usize = 5;
const ROWS: usize = 6;

/// Deterministic (compiler-independent) toy model: weights large
/// enough that every gradient coordinate is well above fd noise.
fn toy_model() -> SoftmaxRegression {
    let mut m = SoftmaxRegression::zeros(CLASSES, FEATS);
    for (k, v) in m.w_mut().data_mut().iter_mut().enumerate() {
        *v = (((k * 7) % 11) as f32 - 5.0) * 0.1;
    }
    for (c, b) in m.b_mut().iter_mut().enumerate() {
        *b = (c as f32 - 1.0) * 0.2;
    }
    m
}

/// Unbalanced labels so the bias gradients stay O(0.1) — a balanced
/// label set cancels them toward the fd noise floor.
fn toy_batch() -> (Matrix, Vec<u8>) {
    let x = Matrix::from_fn(ROWS, FEATS, |r, c| ((r * FEATS + c) % 9) as f32 / 8.0);
    (x, vec![0, 0, 1, 0, 2, 0])
}

/// Relative error with a floor: tiny denominators would make fd
/// rounding noise (~1e-5 absolute at eps=1e-2 in f32) dominate.
fn rel_err(num: f32, ana: f32) -> f32 {
    (num - ana).abs() / ana.abs().max(0.05)
}

#[test]
fn central_differences_match_every_w_coordinate() {
    let (x, y) = toy_batch();
    let mut m = toy_model();
    let (_, g) = m.loss_and_grad(&x, &y);
    let eps = 1e-2f32;
    for r in 0..CLASSES {
        for c in 0..FEATS {
            let orig = m.w()[(r, c)];
            m.w_mut()[(r, c)] = orig + eps;
            let lp = m.loss(&x, &y);
            m.w_mut()[(r, c)] = orig - eps;
            let lm = m.loss(&x, &y);
            m.w_mut()[(r, c)] = orig;
            let num = (lp - lm) / (2.0 * eps);
            let ana = g.dw[(r, c)];
            assert!(
                rel_err(num, ana) <= 1e-3,
                "dW[{r},{c}]: numeric {num} vs analytic {ana} (rel {})",
                rel_err(num, ana)
            );
        }
    }
}

#[test]
fn central_differences_match_every_b_coordinate() {
    let (x, y) = toy_batch();
    let mut m = toy_model();
    let (_, g) = m.loss_and_grad(&x, &y);
    let eps = 1e-2f32;
    for c in 0..CLASSES {
        let orig = m.b()[c];
        m.b_mut()[c] = orig + eps;
        let lp = m.loss(&x, &y);
        m.b_mut()[c] = orig - eps;
        let lm = m.loss(&x, &y);
        m.b_mut()[c] = orig;
        let num = (lp - lm) / (2.0 * eps);
        let ana = g.db[c];
        assert!(
            rel_err(num, ana) <= 1e-3,
            "db[{c}]: numeric {num} vs analytic {ana} (rel {})",
            rel_err(num, ana)
        );
    }
}

#[test]
fn loss_and_grad_loss_matches_loss_helper() {
    let (x, y) = toy_batch();
    let m = toy_model();
    let (l, _) = m.loss_and_grad(&x, &y);
    assert!((l - m.loss(&x, &y)).abs() < 1e-6);
}

#[test]
fn sharded_tree_reduced_gradient_matches_full_batch() {
    let (x, y) = toy_batch();
    let m = toy_model();
    let (full_loss, full_g) = m.loss_and_grad(&x, &y);

    // ragged 3-way split (3 + 2 + 1 rows) through the shard path,
    // combined exactly the way the ParallelTrainer combines shards
    struct Shard {
        g: Gradients,
        loss: f64,
        hits: usize,
    }
    let bounds = [(0usize, 3usize), (3, 5), (5, 6)];
    let mut shards: Vec<Shard> = bounds
        .iter()
        .map(|&(lo, hi)| {
            let rows = hi - lo;
            let mut g = Gradients::zeros(CLASSES, FEATS);
            let mut delta = vec![0.0f32; rows * CLASSES];
            let (loss, hits) = m.shard_loss_grad_sums(
                &x.data()[lo * FEATS..hi * FEATS],
                rows,
                &y[lo..hi],
                &mut delta,
                &mut g,
            );
            Shard { g, loss, hits }
        })
        .collect();
    tree_reduce_with(&mut shards, |a, b| {
        a.g.merge(&b.g);
        a.loss += b.loss;
        a.hits += b.hits;
    });
    let root = &mut shards[0];
    root.g.scale(1.0 / ROWS as f32);

    // 1e-5 gates: the shard path's f32 exp(v−lse) + sum-then-scale
    // rounds differently from the oracle's f64 softmax + pre-scaled
    // contraction (mirror-measured drift ~1e-7; headroom for ulps)
    assert!(
        ((root.loss / ROWS as f64) as f32 - full_loss).abs() < 1e-5,
        "loss {} vs {}",
        root.loss / ROWS as f64,
        full_loss
    );
    for (k, (a, b)) in root.g.dw.data().iter().zip(full_g.dw.data()).enumerate() {
        assert!((a - b).abs() <= 1e-5, "dw[{k}]: {a} vs {b}");
    }
    for (c, (a, b)) in root.g.db.iter().zip(&full_g.db).enumerate() {
        assert!((a - b).abs() <= 1e-5, "db[{c}]: {a} vs {b}");
    }
    let preds = m.predict(&x);
    let want_hits = preds.iter().zip(&y).filter(|(p, t)| p == t).count();
    assert_eq!(root.hits, want_hits, "shard hit counts must match predict()");
}

#[test]
fn tree_reduction_is_pairwise_fixed_order() {
    // f32 catastrophic-cancellation probe: ((a+b)+(c+d)) differs from
    // a left fold, so this pins the reduction *order*, not just the sum.
    let vals = [1e8f32, 1.0, -1e8, 1.0];
    let mut shards: Vec<Gradients> = vals
        .iter()
        .map(|&v| {
            let mut g = Gradients::zeros(1, 1);
            g.dw[(0, 0)] = v;
            g.db[0] = v;
            g
        })
        .collect();
    tree_reduce_with(&mut shards, |a, b| a.merge(b));
    let want = (vals[0] + vals[1]) + (vals[2] + vals[3]);
    assert_eq!(shards[0].dw[(0, 0)].to_bits(), want.to_bits());
    assert_eq!(shards[0].db[0].to_bits(), want.to_bits());
}
