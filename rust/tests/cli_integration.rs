//! CLI integration: drive the subcommand layer end to end (gen-data →
//! train → checkpoint → predict), using the library-level entrypoint.

use mckernel::cli::{commands, Args};

fn run(argv: &[&str]) -> anyhow::Result<()> {
    commands::run(Args::parse(argv.iter().copied()).unwrap())
}

fn tmp(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join("mckernel_cli_it");
    std::fs::create_dir_all(&d).unwrap();
    d.join(name)
}

#[test]
fn help_runs() {
    run(&[]).unwrap();
    run(&["help"]).unwrap();
}

#[test]
fn unknown_command_fails() {
    assert!(run(&["bogus"]).is_err());
    assert!(run(&["train", "--backend", "quantum"]).is_err());
}

#[test]
fn features_command() {
    run(&["features", "--train-size", "5", "--test-size", "5", "--expansions", "2"]).unwrap();
}

#[test]
fn fwht_command_all_engines() {
    // production engines plus the reference baselines (naive/spiral
    // stay CLI-runnable as oracles; the plan never selects them)
    for e in ["naive", "spiral", "iterative", "mckernel", "batch"] {
        run(&["fwht", "--log-n", "8", "--engine", e]).unwrap();
    }
    assert!(run(&["fwht", "--engine", "fft"]).is_err());
    // the O(n²) oracle refuses production-scale sizes
    assert!(run(&["fwht", "--engine", "naive"]).is_err());
}

#[test]
fn train_checkpoint_predict_roundtrip() {
    let ck = tmp("cli_model.mck");
    let csv = tmp("cli_history.csv");
    run(&[
        "train",
        "--train-size", "80", "--test-size", "30",
        "--epochs", "2", "--expansions", "1", "--quiet",
        "--checkpoint", ck.to_str().unwrap(),
        "--csv", csv.to_str().unwrap(),
    ])
    .unwrap();
    assert!(ck.exists());
    let history = std::fs::read_to_string(&csv).unwrap();
    assert_eq!(history.lines().count(), 3); // header + 2 epochs
    run(&[
        "predict",
        "--checkpoint", ck.to_str().unwrap(),
        "--train-size", "5", "--test-size", "30",
    ])
    .unwrap();
}

#[test]
fn lr_baseline_via_flag() {
    run(&[
        "train", "--featurizer", "identity", "--train-size", "50", "--test-size", "20",
        "--epochs", "1", "--lr", "0.05", "--quiet",
    ])
    .unwrap();
}

#[test]
fn gen_data_writes_idx_pair() {
    let out = tmp("gen");
    run(&[
        "gen-data", "--out", out.to_str().unwrap(),
        "--train-size", "12", "--test-size", "6", "--dataset", "fashion",
    ])
    .unwrap();
    assert!(out.join("train-images-idx3-ubyte").exists());
    assert!(out.join("t10k-labels-idx1-ubyte").exists());
    // and they load back
    let d = mckernel::data::Dataset::from_idx_files(
        out.join("train-images-idx3-ubyte"),
        out.join("train-labels-idx1-ubyte"),
    )
    .unwrap();
    assert_eq!(d.len(), 12);
}

#[test]
fn serve_demo_small() {
    run(&[
        "serve", "--train-size", "16", "--test-size", "1", "--expansions", "1",
        "--requests", "32", "--clients", "4", "--max-batch", "8",
    ])
    .unwrap();
}
