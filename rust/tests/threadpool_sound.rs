//! Soundness tests for the `ThreadPool::scope_shards` lifetime-erasure
//! seam, shaped to run under Miri (small shard counts, no timers, no
//! sleeps): the `transmute` in `threadpool.rs` erases the jobs'
//! borrow of the caller's stack, and the completion barrier is the
//! entire soundness argument — these tests are what Miri checks that
//! argument against (`cargo +nightly miri test --test threadpool_sound`).
//!
//! Every test drops the pool at the end of its scope, so Miri also
//! verifies that no erased borrow outlives the frame that created it.

use mckernel::fault::McError;
use mckernel::util::threadpool::ThreadPool;

/// Zero shards: no job is ever submitted, no pointer is ever formed.
#[test]
fn zero_shards_is_noop() {
    let pool = ThreadPool::new(2);
    let mut shards: Vec<u64> = Vec::new();
    let panicked = pool.scope_shards(&mut shards, |_, _| unreachable!()).unwrap();
    assert!(panicked.is_empty());
}

/// More shards than workers: jobs queue behind each other on the same
/// worker, so the barrier must wait across multiple queue generations
/// while the erased borrows stay live.
#[test]
fn more_shards_than_workers() {
    let pool = ThreadPool::new(2);
    let mut shards: Vec<usize> = vec![0; 11];
    // Borrow a stack local through the erased closure: exactly the
    // lifetime the transmute pretends away and the barrier restores.
    let offset = 7usize;
    let off = &offset;
    let panicked = pool.scope_shards(&mut shards, |i, s| *s = i + off).unwrap();
    assert!(panicked.is_empty());
    for (i, &s) in shards.iter().enumerate() {
        assert_eq!(s, i + 7, "shard {i}");
    }
}

/// A panicking shard unwinds through the job while its siblings are
/// still writing: the Drop-based completion guard must still fire
/// (otherwise the barrier deadlocks) and the panicked shard's slot
/// must be left untouched.
#[test]
fn panicking_shards_are_reported_and_contained() {
    let pool = ThreadPool::new(3);
    let mut shards: Vec<u32> = vec![0; 6];
    let panicked = pool
        .scope_shards(&mut shards, |i, s| {
            if i % 2 == 1 {
                panic!("shard {i}");
            }
            *s = 1;
        })
        .unwrap();
    assert_eq!(panicked, vec![1, 3, 5]);
    for (i, &s) in shards.iter().enumerate() {
        assert_eq!(s, if i % 2 == 1 { 0 } else { 1 }, "shard {i}");
    }
    // The workers survived (panics are caught per job): rerun exactly
    // the panicked indices, the trainer's repair pattern.
    let clean = pool
        .scope_shards(&mut shards, |i, s| {
            if panicked.contains(&i) {
                *s = 2;
            }
        })
        .unwrap();
    assert!(clean.is_empty());
    assert_eq!(shards, vec![1, 2, 1, 2, 1, 2]);
}

/// Submission failing mid-loop (pool already shut down): the typed
/// error must come back only after the barrier has drained every job
/// that *was* submitted — on this path zero jobs, so immediately —
/// and the shards must be untouched.
#[test]
fn early_submit_failure_is_typed_and_barriered() {
    let mut pool = ThreadPool::new(2);
    pool.shutdown();
    let mut shards: Vec<u8> = vec![9; 4];
    let err = pool.scope_shards(&mut shards, |_, s| *s = 0).unwrap_err();
    assert_eq!(err, McError::ShuttingDown);
    assert_eq!(shards, vec![9; 4], "no job may have touched a shard");
}

/// Back-to-back scopes reusing one pool: each scope's borrows must
/// end at its own barrier, not at pool drop (a use-after-free here is
/// exactly what Miri would flag if the barrier under-waited).
#[test]
fn sequential_scopes_reuse_the_pool() {
    let pool = ThreadPool::new(2);
    for round in 0u64..4 {
        let mut shards: Vec<u64> = vec![0; 5];
        let panicked = pool.scope_shards(&mut shards, |i, s| *s = round * 100 + i as u64).unwrap();
        assert!(panicked.is_empty());
        for (i, &s) in shards.iter().enumerate() {
            assert_eq!(s, round * 100 + i as u64);
        }
        // `shards` drops here while the pool lives on — the erased
        // pointer must not be retained anywhere past the barrier.
    }
}

/// Single-element and single-worker degenerate shapes.
#[test]
fn degenerate_shapes() {
    let pool = ThreadPool::new(1);
    let mut one = [41u8];
    let panicked = pool.scope_shards(&mut one, |_, s| *s += 1).unwrap();
    assert!(panicked.is_empty());
    assert_eq!(one[0], 42);
}
