//! Plan/engine invariants (PR 4): the compiled [`ExpansionPlan`] must
//! size scratch exactly (no reallocation during `execute`), the
//! engine must reproduce the per-row oracle bit-for-bit on the
//! per-row path and within 1e-6 on the batched path — across odd
//! batch sizes, tail tiles and both kernels — and the normalization
//! fold must equal an explicit post-scale exactly.

use mckernel::linalg::Matrix;
use mckernel::mckernel::{
    DispatchForce, ExpansionEngine, ExpansionPlan, FwhtDispatch, Kernel, McKernel,
    McKernelFactory,
};

fn build(dim: usize, e: usize, kernel: Kernel) -> McKernel {
    let f = McKernelFactory::new(dim).expansions(e).sigma(1.5).seed(21);
    let f = match kernel {
        Kernel::Rbf => f.rbf(),
        Kernel::RbfMatern { t } => f.rbf_matern(t),
    };
    f.build()
}

fn oracle(map: &McKernel, x: &Matrix) -> Matrix {
    let mut out = Matrix::zeros(x.rows(), map.feature_dim());
    ExpansionEngine::per_row_oracle(map).execute_matrix(map, x, &mut out);
    out
}

#[test]
fn scratch_sizes_are_exact_and_never_reallocate() {
    let map = build(12, 2, Kernel::Rbf);
    let mut engine = ExpansionEngine::new(&map, 64);
    let want = engine.plan().scratch_floats();
    assert_eq!(
        want,
        3 * engine.plan().padded_dim() * engine.plan().lanes(),
        "batched scratch formula"
    );
    assert_eq!(engine.scratch_floats(), want);
    // odd row counts, tail tiles, a single row, an empty call: the
    // pool must stay at its compiled size throughout (execute itself
    // asserts the no-realloc invariant on every call)
    let lanes = engine.plan().lanes();
    for rows in [0usize, 1, 3, lanes - 1, lanes, lanes + 3, 2 * lanes + 1] {
        let x = Matrix::from_fn(rows, 12, |r, c| ((r * 7 + c) % 5) as f32 * 0.1);
        let mut out = Matrix::zeros(rows, map.feature_dim());
        engine.execute_matrix(&map, &x, &mut out);
        assert_eq!(engine.scratch_floats(), want, "rows={rows}");
    }
    // per-row plans pool the (padded, tmp) pair
    let oracle = ExpansionEngine::per_row_oracle(&map);
    assert_eq!(oracle.plan().scratch_floats(), 2 * map.padded_dim());
    assert_eq!(oracle.scratch_floats(), 2 * map.padded_dim());
}

#[test]
fn single_row_is_bit_identical_to_the_per_row_oracle() {
    for kernel in [Kernel::Rbf, Kernel::RbfMatern { t: 40 }] {
        let map = build(20, 2, kernel);
        let x: Vec<f32> = (0..20).map(|i| (i as f32 * 0.37).sin()).collect();
        // the per-row plan reproduces McKernel::transform exactly
        let mut out = vec![0.0f32; map.feature_dim()];
        ExpansionEngine::per_row_oracle(&map).execute(&map, &x, 1, 20, &mut out);
        assert_eq!(out, map.transform(&x), "{kernel:?}");
        // and a batched engine is grouping-invariant: one row alone
        // equals that row inside a larger batch, bit for bit
        let xs = Matrix::from_fn(5, 20, |r, c| ((r * 11 + c) % 13) as f32 * 0.05);
        let all = map.transform_batch(&xs);
        let mut engine = ExpansionEngine::new(&map, 5);
        let mut one = Matrix::zeros(1, map.feature_dim());
        for r in 0..5 {
            let row = Matrix::from_vec(1, 20, xs.row(r).to_vec());
            engine.execute_matrix(&map, &row, &mut one);
            assert_eq!(one.row(0), all.row(r), "row {r} {kernel:?}");
        }
    }
}

#[test]
fn batched_engine_tracks_oracle_within_1e6() {
    for kernel in [Kernel::Rbf, Kernel::RbfMatern { t: 40 }] {
        for &(dim, e) in &[(12usize, 1usize), (20, 3)] {
            let map = build(dim, e, kernel);
            let mut engine = ExpansionEngine::new(&map, usize::MAX);
            let lanes = engine.plan().lanes();
            // odd batch sizes + a full-tile-plus-tail shape
            for rows in [1usize, 3, 7, lanes + 3] {
                let x = Matrix::from_fn(rows, dim, |r, c| {
                    (((r * 31 + c * 7) % 17) as f32 - 8.0) * 0.06
                });
                let mut out = Matrix::zeros(rows, map.feature_dim());
                engine.execute_matrix(&map, &x, &mut out);
                let want = oracle(&map, &x);
                for (i, (a, b)) in out.data().iter().zip(want.data()).enumerate() {
                    assert!(
                        (a - b).abs() < 1e-6,
                        "{kernel:?} dim={dim} E={e} rows={rows} i={i}: {a} vs {b}"
                    );
                }
            }
        }
    }
}

#[test]
fn normalization_fold_equals_explicit_post_scale_exactly() {
    let map = build(12, 2, Kernel::Rbf);
    let x = Matrix::from_fn(5, 12, |r, c| ((r * 3 + c) % 9) as f32 * 0.11);
    let s = 1.0f32 / ((map.padded_dim() * map.expansions()) as f32).sqrt();
    // batched: folded write vs plain write × s is the same product
    let plain = map.transform_batch(&x);
    let folded = map.transform_batch_normalized(&x);
    for (a, b) in folded.data().iter().zip(plain.data()) {
        assert_eq!(*a, b * s);
    }
    // per-row: same fold, same exactness
    for r in 0..5 {
        let p = map.transform(x.row(r));
        let f = map.transform_normalized(x.row(r));
        for i in 0..map.feature_dim() {
            assert_eq!(f[i], p[i] * s);
        }
    }
}

#[test]
fn plan_is_the_single_dispatch_point() {
    // small geometry compiles to a tiled arm (which one depends on the
    // dispatch force / CPU, but never the per-row fallback)…
    let small = ExpansionPlan::new(build(12, 1, Kernel::Rbf).config(), 8);
    assert!(small.is_tiled());
    assert_ne!(small.dispatch(), FwhtDispatch::PerRow);
    // …huge geometry to the per-row fallback — consumers never see
    // the difference, they just execute the compiled plan
    let huge_cfg = mckernel::mckernel::McKernelConfig {
        input_dim: 40_000,
        expansions: 1,
        sigma: 1.0,
        kernel: Kernel::Rbf,
        seed: 1,
    };
    let huge = ExpansionPlan::new(&huge_cfg, 8);
    assert_eq!(huge.dispatch(), FwhtDispatch::PerRow);
    assert_eq!(huge.lanes(), 1);
}

/// Run the same batch through explicitly forced scalar and SIMD tiled
/// engines and return both outputs.
fn forced_pair(map: &McKernel, x: &Matrix, rows_hint: usize) -> (Matrix, Matrix) {
    let mut scalar = ExpansionEngine::with_plan(ExpansionPlan::new_forced(
        map.config(),
        rows_hint,
        DispatchForce::Scalar,
    ));
    assert_eq!(scalar.plan().dispatch(), FwhtDispatch::Batched);
    let mut simd = ExpansionEngine::with_plan(ExpansionPlan::new_forced(
        map.config(),
        rows_hint,
        DispatchForce::Simd,
    ));
    assert_eq!(simd.plan().dispatch(), FwhtDispatch::Simd);
    let mut a = Matrix::zeros(x.rows(), map.feature_dim());
    scalar.execute_matrix(map, x, &mut a);
    let mut b = Matrix::zeros(x.rows(), map.feature_dim());
    simd.execute_matrix(map, x, &mut b);
    (a, b)
}

#[test]
fn simd_engine_tracks_scalar_engine_within_1e6() {
    // both kernels × non-pow2 dims × odd batches, tail tiles and a
    // lanes==1 tiled plan (rows_hint = 1): the SIMD arm's only licensed
    // deviation is the trig rounding, bounded at 1e-6
    for kernel in [Kernel::Rbf, Kernel::RbfMatern { t: 40 }] {
        for &(dim, e) in &[(12usize, 1usize), (20, 3), (100, 2)] {
            let map = build(dim, e, kernel);
            for &(rows, hint) in &[(1usize, 1usize), (3, usize::MAX), (7, usize::MAX), (37, 16)] {
                let x = Matrix::from_fn(rows, dim, |r, c| {
                    (((r * 31 + c * 7) % 17) as f32 - 8.0) * 0.06
                });
                let (a, b) = forced_pair(&map, &x, hint);
                for (i, (p, q)) in a.data().iter().zip(b.data()).enumerate() {
                    assert!(
                        (p - q).abs() <= 1e-6,
                        "{kernel:?} dim={dim} E={e} rows={rows} hint={hint} i={i}: {p} vs {q}"
                    );
                }
            }
        }
    }
}

#[test]
fn simd_engine_is_grouping_invariant_like_scalar() {
    // a row alone equals the same row inside a larger batch under the
    // forced SIMD engine too — tail tiles reuse the same kernels
    let map = build(20, 2, Kernel::Rbf);
    let xs = Matrix::from_fn(9, 20, |r, c| ((r * 11 + c) % 13) as f32 * 0.05);
    let mut engine = ExpansionEngine::with_plan(ExpansionPlan::new_forced(
        map.config(),
        9,
        DispatchForce::Simd,
    ));
    let mut all = Matrix::zeros(9, map.feature_dim());
    engine.execute_matrix(&map, &xs, &mut all);
    let mut one = Matrix::zeros(1, map.feature_dim());
    for r in 0..9 {
        let row = Matrix::from_vec(1, 20, xs.row(r).to_vec());
        engine.execute_matrix(&map, &row, &mut one);
        assert_eq!(one.row(0), all.row(r), "row {r}");
    }
}
