//! Property-based invariants (proplite) over the core substrates: the
//! FWHT engines, the hash RNG, permutations, the feature map and the
//! classifier gradients.

use mckernel::fwht::{self, Engine};
use mckernel::hash::HashRng;
use mckernel::linalg::Matrix;
use mckernel::mckernel::{Kernel, McKernelFactory};
use mckernel::model::SoftmaxRegression;
use mckernel::proplite::{self, prop, Outcome};
use mckernel::rand::fisher_yates::{invert_permutation, is_permutation, random_permutation};
use mckernel::util::pow2::{next_pow2, pad_pow2};

fn rand_vec(g: &mut proplite::Gen, n: usize) -> Vec<f32> {
    g.vec_f32(n, -4.0, 4.0)
}

#[test]
fn prop_all_fwht_engines_agree() {
    proplite::check("engines agree", 60, |g| {
        let n = g.pow2(0, 10);
        let x = rand_vec(g, n);
        let mut want = x.clone();
        fwht::reference::fwht_naive(&mut want);
        for eng in Engine::ALL {
            let mut got = x.clone();
            eng.run(&mut got);
            for (a, b) in got.iter().zip(want.iter()) {
                if (a - b).abs() > 1e-2 * b.abs().max(1.0) {
                    return prop(false, format!("{} n={n}: {a} vs {b}", eng.name()));
                }
            }
        }
        Outcome::Pass
    });
}

#[test]
fn prop_fwht_involution_and_parseval() {
    proplite::check("H(Hx)=n*x and |Hx|^2=n|x|^2", 60, |g| {
        let n = g.pow2(0, 12);
        let x = rand_vec(g, n);
        let e0: f64 = x.iter().map(|v| (*v as f64).powi(2)).sum();
        let mut y = x.clone();
        fwht::fwht(&mut y);
        let e1: f64 = y.iter().map(|v| (*v as f64).powi(2)).sum();
        if e0 > 1e-9 && (e1 / (n as f64 * e0) - 1.0).abs() > 1e-3 {
            return prop(false, format!("parseval n={n}: {e1} vs {}", n as f64 * e0));
        }
        fwht::fwht(&mut y);
        for (a, b) in y.iter().zip(x.iter()) {
            if (a / n as f32 - b).abs() > 1e-2 {
                return prop(false, format!("involution n={n}"));
            }
        }
        Outcome::Pass
    });
}

#[test]
fn prop_permutations_valid_and_invertible() {
    proplite::check("Fisher-Yates validity", 80, |g| {
        let n = g.usize_in(0, 2000);
        let mut rng = HashRng::new(g.u64(), 0x91);
        let p = random_permutation(n, &mut rng);
        if !is_permutation(&p) {
            return prop(false, format!("invalid perm n={n}"));
        }
        let inv = invert_permutation(&p);
        let ok = p.iter().enumerate().all(|(i, &v)| inv[v as usize] == i as u32);
        prop(ok, format!("inverse wrong n={n}"))
    });
}

#[test]
fn prop_hash_rng_random_access_consistent() {
    proplite::check("random access stable", 60, |g| {
        let seed = g.u64();
        let stream = g.u64();
        let k = g.usize_in(0, 100) as u64;
        let rng = HashRng::new(seed, stream);
        let direct = rng.at(k);
        let again = rng.at(k);
        prop(direct == again, format!("at({k}) unstable"))
    });
}

#[test]
fn prop_feature_map_bounds_and_determinism() {
    proplite::check("phi in [-1,1], deterministic, correct dim", 25, |g| {
        let input_dim = g.usize_in(2, 200);
        let e = g.usize_in(1, 3);
        let sigma = g.f64_in(0.3, 8.0);
        let seed = g.u64();
        let kernel_rbf = g.bool();
        let mut f = McKernelFactory::new(input_dim).expansions(e).sigma(sigma).seed(seed);
        f = if kernel_rbf { f.rbf() } else { f.rbf_matern(5) };
        let map = f.build();
        let n = next_pow2(input_dim);
        if map.feature_dim() != 2 * n * e {
            return prop(false, format!("dim {} != {}", map.feature_dim(), 2 * n * e));
        }
        let x = g.vec_f32(input_dim, -2.0, 2.0);
        let f1 = map.transform(&x);
        if !f1.iter().all(|v| (-1.0..=1.0).contains(v) && v.is_finite()) {
            return prop(false, "feature out of unit box".to_string());
        }
        let f2 = map.transform(&x);
        prop(f1 == f2, "nondeterministic transform".to_string())
    });
}

#[test]
fn prop_feature_map_padding_invariance() {
    proplite::check("zero-padding does not change phi", 25, |g| {
        let input_dim = g.usize_in(2, 100);
        let map = McKernelFactory::new(input_dim)
            .expansions(1)
            .seed(g.u64())
            .build();
        let x = g.vec_f32(input_dim, -1.0, 1.0);
        let padded = pad_pow2(&x);
        prop(
            map.transform(&x) == map.transform(&padded),
            format!("padding changed features (d={input_dim})"),
        )
    });
}

#[test]
fn prop_kernel_estimate_unbiased_on_self() {
    proplite::check("<phi(x),phi(x)> = 1", 20, |g| {
        let d = g.usize_in(2, 64);
        let map = McKernelFactory::new(d)
            .expansions(g.usize_in(1, 4))
            .sigma(g.f64_in(0.5, 4.0))
            .seed(g.u64())
            .build();
        let x = g.vec_f32(d, -1.0, 1.0);
        let f = map.transform_normalized(&x);
        let dot: f64 = f.iter().map(|v| (*v as f64).powi(2)).sum();
        prop((dot - 1.0).abs() < 1e-3, format!("self-sim {dot}"))
    });
}

#[test]
fn prop_softmax_grad_is_descent_direction() {
    proplite::check("loss decreases along -grad", 25, |g| {
        let classes = g.usize_in(2, 5);
        let feats = g.usize_in(2, 20);
        let batch = g.usize_in(1, 8);
        let mut model = SoftmaxRegression::init(classes, feats, g.u64());
        let x = Matrix::from_fn(batch, feats, |_, _| g.f32_in(-1.0, 1.0));
        let y: Vec<u8> = (0..batch).map(|_| g.usize_in(0, classes - 1) as u8).collect();
        let (l0, grads) = model.loss_and_grad(&x, &y);
        model.w_mut().axpy(-0.01, &grads.dw);
        for (b, d) in model.b_mut().iter_mut().zip(&grads.db) {
            *b -= 0.01 * d;
        }
        let l1 = model.loss(&x, &y);
        prop(
            l1 <= l0 + 1e-6,
            format!("ascent: {l0} -> {l1} (c={classes} f={feats} b={batch})"),
        )
    });
}

#[test]
fn prop_exact_rbf_kernel_bounds() {
    proplite::check("0 < k(x,y) <= 1, k(x,x)=1", 50, |g| {
        let d = g.usize_in(1, 30);
        let x = g.vec_f32(d, -3.0, 3.0);
        let y = g.vec_f32(d, -3.0, 3.0);
        let sigma = g.f64_in(0.1, 10.0);
        let kxy = Kernel::Rbf.exact(&x, &y, sigma);
        let kxx = Kernel::Rbf.exact(&x, &x, sigma);
        prop(
            kxy > 0.0 && kxy <= 1.0 + 1e-12 && (kxx - 1.0).abs() < 1e-9,
            format!("kxy={kxy} kxx={kxx}"),
        )
    });
}

#[test]
fn prop_next_pow2_properties() {
    proplite::check("next_pow2 minimal upper power", 100, |g| {
        let n = g.usize_in(1, 1 << 20);
        let p = next_pow2(n);
        prop(
            p.is_power_of_two() && p >= n && (p == 1 || p / 2 < n),
            format!("n={n} p={p}"),
        )
    });
}
