//! Tentpole acceptance suite for the fault-tolerance layer (PR 7):
//!
//! * **No lost replies** — under a seeded mix of engine faults, worker
//!   panics and latency injection, every admitted request resolves to
//!   exactly one feature row or typed [`McError`]: zero hangs, zero
//!   leaked admission slots, reply count equal to submit count.
//! * **Panic recovery** — an injected serve-loop panic quarantines one
//!   batch (`WorkerPanic` replies, `server.restarts` counted) and the
//!   next request is answered bit-exactly by the rebuilt engine.
//! * **Load shedding** — beyond `max_queue` in-flight requests,
//!   submits shed deterministically with `Overloaded` while every
//!   admitted request is still served.
//! * **Deterministic chaos** — the same seed reproduces the same
//!   reply-kind sequence; a different seed diverges.
//! * **Bit-identical retries** — the sharded trainer under injected
//!   shard panics retries on the surviving pool and lands on weights
//!   bit-identical to the fault-free run.

use mckernel::coordinator::{FeatureServer, ServerConfig};
use mckernel::data::{Dataset, SyntheticSpec};
use mckernel::fault::{FaultPlan, FaultSite, McError};
use mckernel::mckernel::{McKernel, McKernelFactory};
use mckernel::obs::MetricsRegistry;
use mckernel::optim::SgdConfig;
use mckernel::train::{Featurizer, ParallelTrainer, RetryPolicy, TrainConfig};
use std::sync::Arc;
use std::time::Duration;

fn map16(seed: u64) -> Arc<McKernel> {
    Arc::new(McKernelFactory::new(16).expansions(1).rbf().seed(seed).build())
}

#[test]
fn every_admitted_request_is_answered_under_mixed_faults() {
    let reg = MetricsRegistry::new();
    let plan = Arc::new(
        FaultPlan::with_registry(77, &reg)
            .with_rate(FaultSite::EngineFault, 0.30)
            .with_rate(FaultSite::WorkerPanic, 0.15)
            .with_rate(FaultSite::Latency, 0.10)
            .with_latency(Duration::from_millis(1)),
    );
    let config = ServerConfig::new(8, Duration::from_micros(200))
        .max_queue(4096)
        .deadline(Duration::from_secs(10))
        .faults(Arc::clone(&plan));
    let server = FeatureServer::start_with_registry(map16(77), config, &reg);
    let clients = 4usize;
    let per = 48usize;
    let (otx, orx) = std::sync::mpsc::channel();
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            let client = server.client();
            let otx = otx.clone();
            std::thread::spawn(move || {
                for i in 0..per {
                    let x = vec![((c * per + i) % 9) as f32 * 0.1; 16];
                    let _ = otx.send(client.transform(x));
                }
            })
        })
        .collect();
    drop(otx);
    for h in handles {
        h.join().unwrap();
    }
    let (mut ok, mut typed) = (0u64, 0u64);
    for outcome in orx.iter() {
        match outcome {
            Ok(_) => ok += 1,
            Err(McError::WorkerPanic) | Err(McError::NonFinite { .. }) => typed += 1,
            Err(e) => panic!("unexpected error kind under this plan: {e}"),
        }
    }
    let submitted = (clients * per) as u64;
    assert_eq!(ok + typed, submitted, "a transform call went missing");
    assert!(ok > 0, "chaos rates must leave healthy requests");
    assert!(typed > 0, "chaos rates must actually produce faults");
    let stats = server.stats().clone();
    server.shutdown();
    // exactly-once accounting: the serve loop replied to every
    // admitted request, and every admission slot was released
    assert_eq!(stats.requests(), submitted);
    assert_eq!(stats.queue_depth(), 0, "admission slots leaked");
    assert_eq!(stats.rejected(), 0, "queue bound was never hit in this scenario");
    assert!(plan.injected() > 0);
}

#[test]
fn server_survives_injected_panic_and_recovers_bit_exactly() {
    let reg = MetricsRegistry::new();
    let plan = Arc::new(
        FaultPlan::with_registry(5, &reg)
            .with_rate(FaultSite::WorkerPanic, 1.0)
            .with_limit(FaultSite::WorkerPanic, 1),
    );
    let map = map16(5);
    let config = ServerConfig::new(4, Duration::from_micros(50))
        .faults(Arc::clone(&plan));
    let server = FeatureServer::start_with_registry(Arc::clone(&map), config, &reg);
    let x: Vec<f32> = (0..16).map(|i| i as f32 * 0.05).collect();
    // request 1 rides the poisoned batch: typed error, not a hang
    assert_eq!(server.transform(x.clone()), Err(McError::WorkerPanic));
    assert_eq!(server.stats().restarts(), 1);
    // request 2 is served by the rebuilt engine, bit-exactly
    assert_eq!(server.transform(x.clone()), Ok(map.transform(&x)));
    assert_eq!(server.stats().requests(), 2);
    assert_eq!(server.stats().queue_depth(), 0);
    assert_eq!(plan.injected(), 1);
    server.shutdown();
}

#[test]
fn overload_sheds_beyond_max_queue_and_serves_the_admitted() {
    let reg = MetricsRegistry::new();
    // guaranteed 150ms stall per batch: the first two submits hold
    // their admission slots long enough that submits 3..6 must shed
    let plan = Arc::new(
        FaultPlan::with_registry(9, &reg)
            .with_rate(FaultSite::Latency, 1.0)
            .with_latency(Duration::from_millis(150)),
    );
    let config = ServerConfig::new(1, Duration::from_micros(10))
        .max_queue(2)
        .faults(plan);
    let server = FeatureServer::start_with_registry(map16(9), config, &reg);
    let client = server.client();
    let mut admitted = Vec::new();
    let mut shed = 0u64;
    for i in 0..6 {
        match client.submit(vec![0.1 * (i + 1) as f32; 16]) {
            Ok(p) => admitted.push(p),
            Err(McError::Overloaded { limit }) => {
                assert_eq!(limit, 2, "the error carries the configured bound");
                shed += 1;
            }
            Err(e) => panic!("unexpected submit error: {e}"),
        }
    }
    assert_eq!(admitted.len(), 2, "exactly max_queue submits admitted");
    assert_eq!(shed, 4, "overflow shed at submit, without blocking");
    for p in admitted {
        assert!(p.wait().is_ok(), "admitted requests must still be served");
    }
    assert_eq!(server.stats().rejected(), 4);
    assert_eq!(server.stats().queue_depth(), 0);
    server.shutdown();
}

#[test]
fn slow_reply_times_out_with_typed_error() {
    let reg = MetricsRegistry::new();
    let plan = Arc::new(
        FaultPlan::with_registry(3, &reg)
            .with_rate(FaultSite::Latency, 1.0)
            .with_latency(Duration::from_millis(200)),
    );
    let config = ServerConfig::new(1, Duration::from_micros(10))
        .deadline(Duration::from_millis(5))
        .faults(plan);
    let server = FeatureServer::start_with_registry(map16(3), config, &reg);
    assert_eq!(
        server.transform(vec![0.5; 16]),
        Err(McError::Timeout { waited: Duration::from_millis(5) })
    );
    assert_eq!(server.stats().timeouts(), 1);
    server.shutdown();
}

/// Sequential single-row batches make the per-batch fault cursors a
/// pure function of the request index: the whole reply-kind sequence
/// is reproducible from the seed alone.
fn outcome_kinds(seed: u64, n: usize) -> Vec<&'static str> {
    let reg = MetricsRegistry::new();
    let plan = Arc::new(
        FaultPlan::with_registry(seed, &reg)
            .with_rate(FaultSite::EngineFault, 0.4)
            .with_rate(FaultSite::WorkerPanic, 0.2),
    );
    let config = ServerConfig::new(1, Duration::from_micros(10)).faults(plan);
    let server = FeatureServer::start_with_registry(map16(1), config, &reg);
    let kinds = (0..n)
        .map(|i| match server.transform(vec![(i % 7) as f32 * 0.1; 16]) {
            Ok(_) => "ok",
            Err(e) => e.kind(),
        })
        .collect();
    server.shutdown();
    kinds
}

#[test]
fn seeded_chaos_reply_sequence_is_reproducible() {
    let a = outcome_kinds(99, 24);
    assert_eq!(a, outcome_kinds(99, 24), "same seed, same schedule");
    assert_ne!(a, outcome_kinds(100, 24), "different seed, different schedule");
    assert!(a.contains(&"ok"), "some requests must survive");
    assert!(
        a.iter().any(|k| *k == "worker_panic" || *k == "non_finite"),
        "some requests must be faulted: {a:?}"
    );
}

fn trainer_datasets() -> (Dataset, Dataset) {
    let spec = SyntheticSpec::mnist();
    (
        Dataset::synthetic(13, &spec, "train", 60),
        Dataset::synthetic(13, &spec, "test", 20),
    )
}

fn trainer_config() -> TrainConfig {
    TrainConfig {
        epochs: 2,
        batch_size: 10,
        sgd: SgdConfig { lr: 0.05, momentum: 0.0, clip: None },
        seed: 13,
        eval_every_epoch: false,
        verbose: false,
        workers: 4,
        cache_bytes: None,
    }
}

#[test]
fn trainer_weights_bit_identical_with_and_without_injected_panics() {
    let (train, test) = trainer_datasets();
    let (clean, clean_report) = ParallelTrainer::new(trainer_config(), Featurizer::Identity)
        .fit(&train, &test)
        .unwrap();
    let reg = MetricsRegistry::new();
    let plan =
        Arc::new(FaultPlan::with_registry(21, &reg).with_rate(FaultSite::WorkerPanic, 0.25));
    let (chaotic, report) = ParallelTrainer::new(trainer_config(), Featurizer::Identity)
        .with_retry(RetryPolicy {
            max_retries: 8,
            backoff: Duration::from_millis(1),
            backoff_cap: Duration::from_millis(10),
        })
        .with_faults(Arc::clone(&plan))
        .fit(&train, &test)
        .unwrap();
    assert!(plan.injected() > 0, "rate 0.25 over 12 batches x 4 shards must fire");
    assert_eq!(chaotic.w().data(), clean.w().data(), "retried weights diverge");
    assert_eq!(chaotic.b(), clean.b(), "retried biases diverge");
    // the chaotic run's *reported* history matches too (recomputed
    // shards are pure functions of their inputs; reduction order is
    // fixed, so the losses come out bit-identical as well)
    for (a, b) in report.history.iter().zip(&clean_report.history) {
        assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits(), "epoch {}", a.epoch);
    }
}

#[test]
fn trainer_gives_up_with_typed_error_when_faults_never_stop() {
    let (train, test) = trainer_datasets();
    let reg = MetricsRegistry::new();
    // rate 1.0 with no limit: every attempt of every shard panics
    let plan = Arc::new(FaultPlan::with_registry(8, &reg).with_rate(FaultSite::WorkerPanic, 1.0));
    let result = ParallelTrainer::new(trainer_config(), Featurizer::Identity)
        .with_retry(RetryPolicy {
            max_retries: 2,
            backoff: Duration::ZERO,
            backoff_cap: Duration::ZERO,
        })
        .with_faults(plan)
        .fit(&train, &test);
    assert!(
        matches!(result, Err(McError::WorkerPanic)),
        "exhausted retries must surface as a typed error"
    );
}

#[test]
fn trainer_pool_survives_panics_at_full_width() {
    // After a chaotic run the same trainer (same pool) must still
    // train cleanly: panic containment keeps every worker alive.
    let (train, test) = trainer_datasets();
    let reg = MetricsRegistry::new();
    let plan = Arc::new(
        FaultPlan::with_registry(4, &reg)
            .with_rate(FaultSite::WorkerPanic, 1.0)
            .with_limit(FaultSite::WorkerPanic, 3),
    );
    let trainer = ParallelTrainer::new(trainer_config(), Featurizer::Identity)
        .with_retry(RetryPolicy {
            max_retries: 4,
            backoff: Duration::ZERO,
            backoff_cap: Duration::ZERO,
        })
        .with_faults(Arc::clone(&plan));
    let (first, _) = trainer.fit(&train, &test).unwrap();
    assert_eq!(plan.injected(), 3, "the limit caps injection");
    // the plan's limit is exhausted: the second run is fault-free and
    // must match a never-faulted trainer bit-for-bit
    let (second, _) = trainer.fit(&train, &test).unwrap();
    let (clean, _) = ParallelTrainer::new(trainer_config(), Featurizer::Identity)
        .fit(&train, &test)
        .unwrap();
    assert_eq!(first.w().data(), clean.w().data());
    assert_eq!(second.w().data(), clean.w().data());
}
