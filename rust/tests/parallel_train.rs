//! Tentpole acceptance suite for the data-parallel sharded SGD
//! trainer:
//!
//! * N-worker training is **deterministic** — repeated runs with the
//!   same seed produce bit-identical `TrainReport.history` (modulo
//!   wall-clock `seconds`) and bit-identical final weights, for
//!   workers ∈ {1, 2, 4}.
//! * Every worker count matches the single-threaded epoch-loop
//!   `Trainer` oracle within 1e-5 final test accuracy (the only
//!   difference between the paths is floating-point summation order).
//! * Ragged tail batches and workers > batch rows are handled.

use mckernel::data::{Dataset, SyntheticSpec};
use mckernel::mckernel::McKernelFactory;
use mckernel::optim::SgdConfig;
use mckernel::train::{EpochRecord, Featurizer, ParallelTrainer, TrainConfig, Trainer};
use std::sync::Arc;

fn datasets(train_n: usize, test_n: usize) -> (Dataset, Dataset) {
    let spec = SyntheticSpec::mnist();
    (
        Dataset::synthetic(11, &spec, "train", train_n),
        Dataset::synthetic(11, &spec, "test", test_n),
    )
}

fn config(epochs: usize, lr: f32, workers: usize) -> TrainConfig {
    TrainConfig {
        epochs,
        batch_size: 10,
        sgd: SgdConfig { lr, momentum: 0.0, clip: None },
        seed: 1398239763,
        eval_every_epoch: false,
        verbose: false,
        workers,
        cache_bytes: None,
    }
}

fn kernel_featurizer() -> Featurizer {
    // σ=8 matches the data scale (see trainer.rs test notes).
    Featurizer::McKernel(Arc::new(
        McKernelFactory::new(784).expansions(1).sigma(8.0).rbf().seed(1).build(),
    ))
}

/// History equality up to the wall-clock field.
fn histories_bit_identical(a: &[EpochRecord], b: &[EpochRecord]) -> bool {
    a.len() == b.len()
        && a.iter().zip(b).all(|(x, y)| {
            x.epoch == y.epoch
                && x.train_loss.to_bits() == y.train_loss.to_bits()
                && x.train_accuracy.to_bits() == y.train_accuracy.to_bits()
                && x.test_accuracy.to_bits() == y.test_accuracy.to_bits()
        })
}

#[test]
fn n_workers_match_serial_oracle_identity_features() {
    let (train, test) = datasets(300, 100);
    let (_, oracle) = Trainer::new(config(3, 0.05, 1), Featurizer::Identity).fit(&train, &test);
    for workers in [1usize, 2, 4] {
        let trainer = ParallelTrainer::new(config(3, 0.05, workers), Featurizer::Identity);
        let (_, report) = trainer.fit(&train, &test).unwrap();
        assert!(
            (report.final_test_accuracy - oracle.final_test_accuracy).abs() <= 1e-5,
            "workers={workers}: parallel {} vs oracle {}",
            report.final_test_accuracy,
            oracle.final_test_accuracy
        );
    }
}

#[test]
fn n_workers_match_serial_oracle_mckernel_features() {
    let (train, test) = datasets(150, 60);
    let (_, oracle) = Trainer::new(config(2, 0.002, 1), kernel_featurizer()).fit(&train, &test);
    for workers in [1usize, 3] {
        let trainer = ParallelTrainer::new(config(2, 0.002, workers), kernel_featurizer());
        let (_, report) = trainer.fit(&train, &test).unwrap();
        assert!(
            (report.final_test_accuracy - oracle.final_test_accuracy).abs() <= 1e-5,
            "workers={workers}: parallel {} vs oracle {}",
            report.final_test_accuracy,
            oracle.final_test_accuracy
        );
    }
}

#[test]
fn repeated_runs_are_bit_identical_per_worker_count() {
    let (train, test) = datasets(100, 30);
    for workers in [1usize, 2, 4] {
        let mut cfg = config(2, 0.05, workers);
        cfg.eval_every_epoch = true; // every epoch's test accuracy in history
        let (m1, r1) =
            ParallelTrainer::new(cfg.clone(), Featurizer::Identity).fit(&train, &test).unwrap();
        let (m2, r2) = ParallelTrainer::new(cfg, Featurizer::Identity).fit(&train, &test).unwrap();
        assert!(
            histories_bit_identical(&r1.history, &r2.history),
            "workers={workers}: histories diverge:\n{:?}\nvs\n{:?}",
            r1.history,
            r2.history
        );
        assert_eq!(m1.w().data(), m2.w().data(), "workers={workers}: weights diverge");
        assert_eq!(m1.b(), m2.b(), "workers={workers}: biases diverge");
    }
}

#[test]
fn shard_count_invariance_of_final_accuracy() {
    let (train, test) = datasets(200, 80);
    let mut accs = Vec::new();
    for workers in [1usize, 2, 4] {
        let (_, report) = ParallelTrainer::new(config(3, 0.05, workers), Featurizer::Identity)
            .fit(&train, &test)
            .unwrap();
        accs.push(report.final_test_accuracy);
    }
    for (i, acc) in accs.iter().enumerate() {
        assert!(
            (acc - accs[0]).abs() <= 1e-5,
            "workers config #{i}: accuracy {acc} vs 1-worker {}",
            accs[0]
        );
    }
}

#[test]
fn more_workers_than_rows_and_ragged_tail() {
    // 23 samples, batch 10 → batches of 10/10/3; 8 workers shard the
    // tail as 8 × {0,1}-row shards clamped to 3 shards of 1.
    let (train, test) = datasets(23, 20);
    let (_, oracle) = Trainer::new(config(2, 0.05, 1), Featurizer::Identity).fit(&train, &test);
    let trainer = ParallelTrainer::new(config(2, 0.05, 8), Featurizer::Identity);
    let (_, report) = trainer.fit(&train, &test).unwrap();
    assert_eq!(report.history.len(), 2);
    assert!(report.history.iter().all(|r| r.train_loss.is_finite()));
    assert!(
        (report.final_test_accuracy - oracle.final_test_accuracy).abs() <= 1e-5,
        "parallel {} vs oracle {}",
        report.final_test_accuracy,
        oracle.final_test_accuracy
    );
}

#[test]
fn report_metadata_matches_serial_trainer() {
    let (train, test) = datasets(40, 20);
    let (_, serial) = Trainer::new(config(1, 0.05, 1), Featurizer::Identity).fit(&train, &test);
    let (_, parallel) =
        ParallelTrainer::new(config(1, 0.05, 2), Featurizer::Identity).fit(&train, &test).unwrap();
    assert_eq!(parallel.featurizer, serial.featurizer);
    assert_eq!(parallel.param_count, serial.param_count);
    assert_eq!(parallel.history.len(), serial.history.len());
}
