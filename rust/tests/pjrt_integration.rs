//! Cross-layer integration: the Rust-native feature map and the
//! AOT-compiled JAX/Pallas artifacts must agree numerically, and the
//! full PJRT train/predict path must work end to end.
//!
//! Requires `make artifacts` (skipped with a notice otherwise).

use mckernel::data::{Dataset, SyntheticSpec};
use mckernel::linalg::Matrix;
use mckernel::mckernel::McKernelFactory;
use mckernel::model::SoftmaxRegression;
use mckernel::runtime::{FeatureOp, Predictor, Runtime, TrainStep};
use std::sync::Arc;

fn artifact_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts/ (run `make artifacts`)");
        None
    }
}

fn runtime() -> Option<Runtime> {
    artifact_dir().map(|d| Runtime::new(d).expect("runtime"))
}

#[test]
fn manifest_loads_and_validates() {
    let Some(rt) = runtime() else { return };
    assert_eq!(rt.manifest().classes, 10);
    assert_eq!(rt.manifest().n, 1024);
    assert!(rt.manifest().entries.len() >= 11);
    assert_eq!(rt.platform(), "cpu");
}

/// THE cross-layer consistency check: identical coefficients through
/// the Pallas/XLA path and the Rust-native path give identical
/// features (up to f32 noise).
#[test]
fn pjrt_features_match_native_features() {
    let Some(rt) = runtime() else { return };
    for e in [1usize, 2] {
        let map = Arc::new(
            McKernelFactory::new(784)
                .expansions(e)
                .sigma(1.0)
                .rbf_matern(40)
                .seed(1398239763)
                .build(),
        );
        let op = FeatureOp::new(&rt, &map).expect("feature op");
        let data = Dataset::synthetic(3, &SyntheticSpec::mnist(), "train", 8);
        let native = map.transform_batch(data.images());
        let pjrt = op.transform(data.images()).expect("pjrt transform");
        assert_eq!(native.shape(), pjrt.shape());
        let mut max_err = 0.0f32;
        for (a, b) in native.data().iter().zip(pjrt.data()) {
            max_err = max_err.max((a - b).abs());
        }
        assert!(max_err < 1e-3, "E={e}: native vs pjrt max err {max_err}");
    }
}

#[test]
fn pjrt_train_step_decreases_loss() {
    let Some(rt) = runtime() else { return };
    let map = Arc::new(
        McKernelFactory::new(784).expansions(1).sigma(8.0).rbf().seed(7).build(),
    );
    let mut step = TrainStep::new(&rt, "mckernel", Some(&map)).expect("train step");
    assert_eq!(step.entry().batch, 10);
    let data = Dataset::synthetic(9, &SyntheticSpec::mnist(), "train", 10);
    let x = data.images().clone();
    let y = data.labels().to_vec();
    let first = step.step(&x, &y, 0.01).unwrap();
    assert!((first - 10.0f32.ln()).abs() < 0.05, "zero-init loss ≈ ln10, got {first}");
    let mut last = first;
    for _ in 0..30 {
        last = step.step(&x, &y, 0.01).unwrap();
    }
    assert!(last < first * 0.8, "loss {first} -> {last}");
    assert_eq!(step.steps(), 31);
}

#[test]
fn pjrt_lr_baseline_step_matches_native_math() {
    let Some(rt) = runtime() else { return };
    let mut step = TrainStep::new(&rt, "identity", None).expect("lr step");
    let data = Dataset::synthetic(11, &SyntheticSpec::mnist(), "train", 10);
    let x = data.images().clone();
    let y = data.labels().to_vec();
    let loss = step.step(&x, &y, 0.05).unwrap();

    // native reference: same zero-init model, same batch
    let model = SoftmaxRegression::zeros(10, 784);
    let (native_loss, native_grads) = model.loss_and_grad(&x, &y);
    assert!((loss - native_loss).abs() < 1e-4, "loss {loss} vs {native_loss}");

    let updated = step.export_model().unwrap();
    for (idx, (got, want)) in updated
        .w()
        .data()
        .iter()
        .zip(native_grads.dw.data().iter().map(|g| -0.05 * g))
        .enumerate()
    {
        assert!((got - want).abs() < 1e-5, "w[{idx}]: {got} vs {want}");
    }
}

#[test]
fn pjrt_predictor_matches_native_argmax() {
    let Some(rt) = runtime() else { return };
    let predictor = Predictor::new(&rt, "identity", None).expect("predictor");
    let data = Dataset::synthetic(13, &SyntheticSpec::mnist(), "test", 50);
    let model = SoftmaxRegression::init(10, 784, 21);
    let preds = predictor.predict(&model, data.images()).unwrap();
    let native = model.predict(data.images());
    assert_eq!(preds, native);
}

#[test]
fn pjrt_mckernel_predictor_consistent_with_feature_op() {
    let Some(rt) = runtime() else { return };
    let map = Arc::new(
        McKernelFactory::new(784).expansions(1).sigma(1.0).rbf_matern(40).seed(5).build(),
    );
    let predictor = Predictor::new(&rt, "mckernel", Some(&map)).unwrap();
    let data = Dataset::synthetic(15, &SyntheticSpec::mnist(), "test", 20);
    let model = SoftmaxRegression::init(10, map.feature_dim(), 3);
    let preds = predictor.predict(&model, data.images()).unwrap();
    // native: featurize then argmax
    let feats = map.transform_batch(data.images());
    let native = model.predict(&feats);
    assert_eq!(preds, native);
}

#[test]
fn train_step_import_export_roundtrip() {
    let Some(rt) = runtime() else { return };
    let mut step = TrainStep::new(&rt, "identity", None).unwrap();
    let mut m = SoftmaxRegression::zeros(10, 784);
    m.w_mut()[(3, 100)] = 1.5;
    m.b_mut()[2] = -0.5;
    step.import_model(&m).unwrap();
    let back = step.export_model().unwrap();
    assert_eq!(back.w().data(), m.w().data());
    assert_eq!(back.b(), m.b());
}

#[test]
fn ragged_eval_batch_handled() {
    let Some(rt) = runtime() else { return };
    let predictor = Predictor::new(&rt, "identity", None).unwrap();
    let model = SoftmaxRegression::init(10, 784, 1);
    // 7 rows ≪ eval batch 256: padded internally, 7 results back
    let x = Matrix::from_fn(7, 784, |r, c| ((r + c) % 9) as f32 / 9.0);
    let preds = predictor.predict(&model, &x).unwrap();
    assert_eq!(preds.len(), 7);
}
