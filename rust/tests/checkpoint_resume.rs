//! Checkpoint round-trip under training: saving mid-run and loading
//! back must reproduce identical predictions, and resuming from the
//! checkpoint must land exactly where the uninterrupted run lands
//! (momentum 0 ⇒ no optimizer state crosses the restart; the batcher
//! keys each epoch's shuffle by absolute epoch index; the payload is
//! exact little-endian f32).

use mckernel::data::{Dataset, SyntheticSpec};
use mckernel::mckernel::McKernelFactory;
use mckernel::model::checkpoint::Checkpoint;
use mckernel::optim::SgdConfig;
use mckernel::train::{Featurizer, ParallelTrainer, TrainConfig};
use std::collections::BTreeMap;
use std::sync::Arc;

fn datasets(train_n: usize, test_n: usize) -> (Dataset, Dataset) {
    let spec = SyntheticSpec::mnist();
    (
        Dataset::synthetic(5, &spec, "train", train_n),
        Dataset::synthetic(5, &spec, "test", test_n),
    )
}

fn config(epochs: usize, lr: f32, workers: usize) -> TrainConfig {
    TrainConfig {
        epochs,
        batch_size: 10,
        sgd: SgdConfig { lr, momentum: 0.0, clip: None },
        seed: 42,
        eval_every_epoch: false,
        verbose: false,
        workers,
        cache_bytes: None,
    }
}

fn tmp_path(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("mckernel_resume_{}_{name}", std::process::id()))
}

#[test]
fn midtrain_roundtrip_preserves_predictions_and_resume_matches_straight_run() {
    let (train, test) = datasets(120, 40);

    // uninterrupted 4-epoch run (2 workers: the sharded engine)
    let full = ParallelTrainer::new(config(4, 0.05, 2), Featurizer::Identity);
    let (m_full, rep_full) = full.fit(&train, &test).unwrap();

    // first half, checkpointed to disk mid-training
    let half = ParallelTrainer::new(config(2, 0.05, 2), Featurizer::Identity);
    let (m_half, _) = half.fit(&train, &test).unwrap();
    let path = tmp_path("identity.mck");
    Checkpoint { feature_config: None, model: m_half.clone(), meta: BTreeMap::new() }
        .with_epoch(2)
        .save(&path)
        .unwrap();

    // load → identical predictions (bit-exact weights)
    let ck = Checkpoint::load(&path).unwrap();
    assert_eq!(ck.epoch(), Some(2), "resume cursor travels in metadata");
    assert_eq!(ck.model.w().data(), m_half.w().data());
    assert_eq!(ck.model.b(), m_half.b());
    assert_eq!(
        ck.model.predict(test.images()),
        m_half.predict(test.images()),
        "reloaded model must predict identically"
    );

    // resume epochs 2..4 → bit-identical to the straight run
    let cursor = ck.epoch().unwrap();
    let (m_res, rep_res) = full.fit_resume(ck.model, cursor, &train, &test).unwrap();
    assert_eq!(m_res.w().data(), m_full.w().data(), "resumed weights diverge");
    assert_eq!(m_res.b(), m_full.b());
    assert_eq!(rep_res.history.len(), 2);
    assert_eq!(rep_res.history[0].epoch, 2);
    assert_eq!(
        rep_res.final_test_accuracy, rep_full.final_test_accuracy,
        "resumed final accuracy must equal the uninterrupted run"
    );
    let _ = std::fs::remove_file(&path);
}

#[test]
fn midtrain_roundtrip_with_feature_config_resumes_exactly() {
    let (train, test) = datasets(60, 20);
    let map = || {
        Arc::new(McKernelFactory::new(784).expansions(1).sigma(8.0).rbf().seed(9).build())
    };

    let full = ParallelTrainer::new(config(2, 0.002, 3), Featurizer::McKernel(map()));
    let (m_full, rep_full) = full.fit(&train, &test).unwrap();

    let half = ParallelTrainer::new(config(1, 0.002, 3), Featurizer::McKernel(map()));
    let (m_half, _) = half.fit(&train, &test).unwrap();
    let path = tmp_path("mckernel.mck");
    Checkpoint {
        feature_config: Some(map().config().clone()),
        model: m_half,
        meta: BTreeMap::new(),
    }
    .with_epoch(1)
    .save(&path)
    .unwrap();

    // rebuild the featurizer from the stored config — the paper's
    // compact-model story: coefficients regenerate from the seed
    let ck = Checkpoint::load(&path).unwrap();
    let rebuilt = Featurizer::McKernel(Arc::new(mckernel::mckernel::McKernel::new(
        ck.feature_config.clone().unwrap(),
    )));
    let resumer = ParallelTrainer::new(config(2, 0.002, 3), rebuilt);
    let cursor = ck.epoch().unwrap();
    let (m_res, rep_res) = resumer.fit_resume(ck.model, cursor, &train, &test).unwrap();
    assert_eq!(m_res.w().data(), m_full.w().data(), "kernel resume diverges");
    assert_eq!(rep_res.final_test_accuracy, rep_full.final_test_accuracy);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn fit_auto_recovers_a_killed_run_bit_identically() {
    let (train, test) = datasets(80, 30);
    let path = tmp_path("auto.mck");
    let _ = std::fs::remove_file(&path);

    // the run that never dies
    let full = ParallelTrainer::new(config(4, 0.05, 2), Featurizer::Identity);
    let (m_full, _) = full.fit(&train, &test).unwrap();

    // a "killed" run: only 2 of the 4 epochs happen, autosaving as it
    // goes (simulated by configuring fewer epochs on the same seed)
    let killed = ParallelTrainer::new(config(2, 0.05, 2), Featurizer::Identity);
    killed.fit_auto(&path, &train, &test).unwrap();
    assert_eq!(Checkpoint::load(&path).unwrap().epoch(), Some(2));

    // rerunning the full command picks up the cursor and finishes
    let rerun = ParallelTrainer::new(config(4, 0.05, 2), Featurizer::Identity);
    let (m_rec, rep) = rerun.fit_auto(&path, &train, &test).unwrap();
    assert_eq!(rep.history.len(), 2, "only the missing epochs are replayed");
    assert_eq!(rep.history[0].epoch, 2);
    assert_eq!(m_rec.w().data(), m_full.w().data(), "recovered weights diverge");
    assert_eq!(m_rec.b(), m_full.b());

    // a third invocation finds a complete checkpoint: evaluate only
    let again = ParallelTrainer::new(config(4, 0.05, 2), Featurizer::Identity);
    let (m_done, rep_done) = again.fit_auto(&path, &train, &test).unwrap();
    assert!(rep_done.history.is_empty(), "nothing left to train");
    assert_eq!(m_done.w().data(), m_full.w().data());
    assert!(rep_done.final_test_accuracy.is_finite());
    let _ = std::fs::remove_file(&path);
}
