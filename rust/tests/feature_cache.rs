//! Feature-cache invariants (PR 8): a cache-enabled path must be
//! bit-identical to the uncached engine across kernels, odd batch
//! sizes and non-pow2 dims; eviction is exact LRU under a byte
//! budget; hit/miss accounting is exact even under concurrency; maps
//! differing only in seed never share entries; and the `cache.*`
//! counters surface through `MetricsRegistry::snapshot_json`.

use mckernel::coordinator::{FeatureServer, ServerConfig};
use mckernel::linalg::Matrix;
use mckernel::mckernel::cache::entry_cost;
use mckernel::mckernel::{
    CacheKey, ExpansionEngine, FeatureCache, Kernel, McKernel, McKernelFactory,
};
use mckernel::obs::MetricsRegistry;
use mckernel::train::Featurizer;
use std::sync::Arc;
use std::time::Duration;

fn build(dim: usize, e: usize, kernel: Kernel, seed: u64) -> McKernel {
    let f = McKernelFactory::new(dim).expansions(e).sigma(1.3).seed(seed);
    match kernel {
        Kernel::Rbf => f.rbf(),
        Kernel::RbfMatern { t } => f.rbf_matern(t),
    }
    .build()
}

fn batch(rows: usize, dim: usize, salt: usize) -> Matrix {
    Matrix::from_fn(rows, dim, |r, c| {
        (((r * 31 + c * 7 + salt * 13) % 23) as f32 - 11.0) * 0.07
    })
}

/// One row as a 1×dim matrix (distinct per `j`).
fn row(dim: usize, j: usize) -> Matrix {
    Matrix::from_fn(1, dim, |_, c| ((c * 5 + j * 17) % 19) as f32 * 0.11)
}

fn isolated(capacity: usize, shards: usize) -> (FeatureCache, MetricsRegistry) {
    let reg = MetricsRegistry::new();
    let c = FeatureCache::with_registry(capacity, shards, &reg);
    (c, reg)
}

#[test]
fn cached_path_is_bit_identical_across_kernels_and_shapes() {
    for kernel in [Kernel::Rbf, Kernel::RbfMatern { t: 40 }] {
        // non-pow2 dims (padded to 16 and 32) and odd batch sizes
        for &dim in &[12usize, 20] {
            let map = build(dim, 2, kernel, 21);
            let fd = map.feature_dim();
            let mut cached_eng = ExpansionEngine::new(&map, 8);
            let mut plain_eng = ExpansionEngine::new(&map, 8);
            let key = CacheKey::new(map.config(), cached_eng.plan());
            let (cache, _) = isolated(1 << 20, 4);
            for (pass, &rows) in [1usize, 3, 7, 5, 3].iter().enumerate() {
                let x = batch(rows, dim, rows);
                let mut want = Matrix::zeros(rows, fd);
                let mut got = Matrix::zeros(rows, fd);
                plain_eng.execute_matrix(&map, &x, &mut want);
                cache.execute_matrix(key, &mut cached_eng, &map, &x, &mut got);
                assert_eq!(
                    got.data(),
                    want.data(),
                    "{kernel:?} dim={dim} rows={rows} pass={pass}"
                );
            }
            // second full replay: now hit-dominated, still identical
            let before = cache.hits();
            for &rows in &[1usize, 3, 7, 5, 3] {
                let x = batch(rows, dim, rows);
                let mut want = Matrix::zeros(rows, fd);
                let mut got = Matrix::zeros(rows, fd);
                plain_eng.execute_matrix(&map, &x, &mut want);
                cache.execute_matrix(key, &mut cached_eng, &map, &x, &mut got);
                assert_eq!(got.data(), want.data(), "{kernel:?} dim={dim} replay");
            }
            assert!(cache.hits() > before, "{kernel:?} dim={dim}: replay produced no hits");
        }
    }
}

#[test]
fn eviction_is_exact_lru_order() {
    let map = build(16, 1, Kernel::Rbf, 5);
    let fd = map.feature_dim();
    let mut eng = ExpansionEngine::new(&map, 1);
    let key = CacheKey::new(map.config(), eng.plan());
    // room for exactly two entries, one shard so the LRU list is global
    let cost = entry_cost(16, fd);
    let (cache, _) = isolated(2 * cost, 1);
    let mut out = Matrix::zeros(1, fd);
    let mut run = |j: usize| {
        let x = row(16, j);
        cache.execute_matrix(key, &mut eng, &map, &x, &mut out);
    };
    run(0); // A: miss
    run(1); // B: miss — resident {A, B}, A is LRU
    run(0); // A: hit — B becomes LRU
    run(2); // C: miss — evicts B, resident {A, C}
    assert_eq!((cache.hits(), cache.misses(), cache.evictions()), (1, 3, 1));
    assert_eq!((cache.entries(), cache.bytes()), (2, 2 * cost));
    run(0); // A: still resident
    run(2); // C: still resident
    assert_eq!((cache.hits(), cache.misses()), (3, 3));
    run(1); // B: the evicted one — must miss (and evict the new tail)
    assert_eq!((cache.hits(), cache.misses(), cache.evictions()), (3, 4, 2));
    assert_eq!(cache.entries(), 2);
}

#[test]
fn residency_never_exceeds_the_byte_budget() {
    let map = build(12, 1, Kernel::Rbf, 9);
    let fd = map.feature_dim();
    let mut eng = ExpansionEngine::new(&map, 1);
    let key = CacheKey::new(map.config(), eng.plan());
    let cost = entry_cost(12, fd);
    let capacity = 4 * cost + cost / 2; // four entries fit, five don't
    let (cache, _) = isolated(capacity, 1);
    let mut out = Matrix::zeros(1, fd);
    for j in 0..12 {
        let x = row(12, j);
        cache.execute_matrix(key, &mut eng, &map, &x, &mut out);
        assert!(cache.bytes() <= capacity, "insert {j}: {} > {capacity}", cache.bytes());
        assert!(cache.entries() <= 4, "insert {j}: {} entries", cache.entries());
    }
    assert_eq!(cache.misses(), 12);
    assert_eq!(cache.evictions(), 8);
    assert_eq!(cache.bytes(), 4 * cost);
}

#[test]
fn concurrent_lookups_account_exactly_and_stay_bit_identical() {
    let map = Arc::new(build(20, 1, Kernel::RbfMatern { t: 40 }, 3));
    let fd = map.feature_dim();
    let reg = MetricsRegistry::new();
    let cache = Arc::new(FeatureCache::with_registry(1 << 20, 8, &reg));
    let threads = 4;
    let iters = 25;
    let per_batch = 4;
    let handles: Vec<_> = (0..threads)
        .map(|t| {
            let map = Arc::clone(&map);
            let cache = Arc::clone(&cache);
            std::thread::spawn(move || {
                let mut cached_eng = ExpansionEngine::new(&map, per_batch);
                let mut plain_eng = ExpansionEngine::new(&map, per_batch);
                let key = CacheKey::new(map.config(), cached_eng.plan());
                let mut want = Matrix::zeros(per_batch, fd);
                let mut got = Matrix::zeros(per_batch, fd);
                // rows drawn from a pool of 8 shared across threads
                let pool: Vec<Matrix> = (0..8).map(|j| row(20, j)).collect();
                for i in 0..iters {
                    let x = Matrix::from_fn(per_batch, 20, |r, c| {
                        pool[(t + i + r * 3) % 8].row(0)[c]
                    });
                    plain_eng.execute_matrix(&map, &x, &mut want);
                    cache.execute_matrix(key, &mut cached_eng, &map, &x, &mut got);
                    assert_eq!(got.data(), want.data(), "thread {t} iter {i}");
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let lookups = (threads * iters * per_batch) as u64;
    assert_eq!(cache.hits() + cache.misses(), lookups);
    assert!(cache.hits() > cache.misses(), "8-row pool should be hit-dominated");
    assert_eq!(cache.evictions(), 0);
    assert_eq!(reg.counter_value("cache.hits"), Some(cache.hits()));
    assert_eq!(reg.counter_value("cache.misses"), Some(cache.misses()));
}

#[test]
fn maps_differing_only_in_seed_never_share_entries() {
    let a = build(12, 1, Kernel::Rbf, 1);
    let b = build(12, 1, Kernel::Rbf, 2);
    let fd = a.feature_dim();
    let mut eng_a = ExpansionEngine::new(&a, 4);
    let mut eng_b = ExpansionEngine::new(&b, 4);
    let key_a = CacheKey::new(a.config(), eng_a.plan());
    let key_b = CacheKey::new(b.config(), eng_b.plan());
    assert_ne!(key_a, key_b);
    let (cache, _) = isolated(1 << 20, 2);
    let x = batch(4, 12, 0);
    let mut out_a = Matrix::zeros(4, fd);
    let mut out_b = Matrix::zeros(4, fd);
    // same inputs through both maps sharing one cache
    cache.execute_matrix(key_a, &mut eng_a, &a, &x, &mut out_a);
    cache.execute_matrix(key_b, &mut eng_b, &b, &x, &mut out_b);
    assert_eq!(cache.entries(), 8, "disjoint ids must not collapse entries");
    assert_eq!(cache.misses(), 8);
    // and each map's resident rows replay its own features, not the
    // other's
    let mut want = Matrix::zeros(4, fd);
    ExpansionEngine::new(&a, 4).execute_matrix(&a, &x, &mut want);
    let mut replay = Matrix::zeros(4, fd);
    cache.execute_matrix(key_a, &mut eng_a, &a, &x, &mut replay);
    assert_eq!(replay.data(), want.data());
    ExpansionEngine::new(&b, 4).execute_matrix(&b, &x, &mut want);
    cache.execute_matrix(key_b, &mut eng_b, &b, &x, &mut replay);
    assert_eq!(replay.data(), want.data());
    assert_ne!(out_a.data(), out_b.data(), "different seeds, different features");
    assert_eq!(cache.hits(), 8);
}

#[test]
fn cache_metrics_surface_in_snapshot_json() {
    let map = build(12, 1, Kernel::Rbf, 7);
    let fd = map.feature_dim();
    let mut eng = ExpansionEngine::new(&map, 2);
    let key = CacheKey::new(map.config(), eng.plan());
    let (cache, reg) = isolated(1 << 16, 2);
    let x = batch(2, 12, 1);
    let mut out = Matrix::zeros(2, fd);
    cache.execute_matrix(key, &mut eng, &map, &x, &mut out);
    cache.execute_matrix(key, &mut eng, &map, &x, &mut out);
    let snap = reg.snapshot_json().to_string();
    for name in ["cache.hits", "cache.misses", "cache.evictions", "cache.bytes"] {
        assert!(snap.contains(&format!("\"{name}\"")), "snapshot missing {name}: {snap}");
    }
    assert_eq!(reg.counter_value("cache.hits"), Some(2));
    assert_eq!(reg.counter_value("cache.misses"), Some(2));
}

#[test]
fn featurizer_engine_with_cache_matches_uncached() {
    let map = Arc::new(build(20, 2, Kernel::RbfMatern { t: 40 }, 11));
    let f = Featurizer::McKernel(Arc::clone(&map));
    let reg = MetricsRegistry::new();
    let cache = Arc::new(FeatureCache::with_registry(1 << 20, 2, &reg));
    let mut plain = f.make_engine(8);
    let mut cached = f.make_engine_cached(8, Some(cache));
    let x = batch(6, 20, 4);
    let want = f.apply_into(&x, &mut plain).clone();
    let got = f.apply_into(&x, &mut cached).clone();
    assert_eq!(got.data(), want.data());
    // second pass is all hits and still identical
    let got2 = f.apply_into(&x, &mut cached).clone();
    assert_eq!(got2.data(), want.data());
    assert_eq!(reg.counter_value("cache.hits"), Some(6));
    assert_eq!(reg.counter_value("cache.misses"), Some(6));
}

#[test]
fn server_with_cache_replies_bit_identical_and_records_hits() {
    let map = Arc::new(build(12, 2, Kernel::Rbf, 17));
    let reg_plain = MetricsRegistry::new();
    let reg_cached = MetricsRegistry::new();
    let plain = FeatureServer::start_with_registry(
        Arc::clone(&map),
        ServerConfig::new(4, Duration::from_micros(50)),
        &reg_plain,
    );
    let cached = FeatureServer::start_with_registry(
        Arc::clone(&map),
        ServerConfig::new(4, Duration::from_micros(50)).cache_bytes(1 << 20),
        &reg_cached,
    );
    // 3 distinct rows, 8 rounds: repeats hit from round two onward
    for round in 0..8 {
        for j in 0..3 {
            let x = row(12, j).data().to_vec();
            let want = plain.transform(x.clone()).unwrap();
            let got = cached.transform(x).unwrap();
            assert_eq!(got, want, "round {round} row {j}");
        }
    }
    let hits = reg_cached.counter_value("cache.hits").unwrap();
    let misses = reg_cached.counter_value("cache.misses").unwrap();
    assert_eq!(hits + misses, 24);
    assert!(hits >= 21, "3 unique rows over 24 requests: got {hits} hits");
    assert_eq!(reg_plain.counter_value("cache.hits"), None, "uncached server registers none");
    plain.shutdown();
    cached.shutdown();
}
