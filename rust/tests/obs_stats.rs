//! Integration test for `mckernel stats`: drives the instrumented
//! workload in this test binary's own process (so the global registry
//! starts fresh and disabled) and checks the exported snapshot shape
//! deterministically — exact counts where the workload fixes them,
//! finiteness everywhere else.

use mckernel::cli::{commands, Args};
use mckernel::util::json::Json;

#[test]
fn stats_snapshot_has_expected_shape() {
    let out =
        std::env::temp_dir().join(format!("mckernel_stats_test_{}.json", std::process::id()));
    let out_s = out.to_str().unwrap().to_string();
    let argv = [
        "--quick", "--rows", "8", "--input-dim", "32", "--expansions", "1", "--requests", "6",
        "--workers", "2", "--out", out_s.as_str(),
    ];
    let args = Args::parse(argv.iter().copied()).unwrap();
    commands::cmd_stats(&args).unwrap();

    let snap = Json::parse(&std::fs::read_to_string(&out).unwrap()).unwrap();
    assert_eq!(snap.get("enabled").and_then(Json::as_bool), Some(true));

    let hists = snap.get("histograms").and_then(Json::as_obj).expect("histograms object");
    let counters = snap.get("counters").and_then(Json::as_obj).expect("counters object");
    let gauges = snap.get("gauges").and_then(Json::as_obj).expect("gauges object");

    // Engine stage timings keyed by plan fingerprint.
    for stage in [".execute_ns", ".fwht_ns", ".trig_ns", ".write_ns"] {
        assert!(
            hists.keys().any(|k| k.starts_with("engine.") && k.ends_with(stage)),
            "no engine histogram ending in {stage}: {:?}",
            hists.keys().collect::<Vec<_>>()
        );
    }

    // Trainer, server and prefetch histograms all recorded ≥ 1 sample
    // with finite, ordered summary fields.
    for name in [
        "train.epoch_ns",
        "train.shard_ns",
        "train.reduce_ns",
        "server.latency_ns",
        "server.batch_fill",
        "prefetch.stall_ns",
    ] {
        let h = hists.get(name).unwrap_or_else(|| panic!("missing histogram {name}"));
        let f = |k: &str| {
            h.get(k).and_then(Json::as_f64).unwrap_or_else(|| panic!("{name}.{k} not a number"))
        };
        assert!(f("count") >= 1.0, "{name} recorded nothing");
        for field in ["sum", "min", "max", "mean", "p50", "p95", "p99"] {
            assert!(f(field).is_finite(), "{name}.{field} not finite");
        }
        assert!(f("min") <= f("p50") && f("p50") <= f("p95"), "{name} percentiles unordered");
        assert!(f("p95") <= f("p99") && f("p99") <= f("max"), "{name} tail unordered");
    }

    // Deterministic exact counts: one request per transform call, and
    // every request drained before shutdown.
    assert_eq!(counters.get("server.requests").and_then(Json::as_usize), Some(6));
    assert!(counters.get("train.rows").and_then(Json::as_usize).unwrap_or(0) > 0, "train.rows");
    assert_eq!(
        gauges.get("server.queue_depth").and_then(Json::as_f64),
        Some(0.0),
        "queue fully drained"
    );

    let _ = std::fs::remove_file(&out);
}
