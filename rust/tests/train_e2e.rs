//! End-to-end learning behaviour on the synthetic datasets: the
//! qualitative claims behind Figures 3-5 at test-sized scale.
//!
//! * McKernel features match/beat the LR baseline on this data.
//! * Accuracy does not degrade with more expansions E.
//! * Checkpoint round-trip preserves evaluation exactly.

use mckernel::data::{Dataset, SyntheticSpec};
use mckernel::mckernel::McKernelFactory;
use mckernel::model::checkpoint::Checkpoint;
use mckernel::optim::SgdConfig;
use mckernel::train::{Featurizer, TrainConfig, Trainer};
use std::sync::Arc;

fn datasets(train_n: usize, test_n: usize, spec: &SyntheticSpec) -> (Dataset, Dataset) {
    (
        Dataset::synthetic(1398239763, spec, "train", train_n),
        Dataset::synthetic(1398239763, spec, "test", test_n),
    )
}

fn config(epochs: usize, lr: f32) -> TrainConfig {
    TrainConfig {
        epochs,
        batch_size: 10,
        sgd: SgdConfig { lr, momentum: 0.0, clip: None },
        seed: 1398239763,
        eval_every_epoch: false,
        verbose: false,
        workers: 1,
        cache_bytes: None,
    }
}

fn kernel_featurizer(e: usize) -> Featurizer {
    // Matérn t=40 sigma=1 — the paper's Figure 3-5 configuration.
    Featurizer::McKernel(Arc::new(
        McKernelFactory::new(784)
            .expansions(e)
            .sigma(1.0)
            .rbf_matern(40)
            .seed(1398239763)
            .build(),
    ))
}

#[test]
fn mckernel_beats_lr_on_nonlinear_data() {
    let (train, test) = datasets(600, 200, &SyntheticSpec::mnist());
    let (_, lr_report) = Trainer::new(config(6, 0.01), Featurizer::Identity).fit(&train, &test);
    let (_, mk_report) = Trainer::new(config(6, 0.001), kernel_featurizer(2)).fit(&train, &test);
    assert!(
        mk_report.final_test_accuracy >= lr_report.final_test_accuracy - 0.02,
        "kernel {:.3} should match/beat LR {:.3}",
        mk_report.final_test_accuracy,
        lr_report.final_test_accuracy
    );
    assert!(mk_report.final_test_accuracy > 0.5);
}

#[test]
fn accuracy_improves_with_expansions() {
    // The Figure 3/4/5 x-axis claim, at small scale: E=4 >= E=1 - noise.
    let (train, test) = datasets(400, 150, &SyntheticSpec::mnist());
    let (_, e1) = Trainer::new(config(5, 0.001), kernel_featurizer(1)).fit(&train, &test);
    let (_, e4) = Trainer::new(config(5, 0.001), kernel_featurizer(4)).fit(&train, &test);
    assert!(
        e4.final_test_accuracy >= e1.final_test_accuracy - 0.03,
        "E=4 {:.3} vs E=1 {:.3}",
        e4.final_test_accuracy,
        e1.final_test_accuracy
    );
}

#[test]
fn fashion_is_harder_than_mnist() {
    let cfg = config(5, 0.01);
    let (m_train, m_test) = datasets(400, 150, &SyntheticSpec::mnist());
    let (f_train, f_test) = datasets(400, 150, &SyntheticSpec::fashion());
    let (_, m_rep) = Trainer::new(cfg.clone(), Featurizer::Identity).fit(&m_train, &m_test);
    let (_, f_rep) = Trainer::new(cfg, Featurizer::Identity).fit(&f_train, &f_test);
    assert!(
        f_rep.final_test_accuracy < m_rep.final_test_accuracy + 0.02,
        "fashion {:.3} should be <= mnist {:.3}",
        f_rep.final_test_accuracy,
        m_rep.final_test_accuracy
    );
}

#[test]
fn parameter_count_follows_eq22() {
    let (train, test) = datasets(50, 20, &SyntheticSpec::mnist());
    for e in [1usize, 2] {
        let (_, rep) = Trainer::new(config(1, 0.001), kernel_featurizer(e)).fit(&train, &test);
        assert_eq!(rep.param_count, 10 * (2 * 1024 * e + 1), "E={e}");
    }
}

#[test]
fn checkpoint_roundtrip_preserves_eval() {
    let (train, test) = datasets(200, 80, &SyntheticSpec::mnist());
    let trainer = Trainer::new(config(3, 0.001), kernel_featurizer(1));
    let (model, rep) = trainer.fit(&train, &test);

    let map_cfg = match &trainer.featurizer {
        Featurizer::McKernel(m) => m.config().clone(),
        _ => unreachable!(),
    };
    let dir = std::env::temp_dir().join("mckernel_e2e_ckpt");
    let path = dir.join("m.mck");
    Checkpoint {
        feature_config: Some(map_cfg),
        model,
        meta: Default::default(),
    }
    .save(&path)
    .unwrap();

    let ck = Checkpoint::load(&path).unwrap();
    let featurizer = Featurizer::McKernel(Arc::new(mckernel::mckernel::McKernel::new(
        ck.feature_config.clone().unwrap(),
    )));
    let eval_trainer = Trainer::new(config(1, 0.001), featurizer);
    let acc = eval_trainer.evaluate(&ck.model, &test);
    assert!(
        (acc - rep.final_test_accuracy).abs() < 1e-9,
        "restored {acc} vs trained {}",
        rep.final_test_accuracy
    );
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn momentum_and_clip_paths_run() {
    let (train, test) = datasets(100, 40, &SyntheticSpec::mnist());
    let cfg = TrainConfig {
        epochs: 2,
        batch_size: 10,
        sgd: SgdConfig { lr: 0.01, momentum: 0.9, clip: Some(5.0) },
        seed: 3,
        eval_every_epoch: true,
        verbose: false,
        workers: 1,
        cache_bytes: None,
    };
    let (_, rep) = Trainer::new(cfg, Featurizer::Identity).fit(&train, &test);
    assert_eq!(rep.history.len(), 2);
    assert!(rep.history.iter().all(|r| r.train_loss.is_finite()));
}
