//! Self-tests for the invariant linter: run the rule engine against
//! committed good/bad fixture trees so a rule regression fails tier-1.
//!
//! The fixtures are miniature `rust/src` layouts (the rules' path
//! policies key off relative paths like `coordinator/server.rs`), one
//! clean tree and one that trips every rule at least once.

use mckernel_analyze::rules::{analyze_tree, normalize_metric, Report, RULES};
use std::path::PathBuf;

fn fixture(tree: &str) -> (PathBuf, PathBuf) {
    let base = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures").join(tree);
    (base.join("src"), base.join("METRICS.md"))
}

fn run(tree: &str) -> Report {
    let (src, metrics) = fixture(tree);
    analyze_tree(&src, &metrics, &[])
}

fn count(report: &Report, rule: &str) -> usize {
    report.findings.iter().filter(|f| f.rule == rule).count()
}

#[test]
fn good_tree_is_clean() {
    let report = run("good");
    assert!(
        report.findings.is_empty(),
        "good tree must produce zero findings, got:\n{}",
        report.findings.iter().map(|f| format!("  {f}\n")).collect::<String>()
    );
    assert!(report.files >= 6, "good tree should scan all fixture files");
}

#[test]
fn explained_waiver_suppresses_and_is_counted() {
    // good/coordinator/server.rs waives its startup `.expect` with a
    // reasoned waiver: suppressed, but visible in the waived count.
    let report = run("good");
    assert_eq!(report.waived, 1);
}

/// Each of the six rules has at least one bad fixture proving it
/// fires (acceptance criterion).
#[test]
fn every_rule_fires_on_bad_tree() {
    let report = run("bad");
    for (rule, _) in RULES {
        assert!(
            count(&report, rule) >= 1,
            "rule `{rule}` produced no finding on the bad tree:\n{}",
            report.findings.iter().map(|f| format!("  {f}\n")).collect::<String>()
        );
    }
}

#[test]
fn safety_comment_counts_blocks_and_fns() {
    // naked block, naked unsafe fn, naked interior block
    let report = run("bad");
    assert_eq!(count(&report, "safety-comment"), 3);
    assert!(report
        .findings
        .iter()
        .any(|f| f.rule == "safety-comment" && f.file == "safety.rs"));
}

#[test]
fn timing_cast_sees_nanos_and_micros() {
    let report = run("bad");
    let timing: Vec<_> =
        report.findings.iter().filter(|f| f.rule == "timing-cast").collect();
    // both casts in timing.rs; the one in waivers.rs is consumed by
    // its (reasonless) waiver and resurfaces as a `waiver` finding.
    assert_eq!(timing.len(), 2);
    assert!(timing.iter().all(|f| f.file == "timing.rs"));
}

#[test]
fn thread_spawn_exempts_test_regions() {
    // spawn.rs has one production spawn and one inside #[cfg(test)].
    let report = run("bad");
    assert_eq!(count(&report, "thread-spawn"), 1);
}

#[test]
fn no_panic_serving_sees_panic_unwrap_expect() {
    let report = run("bad");
    assert_eq!(count(&report, "no-panic-serving"), 3);
}

#[test]
fn metric_manifest_fires_both_directions() {
    let report = run("bad");
    let findings: Vec<_> =
        report.findings.iter().filter(|f| f.rule == "metric-manifest").collect();
    assert_eq!(findings.len(), 2);
    assert!(findings.iter().any(|f| f.msg.contains("bad.unmanifested")), "code-side");
    assert!(findings.iter().any(|f| f.msg.contains("bad.stale")), "manifest-side");
}

#[test]
fn waiver_hygiene_fires_three_ways() {
    // no `-- reason`, stale (suppresses nothing), unknown rule id.
    let report = run("bad");
    let waiver: Vec<_> = report.findings.iter().filter(|f| f.rule == "waiver").collect();
    assert_eq!(waiver.len(), 3);
    assert!(waiver.iter().any(|f| f.msg.contains("no `-- reason`")));
    assert!(waiver.iter().any(|f| f.msg.contains("suppresses nothing")));
    assert!(waiver.iter().any(|f| f.msg.contains("unknown rule")));
}

#[test]
fn rule_filter_restricts_scope() {
    let (src, metrics) = fixture("bad");
    let report = analyze_tree(&src, &metrics, &["dispatch-confinement".to_string()]);
    assert!(report.findings.iter().all(|f| f.rule == "dispatch-confinement" || f.rule == "waiver"));
    assert_eq!(count(&report, "dispatch-confinement"), 2);
}

#[test]
fn metric_normalization_matches_format_and_manifest_styles() {
    assert_eq!(normalize_metric("engine.{fp}.rows"), "engine.<>.rows");
    assert_eq!(normalize_metric("engine.<fp>.rows"), "engine.<>.rows");
    assert_eq!(normalize_metric("span.{name}_ns"), "span.<>_ns");
    assert_eq!(normalize_metric("cache.hits"), "cache.hits");
}

/// The linter must hold on the real tree: zero findings, every waiver
/// explained. This is the same gate CI runs via `--deny-all`, kept as
/// a test so `cargo test` alone catches drift.
#[test]
fn real_tree_is_clean() {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../..");
    let src = root.join("rust/src");
    let metrics = root.join("METRICS.md");
    if !src.is_dir() {
        return; // vendored/packaged checkout without the main crate
    }
    let report = analyze_tree(&src, &metrics, &[]);
    assert!(
        report.findings.is_empty(),
        "real tree has findings:\n{}",
        report.findings.iter().map(|f| format!("  {f}\n")).collect::<String>()
    );
}
