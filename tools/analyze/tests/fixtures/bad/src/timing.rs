//! Fixture: raw duration narrowing outside obs/ must fire.

use std::time::Instant;

pub fn measure() -> u64 {
    let t0 = Instant::now();
    t0.elapsed().as_nanos() as u64
}

pub fn measure_us() -> u64 {
    let t0 = Instant::now();
    t0.elapsed().as_micros() as u64
}
