//! Fixture: raw thread creation outside the pool must fire.

pub fn rogue() {
    std::thread::spawn(|| {}).join().ok();
}

#[cfg(test)]
mod tests {
    #[test]
    fn stress_threads_are_fine() {
        // test regions are exempt: stress tests spawn competitors.
        std::thread::spawn(|| {}).join().ok();
    }
}
