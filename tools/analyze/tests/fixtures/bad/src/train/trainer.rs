//! Fixture: panics on a typed-error training path must fire.

pub fn step(x: Option<u32>) -> u32 {
    if x.is_none() {
        panic!("empty batch");
    }
    x.unwrap()
}

pub fn step2(x: Option<u32>) -> u32 {
    x.expect("empty batch")
}
