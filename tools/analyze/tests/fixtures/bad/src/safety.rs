//! Fixture: unsafe without a SAFETY comment must fire.

pub fn naked(p: *const u32) -> u32 {
    unsafe { *p }
}

pub unsafe fn naked_fn(p: *const u32) -> u32 {
    unsafe { *p }
}
