//! Fixture: waiver hygiene — a waiver with no reason, a stale waiver
//! suppressing nothing, and a waiver naming an unknown rule all fire.

use std::time::Instant;

pub fn no_reason() -> u64 {
    let t0 = Instant::now();
    // analyze: allow(timing-cast)
    t0.elapsed().as_nanos() as u64
}

// analyze: allow(thread-spawn) -- stale: the spawn below was removed
pub fn stale() {}

// analyze: allow(bogus-rule) -- no such rule id
pub fn unknown() {}
