//! Fixture: a recorded-but-unmanifested metric must fire (and the
//! manifest's stale `bad.stale` entry fires from the other side).

pub fn record(reg: &Registry) {
    reg.counter("bad.unmanifested");
}

pub struct Registry;
impl Registry {
    pub fn counter(&self, _name: &str) {}
}
