//! Fixture: naming `FwhtDispatch` outside the plan/engine/cache seam
//! must fire.

pub fn leak(d: crate::mckernel::plan::FwhtDispatch) -> bool {
    matches!(d, crate::mckernel::plan::FwhtDispatch::PerRow)
}
