//! Fixture: the obs module owns duration narrowing, so `as_nanos`
//! here is legal (timing-cast rule exempts `obs/`).

use std::time::Instant;

pub fn elapsed_ns(t0: Instant) -> u64 {
    u64::try_from(t0.elapsed().as_nanos()).unwrap_or(u64::MAX)
}

pub struct Registry;

impl Registry {
    // A *definition* named `counter` must not be mistaken for a
    // metric-recording call site.
    pub fn counter(&self, _name: &str) -> u64 {
        0
    }
}
