//! Fixture: a coordinator seam — may build service threads, and may
//! carry an *explained* waiver for a startup expect.

pub fn serve(reg: &crate::obs::Registry) {
    let _ = reg.counter("server.requests");
    let builder = std::thread::Builder::new().name("serve".into());
    // analyze: allow(no-panic-serving) -- startup spawn failure is fatal by design
    builder.spawn(|| {}).expect("spawn server thread");
}
