//! Fixture: the engine may *consume* a decided `FwhtDispatch`, and
//! records metrics through format! templates that the manifest lists
//! with `<fp>` placeholders.

use super::plan::FwhtDispatch;

pub fn run(d: &FwhtDispatch, fp: &str, reg: &crate::obs::Registry) {
    let _ = reg.counter(&format!("engine.{fp}.rows"));
    match d {
        FwhtDispatch::PerRow => {}
        FwhtDispatch::Tiled { .. } => {}
    }
}
