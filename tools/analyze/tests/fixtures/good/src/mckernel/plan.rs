//! Fixture: plan.rs is the single decision point for `FwhtDispatch`.

pub enum FwhtDispatch {
    PerRow,
    Tiled { lanes: usize },
}

pub fn decide(rows: usize) -> FwhtDispatch {
    if rows == 1 {
        FwhtDispatch::PerRow
    } else {
        FwhtDispatch::Tiled { lanes: rows }
    }
}
