//! Fixture: the pool is the one place allowed to create threads, and
//! its unsafe sites carry SAFETY comments.

pub fn start(n: usize) {
    for _ in 0..n {
        std::thread::spawn(|| {});
    }
    // SAFETY: the pointer is derived from a live slice above and the
    // scope joins every worker before the slice's borrow ends.
    unsafe {
        erased();
    }
}

/// # Safety
/// Caller must ensure the erased lifetime outlives the scope.
pub unsafe fn erased() {}
