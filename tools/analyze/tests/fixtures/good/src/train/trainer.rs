//! Fixture: a typed-error training path with clean error handling;
//! the `#[cfg(test)]` module below may unwrap/panic freely because
//! most rules skip test regions.

pub fn step(x: Option<u32>) -> Result<u32, String> {
    x.ok_or_else(|| "empty batch".to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unwrap_is_fine_in_tests() {
        assert_eq!(step(Some(3)).unwrap(), 3);
        if step(None).is_ok() {
            panic!("expected error");
        }
    }
}
