//! The six McKernel invariant rules.
//!
//! Each rule is a project convention that clippy cannot express
//! because it is about *this* codebase's architecture, not Rust in
//! general:
//!
//! | rule | invariant |
//! |------|-----------|
//! | `safety-comment` | every `unsafe` block / fn / impl is directly preceded by a `// SAFETY:` comment (or a `/// # Safety` doc section) |
//! | `timing-cast` | no `as_nanos()` / `as_micros()` duration narrowing outside `obs/` — the PR 8 `obs::elapsed_ns` contract |
//! | `thread-spawn` | thread creation (`thread::spawn`, `thread::Builder`) only in `util/threadpool.rs` and the coordinator seams |
//! | `dispatch-confinement` | `FwhtDispatch` is named only by `mckernel/plan.rs` (decision), `mckernel/engine.rs` + `mckernel/cache.rs` (consumption) and the `mckernel/mod.rs` re-export — the PR 4 single-decision-point invariant |
//! | `metric-manifest` | every metric-name literal passed to `counter`/`gauge`/`histogram`/`counter_value` appears in `METRICS.md`, and vice versa |
//! | `no-panic-serving` | no `.unwrap()` / `.expect()` / `panic!` on the `McError`-typed serving & training paths |
//!
//! Violations can be waived — visibly — with a comment directly above
//! the site (or a run of comments ending there):
//!
//! ```text
//! // analyze: allow(<rule-id>) -- <reason>
//! ```
//!
//! A waiver without a ` -- reason` is itself a violation, and so is a
//! waiver that suppresses nothing (stale waivers must be deleted), so
//! every exception in the tree stays explained and greppable.
//!
//! Scope: the linter walks `rust/src/**` — production code. Test
//! modules (`#[cfg(test)]` / `#[test]` items) are skipped by every
//! rule except `safety-comment` and `timing-cast`, which hold
//! everywhere.

use crate::lexer::{lex, Tok, TokKind};
use std::collections::{BTreeMap, BTreeSet};
use std::fs;
use std::path::{Path, PathBuf};

/// Rule ids with one-line descriptions (`--list-rules`).
pub const RULES: &[(&str, &str)] = &[
    ("safety-comment", "unsafe block/fn/impl must be preceded by // SAFETY: (or /// # Safety)"),
    ("timing-cast", "no as_nanos()/as_micros() outside obs/ (use obs::elapsed_ns)"),
    ("thread-spawn", "thread creation only in util/threadpool.rs and coordinator seams"),
    ("dispatch-confinement", "FwhtDispatch named only by plan.rs, engine.rs, cache.rs, mod.rs"),
    ("metric-manifest", "metric-name literals must match METRICS.md exactly, both ways"),
    ("no-panic-serving", "no unwrap/expect/panic! on McError-typed serving/training paths"),
];

/// Synthetic rule id for waiver-hygiene findings (missing reason,
/// unused waiver, unknown rule id). Not waivable.
pub const WAIVER_RULE: &str = "waiver";

/// Files (relative to the source root, `/`-separated) allowed to
/// create threads: the pool itself plus the two coordinator seams
/// that own long-lived named service threads.
const THREAD_SPAWN_ALLOWED: &[&str] =
    &["util/threadpool.rs", "coordinator/pipeline.rs", "coordinator/server.rs"];

/// Files allowed to name `FwhtDispatch`: the plan (single decision
/// point), the engine and the cache key (pure consumers of a decided
/// plan), and the module re-export.
const DISPATCH_ALLOWED: &[&str] =
    &["mckernel/plan.rs", "mckernel/engine.rs", "mckernel/cache.rs", "mckernel/mod.rs"];

/// The `McError`-typed serving/training public paths: panics here
/// would break the PR 7 typed-error contract (every failure surfaces
/// as a `fault::McError`, never an abort of the serving thread).
const NO_PANIC_PATHS: &[&str] = &[
    "coordinator/server.rs",
    "coordinator/pipeline.rs",
    "train/trainer.rs",
    "train/featurizer.rs",
];

/// Registry methods whose first string-literal argument is a metric
/// name (covers direct literals and `format!("…")` templates).
const METRIC_SINKS: &[&str] = &["counter", "gauge", "histogram", "counter_value"];

/// One reported violation.
#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: String,
    /// Path relative to the scanned root (or the manifest path for
    /// manifest-side `metric-manifest` findings).
    pub file: String,
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

/// Outcome of a tree scan.
#[derive(Debug, Default)]
pub struct Report {
    pub findings: Vec<Finding>,
    /// Violations suppressed by an explained waiver.
    pub waived: usize,
    /// `.rs` files scanned.
    pub files: usize,
}

/// A `// analyze: allow(rule) -- reason` comment.
struct Waiver {
    line: usize,
    rule: String,
    reason: bool,
    used: bool,
}

/// Per-file scan state handed to each rule.
struct FileCtx<'a> {
    rel: String,
    toks: Vec<Tok>,
    lines: Vec<&'a str>,
    /// Token-index ranges covering `#[cfg(test)]` / `#[test]` items.
    test_ranges: Vec<(usize, usize)>,
    waivers: Vec<Waiver>,
}

impl FileCtx<'_> {
    fn in_test(&self, tok_idx: usize) -> bool {
        self.test_ranges.iter().any(|&(a, b)| tok_idx >= a && tok_idx <= b)
    }

    /// Trimmed source line (1-based), empty for out-of-range.
    fn line(&self, n: usize) -> &str {
        if n == 0 || n > self.lines.len() {
            ""
        } else {
            self.lines[n - 1].trim_start()
        }
    }

    /// Lines whose comments may cover a violation at `line`: the line
    /// itself plus the contiguous comment/attribute run directly
    /// above it.
    fn cover_lines(&self, line: usize) -> Vec<usize> {
        let mut cover = vec![line];
        let mut ln = line.saturating_sub(1);
        while ln >= 1 {
            let t = self.line(ln);
            if t.starts_with("//") || t.starts_with("#[") || t.starts_with("#![") {
                cover.push(ln);
                ln -= 1;
            } else {
                break;
            }
        }
        cover
    }
}

/// Scan every `.rs` file under `src_root` and cross-check metric
/// names against `metrics_path`. `rule_filter`, when non-empty,
/// restricts which rules run (waiver hygiene always runs).
pub fn analyze_tree(src_root: &Path, metrics_path: &Path, rule_filter: &[String]) -> Report {
    let mut report = Report::default();
    let mut files = Vec::new();
    collect_rs_files(src_root, &mut files);
    files.sort();

    let enabled = |rule: &str| rule_filter.is_empty() || rule_filter.iter().any(|r| r == rule);

    // metric name -> first (file, line) that records it
    let mut metric_uses: BTreeMap<String, (String, usize)> = BTreeMap::new();

    for path in &files {
        let Ok(src) = fs::read_to_string(path) else { continue };
        report.files += 1;
        let rel = rel_path(src_root, path);
        let toks = lex(&src);
        let test_ranges = test_ranges(&toks);
        let waivers = collect_waivers(&toks);
        let mut ctx = FileCtx {
            rel,
            toks,
            lines: src.lines().collect(),
            test_ranges,
            waivers,
        };

        let mut raw: Vec<Finding> = Vec::new();
        if enabled("safety-comment") {
            rule_safety_comment(&ctx, &mut raw);
        }
        if enabled("timing-cast") {
            rule_timing_cast(&ctx, &mut raw);
        }
        if enabled("thread-spawn") {
            rule_thread_spawn(&ctx, &mut raw);
        }
        if enabled("dispatch-confinement") {
            rule_dispatch_confinement(&ctx, &mut raw);
        }
        if enabled("no-panic-serving") {
            rule_no_panic_serving(&ctx, &mut raw);
        }
        if enabled("metric-manifest") {
            collect_metric_uses(&ctx, &mut metric_uses, &mut raw);
        }

        apply_waivers(&mut ctx, raw, &mut report);
    }

    if enabled("metric-manifest") {
        cross_check_manifest(metrics_path, &metric_uses, &mut report);
    }

    report.findings.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    report
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = fs::read_dir(dir) else { return };
    for entry in entries.flatten() {
        let p = entry.path();
        if p.is_dir() {
            collect_rs_files(&p, out);
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
}

fn rel_path(root: &Path, path: &Path) -> String {
    path.strip_prefix(root)
        .unwrap_or(path)
        .components()
        .map(|c| c.as_os_str().to_string_lossy())
        .collect::<Vec<_>>()
        .join("/")
}

/// Token-index ranges of `#[cfg(test)]` / `#[test]` items: from the
/// attribute to the matching close brace of the item it gates.
fn test_ranges(toks: &[Tok]) -> Vec<(usize, usize)> {
    let mut ranges = Vec::new();
    let mut i = 0;
    while i < toks.len() {
        if toks[i].is_punct('#') && i + 1 < toks.len() && toks[i + 1].is_punct('[') {
            // span the attribute to its matching `]`
            let mut depth = 0usize;
            let mut j = i + 1;
            let mut is_test_attr = false;
            while j < toks.len() {
                if toks[j].is_punct('[') {
                    depth += 1;
                } else if toks[j].is_punct(']') {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                } else if toks[j].is_ident("test") {
                    // `#[test]` or `#[cfg(test)]` / `#[cfg(all(test, …))]`
                    is_test_attr = true;
                }
                j += 1;
            }
            if is_test_attr {
                // find the gated item's block: first `{` after the
                // attribute, then its matching `}`
                let mut k = j + 1;
                let mut brace = 0usize;
                let mut end = None;
                while k < toks.len() {
                    if toks[k].is_punct('{') {
                        brace += 1;
                    } else if toks[k].is_punct('}') {
                        brace -= 1;
                        if brace == 0 {
                            end = Some(k);
                            break;
                        }
                    } else if brace == 0 && toks[k].is_punct(';') {
                        // item without a block (`#[cfg(test)] use …;`)
                        end = Some(k);
                        break;
                    }
                    k += 1;
                }
                let end = end.unwrap_or(toks.len() - 1);
                ranges.push((i, end));
                i = end + 1;
                continue;
            }
            i = j + 1;
            continue;
        }
        i += 1;
    }
    ranges
}

fn collect_waivers(toks: &[Tok]) -> Vec<Waiver> {
    let mut out = Vec::new();
    for t in toks {
        let TokKind::Comment { .. } = t.kind else { continue };
        let Some(pos) = t.text.find("analyze: allow(") else { continue };
        let rest = &t.text[pos + "analyze: allow(".len()..];
        let Some(close) = rest.find(')') else { continue };
        let rule = rest[..close].trim().to_string();
        let reason = rest[close + 1..]
            .trim_start()
            .strip_prefix("--")
            .is_some_and(|r| !r.trim().is_empty());
        out.push(Waiver { line: t.line, rule, reason, used: false });
    }
    out
}

/// Match raw findings against the file's waivers: explained waivers
/// suppress (counted), unexplained ones convert the finding into a
/// waiver-hygiene finding, unused waivers are reported at the end.
fn apply_waivers(ctx: &mut FileCtx, raw: Vec<Finding>, report: &mut Report) {
    for f in raw {
        let cover = ctx.cover_lines(f.line);
        let matched =
            ctx.waivers.iter().position(|w| w.rule == f.rule && cover.contains(&w.line));
        match matched {
            Some(wi) => {
                ctx.waivers[wi].used = true;
                if ctx.waivers[wi].reason {
                    report.waived += 1;
                } else {
                    report.findings.push(Finding {
                        rule: WAIVER_RULE.into(),
                        file: f.file,
                        line: ctx.waivers[wi].line,
                        msg: format!(
                            "waiver for `{}` has no `-- reason`; every exception must be explained",
                            f.rule
                        ),
                    });
                }
            }
            None => report.findings.push(f),
        }
    }
    for w in &ctx.waivers {
        if !RULES.iter().any(|(id, _)| *id == w.rule) {
            report.findings.push(Finding {
                rule: WAIVER_RULE.into(),
                file: ctx.rel.clone(),
                line: w.line,
                msg: format!("waiver names unknown rule `{}`", w.rule),
            });
        } else if !w.used {
            report.findings.push(Finding {
                rule: WAIVER_RULE.into(),
                file: ctx.rel.clone(),
                line: w.line,
                msg: format!("waiver for `{}` suppresses nothing; delete the stale waiver", w.rule),
            });
        }
    }
}

/// rule: safety-comment — applies everywhere, tests included: unsafe
/// is unsafe regardless of cfg.
fn rule_safety_comment(ctx: &FileCtx, out: &mut Vec<Finding>) {
    for (i, t) in ctx.toks.iter().enumerate() {
        if !t.is_ident("unsafe") {
            continue;
        }
        // classify the site from the next code token
        let form = ctx.toks[i + 1..]
            .iter()
            .find(|t| !matches!(t.kind, TokKind::Comment { .. }))
            .map(|t| match (&t.kind, t.text.as_str()) {
                (TokKind::Punct('{'), _) => "block",
                (TokKind::Ident, "fn") => "fn",
                (TokKind::Ident, "impl") => "impl",
                (TokKind::Ident, "extern") => "extern block",
                _ => "site",
            })
            .unwrap_or("site");
        if has_safety_run(ctx, t.line) {
            continue;
        }
        out.push(Finding {
            rule: "safety-comment".into(),
            file: ctx.rel.clone(),
            line: t.line,
            msg: format!(
                "`unsafe` {form} without a `// SAFETY:` comment directly above \
                 (state the precondition this site relies on)"
            ),
        });
    }
}

/// Is there a `SAFETY:` / `# Safety` marker on `line` or in the
/// comment/attribute run directly above it?
fn has_safety_run(ctx: &FileCtx, line: usize) -> bool {
    let marker = |t: &str| t.contains("SAFETY:") || t.contains("# Safety");
    if marker(ctx.line(line)) {
        return true;
    }
    let mut ln = line.saturating_sub(1);
    while ln >= 1 {
        let t = ctx.line(ln);
        if t.starts_with("//") {
            if marker(t) {
                return true;
            }
        } else if !(t.starts_with("#[") || t.starts_with("#![")) {
            return false;
        }
        ln -= 1;
    }
    false
}

/// rule: timing-cast — applies everywhere, tests included: the
/// elapsed_ns contract has no test exemption (tests record through
/// the same registry).
fn rule_timing_cast(ctx: &FileCtx, out: &mut Vec<Finding>) {
    if ctx.rel.starts_with("obs/") {
        return;
    }
    for t in &ctx.toks {
        if t.kind == TokKind::Ident && (t.text == "as_nanos" || t.text == "as_micros") {
            out.push(Finding {
                rule: "timing-cast".into(),
                file: ctx.rel.clone(),
                line: t.line,
                msg: format!(
                    "raw `{}()` narrowing outside obs/ — route nanosecond casts \
                     through `obs::elapsed_ns` (PR 8 timing contract)",
                    t.text
                ),
            });
        }
    }
}

/// rule: thread-spawn — skips test modules (stress tests may spawn
/// raw competitor threads on purpose).
fn rule_thread_spawn(ctx: &FileCtx, out: &mut Vec<Finding>) {
    if THREAD_SPAWN_ALLOWED.contains(&ctx.rel.as_str()) {
        return;
    }
    for i in 0..ctx.toks.len().saturating_sub(3) {
        if ctx.toks[i].is_ident("thread")
            && ctx.toks[i + 1].is_punct(':')
            && ctx.toks[i + 2].is_punct(':')
            && (ctx.toks[i + 3].is_ident("spawn") || ctx.toks[i + 3].is_ident("Builder"))
            && !ctx.in_test(i)
        {
            out.push(Finding {
                rule: "thread-spawn".into(),
                file: ctx.rel.clone(),
                line: ctx.toks[i].line,
                msg: format!(
                    "`thread::{}` outside util/threadpool.rs and the coordinator \
                     seams — run work on the pool (or waive a deliberate seam)",
                    ctx.toks[i + 3].text
                ),
            });
        }
    }
}

/// rule: dispatch-confinement — skips test modules (plan tests pin
/// dispatch arms; they live in plan.rs anyway).
fn rule_dispatch_confinement(ctx: &FileCtx, out: &mut Vec<Finding>) {
    if DISPATCH_ALLOWED.contains(&ctx.rel.as_str()) {
        return;
    }
    for (i, t) in ctx.toks.iter().enumerate() {
        if t.is_ident("FwhtDispatch") && !ctx.in_test(i) {
            out.push(Finding {
                rule: "dispatch-confinement".into(),
                file: ctx.rel.clone(),
                line: t.line,
                msg: "`FwhtDispatch` named outside the plan/engine/cache seam — \
                      the batch-vs-per-row-vs-SIMD decision lives in plan.rs only \
                      (PR 4 single-decision-point invariant)"
                    .into(),
            });
        }
    }
}

/// rule: no-panic-serving — only on the typed-error paths, skipping
/// test modules.
fn rule_no_panic_serving(ctx: &FileCtx, out: &mut Vec<Finding>) {
    if !NO_PANIC_PATHS.contains(&ctx.rel.as_str()) {
        return;
    }
    for (i, t) in ctx.toks.iter().enumerate() {
        if ctx.in_test(i) || t.kind != TokKind::Ident {
            continue;
        }
        let prev_dot = i > 0 && ctx.toks[i - 1].is_punct('.');
        let next_bang = ctx.toks.get(i + 1).is_some_and(|n| n.is_punct('!'));
        let hit = match t.text.as_str() {
            "unwrap" | "expect" => prev_dot,
            "panic" | "todo" | "unimplemented" => next_bang,
            _ => false,
        };
        if hit {
            out.push(Finding {
                rule: "no-panic-serving".into(),
                file: ctx.rel.clone(),
                line: t.line,
                msg: format!(
                    "`{}` on a typed-error serving/training path — return a \
                     `fault::McError` instead (PR 7 contract)",
                    t.text
                ),
            });
        }
    }
}

/// Collect metric-name literals flowing into registry sinks. Skips
/// test modules (registry unit tests use throwaway names) and method
/// *definitions* (`fn counter(…)`).
fn collect_metric_uses(
    ctx: &FileCtx,
    uses: &mut BTreeMap<String, (String, usize)>,
    _out: &mut [Finding],
) {
    for (i, t) in ctx.toks.iter().enumerate() {
        if t.kind != TokKind::Ident || !METRIC_SINKS.contains(&t.text.as_str()) {
            continue;
        }
        if ctx.in_test(i) {
            continue;
        }
        // skip definitions: `fn counter(` / `pub fn gauge(`
        let prev_code = ctx.toks[..i]
            .iter()
            .rev()
            .find(|t| !matches!(t.kind, TokKind::Comment { .. }));
        if prev_code.is_some_and(|p| p.is_ident("fn")) {
            continue;
        }
        let Some(open) = ctx.toks.get(i + 1) else { continue };
        if !open.is_punct('(') {
            continue;
        }
        // first string literal inside the call parens (handles both
        // `counter("name")` and `counter(&format!("name.{k}"))`)
        let mut depth = 1usize;
        let mut j = i + 2;
        while j < ctx.toks.len() && depth > 0 {
            match &ctx.toks[j].kind {
                TokKind::Punct('(') => depth += 1,
                TokKind::Punct(')') => depth -= 1,
                TokKind::Str => {
                    let name = normalize_metric(&ctx.toks[j].text);
                    if name.contains('.') || name.contains("<>") {
                        uses.entry(name).or_insert((ctx.rel.clone(), ctx.toks[j].line));
                    }
                    break;
                }
                _ => {}
            }
            j += 1;
        }
    }
}

/// Normalize a metric name for comparison: every `{…}` (format
/// capture) or `<…>` (manifest placeholder) segment becomes `<>`.
pub fn normalize_metric(name: &str) -> String {
    let chars: Vec<char> = name.chars().collect();
    let mut out = String::new();
    let mut i = 0;
    while i < chars.len() {
        match chars[i] {
            '{' => {
                while i < chars.len() && chars[i] != '}' {
                    i += 1;
                }
                i += 1; // past the closer (or end)
                out.push_str("<>");
            }
            '<' => {
                while i < chars.len() && chars[i] != '>' {
                    i += 1;
                }
                i += 1;
                out.push_str("<>");
            }
            c => {
                out.push(c);
                i += 1;
            }
        }
    }
    out
}

/// Both directions of the manifest check: every recorded name is
/// manifested, every manifested name is recorded.
fn cross_check_manifest(
    metrics_path: &Path,
    uses: &BTreeMap<String, (String, usize)>,
    report: &mut Report,
) {
    let manifest_file = metrics_path.to_string_lossy().into_owned();
    let Ok(text) = fs::read_to_string(metrics_path) else {
        report.findings.push(Finding {
            rule: "metric-manifest".into(),
            file: manifest_file,
            line: 0,
            msg: "METRICS.md manifest not found — every metric name must be checked in".into(),
        });
        return;
    };
    // manifest entries: backtick-quoted metric names (must contain a
    // `.` — prose code spans without dots are ignored)
    let mut manifest: BTreeMap<String, usize> = BTreeMap::new();
    for (ln, line) in text.lines().enumerate() {
        let mut rest = line;
        while let Some(a) = rest.find('`') {
            let after = &rest[a + 1..];
            let Some(b) = after.find('`') else { break };
            let span = &after[..b];
            if !span.is_empty()
                && span.contains('.')
                && span
                    .chars()
                    .all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || "._<>".contains(c))
            {
                manifest.entry(normalize_metric(span)).or_insert(ln + 1);
            }
            rest = &after[b + 1..];
        }
    }
    let manifested: BTreeSet<&String> = manifest.keys().collect();
    let used: BTreeSet<&String> = uses.keys().collect();
    for name in used.difference(&manifested) {
        let (file, line) = &uses[*name];
        report.findings.push(Finding {
            rule: "metric-manifest".into(),
            file: file.clone(),
            line: *line,
            msg: format!("metric `{name}` is recorded but missing from METRICS.md"),
        });
    }
    for name in manifested.difference(&used) {
        report.findings.push(Finding {
            rule: "metric-manifest".into(),
            file: manifest_file.clone(),
            line: manifest[*name],
            msg: format!("metric `{name}` is manifested but never recorded in rust/src"),
        });
    }
}
