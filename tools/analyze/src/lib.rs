//! `mckernel-analyze` — project-native invariant linter for the
//! McKernel tree.
//!
//! Clippy checks Rust; this crate checks *McKernel*: the
//! architectural invariants PRs 4–8 established by convention
//! (single FWHT dispatch point, typed-error serving, `elapsed_ns`
//! timing, pool-only threading, manifested metrics, SAFETY-commented
//! unsafe). It is a zero-dependency workspace member so the tier-1
//! gate can run it on a bare offline toolchain.
//!
//! Layout:
//! * [`lexer`] — a small hand-rolled Rust lexer (idents, puncts,
//!   strings incl. raw/byte, char-vs-lifetime, comments with text).
//!   No `syn`: the rules only need token shapes and line geometry.
//! * [`rules`] — the six rules, the waiver engine and the
//!   `METRICS.md` cross-check. See [`rules::RULES`].
//!
//! The binary (`cargo run -p mckernel-analyze -- --deny-all`) wires
//! these to the repo layout; integration tests drive
//! [`rules::analyze_tree`] against committed fixtures.

pub mod lexer;
pub mod rules;
