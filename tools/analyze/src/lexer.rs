//! A small hand-rolled Rust lexer — just enough fidelity for the
//! invariant rules: identifiers/keywords, punctuation, string/char
//! literals (cooked, raw, byte), line and nested block comments, and
//! numbers, each tagged with its 1-based source line.
//!
//! It deliberately does **not** parse: the rules in [`crate::rules`]
//! match token shapes (`unsafe {`, `thread :: spawn`,
//! `counter ( "name" )`) and line geometry (a `// SAFETY:` run
//! directly above an `unsafe` site), which is exactly the level a
//! project-native linter needs — clippy owns everything that requires
//! types or MIR.

/// Token classes the rules care about.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`unsafe`, `fn`, `thread`, …).
    Ident,
    /// Single punctuation character (`::` arrives as two `:`).
    Punct(char),
    /// String literal; `text` holds the raw contents between quotes.
    Str,
    /// Character or byte literal (contents not preserved).
    Char,
    /// Lifetime (`'a`, `'_`).
    Lifetime,
    /// Numeric literal (contents not preserved beyond the lexeme).
    Num,
    /// Comment; `text` holds the body without delimiters, `doc` marks
    /// `///` / `//!` / `/** */` forms.
    Comment { doc: bool },
}

/// One lexed token with its 1-based source line.
#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: usize,
}

impl Tok {
    /// Is this an identifier with exactly this text?
    pub fn is_ident(&self, s: &str) -> bool {
        self.kind == TokKind::Ident && self.text == s
    }

    /// Is this the punctuation character `c`?
    pub fn is_punct(&self, c: char) -> bool {
        self.kind == TokKind::Punct(c)
    }
}

/// Lex `src` into a token stream (comments included, whitespace
/// dropped). Never fails: unterminated constructs are consumed to end
/// of input — good enough for a linter that only runs on code the
/// compiler already accepts.
pub fn lex(src: &str) -> Vec<Tok> {
    Lexer { chars: src.chars().collect(), pos: 0, line: 1, out: Vec::new() }.run()
}

struct Lexer {
    chars: Vec<char>,
    pos: usize,
    line: usize,
    out: Vec<Tok>,
}

impl Lexer {
    fn peek(&self, ahead: usize) -> Option<char> {
        self.chars.get(self.pos + ahead).copied()
    }

    fn bump(&mut self) -> Option<char> {
        let c = self.chars.get(self.pos).copied();
        if let Some(c) = c {
            self.pos += 1;
            if c == '\n' {
                self.line += 1;
            }
        }
        c
    }

    fn push(&mut self, kind: TokKind, text: String, line: usize) {
        self.out.push(Tok { kind, text, line });
    }

    fn run(mut self) -> Vec<Tok> {
        while let Some(c) = self.peek(0) {
            let line = self.line;
            match c {
                c if c.is_whitespace() => {
                    self.bump();
                }
                '/' if self.peek(1) == Some('/') => self.line_comment(line),
                '/' if self.peek(1) == Some('*') => self.block_comment(line),
                '"' => self.cooked_string(line),
                '\'' => self.char_or_lifetime(line),
                c if c.is_ascii_digit() => self.number(line),
                c if c == '_' || c.is_alphabetic() => self.ident_or_prefixed(line),
                c => {
                    self.bump();
                    self.push(TokKind::Punct(c), c.to_string(), line);
                }
            }
        }
        self.out
    }

    fn line_comment(&mut self, line: usize) {
        self.bump();
        self.bump(); // consume `//`
        let doc = matches!(self.peek(0), Some('/') | Some('!'));
        let mut text = String::new();
        while let Some(c) = self.peek(0) {
            if c == '\n' {
                break;
            }
            text.push(c);
            self.bump();
        }
        self.push(TokKind::Comment { doc }, text, line);
    }

    fn block_comment(&mut self, line: usize) {
        self.bump();
        self.bump(); // consume `/*`
        let doc = matches!(self.peek(0), Some('*') | Some('!'));
        let mut depth = 1usize;
        let mut text = String::new();
        while let Some(c) = self.peek(0) {
            if c == '/' && self.peek(1) == Some('*') {
                depth += 1;
                self.bump();
                self.bump();
                text.push_str("/*");
            } else if c == '*' && self.peek(1) == Some('/') {
                depth -= 1;
                self.bump();
                self.bump();
                if depth == 0 {
                    break;
                }
                text.push_str("*/");
            } else {
                text.push(c);
                self.bump();
            }
        }
        self.push(TokKind::Comment { doc }, text, line);
    }

    fn cooked_string(&mut self, line: usize) {
        self.bump(); // opening quote
        let mut text = String::new();
        while let Some(c) = self.bump() {
            match c {
                '"' => break,
                '\\' => {
                    // keep the escape verbatim; rules only need the
                    // shape of the literal, not its cooked value
                    text.push(c);
                    if let Some(e) = self.bump() {
                        text.push(e);
                    }
                }
                _ => text.push(c),
            }
        }
        self.push(TokKind::Str, text, line);
    }

    /// Raw string after an `r`/`br`/`cr` prefix: `r##"…"##`.
    fn raw_string(&mut self, line: usize) {
        let mut hashes = 0usize;
        while self.peek(0) == Some('#') {
            hashes += 1;
            self.bump();
        }
        self.bump(); // opening quote
        let mut text = String::new();
        'outer: while let Some(c) = self.bump() {
            if c == '"' {
                // need `hashes` following '#' to close
                for k in 0..hashes {
                    if self.peek(k) != Some('#') {
                        text.push('"');
                        continue 'outer;
                    }
                }
                for _ in 0..hashes {
                    self.bump();
                }
                break;
            }
            text.push(c);
        }
        self.push(TokKind::Str, text, line);
    }

    fn char_or_lifetime(&mut self, line: usize) {
        self.bump(); // the `'`
        // `'a` / `'_` with no closing quote → lifetime; `'x'` / `'\n'`
        // → char literal. Disambiguate by looking for the close.
        match self.peek(0) {
            Some('\\') => {
                // escaped char literal
                self.bump();
                self.bump(); // escape body (multi-char escapes: eat to quote)
                while let Some(c) = self.bump() {
                    if c == '\'' {
                        break;
                    }
                }
                self.push(TokKind::Char, String::new(), line);
            }
            Some(c) if c == '_' || c.is_alphanumeric() => {
                if self.peek(1) == Some('\'') {
                    self.bump();
                    self.bump();
                    self.push(TokKind::Char, String::new(), line);
                } else {
                    // lifetime: consume the identifier
                    let mut name = String::new();
                    while let Some(c) = self.peek(0) {
                        if c == '_' || c.is_alphanumeric() {
                            name.push(c);
                            self.bump();
                        } else {
                            break;
                        }
                    }
                    self.push(TokKind::Lifetime, name, line);
                }
            }
            Some(c) => {
                // punctuation char literal like `'{'`
                self.bump();
                if self.peek(0) == Some('\'') {
                    self.bump();
                }
                self.push(TokKind::Char, c.to_string(), line);
            }
            None => {}
        }
    }

    fn number(&mut self, line: usize) {
        let mut text = String::new();
        // integer part (also covers 0x/0b/0o bodies: hex digits and
        // `_` all fall under alphanumeric)
        while let Some(c) = self.peek(0) {
            if c.is_alphanumeric() || c == '_' {
                text.push(c);
                self.bump();
            } else {
                break;
            }
        }
        // fractional part — but never swallow `..` (range syntax)
        if self.peek(0) == Some('.') && self.peek(1).is_some_and(|c| c.is_ascii_digit()) {
            text.push('.');
            self.bump();
            while let Some(c) = self.peek(0) {
                if c.is_alphanumeric() || c == '_' {
                    text.push(c);
                    self.bump();
                } else {
                    break;
                }
            }
        }
        // exponent sign (`1e-7`): the `e`/`E` was consumed above; a
        // trailing +/- digit run still belongs to the literal
        if matches!(self.peek(0), Some('+') | Some('-'))
            && text.ends_with(['e', 'E'])
            && self.peek(1).is_some_and(|c| c.is_ascii_digit())
        {
            text.push(self.bump().unwrap());
            while let Some(c) = self.peek(0) {
                if c.is_alphanumeric() || c == '_' {
                    text.push(c);
                    self.bump();
                } else {
                    break;
                }
            }
        }
        self.push(TokKind::Num, text, line);
    }

    fn ident_or_prefixed(&mut self, line: usize) {
        let mut text = String::new();
        while let Some(c) = self.peek(0) {
            if c == '_' || c.is_alphanumeric() {
                text.push(c);
                self.bump();
            } else {
                break;
            }
        }
        // string/char prefixes: r"…", r#"…"#, b"…", br#"…"#, c"…", b'…'
        let is_raw_prefix = matches!(text.as_str(), "r" | "br" | "cr");
        let is_cooked_prefix = matches!(text.as_str(), "b" | "c");
        match self.peek(0) {
            Some('"') if is_raw_prefix => {
                self.raw_string(line);
                return;
            }
            Some('#') if is_raw_prefix && self.raw_hashes_then_quote() => {
                self.raw_string(line);
                return;
            }
            Some('"') if is_cooked_prefix => {
                self.cooked_string(line);
                return;
            }
            Some('\'') if text == "b" => {
                self.char_or_lifetime(line);
                return;
            }
            _ => {}
        }
        self.push(TokKind::Ident, text, line);
    }

    /// Looking at `#`: does a run of `#` end in `"` (raw-string open)?
    fn raw_hashes_then_quote(&self) -> bool {
        let mut k = 0;
        while self.peek(k) == Some('#') {
            k += 1;
        }
        self.peek(k) == Some('"')
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<TokKind> {
        lex(src).into_iter().map(|t| t.kind).collect()
    }

    #[test]
    fn idents_keywords_punct() {
        let toks = lex("unsafe fn f() { x }");
        assert!(toks[0].is_ident("unsafe"));
        assert!(toks[1].is_ident("fn"));
        assert!(toks[2].is_ident("f"));
        assert!(toks[3].is_punct('('));
        assert!(toks[5].is_punct('{'));
    }

    #[test]
    fn strings_hide_their_contents_from_rules() {
        let toks = lex(r#"let s = "unsafe { thread::spawn }";"#);
        let strs: Vec<_> = toks.iter().filter(|t| t.kind == TokKind::Str).collect();
        assert_eq!(strs.len(), 1);
        assert!(!toks.iter().any(|t| t.is_ident("spawn")));
    }

    #[test]
    fn raw_strings_and_hashes() {
        let toks = lex(r##"let s = r#"a "quoted" b"#;"##);
        let s = toks.iter().find(|t| t.kind == TokKind::Str).unwrap();
        assert_eq!(s.text, r#"a "quoted" b"#);
    }

    #[test]
    fn comments_carry_text_and_line() {
        let toks = lex("let a = 1;\n// SAFETY: fine\nlet b = 2;");
        let c = toks.iter().find(|t| matches!(t.kind, TokKind::Comment { .. })).unwrap();
        assert_eq!(c.line, 2);
        assert!(c.text.contains("SAFETY:"));
    }

    #[test]
    fn nested_block_comments() {
        let toks = lex("/* a /* b */ c */ x");
        assert!(matches!(toks[0].kind, TokKind::Comment { .. }));
        assert!(toks[1].is_ident("x"));
    }

    #[test]
    fn lifetimes_vs_char_literals() {
        let toks = lex("fn f<'a>(x: &'a u8) { let c = 'y'; let n = '\\n'; }");
        assert_eq!(toks.iter().filter(|t| t.kind == TokKind::Lifetime).count(), 2);
        assert_eq!(toks.iter().filter(|t| t.kind == TokKind::Char).count(), 2);
    }

    #[test]
    fn ranges_do_not_merge_into_floats() {
        let toks = lex("for i in 0..n {}");
        assert!(toks.iter().any(|t| t.kind == TokKind::Num && t.text == "0"));
        assert_eq!(toks.iter().filter(|t| t.is_punct('.')).count(), 2);
    }

    #[test]
    fn exponent_literals_stay_single_tokens() {
        let toks = lex("const C: f32 = 7.549_789e-8;");
        let n = toks.iter().find(|t| t.kind == TokKind::Num).unwrap();
        assert_eq!(n.text, "7.549_789e-8");
    }

    #[test]
    fn line_numbers_advance_inside_strings_and_comments() {
        let toks = lex("\"a\nb\"\n/* c\nd */\nx");
        let x = toks.iter().find(|t| t.is_ident("x")).unwrap();
        assert_eq!(x.line, 5);
    }

    #[test]
    fn doc_comment_flag() {
        let k = kinds("/// doc\n// plain");
        assert_eq!(k[0], TokKind::Comment { doc: true });
        assert_eq!(k[1], TokKind::Comment { doc: false });
    }
}
