//! CLI for the McKernel invariant linter.
//!
//! ```text
//! cargo run -p mckernel-analyze -- --deny-all          # CI gate: exit 1 on any finding
//! cargo run -p mckernel-analyze                        # warn mode: print, exit 0
//! cargo run -p mckernel-analyze -- --rule timing-cast  # run one rule
//! cargo run -p mckernel-analyze -- --list-rules
//! ```
//!
//! With no `--root`, the repo root is found by walking up from the
//! current directory to the first ancestor containing `rust/src`
//! (so the tool works from the workspace root, `tools/analyze`, or
//! anywhere inside the repo).

use mckernel_analyze::rules::{analyze_tree, RULES};
use std::path::PathBuf;
use std::process::ExitCode;

struct Args {
    root: Option<PathBuf>,
    metrics: Option<PathBuf>,
    rules: Vec<String>,
    deny_all: bool,
    quiet: bool,
    list_rules: bool,
}

fn parse_args() -> Result<Args, String> {
    let mut args = Args {
        root: None,
        metrics: None,
        rules: Vec::new(),
        deny_all: false,
        quiet: false,
        list_rules: false,
    };
    let mut it = std::env::args().skip(1);
    while let Some(a) = it.next() {
        match a.as_str() {
            "--deny-all" => args.deny_all = true,
            "--quiet" | "-q" => args.quiet = true,
            "--list-rules" => args.list_rules = true,
            "--root" => {
                args.root = Some(PathBuf::from(
                    it.next().ok_or("--root needs a path".to_string())?,
                ))
            }
            "--metrics" => {
                args.metrics = Some(PathBuf::from(
                    it.next().ok_or("--metrics needs a path".to_string())?,
                ))
            }
            "--rule" => {
                let r = it.next().ok_or("--rule needs a rule id".to_string())?;
                if !RULES.iter().any(|(id, _)| *id == r) {
                    return Err(format!("unknown rule `{r}` (see --list-rules)"));
                }
                args.rules.push(r);
            }
            "--help" | "-h" => {
                print!(
                    "mckernel-analyze: project-native invariant linter\n\n\
                     USAGE: mckernel-analyze [--deny-all] [--quiet] [--list-rules]\n\
                            [--root <repo-root>] [--metrics <METRICS.md>] [--rule <id>]...\n\n\
                     Exit code is 1 when --deny-all is set and findings exist, else 0.\n"
                );
                std::process::exit(0);
            }
            other => return Err(format!("unknown argument `{other}`")),
        }
    }
    Ok(args)
}

/// Walk up from cwd to the first directory containing `rust/src`.
fn find_repo_root() -> Option<PathBuf> {
    let mut dir = std::env::current_dir().ok()?;
    loop {
        if dir.join("rust/src").is_dir() {
            return Some(dir);
        }
        if !dir.pop() {
            return None;
        }
    }
}

fn main() -> ExitCode {
    let args = match parse_args() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            return ExitCode::from(2);
        }
    };

    if args.list_rules {
        for (id, desc) in RULES {
            println!("{id:<22} {desc}");
        }
        return ExitCode::SUCCESS;
    }

    let root = match args.root.or_else(find_repo_root) {
        Some(r) => r,
        None => {
            eprintln!("error: could not locate repo root (no rust/src above cwd); pass --root");
            return ExitCode::from(2);
        }
    };
    let src_root = root.join("rust/src");
    let metrics = args.metrics.unwrap_or_else(|| root.join("METRICS.md"));

    let report = analyze_tree(&src_root, &metrics, &args.rules);

    if !args.quiet {
        for f in &report.findings {
            // source findings carry src-root-relative paths; prefix
            // them so the output is repo-relative and clickable.
            // Manifest-side findings already carry the manifest path.
            if f.file.ends_with(".rs") {
                println!("rust/src/{f}");
            } else {
                println!("{f}");
            }
        }
    }
    eprintln!(
        "mckernel-analyze: {} files, {} finding(s), {} waived",
        report.files,
        report.findings.len(),
        report.waived
    );

    if args.deny_all && !report.findings.is_empty() {
        ExitCode::FAILURE
    } else {
        ExitCode::SUCCESS
    }
}
