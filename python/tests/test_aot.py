"""AOT pipeline: lowering produces loadable HLO text and a coherent
manifest; the lowered train step numerically matches the eager one."""

import json
import os
import tempfile

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import aot, model
from compile.model import FeatureParams


class TestLowering:
    def test_hlo_text_shape(self):
        lowered = jax.jit(model.train_step_lr).lower(
            aot.f32(3, 5), aot.f32(3), aot.f32(2, 5), aot.i32(2), aot.f32()
        )
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule")
        assert "parameter(0)" in text

    def test_pallas_kernel_lowers_to_plain_hlo(self):
        # interpret=True must leave no Mosaic custom-call behind.
        params = aot.feature_param_specs(1)
        lowered = jax.jit(model.features_only).lower(aot.f32(2, aot.N), params)
        text = aot.to_hlo_text(lowered)
        assert "custom-call" not in text.lower(), "Mosaic custom-call leaked into HLO"

    def test_lowered_executable_matches_eager(self):
        rng = np.random.RandomState(0)
        w = jnp.asarray(rng.randn(3, 5).astype(np.float32) * 0.1)
        b = jnp.zeros(3, dtype=jnp.float32)
        x = jnp.asarray(rng.randn(2, 5).astype(np.float32))
        y = jnp.asarray(np.array([0, 2], dtype=np.int32))
        lr = jnp.float32(0.1)
        compiled = jax.jit(model.train_step_lr).lower(w, b, x, y, lr).compile()
        got = compiled(w, b, x, y, lr)
        want = model.train_step_lr(w, b, x, y, lr)
        for g_, w_ in zip(got, want):
            np.testing.assert_allclose(np.asarray(g_), np.asarray(w_), rtol=1e-5)


class TestArtifactBuild:
    def test_build_list_complete(self):
        arts = aot.build_artifacts()
        names = [a[0] for a in arts]
        for e in aot.EXPANSIONS:
            assert f"train_mck_b{aot.TRAIN_BATCH}_e{e}" in names
            assert f"predict_mck_b{aot.EVAL_BATCH}_e{e}" in names
            assert f"features_b{aot.FEATURE_BATCH}_e{e}" in names
        assert f"train_lr_b{aot.TRAIN_BATCH}" in names
        assert f"predict_lr_b{aot.EVAL_BATCH}" in names

    def test_spec_meta_shapes(self):
        arts = aot.build_artifacts()
        _, _, specs, meta = arts[0]
        described = aot.spec_meta(specs)
        # first input is W: (classes, feature_dim)
        assert described[0]["shape"] == [aot.CLASSES, meta["feature_dim"]]
        assert described[0]["dtype"] == "float32"

    @pytest.mark.slow
    def test_end_to_end_export_one_artifact(self):
        with tempfile.TemporaryDirectory() as d:
            import sys
            argv = sys.argv
            sys.argv = ["aot", "--out-dir", d, "--only", "train_lr"]
            try:
                aot.main()
            finally:
                sys.argv = argv
            files = os.listdir(d)
            assert f"train_lr_b{aot.TRAIN_BATCH}.hlo.txt" in files
            assert "manifest.json" in files
            with open(os.path.join(d, "manifest.json")) as f:
                manifest = json.load(f)
            assert manifest["classes"] == aot.CLASSES
            entry = manifest["entries"][0]
            assert entry["kind"] == "train"
            assert entry["outputs"] == ["w", "bias", "loss"]
            with open(os.path.join(d, entry["file"])) as f:
                assert f.read().startswith("HloModule")
