"""Layer-1 correctness: Pallas FWHT vs the pure-jnp oracle and the
explicit Hadamard matrix, including hypothesis sweeps over shapes."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels.fwht import fwht
from compile.kernels.ref import fwht_ref, hadamard_matrix


def rand(batch, n, seed=0):
    rng = np.random.RandomState(seed)
    return rng.randn(batch, n).astype(np.float32)


class TestOracle:
    """fwht_ref itself is validated against the explicit matrix."""

    @pytest.mark.parametrize("n", [1, 2, 4, 8, 32, 128])
    def test_ref_matches_matrix(self, n):
        x = rand(3, n, seed=n)
        want = x @ hadamard_matrix(n).T
        got = np.asarray(fwht_ref(jnp.asarray(x)))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_ref_involution(self):
        x = rand(2, 64, seed=1)
        twice = np.asarray(fwht_ref(fwht_ref(jnp.asarray(x))))
        np.testing.assert_allclose(twice / 64.0, x, rtol=1e-4, atol=1e-4)

    def test_ref_parseval(self):
        x = rand(1, 256, seed=2)
        y = np.asarray(fwht_ref(jnp.asarray(x)))
        assert np.isclose((y ** 2).sum(), 256 * (x ** 2).sum(), rtol=1e-4)


class TestPallasKernel:
    @pytest.mark.parametrize("batch", [1, 3, 10])
    @pytest.mark.parametrize("n", [2, 16, 256, 1024])
    def test_matches_ref(self, batch, n):
        x = jnp.asarray(rand(batch, n, seed=batch * 1000 + n))
        got = np.asarray(fwht(x))
        want = np.asarray(fwht_ref(x))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_impulse(self):
        x = jnp.zeros((1, 128)).at[0, 0].set(1.0)
        np.testing.assert_allclose(np.asarray(fwht(x)), np.ones((1, 128)), atol=1e-6)

    def test_linearity(self):
        a = jnp.asarray(rand(2, 64, seed=5))
        b = jnp.asarray(rand(2, 64, seed=6))
        lhs = np.asarray(fwht(2.0 * a + 3.0 * b))
        rhs = 2.0 * np.asarray(fwht(a)) + 3.0 * np.asarray(fwht(b))
        np.testing.assert_allclose(lhs, rhs, rtol=1e-3, atol=1e-3)

    @settings(max_examples=25, deadline=None)
    @given(
        batch=st.integers(min_value=1, max_value=8),
        log_n=st.integers(min_value=0, max_value=11),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_hypothesis_shapes(self, batch, log_n, seed):
        n = 1 << log_n
        x = jnp.asarray(rand(batch, n, seed=seed))
        got = np.asarray(fwht(x))
        want = np.asarray(fwht_ref(x))
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)

    def test_rejects_non_pow2(self):
        with pytest.raises(AssertionError):
            fwht(jnp.zeros((1, 12)))
