"""Layer-1 correctness: the fused feature-map Pallas kernel vs the
pure-jnp oracle (paper Eq. 8 + Eq. 9)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels.mckernel import feature_expansion, features
from compile.kernels.ref import fastfood_ref, features_ref


def make_params(e, n, seed=0):
    rng = np.random.RandomState(seed)
    b = rng.choice([-1.0, 1.0], size=(e, n)).astype(np.float32)
    g = rng.randn(e, n).astype(np.float32)
    s = (rng.rand(e, n).astype(np.float32) + 0.1) / np.sqrt(n)
    perm = np.stack([rng.permutation(n) for _ in range(e)]).astype(np.int32)
    return map(jnp.asarray, (b, g, s, perm))


def rand_x(batch, n, seed=1):
    return jnp.asarray(np.random.RandomState(seed).randn(batch, n).astype(np.float32))


class TestFeatureExpansion:
    @pytest.mark.parametrize("n", [8, 64, 1024])
    def test_matches_ref(self, n):
        b, g, s, perm = make_params(1, n, seed=n)
        x = rand_x(4, n, seed=n + 1)
        got = np.asarray(feature_expansion(x, b[0], g[0], s[0], perm[0]))
        z = fastfood_ref(x, b[0], g[0], s[0], perm[0])
        want = np.asarray(jnp.concatenate([jnp.cos(z), jnp.sin(z)], axis=-1))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_output_shape(self):
        b, g, s, perm = make_params(1, 32)
        out = feature_expansion(rand_x(5, 32), b[0], g[0], s[0], perm[0])
        assert out.shape == (5, 64)

    def test_cos_sin_identity(self):
        b, g, s, perm = make_params(1, 64, seed=3)
        out = np.asarray(feature_expansion(rand_x(2, 64), b[0], g[0], s[0], perm[0]))
        c, sn = out[:, :64], out[:, 64:]
        np.testing.assert_allclose(c ** 2 + sn ** 2, 1.0, atol=1e-5)

    def test_deterministic(self):
        b, g, s, perm = make_params(1, 16, seed=4)
        x = rand_x(3, 16, seed=5)
        a1 = np.asarray(feature_expansion(x, b[0], g[0], s[0], perm[0]))
        a2 = np.asarray(feature_expansion(x, b[0], g[0], s[0], perm[0]))
        np.testing.assert_array_equal(a1, a2)


class TestStackedFeatures:
    @pytest.mark.parametrize("e", [1, 2, 4])
    def test_matches_ref(self, e):
        n = 64
        b, g, s, perm = make_params(e, n, seed=e)
        x = rand_x(3, n, seed=e + 10)
        got = np.asarray(features(x, b, g, s, perm))
        want = np.asarray(features_ref(x, b, g, s, perm))
        assert got.shape == (3, 2 * n * e)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_expansion_blocks_independent(self):
        # Expansion e's slice equals running that expansion alone.
        n, e = 32, 3
        b, g, s, perm = make_params(e, n, seed=9)
        x = rand_x(2, n, seed=11)
        full = np.asarray(features(x, b, g, s, perm))
        for k in range(e):
            alone = np.asarray(feature_expansion(x, b[k], g[k], s[k], perm[k]))
            np.testing.assert_allclose(
                full[:, k * 2 * n:(k + 1) * 2 * n], alone, rtol=1e-5, atol=1e-5
            )

    @settings(max_examples=15, deadline=None)
    @given(
        batch=st.integers(min_value=1, max_value=5),
        log_n=st.integers(min_value=1, max_value=8),
        e=st.integers(min_value=1, max_value=3),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_hypothesis_sweep(self, batch, log_n, e, seed):
        n = 1 << log_n
        b, g, s, perm = make_params(e, n, seed=seed % 10000)
        x = rand_x(batch, n, seed=(seed + 1) % 10000)
        got = np.asarray(features(x, b, g, s, perm))
        want = np.asarray(features_ref(x, b, g, s, perm))
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)
