"""Layer-2 correctness: loss/gradients/train step of the JAX model."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import model
from compile.model import FeatureParams


def toy(batch=6, d=5, classes=3, seed=0):
    rng = np.random.RandomState(seed)
    w = jnp.asarray(rng.randn(classes, d).astype(np.float32) * 0.1)
    b = jnp.zeros(classes, dtype=jnp.float32)
    x = jnp.asarray(rng.randn(batch, d).astype(np.float32))
    y = jnp.asarray(rng.randint(0, classes, size=batch).astype(np.int32))
    return w, b, x, y


def make_params(e, n, seed=0):
    rng = np.random.RandomState(seed)
    return FeatureParams(
        b_diag=jnp.asarray(rng.choice([-1.0, 1.0], size=(e, n)).astype(np.float32)),
        g_diag=jnp.asarray(rng.randn(e, n).astype(np.float32)),
        scale=jnp.asarray(((rng.rand(e, n) + 0.1) / np.sqrt(n)).astype(np.float32)),
        perm=jnp.asarray(np.stack([rng.permutation(n) for _ in range(e)]).astype(np.int32)),
    )


class TestLoss:
    def test_uniform_loss_is_ln_c(self):
        w, b, x, y = toy()
        zero_w = jnp.zeros_like(w)
        loss = model.loss_fn(zero_w, b, x, y)
        assert np.isclose(float(loss), np.log(3.0), atol=1e-5)

    def test_loss_decreases_along_gradient(self):
        w, b, x, y = toy(seed=1)
        g = jax.grad(model.loss_fn, argnums=0)(w, b, x, y)
        l0 = float(model.loss_fn(w, b, x, y))
        l1 = float(model.loss_fn(w - 0.1 * g, b, x, y))
        assert l1 < l0

    def test_grad_matches_numeric(self):
        w, b, x, y = toy(seed=2)
        g = jax.grad(model.loss_fn, argnums=0)(w, b, x, y)
        eps = 1e-3
        for idx in [(0, 0), (1, 3), (2, 4)]:
            wp = w.at[idx].add(eps)
            wm = w.at[idx].add(-eps)
            num = (float(model.loss_fn(wp, b, x, y)) -
                   float(model.loss_fn(wm, b, x, y))) / (2 * eps)
            assert np.isclose(num, float(g[idx]), atol=1e-3)


class TestTrainSteps:
    def test_lr_step_shapes_and_descent(self):
        w, b, x, y = toy(seed=3)
        w2, b2, loss = model.train_step_lr(w, b, x, y, jnp.float32(0.5))
        assert w2.shape == w.shape and b2.shape == b.shape
        l_after = float(model.loss_fn(w2, b2, x, y))
        assert l_after < float(loss)

    def test_mckernel_step_runs_and_descends(self):
        n, e, classes, batch = 16, 2, 3, 4
        params = make_params(e, n, seed=4)
        rng = np.random.RandomState(5)
        w = jnp.zeros((classes, 2 * n * e), dtype=jnp.float32)
        b = jnp.zeros(classes, dtype=jnp.float32)
        x = jnp.asarray(rng.randn(batch, n).astype(np.float32))
        y = jnp.asarray(rng.randint(0, classes, size=batch).astype(np.int32))
        losses = []
        for _ in range(10):
            w, b, loss = model.train_step_mckernel(w, b, x, y, jnp.float32(0.05), params)
            losses.append(float(loss))
        assert losses[-1] < losses[0]

    def test_predict_matches_argmax(self):
        w, b, x, _ = toy(seed=6)
        preds = model.predict_lr(w, b, x)
        want = jnp.argmax(x @ w.T + b, axis=-1)
        np.testing.assert_array_equal(np.asarray(preds), np.asarray(want))
        assert preds.dtype == jnp.int32

    def test_mckernel_predict_consistent_with_features(self):
        n, e, classes, batch = 8, 1, 3, 5
        params = make_params(e, n, seed=7)
        rng = np.random.RandomState(8)
        w = jnp.asarray(rng.randn(classes, 2 * n * e).astype(np.float32))
        b = jnp.asarray(rng.randn(classes).astype(np.float32))
        x = jnp.asarray(rng.randn(batch, n).astype(np.float32))
        preds = model.predict_mckernel(w, b, x, params)
        feats = model.mckernel_features(x, params)
        want = jnp.argmax(feats @ w.T + b, axis=-1)
        np.testing.assert_array_equal(np.asarray(preds), np.asarray(want))
