"""Layer 2: the JAX model - softmax classifier over McKernel features.

The paper's learning rule (Eq. 23): SGD finds W, b in
softmax(W [phi(Zhat x)] + b), minimizing the multiclass logistic loss
(Eq. 20). This module expresses the forward/backward pass and the SGD
update as pure JAX functions calling the Layer-1 Pallas kernels, so a
single `jax.jit(...).lower()` captures the whole train step for AOT
export (aot.py); the Rust coordinator then drives the compiled
artifact with no Python on the request path.

The feature-map coefficients (B, G, C-merged scale, Pi) enter as
*runtime inputs*: they are hash-derived on the Rust side (the paper's
no-stored-coefficients trick), so one artifact serves every seed.
"""

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .kernels import mckernel as kern


class FeatureParams(NamedTuple):
    """Per-expansion Fastfood coefficients, each (E, n); perm int32."""

    b_diag: jnp.ndarray
    g_diag: jnp.ndarray
    scale: jnp.ndarray
    perm: jnp.ndarray


def mckernel_features(x: jnp.ndarray, params: FeatureParams, interpret: bool = True):
    """phi(x): (batch, n) -> (batch, 2nE) via the fused Pallas kernel."""
    return kern.features(
        x, params.b_diag, params.g_diag, params.scale, params.perm, interpret=interpret
    )


def logits(w: jnp.ndarray, bias: jnp.ndarray, feats: jnp.ndarray) -> jnp.ndarray:
    """W feats + b: (classes, d) x (batch, d) -> (batch, classes)."""
    return feats @ w.T + bias


def softmax_xent(lg: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Mean multiclass logistic loss (paper Eq. 20 generalized)."""
    lse = jax.scipy.special.logsumexp(lg, axis=-1)
    true = jnp.take_along_axis(lg, y[:, None].astype(jnp.int32), axis=-1)[:, 0]
    return jnp.mean(lse - true)


def loss_fn(w, bias, feats, y):
    """Loss as a function of the learned parameters only."""
    return softmax_xent(logits(w, bias, feats), y)


def train_step_mckernel(w, bias, x, y, lr, params: FeatureParams, interpret: bool = True):
    """One SGD step (paper Eq. 21) on McKernel features.

    Returns (w', bias', loss). Featurization runs inside the graph
    (Pallas kernel), so the exported artifact is the full hot path.
    """
    feats = mckernel_features(x, params, interpret=interpret)
    loss, grads = jax.value_and_grad(loss_fn, argnums=(0, 1))(w, bias, feats, y)
    return (w - lr * grads[0], bias - lr * grads[1], loss)


def train_step_lr(w, bias, x, y, lr):
    """One SGD step of the raw-pixel logistic-regression baseline."""
    loss, grads = jax.value_and_grad(loss_fn, argnums=(0, 1))(w, bias, x, y)
    return (w - lr * grads[0], bias - lr * grads[1], loss)


def predict_mckernel(w, bias, x, params: FeatureParams, interpret: bool = True):
    """Hard predictions on McKernel features -> (batch,) int32."""
    feats = mckernel_features(x, params, interpret=interpret)
    return jnp.argmax(logits(w, bias, feats), axis=-1).astype(jnp.int32)


def predict_lr(w, bias, x):
    """Hard predictions of the LR baseline -> (batch,) int32."""
    return jnp.argmax(logits(w, bias, x), axis=-1).astype(jnp.int32)


def features_only(x, params: FeatureParams, interpret: bool = True):
    """Feature generation alone (the paper's drop-in feature server)."""
    return mckernel_features(x, params, interpret=interpret)
