"""AOT export: lower the Layer-2 JAX functions (wrapping the Layer-1
Pallas kernels) to HLO *text* artifacts + a JSON manifest the Rust
runtime consumes.

HLO text - not `.serialize()` - is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Run from python/:  python -m compile.aot --out-dir ../artifacts
Re-running is cheap and deterministic; `make artifacts` skips it when
inputs are unchanged.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .model import FeatureParams

# Static shape configuration (matches the paper's MNIST experiments:
# 28x28=784 pixels padded to [784]_2 = 1024, 10 classes).
PIXELS = 784
N = 1024
CLASSES = 10
TRAIN_BATCH = 10        # paper figures: batch size 10
EVAL_BATCH = 256
FEATURE_BATCH = 32      # feature-server granularity
EXPANSIONS = (1, 2, 4)  # artifact per E


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def feature_param_specs(e: int):
    return FeatureParams(
        b_diag=f32(e, N), g_diag=f32(e, N), scale=f32(e, N), perm=i32(e, N)
    )


def spec_meta(args):
    """Manifest description of a flat argument list."""
    flat, _ = jax.tree_util.tree_flatten(args)
    return [
        {"shape": list(s.shape), "dtype": str(s.dtype)}
        for s in flat
    ]


def build_artifacts():
    """(name, callable, example-arg pytree, metadata) for every export."""
    arts = []
    for e in EXPANSIONS:
        fd = 2 * N * e
        arts.append((
            f"train_mck_b{TRAIN_BATCH}_e{e}",
            lambda w, b, x, y, lr, bd, gd, sc, pm: model.train_step_mckernel(
                w, b, x, y, lr, FeatureParams(bd, gd, sc, pm)
            ),
            (
                f32(CLASSES, fd), f32(CLASSES), f32(TRAIN_BATCH, N),
                i32(TRAIN_BATCH), f32(), *feature_param_specs(e),
            ),
            {"kind": "train", "featurizer": "mckernel", "batch": TRAIN_BATCH,
             "n": N, "expansions": e, "classes": CLASSES, "feature_dim": fd,
             "outputs": ["w", "bias", "loss"]},
        ))
        arts.append((
            f"predict_mck_b{EVAL_BATCH}_e{e}",
            lambda w, b, x, bd, gd, sc, pm: model.predict_mckernel(
                w, b, x, FeatureParams(bd, gd, sc, pm)
            ),
            (
                f32(CLASSES, fd), f32(CLASSES), f32(EVAL_BATCH, N),
                *feature_param_specs(e),
            ),
            {"kind": "predict", "featurizer": "mckernel", "batch": EVAL_BATCH,
             "n": N, "expansions": e, "classes": CLASSES, "feature_dim": fd,
             "outputs": ["preds"]},
        ))
        arts.append((
            f"features_b{FEATURE_BATCH}_e{e}",
            lambda x, bd, gd, sc, pm: model.features_only(
                x, FeatureParams(bd, gd, sc, pm)
            ),
            (f32(FEATURE_BATCH, N), *feature_param_specs(e)),
            {"kind": "features", "featurizer": "mckernel", "batch": FEATURE_BATCH,
             "n": N, "expansions": e, "classes": 0, "feature_dim": fd,
             "outputs": ["features"]},
        ))
    arts.append((
        f"train_lr_b{TRAIN_BATCH}",
        model.train_step_lr,
        (f32(CLASSES, PIXELS), f32(CLASSES), f32(TRAIN_BATCH, PIXELS),
         i32(TRAIN_BATCH), f32()),
        {"kind": "train", "featurizer": "identity", "batch": TRAIN_BATCH,
         "n": PIXELS, "expansions": 0, "classes": CLASSES, "feature_dim": PIXELS,
         "outputs": ["w", "bias", "loss"]},
    ))
    arts.append((
        f"predict_lr_b{EVAL_BATCH}",
        model.predict_lr,
        (f32(CLASSES, PIXELS), f32(CLASSES), f32(EVAL_BATCH, PIXELS)),
        {"kind": "predict", "featurizer": "identity", "batch": EVAL_BATCH,
         "n": PIXELS, "expansions": 0, "classes": CLASSES, "feature_dim": PIXELS,
         "outputs": ["preds"]},
    ))
    return arts


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="artifact-name substring filter")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"n": N, "pixels": PIXELS, "classes": CLASSES, "entries": []}
    for name, fn, specs, meta in build_artifacts():
        if args.only and args.only not in name:
            continue
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        entry = dict(meta)
        entry["name"] = name
        entry["file"] = fname
        entry["inputs"] = spec_meta(specs)
        manifest["entries"].append(entry)
        print(f"wrote {fname}  ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"wrote manifest.json ({len(manifest['entries'])} entries)")


if __name__ == "__main__":
    main()
