"""Pure-jnp oracle for the Pallas kernels.

This module is the correctness ground truth for Layer 1: every Pallas
kernel in this package must agree with these reference implementations
(pytest enforces it, including hypothesis sweeps over shapes).

The math follows the paper:
  Eq. 10-12: Walsh-Hadamard butterflies (Sylvester ordering).
  Eq. 8:     Zhat = (1/(sigma*sqrt(n))) * C H G Pi H B   (the diagonal
             `scale` input here is C premultiplied with 1/(sigma*sqrt(n)*|g|),
             exactly as the Rust layer materializes it).
  Eq. 9:     phi(x) = [cos(Zhat x), sin(Zhat x)].
"""

import numpy as np

import jax.numpy as jnp


def fwht_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Unnormalized Walsh-Hadamard transform along the last axis.

    Works for any leading batch shape; the last dimension must be a
    power of two. Unrolled butterfly stages (log2 n of them), each a
    reshape + stack: stage h combines elements at stride h.
    """
    n = x.shape[-1]
    assert n & (n - 1) == 0, "FWHT length must be a power of two"
    lead = x.shape[:-1]
    h = 1
    while h < n:
        # group pairs of h-blocks: (..., n/(2h), 2, h)
        x = x.reshape(*lead, n // (2 * h), 2, h)
        a = x[..., 0, :]
        b = x[..., 1, :]
        x = jnp.stack([a + b, a - b], axis=-2)
        x = x.reshape(*lead, n)
        h *= 2
    return x


def hadamard_matrix(n: int) -> np.ndarray:
    """Explicit Sylvester Hadamard matrix (test-only O(n^2) oracle)."""
    assert n & (n - 1) == 0
    h = np.array([[1.0]], dtype=np.float32)
    while h.shape[0] < n:
        h = np.block([[h, h], [h, -h]])
    return h.astype(np.float32)


def fastfood_ref(
    x: jnp.ndarray,
    b_diag: jnp.ndarray,
    g_diag: jnp.ndarray,
    scale: jnp.ndarray,
    perm: jnp.ndarray,
) -> jnp.ndarray:
    """One expansion's linear stage `Zhat x` (paper Eq. 8).

    x:      (..., n) padded input
    b_diag: (n,) +-1 signs            (B)
    g_diag: (n,) gaussian diagonal    (G)
    scale:  (n,) calibration merged with 1/(sigma*sqrt(n)*|g|)  (C)
    perm:   (n,) int32 gather indices (Pi: y[i] = v[perm[i]])
    """
    v = x * b_diag
    v = fwht_ref(v)
    v = jnp.take(v, perm, axis=-1)
    v = v * g_diag
    v = fwht_ref(v)
    return v * scale


def features_ref(
    x: jnp.ndarray,
    b_diag: jnp.ndarray,
    g_diag: jnp.ndarray,
    scale: jnp.ndarray,
    perm: jnp.ndarray,
) -> jnp.ndarray:
    """Full feature map for E stacked expansions (paper Eq. 9).

    x:     (batch, n)
    diags: (E, n) each; perm (E, n) int32
    returns (batch, 2*n*E), expansion-major layout
    [cos_0 | sin_0 | cos_1 | sin_1 | ...], matching the Rust
    `McKernel::transform` layout.
    """
    outs = []
    e_count = b_diag.shape[0]
    for e in range(e_count):
        z = fastfood_ref(x, b_diag[e], g_diag[e], scale[e], perm[e])
        outs.append(jnp.concatenate([jnp.cos(z), jnp.sin(z)], axis=-1))
    return jnp.concatenate(outs, axis=-1)
