"""Layer 1: fused McKernel feature-map Pallas kernel.

One expansion of paper Eq. 8 + Eq. 9 in a single kernel:

    z   = scale * H (g * gather(H (b * x), perm))
    out = [cos(z) | sin(z)]

Fusion rationale (DESIGN.md SS Hardware-Adaptation): the diagonals and
the trig map are elementwise VPU ops and the permutation is a VMEM
gather, so the entire expansion for one row costs exactly two in-VMEM
butterfly pyramids with zero intermediate HBM traffic - the TPU
restatement of the paper's "compute Zhat on-the-fly" SIMD pipeline.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .fwht import _fwht_stages


def _feature_kernel(x_ref, b_ref, g_ref, s_ref, p_ref, o_ref, *, n: int):
    """One batch row: fused B -> H -> Pi -> G -> H -> C -> cos/sin."""
    v = x_ref[...] * b_ref[...]
    v = _fwht_stages(v, n)
    v = jnp.take(v, p_ref[...][0], axis=-1)
    v = v * g_ref[...]
    v = _fwht_stages(v, n)
    z = v * s_ref[...]
    o_ref[...] = jnp.concatenate([jnp.cos(z), jnp.sin(z)], axis=-1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def feature_expansion(
    x: jnp.ndarray,
    b_diag: jnp.ndarray,
    g_diag: jnp.ndarray,
    scale: jnp.ndarray,
    perm: jnp.ndarray,
    interpret: bool = True,
) -> jnp.ndarray:
    """One expansion's features: (batch, n) -> (batch, 2n).

    b_diag/g_diag/scale: (n,) f32;  perm: (n,) int32.
    """
    batch, n = x.shape
    assert n & (n - 1) == 0
    row = lambda i: (i, 0)
    broadcast = lambda i: (0, 0)
    return pl.pallas_call(
        functools.partial(_feature_kernel, n=n),
        out_shape=jax.ShapeDtypeStruct((batch, 2 * n), x.dtype),
        grid=(batch,),
        in_specs=[
            pl.BlockSpec((1, n), row),          # x row
            pl.BlockSpec((1, n), broadcast),    # B
            pl.BlockSpec((1, n), broadcast),    # G
            pl.BlockSpec((1, n), broadcast),    # scale (C merged)
            pl.BlockSpec((1, n), broadcast),    # perm indices
        ],
        out_specs=pl.BlockSpec((1, 2 * n), row),
        interpret=interpret,
    )(
        x,
        b_diag.reshape(1, n),
        g_diag.reshape(1, n),
        scale.reshape(1, n),
        perm.reshape(1, n),
    )


def features(
    x: jnp.ndarray,
    b_diag: jnp.ndarray,
    g_diag: jnp.ndarray,
    scale: jnp.ndarray,
    perm: jnp.ndarray,
    interpret: bool = True,
) -> jnp.ndarray:
    """E stacked expansions: (batch, n) + (E, n) params -> (batch, 2nE).

    Layout matches the Rust `McKernel::transform`:
    [cos_0 | sin_0 | cos_1 | sin_1 | ...].
    """
    e_count = b_diag.shape[0]
    outs = [
        feature_expansion(x, b_diag[e], g_diag[e], scale[e], perm[e], interpret=interpret)
        for e in range(e_count)
    ]
    return jnp.concatenate(outs, axis=-1)
