"""Layer 1: Pallas Fast Walsh-Hadamard Transform kernel.

TPU adaptation of the paper's cache-blocked SSE2 FWHT (DESIGN.md
SS Hardware-Adaptation): one grid step owns one batch row, the row
lives in VMEM for all log2(n) butterfly stages (the analogue of the
paper's "small routine Hadamard that fits in cache"), and each stage
is a reshape + elementwise add/sub pair, i.e. pure VPU work with no
HBM round-trips between stages.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO so the same artifact
runs under the Rust runtime (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fwht_stages(v: jnp.ndarray, n: int) -> jnp.ndarray:
    """All log2(n) butterfly stages over a (1, n) VMEM-resident row."""
    h = 1
    while h < n:
        x = v.reshape(n // (2 * h), 2, h)
        a = x[:, 0, :]
        b = x[:, 1, :]
        v = jnp.stack([a + b, a - b], axis=1).reshape(1, n)
        h *= 2
    return v


def _fwht_kernel(x_ref, o_ref, *, n: int):
    """Pallas body: one batch row per grid step, resident in VMEM."""
    o_ref[...] = _fwht_stages(x_ref[...], n)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fwht(x: jnp.ndarray, interpret: bool = True) -> jnp.ndarray:
    """Batched FWHT: x (batch, n) -> H x per row, n a power of two."""
    batch, n = x.shape
    assert n & (n - 1) == 0, "FWHT length must be a power of two"
    return pl.pallas_call(
        functools.partial(_fwht_kernel, n=n),
        out_shape=jax.ShapeDtypeStruct((batch, n), x.dtype),
        grid=(batch,),
        in_specs=[pl.BlockSpec((1, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, n), lambda i: (i, 0)),
        interpret=interpret,
    )(x)
