//! Quickstart: build a feature map, verify the kernel approximation,
//! train a small classifier — the 60-second tour of the public API.
//!
//!     cargo run --release --example quickstart

use mckernel::data::{Dataset, SyntheticSpec};
use mckernel::mckernel::{Kernel, McKernelFactory};
use mckernel::optim::SgdConfig;
use mckernel::train::{Featurizer, TrainConfig, Trainer};
use std::sync::Arc;

fn main() {
    // 1. A feature map: 64-dim inputs, 8 expansions, RBF σ=2.
    //    Everything is derived from the seed — nothing random is stored.
    let map = McKernelFactory::new(64)
        .expansions(8)
        .sigma(2.0)
        .rbf()
        .seed(1398239763)
        .build();
    println!(
        "feature map: {} → {} features ({} expansions of n={})",
        map.input_dim(),
        map.feature_dim(),
        map.expansions(),
        map.padded_dim()
    );

    // 2. The kernel approximation (paper Eq. 6-9): inner products of
    //    normalized features converge to the exact RBF kernel.
    let mut rng = mckernel::hash::HashRng::new(7, 7);
    let x: Vec<f32> = (0..64).map(|_| rng.next_f32() - 0.5).collect();
    let y: Vec<f32> = (0..64).map(|_| rng.next_f32() - 0.5).collect();
    let fx = map.transform_normalized(&x);
    let fy = map.transform_normalized(&y);
    let approx: f64 = fx.iter().zip(&fy).map(|(a, b)| (*a as f64) * (*b as f64)).sum();
    let exact = Kernel::Rbf.exact(&x, &y, 2.0);
    println!("k(x,y) exact {exact:.4}  ≈ ⟨φ(x),φ(y)⟩ {approx:.4}  (err {:.4})", (approx - exact).abs());

    // 3. Train a classifier on synthetic MNIST-like data.
    let spec = SyntheticSpec::mnist();
    let train = Dataset::synthetic(1, &spec, "train", 1000);
    let test = Dataset::synthetic(1, &spec, "test", 300);
    let fm = Arc::new(
        McKernelFactory::new(784).expansions(2).sigma(1.0).rbf_matern(40).seed(1).build(),
    );
    let config = TrainConfig {
        epochs: 5,
        batch_size: 10,
        sgd: SgdConfig { lr: 0.001, momentum: 0.0, clip: None },
        seed: 1,
        eval_every_epoch: true,
        verbose: true,
        workers: 1,
        cache_bytes: None,
    };
    let trainer = Trainer::new(config, Featurizer::McKernel(fm));
    let (model, report) = trainer.fit(&train, &test);
    println!(
        "\ntest accuracy {:.3} with {} learned parameters (Eq. 22: 10·(2·1024·2+1) = {})",
        report.final_test_accuracy,
        model.param_count(),
        10 * (2 * 1024 * 2 + 1)
    );
}
