//! Feature-server scenario: McKernel as the paper's "drop-in generator
//! of features … generated on-the-fly" (§1) behind a dynamic-batching
//! coordinator — concurrent clients, coalesced batches, latency and
//! throughput reporting.
//!
//!     cargo run --release --example feature_server -- \
//!         [--clients 8] [--requests 2000] [--max-batch 32] [--max-wait-us 200]

use mckernel::cli::Args;
use mckernel::coordinator::{FeatureServer, ServerConfig};
use mckernel::mckernel::McKernelFactory;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let clients: usize = args.parse_or("clients", 8usize)?;
    let requests: usize = args.parse_or("requests", 2000usize)?;
    let max_batch: usize = args.parse_or("max-batch", 32usize)?;
    let wait_us: u64 = args.parse_or("max-wait-us", 200u64)?;
    let expansions: usize = args.parse_or("expansions", 2usize)?;

    let map = Arc::new(
        McKernelFactory::new(784)
            .expansions(expansions)
            .sigma(1.0)
            .rbf_matern(40)
            .seed(mckernel::PAPER_SEED)
            .build(),
    );
    println!(
        "feature server: 784 → {} features (E={expansions}), max batch {max_batch}, window {wait_us}µs, {clients} clients × {} requests",
        map.feature_dim(),
        requests / clients
    );
    let server = FeatureServer::start(
        Arc::clone(&map),
        ServerConfig::new(max_batch, Duration::from_micros(wait_us)),
    );

    let per_client = requests / clients;
    let t0 = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            let client = server.client();
            std::thread::spawn(move || {
                let mut latencies = Vec::with_capacity(per_client);
                let mut rng = mckernel::hash::HashRng::new(c as u64, 0x5e);
                for _ in 0..per_client {
                    let x: Vec<f32> = (0..784).map(|_| rng.next_f32()).collect();
                    let t = Instant::now();
                    client.transform(x).expect("server alive");
                    latencies.push(t.elapsed().as_secs_f64());
                }
                latencies
            })
        })
        .collect();
    let mut all: Vec<f64> = Vec::new();
    for h in handles {
        all.extend(h.join().unwrap());
    }
    let wall = t0.elapsed().as_secs_f64();
    all.sort_by(f64::total_cmp);
    let pct = |p: f64| all[((all.len() - 1) as f64 * p) as usize] * 1e3;
    println!(
        "\nserved {} requests in {wall:.2}s  →  {:.0} req/s",
        all.len(),
        all.len() as f64 / wall
    );
    println!(
        "latency p50 {:.3} ms   p95 {:.3} ms   p99 {:.3} ms",
        pct(0.50),
        pct(0.95),
        pct(0.99)
    );
    println!(
        "batching: {} batches, mean occupancy {:.1} rows/batch",
        server.stats().batches(),
        server.stats().mean_batch_size()
    );
    server.shutdown();
    Ok(())
}
