//! Figures 4 & 5 driver — MNIST / FASHION-MNIST mini-batch
//! classification: logistic regression vs RBF Matérn with increasing
//! kernel expansions. Also the repo's END-TO-END system driver: with
//! `--backend pjrt` the whole hot path runs through the AOT-compiled
//! JAX+Pallas artifacts under the Rust coordinator.
//!
//! Paper settings (figures 4/5): 60000 train / 10000 test, σ=1, t=40,
//! seed 1398239763, McKernel lr 0.001, LR lr 0.01, batch 10, 20 epochs.
//! Those take hours on a laptop-class CPU; defaults here are scaled
//! down (5000/2000, 5 epochs, E ≤ 4) — pass `--paper` for full scale.
//!
//!     cargo run --release --example mnist_minibatch -- \
//!         [--dataset mnist|fashion] [--backend native|pjrt] [--paper]
//!         [--train-size N] [--test-size N] [--epochs N] [--expansions 1,2,4]

use mckernel::cli::Args;
use mckernel::coordinator::PjrtTrainer;
use mckernel::data::{Dataset, SyntheticSpec};
use mckernel::mckernel::McKernelFactory;
use mckernel::optim::SgdConfig;
use mckernel::runtime::Runtime;
use mckernel::train::{Featurizer, TrainConfig, TrainReport, Trainer};
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let paper = args.flag("paper");
    let dataset = args.get_or("dataset", "mnist");
    let backend = args.get_or("backend", "native");
    let train_n: usize = args.parse_or("train-size", if paper { 60_000 } else { 5_000 })?;
    let test_n: usize = args.parse_or("test-size", if paper { 10_000 } else { 2_000 })?;
    let epochs: usize = args.parse_or("epochs", if paper { 20 } else { 5 })?;
    let expansions: Vec<usize> =
        args.list_or("expansions", if paper { &[1, 2, 4, 8, 16] } else { &[1, 2, 4] })?;
    let seed: u64 = args.parse_or("seed", mckernel::PAPER_SEED)?;

    let spec = SyntheticSpec::by_name(&dataset).expect("dataset mnist|fashion");
    let figure = if dataset == "mnist" { "Figure 4" } else { "Figure 5" };
    println!(
        "=== {figure}: {dataset} mini-batch classification ({train_n} train / {test_n} test, {epochs} epochs, backend {backend}) ===\n"
    );
    let train = Arc::new(Dataset::synthetic(seed, &spec, "train", train_n));
    let test = Dataset::synthetic(seed, &spec, "test", test_n);

    let cfg = |lr: f32| TrainConfig {
        epochs,
        batch_size: 10,
        sgd: SgdConfig { lr, momentum: 0.0, clip: None },
        seed,
        eval_every_epoch: false,
        verbose: args.flag("verbose"),
        workers: 1,
        cache_bytes: None,
    };

    let runtime = if backend == "pjrt" { Some(Runtime::new(args.get_or("artifacts", "artifacts"))?) } else { None };

    let fit = |map: Option<Arc<mckernel::mckernel::McKernel>>, lr: f32| -> anyhow::Result<TrainReport> {
        match &runtime {
            Some(rt) => {
                let trainer = PjrtTrainer::new(rt, cfg(lr), map);
                Ok(trainer.fit(&train, &test)?.1)
            }
            None => {
                let featurizer = match map {
                    Some(m) => Featurizer::McKernelParallel(
                        m,
                        Arc::new(mckernel::util::ThreadPool::with_default_size()),
                    ),
                    None => Featurizer::Identity,
                };
                Ok(Trainer::new(cfg(lr), featurizer).fit(&train, &test).1)
            }
        }
    };

    // Baseline: logistic regression (blue curve).
    let t0 = std::time::Instant::now();
    let lr_report = fit(None, 0.01)?;
    println!(
        "LR baseline:              test acc {:.4}   params {:>9}   ({:.1}s)",
        lr_report.final_test_accuracy,
        lr_report.param_count,
        t0.elapsed().as_secs_f64()
    );

    // RBF Matérn with increasing E (red curve).
    println!("\n{:>4} {:>10} {:>12} {:>10}", "E", "test acc", "params(Eq22)", "secs");
    let mut csv = String::from("expansions,test_accuracy,params,lr_baseline\n");
    for &e in &expansions {
        if runtime.is_some() && ![1, 2, 4].contains(&e) {
            eprintln!("   (skipping E={e}: no pjrt artifact; default export covers E=1,2,4)");
            continue;
        }
        let map = Arc::new(
            McKernelFactory::new(784)
                .expansions(e)
                .sigma(args.parse_or("sigma", 1.0)?)
                .rbf_matern(args.parse_or("matern-t", 40u32)?)
                .seed(seed)
                .build(),
        );
        let t0 = std::time::Instant::now();
        let rep = fit(Some(map), 0.001)?;
        println!(
            "{e:>4} {:>10.4} {:>12} {:>10.1}",
            rep.final_test_accuracy,
            rep.param_count,
            t0.elapsed().as_secs_f64()
        );
        csv += &format!(
            "{e},{},{},{}\n",
            rep.final_test_accuracy, rep.param_count, lr_report.final_test_accuracy
        );
    }
    let out = format!("bench_results/{dataset}_minibatch_{backend}.csv");
    std::fs::create_dir_all("bench_results").ok();
    std::fs::write(&out, csv)?;
    println!("\nwrote {out} ({figure} series: LR flat line vs Matérn-by-E)");
    Ok(())
}
