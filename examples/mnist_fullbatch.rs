//! Figure 3 driver — MNIST full-batch classification: LR vs RBF
//! Matérn with increasing kernel expansions, with train/test sizes
//! rounded to powers of two (32768 / 8192 in the paper — "due to
//! algorithm constraint").
//!
//! Defaults are scaled down (4096/1024, 5 epochs, E ≤ 4); pass
//! `--paper` for the full Figure 3 configuration.
//!
//!     cargo run --release --example mnist_fullbatch -- [--paper]

use mckernel::cli::Args;
use mckernel::data::{Dataset, SyntheticSpec};
use mckernel::mckernel::McKernelFactory;
use mckernel::optim::SgdConfig;
use mckernel::train::{Featurizer, TrainConfig, Trainer};
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let paper = args.flag("paper");
    let train_n: usize = args.parse_or("train-size", if paper { 32_768 } else { 4_096 })?;
    let test_n: usize = args.parse_or("test-size", if paper { 8_192 } else { 1_024 })?;
    let epochs: usize = args.parse_or("epochs", if paper { 20 } else { 5 })?;
    let expansions: Vec<usize> =
        args.list_or("expansions", if paper { &[1, 2, 4, 8, 16] } else { &[1, 2, 4] })?;
    let seed: u64 = args.parse_or("seed", mckernel::PAPER_SEED)?;
    assert!(train_n.is_power_of_two() && test_n.is_power_of_two(), "full-batch sizes must be powers of two (paper constraint)");

    println!(
        "=== Figure 3: MNIST full-batch classification ({train_n} train / {test_n} test, {epochs} epochs) ===\n"
    );
    let spec = SyntheticSpec::mnist();
    let train = Dataset::synthetic(seed, &spec, "train", train_n);
    let test = Dataset::synthetic(seed, &spec, "test", test_n);

    // "Full-batch" in the paper's Figure 3 sense: the batch spans the
    // rounded power-of-two dataset; SGD still runs per paper (batch 10
    // inside, sizes rounded) — we follow the figure caption: batch 10.
    let cfg = |lr: f32| TrainConfig {
        epochs,
        batch_size: 10,
        sgd: SgdConfig { lr, momentum: 0.0, clip: None },
        seed,
        eval_every_epoch: false,
        verbose: false,
        workers: 1,
        cache_bytes: None,
    };

    let t0 = std::time::Instant::now();
    let (_, lr_rep) = Trainer::new(cfg(0.01), Featurizer::Identity).fit(&train, &test);
    println!(
        "LR baseline:              test acc {:.4}   params {:>9}   ({:.1}s)",
        lr_rep.final_test_accuracy,
        lr_rep.param_count,
        t0.elapsed().as_secs_f64()
    );

    println!("\n{:>4} {:>10} {:>12} {:>10}", "E", "test acc", "params(Eq22)", "secs");
    let mut csv = String::from("expansions,test_accuracy,params,lr_baseline\n");
    for &e in &expansions {
        let map = Arc::new(
            McKernelFactory::new(784)
                .expansions(e)
                .sigma(1.0)
                .rbf_matern(40)
                .seed(seed)
                .build(),
        );
        let featurizer = Featurizer::McKernelParallel(
            map,
            Arc::new(mckernel::util::ThreadPool::with_default_size()),
        );
        let t0 = std::time::Instant::now();
        let (_, rep) = Trainer::new(cfg(0.001), featurizer).fit(&train, &test);
        println!(
            "{e:>4} {:>10.4} {:>12} {:>10.1}",
            rep.final_test_accuracy,
            rep.param_count,
            t0.elapsed().as_secs_f64()
        );
        csv += &format!(
            "{e},{},{},{}\n",
            rep.final_test_accuracy, rep.param_count, lr_rep.final_test_accuracy
        );
    }
    std::fs::create_dir_all("bench_results").ok();
    std::fs::write("bench_results/mnist_fullbatch.csv", csv)?;
    println!("\nwrote bench_results/mnist_fullbatch.csv (Figure 3 series)");
    Ok(())
}
