//! Table 1 / Figure 2 driver: McKernel FWHT vs the Spiral-like
//! recursive baseline across n = 2^10 … 2^20, printed in the paper's
//! row format plus the paper's reference numbers for comparison.
//!
//!     cargo run --release --example fwht_comparison [-- --quick]

use mckernel::benchkit::{bench, BenchConfig};
use mckernel::fwht::{optimized, reference};
use mckernel::hash::HashRng;

/// Paper Table 1 (intel i5-4200 @ 1.6GHz): (n, mckernel ms, spiral ms).
const PAPER: [(usize, f64, f64); 11] = [
    (1024, 0.0, 0.0333),
    (2048, 0.0333, 0.0667),
    (4096, 0.1, 0.167),
    (8192, 0.0667, 0.2),
    (16384, 0.2, 0.467),
    (32768, 0.2, 0.9),
    (65536, 0.7, 1.667),
    (131072, 1.3, 3.5),
    (262144, 3.6, 7.667),
    (524288, 7.86, 15.9667),
    (1048576, 15.9667, 35.7),
];

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let cfg = if quick { BenchConfig::quick() } else { BenchConfig::default() };
    println!("Table 1 — Numeric Comparison of Fast Walsh Hadamard");
    println!("(paper numbers from an i5-4200 @1.6GHz; ours from this machine — compare the RATIO)\n");
    println!(
        "{:>9}  {:>12} {:>12} {:>8}   {:>12} {:>12} {:>8}",
        "|H_n|", "ours mck(ms)", "ours spi(ms)", "ratio", "paper mck", "paper spi", "ratio"
    );
    let mut geo_ours = 1.0f64;
    let mut geo_paper = 1.0f64;
    let mut count = 0;
    for (n, p_mck, p_spi) in PAPER {
        let mut r = HashRng::new(n as u64, 0xF0);
        let mut data: Vec<f32> = (0..n).map(|_| r.next_f32() - 0.5).collect();
        let mck = bench("mck", &cfg, |_| optimized::fwht(&mut data));
        let plan = reference::Plan::build(n);
        let mut data2: Vec<f32> = (0..n).map(|_| r.next_f32() - 0.5).collect();
        let spi = bench("spi", &cfg, |_| plan.execute(&mut data2));
        let ratio = spi.stats.median / mck.stats.median;
        let paper_ratio = if p_mck > 0.0 { p_spi / p_mck } else { f64::NAN };
        println!(
            "{:>9}  {:>12.4} {:>12.4} {:>7.2}x   {:>12.4} {:>12.4} {:>7}",
            n,
            mck.median_ms(),
            spi.median_ms(),
            ratio,
            p_mck,
            p_spi,
            if paper_ratio.is_nan() { "—".to_string() } else { format!("{paper_ratio:.2}x") },
        );
        geo_ours *= ratio;
        if !paper_ratio.is_nan() {
            geo_paper *= paper_ratio;
            count += 1;
        }
    }
    println!(
        "\ngeometric-mean speedup over the range: ours {:.2}x, paper {:.2}x",
        geo_ours.powf(1.0 / PAPER.len() as f64),
        geo_paper.powf(1.0 / count as f64)
    );
    println!("(Figure 2 is these two series; CSV via `cargo bench --bench bench_fwht`)");
}
